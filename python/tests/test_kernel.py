"""Pallas kernels vs pure-jnp oracles -- the CORE correctness signal.

hypothesis sweeps shapes (including non-multiples of the block sizes,
which exercise the zero-pad paths) and value regimes (extreme logits for
BCE stability). Every property asserts allclose against kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    bce_logits_loss,
    linear,
    pallas_matmul,
    ref,
    sketch_decode,
)
from compile.kernels.bce import _bce_grad, _bce_sum
from compile.kernels.hashed_linear import vmem_footprint_bytes

SETTINGS = dict(max_examples=25, deadline=None)

dims = st.integers(min_value=1, max_value=96)


def _arr(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- matmul

@settings(**SETTINGS)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_pallas_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, m, k), _arr(rng, k, n)
    got = pallas_matmul(a, b)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_pallas_matmul_large_blocks():
    """Shapes bigger than one block in every grid axis."""
    rng = np.random.default_rng(7)
    a, b = _arr(rng, 300, 260), _arr(rng, 260, 1100)
    np.testing.assert_allclose(
        pallas_matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-3
    )


def test_pallas_matmul_rejects_bad_shapes():
    a = jnp.zeros((3, 4))
    b = jnp.zeros((5, 6))
    with pytest.raises(ValueError):
        pallas_matmul(a, b)


def test_vmem_footprint_under_tpu_budget():
    # Default tiles must leave VMEM headroom for double buffering.
    assert vmem_footprint_bytes() * 2 < 16 * 1024 * 1024


# ---------------------------------------------------------------- linear

@settings(**SETTINGS)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_linear_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, m, k), _arr(rng, k, n), _arr(rng, n)
    np.testing.assert_allclose(
        linear(x, w, b), ref.linear_ref(x, w, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 32),
    k=st.integers(2, 32),
    n=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_vjp_matches_ref_vjp(m, k, n, seed):
    """grad through (pallas linear -> pallas bce) == grad through jnp twin."""
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, m, k), _arr(rng, k, n), _arr(rng, n)
    y = (rng.random((m, n)) < 0.1).astype(np.float32)

    def f_pallas(x, w, b):
        return bce_logits_loss(linear(x, w, b), y)

    def f_ref(x, w, b):
        return ref.bce_logits_loss_ref(ref.linear_ref(x, w, b), y)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for got, want in zip(gp, gr):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------- bce

@settings(**SETTINGS)
@given(
    m=dims,
    n=dims,
    scale=st.sampled_from([0.1, 1.0, 10.0, 50.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bce_loss_matches_ref(m, n, scale, seed):
    rng = np.random.default_rng(seed)
    z = _arr(rng, m, n, scale=scale)
    y = (rng.random((m, n)) < 0.2).astype(np.float32)
    np.testing.assert_allclose(
        bce_logits_loss(z, y),
        ref.bce_logits_loss_ref(z, y),
        rtol=1e-5,
        atol=1e-6,
    )


def test_bce_loss_stable_at_extreme_logits():
    """No overflow/NaN at |z| = 80 where naive sigmoid-log blows up."""
    z = jnp.array([[80.0, -80.0], [0.0, 80.0]], jnp.float32)
    y = jnp.array([[1.0, 0.0], [1.0, 1.0]], jnp.float32)
    loss = bce_logits_loss(z, y)
    assert np.isfinite(float(loss))
    np.testing.assert_allclose(
        loss, ref.bce_logits_loss_ref(z, y), rtol=1e-6, atol=1e-7
    )


@settings(**SETTINGS)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_bce_grad_matches_analytic(m, n, seed):
    rng = np.random.default_rng(seed)
    z = _arr(rng, m, n, scale=3.0)
    y = (rng.random((m, n)) < 0.2).astype(np.float32)
    got = jax.grad(bce_logits_loss)(z, y)
    np.testing.assert_allclose(got, ref.bce_grad_ref(z, y), rtol=1e-4, atol=1e-7)


def test_bce_pad_correction_exact():
    """Odd shapes hit the zero-pad path; the log(2) correction is exact."""
    rng = np.random.default_rng(3)
    z = _arr(rng, 9, 130)  # 130 pads to block multiple
    y = (rng.random((9, 130)) < 0.5).astype(np.float32)
    np.testing.assert_allclose(
        bce_logits_loss(z, y),
        ref.bce_logits_loss_ref(z, y),
        rtol=1e-5,
        atol=1e-6,
    )


# ---------------------------------------------------------------- decode

@settings(**SETTINGS)
@given(
    r=st.integers(1, 8),
    n=st.integers(1, 16),
    b=st.integers(2, 64),
    p=st.integers(1, 700),
    seed=st.integers(0, 2**31 - 1),
)
def test_sketch_decode_matches_ref(r, n, b, p, seed):
    rng = np.random.default_rng(seed)
    logits = _arr(rng, r, n, b)
    idx = rng.integers(0, b, size=(r, p)).astype(np.int32)
    np.testing.assert_allclose(
        sketch_decode(logits, idx),
        ref.sketch_decode_ref(logits, idx),
        rtol=1e-5,
        atol=1e-6,
    )


def test_sketch_decode_mean_of_constant_tables():
    """If every table holds the same value v, every class scores v."""
    r, n, b, p = 4, 3, 8, 40
    logits = np.full((r, n, b), 2.5, np.float32)
    idx = np.random.default_rng(0).integers(0, b, (r, p)).astype(np.int32)
    out = sketch_decode(logits, idx)
    np.testing.assert_allclose(out, np.full((n, p), 2.5, np.float32), rtol=1e-6)


def test_sketch_decode_rejects_bad_shapes():
    with pytest.raises(ValueError):
        sketch_decode(jnp.zeros((2, 3, 4)), jnp.zeros((3, 10), jnp.int32))


# ------------------------------------------------- internal pallas paths

def test_bce_sum_internal_blocked_path():
    rng = np.random.default_rng(11)
    z = _arr(rng, 17, 23)
    y = (rng.random((17, 23)) < 0.3).astype(np.float32)
    got = _bce_sum(z, y, block_m=8, block_n=8, interpret=True)
    want = ref.bce_logits_loss_ref(z, y) * (17 * 23)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bce_grad_internal_scaling():
    rng = np.random.default_rng(12)
    z = _arr(rng, 5, 6)
    y = (rng.random((5, 6)) < 0.3).astype(np.float32)
    got = _bce_grad(z, y, jnp.float32(1.0 / 30), block_m=8, block_n=8,
                    interpret=True)
    np.testing.assert_allclose(got, ref.bce_grad_ref(z, y), rtol=1e-5, atol=1e-7)
