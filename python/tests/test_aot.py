"""AOT pipeline: emitted HLO text is loadable and the manifest is sound."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.variants import PRESET_BY_NAME, Variant, all_variants, variants_for


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out), only={"tiny"}, verbose=False)
    with open(out / "manifest.json") as f:
        manifest = json.load(f)
    return out, manifest


def test_variant_table_covers_all_presets_and_kinds():
    keys = {v.key for v in all_variants()}
    for preset in PRESET_BY_NAME:
        for kind in ("fedavg.train", "fedavg.predict", "fedmlh.train",
                     "fedmlh.predict", "fedmlh.decode"):
            assert f"{preset}.{kind}" in keys


def test_sweep_variants_present_for_eurlex():
    keys = {v.key for v in variants_for(PRESET_BY_NAME["eurlex"])}
    assert "eurlex.fedmlh_b500.train" in keys
    assert "eurlex.fedmlh_b500.decode" in keys
    assert "eurlex.fedmlh_r8.decode" in keys


def test_manifest_records_signatures(tiny_build):
    _, manifest = tiny_build
    art = manifest["artifacts"]["tiny.fedmlh.train"]
    names = [i["name"] for i in art["inputs"]]
    assert names == ["w1", "b1", "w2", "b2", "w3", "b3", "x", "y", "lr"]
    tiny = manifest["presets"]["tiny"]
    # x: [batch, d]; y: [batch, B]; last-layer weight: [hidden, B]
    assert art["inputs"][6]["shape"] == [tiny["batch"], tiny["d"]]
    assert art["inputs"][7]["shape"] == [tiny["batch"], tiny["b"]]
    assert art["inputs"][4]["shape"] == [tiny["hidden"], tiny["b"]]
    outs = [o["name"] for o in art["outputs"]]
    assert outs == ["w1", "b1", "w2", "b2", "w3", "b3", "loss"]


def test_hlo_text_is_parseable_entry(tiny_build):
    out, manifest = tiny_build
    for key, art in manifest["artifacts"].items():
        text = (out / art["file"]).read_text()
        assert "ENTRY" in text and "HloModule" in text, key


def _exec_hlo(text, args):
    """Round-trip: HLO text -> XlaComputation -> local CPU execute.

    This mirrors what the rust runtime does via the PJRT C API
    (HloModuleProto::from_text_file -> compile -> execute): if the text
    parses and executes here, the interchange format is sound.
    """
    import jax.extend

    backend = jax.extend.backend.get_backend("cpu")
    mod = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    exe = backend.compile_and_load(mlir, backend.local_devices())
    bufs = [backend.buffer_from_pyval(a) for a in args]
    out = [np.asarray(o) for o in exe.execute(bufs)]
    return out


def test_train_artifact_executes_and_matches_model(tiny_build):
    out, manifest = tiny_build
    art = manifest["artifacts"]["tiny.fedmlh.train"]
    text = (out / art["file"]).read_text()
    rng = np.random.default_rng(0)
    args = []
    for spec in art["inputs"]:
        shape = tuple(spec["shape"])
        if spec["name"] == "y":
            args.append((rng.random(shape) < 0.1).astype(np.float32))
        elif spec["name"] == "lr":
            args.append(np.float32(0.05))
        else:
            args.append((rng.standard_normal(shape) * 0.1).astype(np.float32))
    got = _exec_hlo(text, args)
    want = model.train_step(*args)
    assert len(got) == len(want) == 7
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-4, atol=1e-5
        )


def test_decode_artifact_executes(tiny_build):
    out, manifest = tiny_build
    art = manifest["artifacts"]["tiny.fedmlh.decode"]
    text = (out / art["file"]).read_text()
    tiny = manifest["presets"]["tiny"]
    rng = np.random.default_rng(1)
    logits = rng.standard_normal(
        (tiny["r"], tiny["batch"], tiny["b"])
    ).astype(np.float32)
    idx = rng.integers(0, tiny["b"], (tiny["r"], tiny["p"])).astype(np.int32)
    (got,) = _exec_hlo(text, [logits, idx])
    from compile.kernels import ref

    np.testing.assert_allclose(
        got, ref.sketch_decode_ref(logits, idx), rtol=1e-5, atol=1e-6
    )


def test_sha256_matches_file_contents(tiny_build):
    import hashlib

    out, manifest = tiny_build
    for art in manifest["artifacts"].values():
        text = (out / art["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == art["sha256"]
