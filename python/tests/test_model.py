"""L2 model: pallas-routed graph == jnp twin; SGD actually learns."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def _data(rng, n, d, out, positives=3):
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = np.zeros((n, out), np.float32)
    for i in range(n):
        y[i, rng.integers(0, out, positives)] = 1.0
    return x, y


def test_param_shapes_order_matches_names():
    shapes = model.param_shapes(10, 4, 7)
    assert len(shapes) == len(model.PARAM_NAMES) == 6
    assert shapes[0] == (10, 4) and shapes[4] == (4, 7) and shapes[5] == (7,)


@settings(max_examples=8, deadline=None)
@given(
    d=st.integers(4, 40),
    h=st.integers(4, 32),
    out=st.integers(4, 80),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_train_step_matches_ref(d, h, out, n, seed):
    rng = np.random.default_rng(seed)
    params = model.init_params(jax.random.PRNGKey(seed), d, h, out)
    x, y = _data(rng, n, d, out)
    lr = jnp.float32(0.1)
    got = model.train_step(*params, x, y, lr)
    want = model.train_step_ref(*params, x, y, lr)
    for g, w in zip(got, want):
        # Differences are pure float reassociation (blocked vs flat sums);
        # tolerances sized for f32 accumulation over <=96-wide tiles.
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=5e-4)


def test_predict_shape_and_forward_consistency():
    params = model.init_params(jax.random.PRNGKey(0), 12, 8, 20)
    x = np.random.default_rng(0).standard_normal((5, 12)).astype(np.float32)
    logits = model.predict(*params, x)
    assert logits.shape == (5, 20)
    np.testing.assert_allclose(
        logits, model.forward(params, x), rtol=1e-6, atol=1e-6
    )


def test_sgd_reduces_loss_on_learnable_task():
    """A few steps on a fixed batch must reduce the pallas-routed loss."""
    rng = np.random.default_rng(42)
    d, h, out, n = 16, 12, 24, 32
    params = model.init_params(jax.random.PRNGKey(1), d, h, out)
    x, y = _data(rng, n, d, out)
    lr = jnp.float32(0.5)
    first = float(model.loss_fn(params, x, y))
    for _ in range(20):
        res = model.train_step(*params, x, y, lr)
        params = res[:6]
    last = float(res[6])
    assert last < first * 0.9, (first, last)


def test_train_step_loss_is_pre_update_loss():
    """Returned loss is evaluated at the *input* params (paper's Alg 2)."""
    params = model.init_params(jax.random.PRNGKey(2), 8, 6, 10)
    rng = np.random.default_rng(3)
    x, y = _data(rng, 4, 8, 10)
    res = model.train_step(*params, x, y, jnp.float32(0.1))
    np.testing.assert_allclose(
        res[6], model.loss_fn(params, x, y), rtol=1e-6, atol=1e-7
    )


def test_decode_is_kernel_decode():
    rng = np.random.default_rng(5)
    logits = rng.standard_normal((3, 4, 8)).astype(np.float32)
    idx = rng.integers(0, 8, (3, 50)).astype(np.int32)
    from compile.kernels import ref

    np.testing.assert_allclose(
        model.decode(logits, idx),
        ref.sketch_decode_ref(logits, idx),
        rtol=1e-5,
        atol=1e-6,
    )


# -- scan-fused training (the *.train8 artifacts) ----------------------

def test_train_scan_equals_sequential_steps():
    """S scan-fused steps == S sequential train_step calls, bitwise-ish."""
    rng = np.random.default_rng(3)
    d, h, out, n, S = 6, 5, 9, 4, 3
    params = model.init_params(jax.random.PRNGKey(0), d, h, out)
    xs = rng.standard_normal((S, n, d)).astype(np.float32)
    ys = (rng.random((S, n, out)) < 0.3).astype(np.float32)
    lr = jnp.float32(0.2)

    seq = params
    losses = []
    for s in range(S):
        out_step = model.train_step(*seq, xs[s], ys[s], lr)
        seq, losses = out_step[:6], losses + [out_step[6]]

    scanned = model.train_scan(*params, jnp.asarray(xs), jnp.asarray(ys), lr)
    for a, b in zip(scanned[:6], seq):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(
        float(scanned[6]), float(np.sum(losses)), atol=1e-5, rtol=1e-5
    )


def test_train_scan_ref_equals_pallas_scan():
    """The *_fast family twin is numerically the same graph."""
    rng = np.random.default_rng(4)
    d, h, out, n, S = 5, 4, 11, 3, 2
    params = model.init_params(jax.random.PRNGKey(1), d, h, out)
    xs = jnp.asarray(rng.standard_normal((S, n, d)).astype(np.float32))
    ys = jnp.asarray((rng.random((S, n, out)) < 0.4).astype(np.float32))
    a = model.train_scan(*params, xs, ys, jnp.float32(0.1))
    b = model.train_scan_ref(*params, xs, ys, jnp.float32(0.1))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5, rtol=2e-5)


def test_predict_ref_and_decode_ref_match_pallas():
    rng = np.random.default_rng(5)
    d, h, out, n = 7, 6, 13, 5
    params = model.init_params(jax.random.PRNGKey(2), d, h, out)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(model.predict(*params, x)),
        np.asarray(model.predict_ref(*params, x)),
        atol=2e-5, rtol=2e-5,
    )
    r, b, p = 3, 8, 21
    logits = jnp.asarray(rng.standard_normal((r, n, b)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, b, (r, p)).astype(np.int32))
    np.testing.assert_allclose(
        np.asarray(model.decode(logits, idx)),
        np.asarray(model.decode_ref(logits, idx)),
        atol=1e-6,
    )
