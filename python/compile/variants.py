"""Artifact variant table: which HLO executables `aot.py` emits.

One `Preset` per paper dataset (scaled to this testbed -- see DESIGN.md
section 3 for the substitution rationale) plus `tiny` for tests. For each
preset we emit:

- ``<preset>.fedavg.{train,predict}``   -- full-p output layer
- ``<preset>.fedmlh.{train,predict}``   -- B-bucket output layer (shared
  by all R sub-models: identical shapes, one compile, R executions)
- ``<preset>.{fedavg,fedmlh}.train8``   -- 8 SGD steps fused via
  jax.lax.scan (one dispatch per 8 batches; the perf-pass hot path)
- ``<preset>.fedmlh.decode``            -- count-sketch mean decode

plus extra fedmlh variants for the Figure-5 hyper-parameter sweeps
(different B / R change artifact shapes).

The same tables are mirrored in rust (`config::presets`); the manifest
emitted by aot.py is the source of truth the rust side validates against.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Preset:
    name: str
    d: int          # hashed feature dimension (d-tilde in the paper)
    p: int          # number of classes
    n_train: int    # synthetic train samples (generated on the rust side)
    n_test: int
    hidden: int
    r: int          # hash tables / sub-models
    b: int          # buckets per table
    batch: int
    lr: float
    paper_analog: str
    # Figure 5 sweep values (empty = no sweep artifacts for this preset).
    sweep_b: tuple = field(default_factory=tuple)
    sweep_r: tuple = field(default_factory=tuple)


PRESETS = [
    Preset("tiny", d=32, p=64, n_train=512, n_test=128, hidden=16,
           r=2, b=16, batch=16, lr=0.1, paper_analog="(test only)"),
    Preset("eurlex", d=256, p=4000, n_train=6000, n_test=1500, hidden=128,
           r=4, b=250, batch=64, lr=32.0, paper_analog="EURLex-4K",
           sweep_b=(125, 500, 1000), sweep_r=(2, 8)),
    Preset("wiki31", d=512, p=8000, n_train=4000, n_test=1000, hidden=128,
           r=4, b=500, batch=64, lr=48.0, paper_analog="Wiki10-31K",
           sweep_b=(250, 1000, 2000), sweep_r=(2, 8)),
    Preset("amztitle", d=512, p=16384, n_train=8000, n_test=2000,
           hidden=128, r=4, b=1024, batch=64, lr=64.0,
           paper_analog="LF-AmazonTitle-131K"),
    Preset("wikititle", d=512, p=32768, n_train=8000, n_test=2000,
           hidden=128, r=8, b=2048, batch=64, lr=64.0,
           paper_analog="LF-WikiSeeAlsoTitles-320K"),
]

PRESET_BY_NAME = {p.name: p for p in PRESETS}


# Steps fused into one HLO dispatch by the train_scan variants.
SCAN_STEPS = 8


@dataclass(frozen=True)
class Variant:
    """One HLO artifact to emit."""

    key: str        # manifest key, e.g. "eurlex.fedmlh.train"
    kind: str       # "train" | "train_scan" | "predict" | "decode"
    preset: str
    d: int
    hidden: int
    out: int        # p (fedavg) or B (fedmlh sub-model)
    batch: int
    r: int = 0      # decode only
    p: int = 0      # decode only
    scan: int = 0   # train_scan only: fused steps S
    impl: str = "pallas"   # "pallas" (L1 kernels) | "jnp" (ref twins)


def variants_for(preset: Preset):
    """All artifacts for one preset (base config + figure-5 sweeps)."""
    vs = []

    def model_pair(tag: str, out: int):
        vs.append(Variant(f"{preset.name}.{tag}.train", "train",
                          preset.name, preset.d, preset.hidden, out,
                          preset.batch))
        vs.append(Variant(f"{preset.name}.{tag}.train{SCAN_STEPS}",
                          "train_scan", preset.name, preset.d,
                          preset.hidden, out, preset.batch,
                          scan=SCAN_STEPS))
        vs.append(Variant(f"{preset.name}.{tag}.predict", "predict",
                          preset.name, preset.d, preset.hidden, out,
                          preset.batch))

    model_pair("fedavg", preset.p)
    model_pair("fedmlh", preset.b)
    vs.append(Variant(f"{preset.name}.fedmlh.decode", "decode",
                      preset.name, preset.d, preset.hidden, preset.b,
                      preset.batch, r=preset.r, p=preset.p))
    # "_fast" family: identical math lowered through the pure-jnp ref
    # twins -- the CPU-testbed hot path for long sweeps (interpret-mode
    # Pallas emulation costs ~7x on the last-layer matmul; see DESIGN.md
    # section Perf). Kernel-vs-ref equality is pinned by python/tests.
    for tag, out in (("fedavg_fast", preset.p), ("fedmlh_fast", preset.b)):
        vs.append(Variant(f"{preset.name}.{tag}.train", "train",
                          preset.name, preset.d, preset.hidden, out,
                          preset.batch, impl="jnp"))
        vs.append(Variant(f"{preset.name}.{tag}.train{SCAN_STEPS}",
                          "train_scan", preset.name, preset.d,
                          preset.hidden, out, preset.batch,
                          scan=SCAN_STEPS, impl="jnp"))
        vs.append(Variant(f"{preset.name}.{tag}.predict", "predict",
                          preset.name, preset.d, preset.hidden, out,
                          preset.batch, impl="jnp"))
    vs.append(Variant(f"{preset.name}.fedmlh_fast.decode", "decode",
                      preset.name, preset.d, preset.hidden, preset.b,
                      preset.batch, r=preset.r, p=preset.p, impl="jnp"))
    # Figure-5 B sweep: new train/predict/decode shapes per B.
    for b in preset.sweep_b:
        model_pair(f"fedmlh_b{b}", b)
        vs.append(Variant(f"{preset.name}.fedmlh_b{b}.decode", "decode",
                          preset.name, preset.d, preset.hidden, b,
                          preset.batch, r=preset.r, p=preset.p))
    # Figure-5 R sweep: same sub-model shapes, different table count --
    # only the decode artifact changes (idx matrix has R rows).
    for r in preset.sweep_r:
        vs.append(Variant(f"{preset.name}.fedmlh_r{r}.decode", "decode",
                          preset.name, preset.d, preset.hidden, preset.b,
                          preset.batch, r=r, p=preset.p))
    return vs


def all_variants():
    out = []
    for p in PRESETS:
        out.extend(variants_for(p))
    return out
