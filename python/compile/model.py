"""L2: the FedMLH / FedAvg classifier as a JAX compute graph.

Both algorithms share one architecture (paper Section 6 "Baselines"):
a 2-hidden-layer MLP over feature-hashed inputs. The only difference is
the width of the last layer -- ``p`` classes for FedAvg, ``B`` buckets
for one FedMLH sub-model -- so one set of functions serves both; the
output width is baked into each AOT artifact's shapes.

Everything here is build-time only. ``aot.py`` lowers:

- ``train_step``:  (w1,b1,w2,b2,w3,b3, x, y, lr) -> (w1',...,b3', loss)
  one SGD minibatch step, forward + backward + update fused in one HLO
  so the rust coordinator's local-epoch loop is a single PJRT execute
  per batch with buffer-resident parameters.
- ``predict``:     (w1,b1,w2,b2,w3,b3, x) -> logits
- ``decode``:      (logits[R,n,B], idx[R,p]) -> scores[n,p]

The last layer and the loss route through the L1 Pallas kernels
(:mod:`kernels.hashed_linear`, :mod:`kernels.bce`); the two hidden
layers are plain jnp (they are small: d*h + h*h << h*out for extreme
output widths) and XLA fuses them.
"""

import jax
import jax.numpy as jnp

from .kernels import bce_logits_loss, linear, sketch_decode

# Parameter tuple order -- the rust side (runtime::manifest) relies on it.
PARAM_NAMES = ("w1", "b1", "w2", "b2", "w3", "b3")


def param_shapes(d: int, h: int, out: int):
    """Shapes of the parameter tuple for input dim d, hidden h, output out."""
    return ((d, h), (h,), (h, h), (h,), (h, out), (out,))


def init_params(key, d: int, h: int, out: int):
    """He-uniform init (test/reference use; the rust side owns real init)."""
    shapes = param_shapes(d, h, out)
    keys = jax.random.split(key, len(shapes))
    params = []
    for k, shape in zip(keys, shapes):
        if len(shape) == 2:
            bound = jnp.sqrt(6.0 / shape[0])
            params.append(jax.random.uniform(k, shape, jnp.float32, -bound, bound))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def forward(params, x):
    """MLP forward; the wide output layer is the Pallas ``linear`` kernel."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = jax.nn.relu(jnp.matmul(x, w1) + b1[None, :])
    h2 = jax.nn.relu(jnp.matmul(h1, w2) + b2[None, :])
    return linear(h2, w3, b3)


def loss_fn(params, x, y):
    """Mean multi-hot BCE-with-logits (Pallas fused loss kernel)."""
    return bce_logits_loss(forward(params, x), y)


def train_step(w1, b1, w2, b2, w3, b3, x, y, lr):
    """One SGD step; flat-arg signature so the HLO entry takes 9 buffers.

    ``lr`` is a scalar *input* (not baked in) so one compiled artifact
    serves every learning-rate sweep.
    """
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return new + (loss,)


def train_scan(w1, b1, w2, b2, w3, b3, xs, ys, lr):
    """S fused SGD steps in one HLO module via ``jax.lax.scan``.

    ``xs`` is ``[S, n, d]``, ``ys`` is ``[S, n, out]`` — S consecutive
    minibatches of one client epoch. Bit-for-bit the same math as S
    sequential :func:`train_step` executions, but one PJRT dispatch and
    one parameter round trip instead of S, which removes the per-step
    host↔device copy overhead that dominates small-step training (see
    EXPERIMENTS.md §Perf). Returns updated params + the *sum* of the S
    pre-update losses (the coordinator divides by S).
    """
    params = (w1, b1, w2, b2, w3, b3)

    def body(p, batch):
        x, y = batch
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        return tuple(w - lr * g for w, g in zip(p, grads)), loss

    params, losses = jax.lax.scan(body, params, (xs, ys))
    return params + (jnp.sum(losses),)


def predict(w1, b1, w2, b2, w3, b3, x):
    """Inference logits for a feature-hashed batch."""
    return forward((w1, b1, w2, b2, w3, b3), x)


def decode(logits, idx):
    """Count-sketch mean decode of R sub-model logit tables (Fig. 1b)."""
    return sketch_decode(logits, idx)


# -- reference (pure-jnp) twins used by python/tests to validate the
#    pallas-routed graph end to end ------------------------------------

def forward_ref(params, x):
    from .kernels import ref

    w1, b1, w2, b2, w3, b3 = params
    h1 = jax.nn.relu(jnp.matmul(x, w1) + b1[None, :])
    h2 = jax.nn.relu(jnp.matmul(h1, w2) + b2[None, :])
    return ref.linear_ref(h2, w3, b3)


def loss_fn_ref(params, x, y):
    from .kernels import ref

    return ref.bce_logits_loss_ref(forward_ref(params, x), y)


def train_step_ref(w1, b1, w2, b2, w3, b3, x, y, lr):
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(loss_fn_ref)(params, x, y)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return new + (loss,)


def train_scan_ref(w1, b1, w2, b2, w3, b3, xs, ys, lr):
    """Scan twin of :func:`train_scan` over the pure-jnp graph.

    Lowered into the ``*_fast`` artifact family: numerically identical
    to the Pallas-routed variants (asserted by python/tests and the rust
    runtime integration tests) but ~7x faster under the CPU PJRT plugin,
    where interpret-mode Pallas emits a blocked while-loop XLA cannot
    rewrite into one GEMM. See DESIGN.md / EXPERIMENTS.md §Perf.
    """
    params = (w1, b1, w2, b2, w3, b3)

    def body(p, batch):
        x, y = batch
        loss, grads = jax.value_and_grad(loss_fn_ref)(p, x, y)
        return tuple(w - lr * g for w, g in zip(p, grads)), loss

    params, losses = jax.lax.scan(body, params, (xs, ys))
    return params + (jnp.sum(losses),)


def predict_ref(w1, b1, w2, b2, w3, b3, x):
    return forward_ref((w1, b1, w2, b2, w3, b3), x)


def decode_ref(logits, idx):
    from .kernels import ref

    return ref.sketch_decode_ref(logits, idx)
