"""AOT pipeline: lower every artifact variant to HLO text + manifest.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
pinned xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Usage (from python/):

    python -m compile.aot --out-dir ../artifacts [--only tiny,eurlex]

Python runs only here, at build time. The emitted ``manifest.json``
(parsed by ``rust/src/runtime/manifest.rs``) records every artifact's
entry signature so the rust coordinator can validate buffers before the
first execute.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .variants import PRESETS, Variant, all_variants


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _train_signature(v: Variant):
    """(name, spec) list for a train-step artifact, in entry order."""
    d, h, out, n = v.d, v.hidden, v.out, v.batch
    return [
        ("w1", _spec((d, h))),
        ("b1", _spec((h,))),
        ("w2", _spec((h, h))),
        ("b2", _spec((h,))),
        ("w3", _spec((h, out))),
        ("b3", _spec((out,))),
        ("x", _spec((n, d))),
        ("y", _spec((n, out))),
        ("lr", _spec((), jnp.float32)),
    ]


def _train_scan_signature(v: Variant):
    """train_scan: params + stacked [S, n, ...] batches + lr."""
    d, h, out, n, s = v.d, v.hidden, v.out, v.batch, v.scan
    sig = _train_signature(v)[:6]
    sig += [
        ("xs", _spec((s, n, d))),
        ("ys", _spec((s, n, out))),
        ("lr", _spec((), jnp.float32)),
    ]
    return sig


def _predict_signature(v: Variant):
    return _train_signature(v)[:7]


def _decode_signature(v: Variant):
    return [
        ("logits", _spec((v.r, v.batch, v.out))),
        ("idx", _spec((v.r, v.p), jnp.int32)),
    ]


SIGNATURES = {
    "train": _train_signature,
    "train_scan": _train_scan_signature,
    "predict": _predict_signature,
    "decode": _decode_signature,
}

FUNCTIONS = {
    ("train", "pallas"): model.train_step,
    ("train_scan", "pallas"): model.train_scan,
    ("predict", "pallas"): model.predict,
    ("decode", "pallas"): model.decode,
    ("train", "jnp"): model.train_step_ref,
    ("train_scan", "jnp"): model.train_scan_ref,
    ("predict", "jnp"): model.predict_ref,
    ("decode", "jnp"): model.decode_ref,
}

TRAIN_OUTPUTS = [
    ("w1", "f32"), ("b1", "f32"), ("w2", "f32"), ("b2", "f32"),
    ("w3", "f32"), ("b3", "f32"), ("loss", "f32"),
]


def _output_desc(v: Variant):
    if v.kind in ("train", "train_scan"):
        d, h, out = v.d, v.hidden, v.out
        shapes = [(d, h), (h,), (h, h), (h,), (h, out), (out,), ()]
        return [
            {"name": n, "dtype": t, "shape": list(s)}
            for (n, t), s in zip(TRAIN_OUTPUTS, shapes)
        ]
    if v.kind == "predict":
        return [{"name": "logits", "dtype": "f32", "shape": [v.batch, v.out]}]
    return [{"name": "scores", "dtype": "f32", "shape": [v.batch, v.p]}]


def _dtype_tag(dt) -> str:
    return "i32" if jnp.dtype(dt) == jnp.int32 else "f32"


def lower_variant(v: Variant) -> str:
    sig = SIGNATURES[v.kind](v)
    specs = [s for _, s in sig]
    lowered = jax.jit(FUNCTIONS[(v.kind, v.impl)]).lower(*specs)
    return to_hlo_text(lowered)


def build(out_dir: str, only=None, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "presets": {}, "artifacts": {}}
    for p in PRESETS:
        manifest["presets"][p.name] = {
            "d": p.d, "p": p.p, "n_train": p.n_train, "n_test": p.n_test,
            "hidden": p.hidden, "r": p.r, "b": p.b, "batch": p.batch,
            "lr": p.lr, "paper_analog": p.paper_analog,
            "sweep_b": list(p.sweep_b), "sweep_r": list(p.sweep_r),
        }

    todo = [v for v in all_variants() if only is None or v.preset in only]
    t0 = time.time()
    for i, v in enumerate(todo):
        fname = f"{v.key}.hlo.txt"
        path = os.path.join(out_dir, fname)
        t1 = time.time()
        text = lower_variant(v)
        with open(path, "w") as f:
            f.write(text)
        sig = SIGNATURES[v.kind](v)
        manifest["artifacts"][v.key] = {
            "file": fname,
            "kind": v.kind,
            "preset": v.preset,
            "impl": v.impl,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {
                    "name": name,
                    "dtype": _dtype_tag(spec.dtype),
                    "shape": list(spec.shape),
                }
                for name, spec in sig
            ],
            "outputs": _output_desc(v),
        }
        if verbose:
            print(
                f"[{i + 1}/{len(todo)}] {v.key}: {len(text)} chars "
                f"({time.time() - t1:.1f}s)",
                flush=True,
            )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"wrote {len(todo)} artifacts in {time.time() - t0:.1f}s")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(Makefile stamp compat) ignored path")
    ap.add_argument("--only", default=None,
                    help="comma-separated preset names to build")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    build(args.out_dir, only=only)


if __name__ == "__main__":
    main()
