"""L1 Pallas kernels for FedMLH.

Three kernels implement the paper's compute hot spots:

- :mod:`hashed_linear` -- the last fully-connected layer (the layer whose
  size FedMLH's label hashing shrinks) as a tiled MXU-shaped matmul.
- :mod:`bce` -- fused numerically-stable sigmoid binary-cross-entropy
  loss + gradient over the (batch, buckets) logit tile.
- :mod:`sketch_decode` -- count-sketch mean decode that merges the R
  sub-model bucket logits back into per-class scores (paper Fig. 1b).

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); block shapes are still chosen for the TPU memory system --
see DESIGN.md "Hardware-Adaptation".

Each kernel has a pure-jnp oracle in :mod:`ref`; python/tests sweeps
shapes and dtypes with hypothesis and asserts allclose.
"""

from . import ref  # noqa: F401
from .hashed_linear import linear, pallas_matmul  # noqa: F401
from .bce import bce_logits_loss  # noqa: F401
from .sketch_decode import sketch_decode  # noqa: F401
