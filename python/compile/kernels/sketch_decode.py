"""Count-sketch mean decode as a Pallas kernel (paper Fig. 1b).

At inference FedMLH recovers a per-class score from the R sub-models:
class ``j`` was hashed to bucket ``h_r(j)`` in table ``r``, so

    scores[n, j] = (1/R) * sum_r logits[r, n, h_r(j)]

This is the count-sketch *mean* retrieval from Section 3.2 applied to
bucket log-probabilities. It is the serving-path hot spot: for Wikititle
``p = 312k`` classes are gathered from ``R = 8`` tables per sample.

TPU mapping: a CUDA implementation would give each warp a slice of
classes and do gather loads from global memory. Here each grid step
stages one sub-model's full ``[batch, B]`` logit tile in VMEM (B <= 4096
=> <= 1 MiB at batch 64) plus a block of the ``[R, p]`` hash-index
matrix, and the gather becomes a vectorized ``jnp.take`` over
VMEM-resident data. The p axis is blocked; the R axis is the
accumulation grid axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_P = 512


def _decode_kernel(logits_ref, idx_ref, o_ref, *, r_count):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # logits_ref: [1, batch, B] (table r); idx_ref: [1, bp] buckets for
    # this class block in table r. Gather columns then accumulate.
    table = logits_ref[0]  # [batch, B]
    cols = idx_ref[0]  # [bp] int32
    o_ref[...] += jnp.take(table, cols, axis=1) / jnp.float32(r_count)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def sketch_decode(logits, idx, *, block_p: int = DEFAULT_BLOCK_P, interpret: bool = True):
    """Merge R bucket-logit tables into class scores.

    Args:
      logits: ``[R, batch, B]`` f32 bucket logits, one table per sub-model.
      idx:    ``[R, p]`` int32, ``idx[r, j] = h_r(j)``.

    Returns:
      ``[batch, p]`` f32 class scores (mean over tables).
    """
    if logits.ndim != 3 or idx.ndim != 2 or logits.shape[0] != idx.shape[0]:
        raise ValueError(f"bad decode shapes {logits.shape}, {idx.shape}")
    r, batch, b = logits.shape
    p = idx.shape[1]

    bp = min(block_p, p)
    pp = _ceil_to(p, bp)
    if pp != p:
        # Pad with bucket 0: harmless, sliced away below.
        idx = jnp.pad(idx, ((0, 0), (0, pp - p)))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, r_count=r),
        grid=(pp // bp, r),
        in_specs=[
            pl.BlockSpec((1, batch, b), lambda j, rr: (rr, 0, 0)),
            pl.BlockSpec((1, bp), lambda j, rr: (rr, j)),
        ],
        out_specs=pl.BlockSpec((batch, bp), lambda j, rr: (0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, pp), logits.dtype),
        interpret=interpret,
    )(logits, idx.astype(jnp.int32))
    if pp != p:
        out = out[:, :p]
    return out


def vmem_footprint_bytes(batch: int, b: int, block_p: int = DEFAULT_BLOCK_P) -> int:
    """Static VMEM footprint of one grid step (perf-pass reporting)."""
    return 4 * (batch * b + block_p + batch * block_p)
