"""Fused sigmoid binary-cross-entropy-with-logits as a Pallas kernel.

FedMLH trains every sub-model against multi-hot *bucket* labels
(Algorithm 2, line 6), so the loss is an elementwise BCE over the
``[batch, out]`` logit tile -- ``out`` = p for FedAvg, B for a FedMLH
sub-model. Fusing loss and gradient into one pass over the tile avoids
materializing ``sigmoid(logits)`` in HBM, which for the FedAvg baseline
(``out`` up to 312k in the paper) is as large as the logits themselves.

Numerically stable form (same as torch's BCEWithLogits):

    l(z, y) = max(z, 0) - z*y + log1p(exp(-|z|))

Gradient of the *mean* loss:  (sigmoid(z) - y) / (batch * out).

The kernel grid walks (8k, 128)-aligned VPU tiles and accumulates the
partial sums into a (1, 1) output block that every grid step maps to;
grid steps are sequential, so the accumulation is race-free both on TPU
(sequential grid) and in interpret mode.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 512


def _bce_sum_kernel(z_ref, y_ref, o_ref):
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    z = z_ref[...]
    y = y_ref[...]
    elt = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    o_ref[0, 0] += jnp.sum(elt)


def _grad_kernel(z_ref, y_ref, g_ref, o_ref):
    # d(mean bce)/dz = g * (sigmoid(z) - y) / count ; count folded into g.
    o_ref[...] = g_ref[0, 0] * (jax.nn.sigmoid(z_ref[...]) - y_ref[...])


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, target: int) -> int:
    if dim >= target:
        return target
    return _ceil_to(dim, 8) if dim > 8 else dim


def _blocked(z, y, block_m, block_n):
    """Common zero-pad to the block grid.

    Padding is exact for the *sum* kernel because l(0, 0) = log(2) != 0
    would poison it -- so the pad region must be masked. We instead pad
    with z=0, y=0 and subtract the closed-form pad contribution
    (log 2 per padded element) after the kernel.
    """
    m, n = z.shape
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    if (mp, np_) != (m, n):
        z = jnp.pad(z, ((0, mp - m), (0, np_ - n)))
        y = jnp.pad(y, ((0, mp - m), (0, np_ - n)))
    pad_elems = mp * np_ - m * n
    return z, y, bm, bn, mp, np_, pad_elems


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def _bce_sum(z, y, *, block_m, block_n, interpret):
    z, y, bm, bn, mp, np_, pad = _blocked(z, y, block_m, block_n)
    total = pl.pallas_call(
        _bce_sum_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), z.dtype),
        interpret=interpret,
    )(z, y)[0, 0]
    # Each padded element contributed l(0,0) = log 2.
    return total - jnp.float32(pad) * jnp.log(jnp.float32(2.0))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def _bce_grad(z, y, gscaled, *, block_m, block_n, interpret):
    m, n = z.shape
    zp, yp, bm, bn, mp, np_, _ = _blocked(z, y, block_m, block_n)
    g2 = jnp.reshape(gscaled.astype(z.dtype), (1, 1))
    out = pl.pallas_call(
        _grad_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), z.dtype),
        interpret=interpret,
    )(zp, yp, g2)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def bce_logits_loss(logits, targets):
    """Mean numerically-stable BCE-with-logits over a [batch, out] tile."""
    count = logits.shape[0] * logits.shape[1]
    return _bce_sum(
        logits,
        targets,
        block_m=DEFAULT_BLOCK_M,
        block_n=DEFAULT_BLOCK_N,
        interpret=True,
    ) / jnp.float32(count)


def _loss_fwd(logits, targets):
    return bce_logits_loss(logits, targets), (logits, targets)


def _loss_bwd(res, g):
    logits, targets = res
    count = logits.shape[0] * logits.shape[1]
    dz = _bce_grad(
        logits,
        targets,
        g / jnp.float32(count),
        block_m=DEFAULT_BLOCK_M,
        block_n=DEFAULT_BLOCK_N,
        interpret=True,
    )
    return dz, None


bce_logits_loss.defvjp(_loss_fwd, _loss_bwd)
