"""Tiled Pallas matmul + the FedMLH "hashed linear" output layer.

The last fully-connected layer is the compute (and, in FedAvg, the
communication) hot spot of an extreme classifier: its weight is
``[hidden, out]`` where ``out`` is either the full class count ``p``
(FedAvg baseline) or the hashed bucket count ``B`` (FedMLH sub-model).
FedMLH's whole contribution is shrinking ``out``; this kernel is the
layer it shrinks.

TPU mapping (see DESIGN.md "Hardware-Adaptation"): the GPU version of
this layer would tile for shared memory and warps. Here we tile for VMEM
with ``BlockSpec`` blocks that are multiples of the 128x128 MXU systolic
array, accumulating over the contraction dimension in the innermost grid
axis. The HBM->VMEM schedule a CUDA kernel would express with
threadblocks is expressed by the ``index_map`` of each ``BlockSpec``.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO (a ``while`` loop over
the grid) and runs on any backend. Correctness vs :mod:`ref` is asserted
in python/tests.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tile. f32 VMEM cost per grid step:
#   a-block  bm*bk*4  +  b-block  bk*bn*4  +  out-block  bm*bn*4
# With the defaults below that is 128*512*4 * 3 = 786 KiB, far inside a
# TPU core's ~16 MiB VMEM, leaving room for double buffering.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_K = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile; accumulate over the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation on the MXU. `preferred_element_type` keeps the
    # accumulator in f32 even if inputs are later switched to bf16.
    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, target: int) -> int:
    """Largest block <= target; dims smaller than target use the padded dim.

    Blocks stay multiples of 8 (the f32 sublane count) when dim allows,
    so the VPU/MXU tiles stay aligned even for the small shapes the
    hypothesis sweep generates.
    """
    if dim >= target:
        return target
    return _ceil_to(dim, 8) if dim > 8 else dim


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def pallas_matmul(
    a,
    b,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """``a @ b`` via a grid of MXU-shaped tiles.

    Arbitrary ``[m, k] @ [k, n]`` shapes are supported by zero-padding up
    to the block grid and slicing the result back; zero padding is exact
    for matmul.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=interpret,
    )(a, b)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def linear(x, w, b):
    """The hashed output layer: ``x @ w + b`` with a Pallas forward/backward.

    ``custom_vjp`` so that ``jax.grad`` through the training loss routes
    the three large matmuls (fwd, dx, dw) through :func:`pallas_matmul`
    instead of XLA's generic dot.
    """
    return pallas_matmul(x, w) + b[None, :]


def _linear_fwd(x, w, b):
    return linear(x, w, b), (x, w)


def _linear_bwd(res, g):
    x, w = res
    # dx = g @ w^T ; dw = x^T @ g ; db = sum_batch g.
    dx = pallas_matmul(g, w.T)
    dw = pallas_matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)


def vmem_footprint_bytes(
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    dtype_bytes: int = 4,
) -> int:
    """Static VMEM footprint of one grid step (perf-pass reporting)."""
    return dtype_bytes * (
        block_m * block_k + block_k * block_n + block_m * block_n
    )
