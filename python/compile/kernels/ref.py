"""Pure-jnp oracles for the Pallas kernels.

These are the single source of truth for kernel correctness: every
Pallas kernel in this package must match its oracle to float32 tolerance
on every shape/dtype the hypothesis sweep generates (python/tests).
They are also used by python/tests to check the full L2 train step.
"""

import jax
import jax.numpy as jnp


def matmul_ref(a, b):
    """Oracle for :func:`hashed_linear.pallas_matmul`."""
    return jnp.matmul(a, b)


def linear_ref(x, w, b):
    """Oracle for :func:`hashed_linear.linear`."""
    return jnp.matmul(x, w) + b[None, :]


def bce_logits_loss_ref(logits, targets):
    """Oracle for :func:`bce.bce_logits_loss` (stable mean BCE-with-logits).

    Written as ``softplus(z) - z*y`` -- identical value to the
    ``max(z,0) - z*y + log1p(e^{-|z|})`` rewrite, but *smooth*, so its
    autodiff is exactly ``sigmoid(z) - y`` everywhere. The max/abs
    rewrite has a subgradient kink at z = 0 where JAX's tie-splitting
    returns a different derivative -- and z = 0 is hit for real at
    initialization (zero b3 + ReLU-dead rows), which is how the
    hypothesis sweep caught it. The Pallas kernel's custom_vjp uses the
    analytic gradient and was already correct; this keeps the oracle
    (and the ``*_fast`` artifact family lowered from it) in exact
    agreement.
    """
    z, y = logits, targets
    return jnp.mean(jax.nn.softplus(z) - z * y)


def bce_grad_ref(logits, targets):
    """Analytic gradient of the mean BCE-with-logits (for grad checks)."""
    count = logits.shape[0] * logits.shape[1]
    return (jax.nn.sigmoid(logits) - targets) / count


def sketch_decode_ref(logits, idx):
    """Oracle for :func:`sketch_decode.sketch_decode`.

    scores[n, j] = mean_r logits[r, n, idx[r, j]]
    """
    r = logits.shape[0]
    gathered = jnp.stack(
        [jnp.take(logits[t], idx[t], axis=1) for t in range(r)], axis=0
    )
    return jnp.mean(gathered, axis=0)
