//! Training-step and forward-pass benchmarks at representative FedMLH
//! shapes (feature-hashed sparse inputs, hashed out-dim ≫ hidden),
//! run side by side on the tiled kernel path (`fedmlh::kernels` via
//! `model::mlp`) and the frozen naive baseline
//! (`fedmlh::kernels::naive`) so the speedup is measured, not assumed.
//!
//! Besides the usual `Bencher` table/CSV, this bench writes
//! `BENCH_train.json` (override the path with `FEDMLH_BENCH_JSON`):
//!
//! ```json
//! {
//!   "suite": "train",
//!   "fast": false,
//!   "shapes": [
//!     {
//!       "shape": "xc_sub", "batch": 32, "d": 4096, "nnz_per_row": 32,
//!       "hidden": 256, "out": 8192,
//!       "naive_train_s": 0.0, "tiled_train_s": 0.0, "train_speedup": 0.0,
//!       "naive_forward_s": 0.0, "tiled_forward_s": 0.0, "forward_speedup": 0.0
//!     }
//!   ]
//! }
//! ```
//!
//! (times are median seconds per call; speedup = naive / tiled.)
//!
//! Each shape also gets a kernel-dispatch ladder over the *same* tiled
//! step — `tiled_scalar` (SIMD forced off, one thread), `tiled_simd`
//! (runtime dispatch; identical to scalar unless built with
//! `--features simd` on AVX2 hardware) and `tiled_simd_par` (SIMD plus
//! the intra-step row-slicing budget set to every available core) —
//! reported as `simd_speedup` / `par_speedup` vs `tiled_scalar`.
//! Shapes below the `PAR_MIN_FLOPS` floor read ~1.0× on the parallel
//! row by design. All three variants produce bit-identical results, so
//! the ladder times the dispatch, never different math.

use std::collections::BTreeMap;

use fedmlh::bench::Bencher;
use fedmlh::kernels::{naive, parallel, simd};
use fedmlh::model::mlp;
use fedmlh::model::params::ModelParams;
use fedmlh::util::json::Json;
use fedmlh::util::rng::Rng;

#[derive(Clone, Copy)]
struct Shape {
    name: &'static str,
    batch: usize,
    d: usize,
    /// Nonzero features per row (= d for a dense batch).
    nnz_per_row: usize,
    hidden: usize,
    out: usize,
}

const SHAPES: &[Shape] = &[
    // eurlex-ish sub-model: modest hash dims, sparse rows.
    Shape {
        name: "eurlex_sub",
        batch: 32,
        d: 1024,
        nnz_per_row: 32,
        hidden: 128,
        out: 1024,
    },
    // the acceptance shape: extreme hashed out-dim, sparse input.
    Shape {
        name: "xc_sub",
        batch: 32,
        d: 4096,
        nnz_per_row: 32,
        hidden: 256,
        out: 8192,
    },
    // fully dense input: exercises the dense blocked path end to end.
    Shape {
        name: "dense_small",
        batch: 16,
        d: 256,
        nnz_per_row: 256,
        hidden: 64,
        out: 512,
    },
];

fn input_batch(rng: &mut Rng, s: &Shape) -> Vec<f32> {
    let mut x = vec![0.0f32; s.batch * s.d];
    if s.nnz_per_row >= s.d {
        for v in x.iter_mut() {
            *v = rng.gaussian_f32(0.0, 1.0);
        }
    } else {
        for r in 0..s.batch {
            for _ in 0..s.nnz_per_row {
                let c = rng.below(s.d);
                x[r * s.d + c] = rng.gaussian_f32(0.0, 1.0);
            }
        }
    }
    x
}

fn label_batch(rng: &mut Rng, s: &Shape) -> Vec<f32> {
    (0..s.batch * s.out)
        .map(|_| if rng.bernoulli(0.01) { 1.0 } else { 0.0 })
        .collect()
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() {
    let mut bench = Bencher::from_env("train");
    let fast = std::env::var("FEDMLH_BENCH_FAST").ok().as_deref() == Some("1");
    let lr = 0.05f32;
    let mut rows: Vec<Json> = Vec::new();

    for s in SHAPES {
        let mut rng = Rng::new(0x7a41);
        let x = input_batch(&mut rng, s);
        let y = label_batch(&mut rng, s);

        // -- forward
        let params = ModelParams::init(s.d, s.hidden, s.out, 1);
        let naive_fwd = bench
            .bench_val(&format!("{}/forward/naive", s.name), || {
                naive::forward(&params, &x, s.batch)
            })
            .median;
        let mut scratch = mlp::InferScratch::new();
        let mut z = vec![0.0f32; s.batch * s.out];
        let tiled_fwd = bench
            .bench(&format!("{}/forward/tiled", s.name), || {
                mlp::forward_into(&params, &x, s.batch, &mut scratch, &mut z);
                std::hint::black_box(&z);
            })
            .median;

        // -- full SGD step (params drift across iterations; both
        // variants drift the same way, timing is shape-bound)
        let mut p_naive = ModelParams::init(s.d, s.hidden, s.out, 2);
        let mut ws_naive = naive::NaiveWorkspace::new(&p_naive, s.batch);
        let naive_train = bench
            .bench_val(&format!("{}/train_step/naive", s.name), || {
                naive::train_step(&mut p_naive, &mut ws_naive, &x, &y, lr)
            })
            .median;
        let mut p_tiled = ModelParams::init(s.d, s.hidden, s.out, 2);
        let mut ws_tiled = mlp::Workspace::new(&p_tiled, s.batch);
        let tiled_train = bench
            .bench_val(&format!("{}/train_step/tiled", s.name), || {
                mlp::train_step(&mut p_tiled, &mut ws_tiled, &x, &y, lr)
            })
            .median;

        // -- kernel-dispatch ladder on the same tiled step: scalar →
        // simd → simd + intra-step parallel. One params/workspace pair
        // drifts through all three (timing is shape-bound, and the
        // variants are bit-identical anyway).
        let mut p_lad = ModelParams::init(s.d, s.hidden, s.out, 2);
        let mut ws_lad = mlp::Workspace::new(&p_lad, s.batch);
        simd::force_scalar(true);
        let scalar_train = bench
            .bench_val(&format!("{}/train_step/tiled_scalar", s.name), || {
                mlp::train_step(&mut p_lad, &mut ws_lad, &x, &y, lr)
            })
            .median;
        simd::force_scalar(false);
        let simd_train = bench
            .bench_val(&format!("{}/train_step/tiled_simd", s.name), || {
                mlp::train_step(&mut p_lad, &mut ws_lad, &x, &y, lr)
            })
            .median;
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let par_train = {
            let _budget = parallel::set_kernel_threads(threads);
            bench
                .bench_val(&format!("{}/train_step/tiled_simd_par", s.name), || {
                    mlp::train_step(&mut p_lad, &mut ws_lad, &x, &y, lr)
                })
                .median
        };
        let simd_speedup = scalar_train / simd_train;
        let par_speedup = scalar_train / par_train;

        let train_speedup = naive_train / tiled_train;
        let forward_speedup = naive_fwd / tiled_fwd;
        eprintln!(
            "# {}: train {:.2}x, forward {:.2}x vs naive; simd {:.2}x, \
             simd+par({threads}) {:.2}x vs scalar (simd compiled: {})",
            s.name,
            train_speedup,
            forward_speedup,
            simd_speedup,
            par_speedup,
            simd::compiled()
        );

        let mut o = BTreeMap::new();
        o.insert("shape".to_string(), Json::Str(s.name.to_string()));
        o.insert("batch".to_string(), num(s.batch as f64));
        o.insert("d".to_string(), num(s.d as f64));
        o.insert("nnz_per_row".to_string(), num(s.nnz_per_row as f64));
        o.insert("hidden".to_string(), num(s.hidden as f64));
        o.insert("out".to_string(), num(s.out as f64));
        o.insert("naive_train_s".to_string(), num(naive_train));
        o.insert("tiled_train_s".to_string(), num(tiled_train));
        o.insert("train_speedup".to_string(), num(train_speedup));
        o.insert("naive_forward_s".to_string(), num(naive_fwd));
        o.insert("tiled_forward_s".to_string(), num(tiled_fwd));
        o.insert("forward_speedup".to_string(), num(forward_speedup));
        o.insert("scalar_train_s".to_string(), num(scalar_train));
        o.insert("simd_train_s".to_string(), num(simd_train));
        o.insert("par_train_s".to_string(), num(par_train));
        o.insert("simd_speedup".to_string(), num(simd_speedup));
        o.insert("par_speedup".to_string(), num(par_speedup));
        o.insert("par_threads".to_string(), num(threads as f64));
        rows.push(Json::Obj(o));
    }

    let mut top = BTreeMap::new();
    top.insert("suite".to_string(), Json::Str("train".to_string()));
    top.insert("fast".to_string(), Json::Bool(fast));
    top.insert("simd_compiled".to_string(), Json::Bool(simd::compiled()));
    top.insert("shapes".to_string(), Json::Arr(rows));
    let path = std::env::var("FEDMLH_BENCH_JSON").unwrap_or_else(|_| "BENCH_train.json".into());
    match std::fs::write(&path, Json::Obj(top).to_string_pretty(2)) {
        Ok(()) => eprintln!("# wrote {path}"),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
    bench.finish();
}
