//! Wire-codec benchmarks: encode/decode throughput and achieved
//! compression per preset model size — the client-side cost of buying
//! Table 4's communication reduction. Dense is the memcpy baseline;
//! q8 pays a scan + scale; topk pays a sort over |delta|.
//!
//! The big presets (amztitle/wikititle FedAvg models are multi-million
//! parameter) are skipped by default to keep the suite quick; set
//! `FEDMLH_BENCH_WIRE_FULL=1` to include them.

use fedmlh::bench::Bencher;
use fedmlh::config::presets::by_name;
use fedmlh::federated::wire::{decode_update, encode_update, CodecSpec};
use fedmlh::model::params::ModelParams;
use fedmlh::util::rng::Rng;

fn main() {
    let mut bench = Bencher::from_env("wire");
    let full = std::env::var("FEDMLH_BENCH_WIRE_FULL").ok().as_deref() == Some("1");
    let presets: &[&str] = if full {
        &["tiny", "eurlex", "wiki31", "amztitle", "wikititle"]
    } else {
        &["tiny", "eurlex"]
    };

    for name in presets {
        let preset = by_name(name).unwrap();
        for (tag, out) in [("fedavg", preset.p), ("fedmlh_sub", preset.b)] {
            let global = ModelParams::init(preset.d, preset.hidden, out, 1);
            let mut local = global.clone();
            let mut rng = Rng::new(2);
            for t in local.tensors.iter_mut() {
                for v in t.data_mut() {
                    *v += (rng.next_f32() - 0.5) * 0.05;
                }
            }
            let dense_bytes = local.byte_size();
            for codec in [
                CodecSpec::Dense,
                CodecSpec::QuantI8,
                CodecSpec::TopK { frac: 0.1 },
                CodecSpec::TopKPacked { frac: 0.1 },
            ] {
                let enc = encode_update(codec, &global, &local).unwrap();
                let ratio = dense_bytes as f64 / enc.byte_len() as f64;
                bench.bench_val(
                    &format!("{name}/{tag}/encode/{} ({ratio:.1}x)", codec.name()),
                    || encode_update(codec, &global, &local).unwrap(),
                );
                bench.bench_val(
                    &format!("{name}/{tag}/decode/{}", codec.name()),
                    || decode_update(&global, &enc).unwrap(),
                );
            }
        }
    }
    bench.finish();
}
