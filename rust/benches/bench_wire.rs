//! Wire-codec benchmarks: encode/decode throughput and achieved
//! compression per preset model size — the client-side cost of buying
//! Table 4's communication reduction. Dense is the memcpy baseline;
//! q8 pays a scan + scale; q8g pays the same scan with per-block
//! scales; q4g pays the q8g scan plus nibble packing for roughly half
//! the bytes (the `q4g_vs_q8g` rows pin the measured ratio); topk pays
//! a select over |delta|. The delta rows measure the
//! downlink's per-client framing (`encode_delta`/`apply_delta`) on a
//! drifted base — what the server pays per selected client per round.
//!
//! Besides the `Bencher` table/CSV, this bench writes `BENCH_wire.json`
//! (override the path with `FEDMLH_BENCH_WIRE_JSON`): per
//! preset × model × codec, the median encode/decode seconds and the
//! achieved compression ratio vs dense f32. CI uploads it as the
//! `bench-wire-json` artifact next to `bench-train-json`.
//!
//! The big presets (amztitle/wikititle FedAvg models are multi-million
//! parameter) are skipped by default to keep the suite quick; set
//! `FEDMLH_BENCH_WIRE_FULL=1` to include them.

use std::collections::BTreeMap;

use fedmlh::bench::Bencher;
use fedmlh::config::presets::by_name;
use fedmlh::federated::wire::{
    apply_delta, decode_update, encode_delta, encode_update, CodecSpec,
};
use fedmlh::model::params::ModelParams;
use fedmlh::util::json::Json;
use fedmlh::util::rng::Rng;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() {
    let mut bench = Bencher::from_env("wire");
    let full = std::env::var("FEDMLH_BENCH_WIRE_FULL").ok().as_deref() == Some("1");
    let presets: &[&str] = if full {
        &["tiny", "eurlex", "wiki31", "amztitle", "wikititle"]
    } else {
        &["tiny", "eurlex"]
    };
    let mut rows: Vec<Json> = Vec::new();

    for name in presets {
        let preset = by_name(name).unwrap();
        for (tag, out) in [("fedavg", preset.p), ("fedmlh_sub", preset.b)] {
            let global = ModelParams::init(preset.d, preset.hidden, out, 1);
            let mut local = global.clone();
            let mut rng = Rng::new(2);
            for t in local.tensors.iter_mut() {
                for v in t.data_mut() {
                    *v += (rng.next_f32() - 0.5) * 0.05;
                }
            }
            let dense_bytes = local.byte_size();
            for codec in [
                CodecSpec::Dense,
                CodecSpec::QuantI8,
                CodecSpec::QuantI8Group { block: 64 },
                CodecSpec::QuantI4Group { block: 64 },
                CodecSpec::TopK { frac: 0.1 },
                CodecSpec::TopKPacked { frac: 0.1 },
            ] {
                let enc = encode_update(codec, &global, &local).unwrap();
                let ratio = dense_bytes as f64 / enc.byte_len() as f64;
                let enc_s = bench
                    .bench_val(
                        &format!("{name}/{tag}/encode/{} ({ratio:.1}x)", codec.name()),
                        || encode_update(codec, &global, &local).unwrap(),
                    )
                    .median;
                let dec_s = bench
                    .bench_val(&format!("{name}/{tag}/decode/{}", codec.name()), || {
                        decode_update(&global, &enc).unwrap()
                    })
                    .median;
                let mut o = BTreeMap::new();
                o.insert("preset".to_string(), Json::Str(name.to_string()));
                o.insert("model".to_string(), Json::Str(tag.to_string()));
                o.insert("codec".to_string(), Json::Str(codec.name()));
                o.insert("dense_bytes".to_string(), num(dense_bytes as f64));
                o.insert("encoded_bytes".to_string(), num(enc.byte_len() as f64));
                o.insert("compression".to_string(), num(ratio));
                o.insert("encode_s".to_string(), num(enc_s));
                o.insert("decode_s".to_string(), num(dec_s));
                rows.push(Json::Obj(o));
            }

            // Sub-byte headline: q4g vs q8g at the same block size. The
            // nibble packing halves the value payload while the scales
            // stay, so the ratio lands near 0.53 at block 64 (the
            // acceptance bound is ≤ 0.55).
            let q8g_len = encode_update(CodecSpec::QuantI8Group { block: 64 }, &global, &local)
                .unwrap()
                .byte_len();
            let q4g_len = encode_update(CodecSpec::QuantI4Group { block: 64 }, &global, &local)
                .unwrap()
                .byte_len();
            let sub_byte = q4g_len as f64 / q8g_len as f64;
            eprintln!("# {name}/{tag}: q4g bytes = {sub_byte:.3}x q8g (block 64)");
            let mut o = BTreeMap::new();
            o.insert("preset".to_string(), Json::Str(name.to_string()));
            o.insert("model".to_string(), Json::Str(tag.to_string()));
            o.insert("codec".to_string(), Json::Str("q4g_vs_q8g:64".to_string()));
            o.insert("q8g_bytes".to_string(), num(q8g_len as f64));
            o.insert("q4g_bytes".to_string(), num(q4g_len as f64));
            o.insert("q4g_vs_q8g_bytes".to_string(), num(sub_byte));
            rows.push(Json::Obj(o));

            // Delta framing: what the per-client downlink pays per round
            // (`local` stands in for "the global one training step past
            // the client's base").
            for codec in [
                CodecSpec::TopKPacked { frac: 0.1 },
                CodecSpec::QuantI8,
                CodecSpec::QuantI4Group { block: 64 },
            ] {
                let enc = encode_delta(codec, &global, &local).unwrap();
                let ratio = dense_bytes as f64 / enc.byte_len() as f64;
                let enc_s = bench
                    .bench_val(
                        &format!("{name}/{tag}/delta_encode/{} ({ratio:.1}x)", codec.name()),
                        || encode_delta(codec, &global, &local).unwrap(),
                    )
                    .median;
                let dec_s = bench
                    .bench_val(
                        &format!("{name}/{tag}/delta_apply/{}", codec.name()),
                        || apply_delta(&global, &enc).unwrap(),
                    )
                    .median;
                let mut o = BTreeMap::new();
                o.insert("preset".to_string(), Json::Str(name.to_string()));
                o.insert("model".to_string(), Json::Str(tag.to_string()));
                o.insert(
                    "codec".to_string(),
                    Json::Str(format!("delta:{}", codec.name())),
                );
                o.insert("dense_bytes".to_string(), num(dense_bytes as f64));
                o.insert("encoded_bytes".to_string(), num(enc.byte_len() as f64));
                o.insert("compression".to_string(), num(ratio));
                o.insert("encode_s".to_string(), num(enc_s));
                o.insert("decode_s".to_string(), num(dec_s));
                rows.push(Json::Obj(o));
            }
        }
    }

    let mut top = BTreeMap::new();
    top.insert("suite".to_string(), Json::Str("wire".to_string()));
    top.insert("full".to_string(), Json::Bool(full));
    top.insert("codecs".to_string(), Json::Arr(rows));
    let path =
        std::env::var("FEDMLH_BENCH_WIRE_JSON").unwrap_or_else(|_| "BENCH_wire.json".into());
    match std::fs::write(&path, Json::Obj(top).to_string_pretty(2)) {
        Ok(()) => eprintln!("# wrote {path}"),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
    bench.finish();
}
