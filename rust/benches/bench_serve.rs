//! Serving-path benchmarks: checkpoint encode/decode cost and predict
//! throughput, single-row vs batched — the numbers that justify the
//! micro-batcher (one [rows, d] forward amortizes the weight-matrix
//! streaming that dominates a single-row pass) and quantify what q8
//! checkpoint loading costs relative to dense.
//!
//! Weights are untrained (`ModelParams::init`): throughput does not
//! depend on parameter values.

use std::sync::Arc;

use fedmlh::bench::Bencher;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::model::params::ModelParams;
use fedmlh::serve::{
    Checkpoint, CheckpointCodec, InferenceEngine, ModelVersion, Predictor, ServeMetrics, ServeOpts,
};
use fedmlh::util::rng::Rng;

fn eurlex_checkpoint() -> Checkpoint {
    let cfg = ExperimentConfig::preset("eurlex").unwrap();
    let models: Vec<ModelParams> = (0..cfg.r())
        .map(|j| ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), 1 + j as u64))
        .collect();
    Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap()
}

fn main() {
    let mut bench = Bencher::from_env("serve");
    let ckpt = eurlex_checkpoint();
    let d = ckpt.meta.d;

    // -- checkpoint codec cost + achieved sizes
    let dense_bytes = ckpt.to_bytes(CheckpointCodec::Dense).unwrap();
    let q8_bytes = ckpt.to_bytes(CheckpointCodec::QuantI8).unwrap();
    let ratio = dense_bytes.len() as f64 / q8_bytes.len() as f64;
    bench.bench_val("checkpoint/encode/dense", || {
        ckpt.to_bytes(CheckpointCodec::Dense).unwrap()
    });
    bench.bench_val(&format!("checkpoint/encode/q8 ({ratio:.1}x)"), || {
        ckpt.to_bytes(CheckpointCodec::QuantI8).unwrap()
    });
    bench.bench_val("checkpoint/decode/dense", || {
        Checkpoint::from_bytes(&dense_bytes).unwrap()
    });
    bench.bench_val("checkpoint/decode/q8", || {
        Checkpoint::from_bytes(&q8_bytes).unwrap()
    });

    // -- raw engine throughput: single row vs one batched forward
    let engine = InferenceEngine::new(Checkpoint::from_bytes(&q8_bytes).unwrap()).unwrap();
    let mut rng = Rng::new(7);
    let row: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
    bench.bench_val("predict/engine/rows1_top5", || {
        engine.predict_topk(&row, 1, 5).unwrap()
    });
    for rows in [8usize, 32] {
        let batch: Vec<f32> = (0..rows * d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        bench.bench_val(&format!("predict/engine/rows{rows}_top5"), || {
            engine.predict_topk(&batch, rows, 5).unwrap()
        });
    }

    // -- through the micro-batching queue (sequential caller: measures
    // the queue/handoff overhead over the raw single-row forward)
    let predictor = Predictor::new(
        Arc::new(InferenceEngine::new(Checkpoint::from_bytes(&q8_bytes).unwrap()).unwrap()),
        2,
        32,
        Arc::new(ServeMetrics::new()),
    );
    bench.bench_val("predict/queue/rows1_top5", || {
        predictor.predict(row.clone(), 5).unwrap()
    });

    // -- hot-reload cost: everything a `POST /reload` does off the
    // request path (decode the checkpoint, spawn replica pools). The
    // swap itself is one Arc pointer write under a write lock.
    let opts = ServeOpts {
        workers: 1,
        max_batch: 8,
        ..ServeOpts::default()
    };
    let totals = Arc::new(ServeMetrics::new());
    bench.bench_val("reload/build_version", || {
        ModelVersion::build(
            Checkpoint::from_bytes(&q8_bytes).unwrap(),
            1,
            "bench".into(),
            &opts,
            &totals,
        )
        .unwrap()
    });

    bench.finish();
}
