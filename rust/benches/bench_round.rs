//! Table 7 source: wall-clock of one client's local synchronization
//! round (E = 5 epochs), FedAvg vs FedMLH, rust and XLA backends.
//! The paper's claim is the ratio (FedMLH trains faster because the
//! last layer is B-wide, not p-wide).

use std::path::Path;

use fedmlh::bench::Bencher;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::federated::backend::{RustBackend, TrainBackend};
use fedmlh::federated::batcher::ClientBatcher;
use fedmlh::harness;
use fedmlh::model::params::ModelParams;
use fedmlh::runtime::RuntimeClient;

fn bench_local_round(
    bench: &mut Bencher,
    tag: &str,
    cfg: &ExperimentConfig,
    algo: Algo,
    backend: &dyn TrainBackend,
) {
    let world = harness::build_world(cfg);
    let scheme = fedmlh::algo::scheme_for(cfg, algo, &world.data.train);
    let shard = &world.partition.clients[0];
    let mut params = ModelParams::init(
        cfg.preset.d,
        cfg.preset.hidden,
        scheme.out_dim(),
        1,
    );
    bench.bench(tag, || {
        // one sub-model's DeviceTrain (E epochs); FedMLH runs R of these
        let mut batcher = ClientBatcher::new(
            &world.data.train,
            shard,
            scheme.target(0),
            cfg.preset.batch,
            42,
        );
        backend
            .local_train(&mut params, &mut batcher, cfg.local_epochs, cfg.lr)
            .unwrap();
    });
}

fn main() {
    let mut bench = Bencher::from_env("round");
    // keep the bench window reasonable: these are whole local rounds
    let fast = std::env::var("FEDMLH_BENCH_FAST").ok().as_deref() == Some("1");
    let presets: &[&str] = if fast { &["tiny"] } else { &["tiny", "eurlex"] };

    for name in presets {
        let cfg = ExperimentConfig::preset(name).unwrap();
        let rust = RustBackend::with_batch(cfg.preset.batch);
        bench_local_round(&mut bench, &format!("rust/{name}/fedavg_E5"), &cfg, Algo::FedAvg, &rust);
        bench_local_round(&mut bench, &format!("rust/{name}/fedmlh_sub_E5"), &cfg, Algo::FedMlh, &rust);
    }

    let dir = Path::new("artifacts");
    if cfg!(feature = "xla") && dir.join("manifest.json").exists() {
        let rt = RuntimeClient::new(dir).unwrap();
        for name in presets {
            let cfg = ExperimentConfig::preset(name).unwrap();
            for algo in [Algo::FedAvg, Algo::FedMlh] {
                let be = fedmlh::runtime::XlaBackend::new(rt.clone(), &cfg, algo).unwrap();
                let tag = format!("xla/{name}/{}_E5", if algo == Algo::FedAvg { "fedavg" } else { "fedmlh_sub" });
                bench_local_round(&mut bench, &tag, &cfg, algo, &be);
            }
        }
    } else {
        eprintln!("# artifacts missing — skipping XLA round benches");
    }
    bench.finish();
}
