//! Aggregation benchmarks (Algorithm 2 line 17): weighted parameter
//! averaging at every preset's model size, for S=4 and S=10 clients.
//! This is the L3 server-side cost that scales with model bytes — the
//! quantity FedMLH shrinks.

use fedmlh::bench::Bencher;
use fedmlh::config::presets::PRESETS;
use fedmlh::federated::aggregate::{aggregate, Weighting};
use fedmlh::model::params::ModelParams;

fn main() {
    let mut b = Bencher::from_env("aggregate");

    for preset in PRESETS {
        for (algo, out) in [("fedavg", preset.p), ("fedmlh_sub", preset.b)] {
            let models: Vec<ModelParams> = (0..10)
                .map(|i| ModelParams::init(preset.d, preset.hidden, out, i as u64))
                .collect();
            for s in [4usize, 10] {
                let refs: Vec<(&ModelParams, usize)> =
                    models[..s].iter().map(|m| (m, 100)).collect();
                let mb = models[0].byte_size() as f64 / 1e6;
                b.bench_val(
                    &format!("{}/{algo}/S{s} ({mb:.1}MB)", preset.name),
                    || aggregate(&refs, Weighting::Uniform).unwrap(),
                );
            }
        }
    }
    b.finish();
}
