//! End-to-end table regeneration benchmark: times a full FedAvg+FedMLH
//! comparison pair (the generator of Tables 3–7) on the tiny preset,
//! plus the per-table formatting. `FEDMLH_BENCH_FULL=eurlex` upgrades
//! the measured preset (minutes, not seconds).

use fedmlh::bench::Bencher;
use fedmlh::config::ExperimentConfig;
use fedmlh::harness::{self, tables, BackendKind, HarnessOpts};

fn main() {
    let mut bench = Bencher::from_env("tables");
    let preset = std::env::var("FEDMLH_BENCH_FULL").unwrap_or_else(|_| "tiny".into());
    let rounds = if preset == "tiny" { 5 } else { 10 };

    let cfg = ExperimentConfig::preset(&preset).unwrap();
    let mk_opts = |backend| HarnessOpts {
        backend,
        rounds: Some(rounds),
        ..HarnessOpts::default()
    };

    bench.min_iters = 3;
    let mut last_pair = None;
    bench.bench(&format!("pair/{preset}/rust_{rounds}r"), || {
        last_pair = Some(harness::run_pair(&cfg, &mk_opts(BackendKind::Rust)).unwrap());
    });
    if std::path::Path::new("artifacts/manifest.json").exists() {
        bench.bench(&format!("pair/{preset}/xla_{rounds}r"), || {
            last_pair = Some(harness::run_pair(&cfg, &mk_opts(BackendKind::Xla)).unwrap());
        });
    }

    let pairs = vec![last_pair.unwrap()];
    bench.bench_val("format/tables_3_to_7", || tables::all_pair_tables(&pairs));
    bench.finish();
}
