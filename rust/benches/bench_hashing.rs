//! Hashing-substrate micro-benchmarks: 2-universal evaluation, label
//! hashing (Algorithm 2 lines 4–7), index-matrix construction and the
//! count-sketch primitives. These are L3 per-batch hot-path pieces.

use fedmlh::bench::Bencher;
use fedmlh::hashing::count_sketch::{CountSketch, Estimator};
use fedmlh::hashing::label_hash::LabelHasher;
use fedmlh::hashing::universal::UniversalHash;
use fedmlh::util::rng::Rng;

fn main() {
    let mut b = Bencher::from_env("hashing");

    // raw 2-universal throughput
    let mut rng = Rng::new(1);
    let h = UniversalHash::draw(&mut rng, 4096);
    b.bench_val("universal/1e5_hashes", || {
        let mut acc = 0usize;
        for x in 0..100_000u64 {
            acc ^= h.hash(x);
        }
        acc
    });

    // bucket-label construction at eurlex and amztitle scale
    for (name, p, bb, r) in [("eurlex", 4000usize, 250usize, 4usize), ("amztitle", 16384, 1024, 4)] {
        let hasher = LabelHasher::new(7, r, p, bb);
        let positives: Vec<u32> = (0..8).map(|i| (i * (p / 8)) as u32).collect();
        let mut out = vec![0.0f32; bb];
        b.bench(&format!("bucket_labels/{name}_batch64"), || {
            for _ in 0..64 {
                hasher.bucket_labels_table_into(0, &positives, &mut out);
            }
        });
        b.bench_val(&format!("index_matrix/{name}"), || hasher.index_matrix_i32());
    }

    // count-sketch insert + retrieve
    let mut cs = CountSketch::new(3, 5, 1024);
    b.bench("count_sketch/insert_1e4", || {
        for i in 0..10_000u64 {
            cs.insert(i, 1.0);
        }
    });
    b.bench_val("count_sketch/retrieve_1e4", || {
        let mut acc = 0.0f32;
        for i in 0..10_000u64 {
            acc += cs.retrieve(i, Estimator::Median);
        }
        acc
    });

    b.finish();
}
