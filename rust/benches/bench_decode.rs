//! Count-sketch decode benchmarks (Fig. 1b): the rust reference decode
//! at every preset's (R, B, p) and, when artifacts are present, the
//! compiled HLO decode through PJRT for comparison (§Perf L1/L3 split).

use std::path::Path;

use fedmlh::bench::Bencher;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::eval::decode::sketch_decode;
use fedmlh::federated::backend::TrainBackend;
use fedmlh::hashing::label_hash::LabelHasher;
use fedmlh::runtime::{RuntimeClient, XlaBackend};

fn main() {
    let mut bench = Bencher::from_env("decode");

    for name in ["eurlex", "wiki31", "amztitle", "wikititle"] {
        let cfg = ExperimentConfig::preset(name).unwrap();
        let (r, b, p, rows) = (cfg.r(), cfg.b(), cfg.preset.p, cfg.preset.batch);
        let hasher = LabelHasher::new(1, r, p, b);
        let idx = hasher.index_matrix_i32();
        let logits: Vec<f32> = (0..r * rows * b).map(|i| (i as f32).sin()).collect();
        bench.bench_val(&format!("rust/{name} R{r} B{b} p{p}"), || {
            sketch_decode(&logits, &idx, r, rows, b, p)
        });
    }

    // HLO decode (artifact-backed), when built with the xla feature.
    let dir = Path::new("artifacts");
    if cfg!(feature = "xla") && dir.join("manifest.json").exists() {
        let rt = RuntimeClient::new(dir).unwrap();
        for name in ["eurlex", "amztitle"] {
            let cfg = ExperimentConfig::preset(name).unwrap();
            let be = XlaBackend::new(rt.clone(), &cfg, Algo::FedMlh).unwrap();
            let (r, b, p, rows) = (cfg.r(), cfg.b(), cfg.preset.p, cfg.preset.batch);
            let hasher = LabelHasher::new(1, r, p, b);
            let idx = hasher.index_matrix_i32();
            let logits: Vec<f32> = (0..r * rows * b).map(|i| (i as f32).sin()).collect();
            bench.bench_val(&format!("hlo/{name} R{r} B{b} p{p}"), || {
                be.decode(&logits, &idx, r, rows, b, p).unwrap()
            });
        }
    } else {
        eprintln!("# artifacts missing — skipping HLO decode benches");
    }
    bench.finish();
}
