//! The per-client versioned delta downlink, end to end:
//!
//! 1. `--down-codec topk:0.1` achieves ≥ 5× *measured* download
//!    compression (`CommMeter::download_compression()`) on a real run,
//!    with final accuracy within tolerance of the dense-downlink run;
//! 2. resync correctness: a client sampled out past `--resync-every`
//!    decodes to exactly the server's current broadcast base, bitwise,
//!    on its next participation — driven through the `Transport`
//!    facade with evolving globals;
//! 3. the per-round `down_bytes`/`up_bytes` columns sum exactly to
//!    `CommMeter::downloaded()`/`uploaded()` for every uplink ×
//!    downlink codec combination (dense/q8/q8g/q4g × dense/q8/q4g/delta);
//! 4. the delta downlink keeps the engine's worker-count invariance
//!    (`workers = 4` bitwise equals `workers = 1`).

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::data::synth::generate_preset;
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::server::{self, RunOutput};
use fedmlh::federated::transport::{DownCodec, Transport};
use fedmlh::federated::wire::{
    apply_delta, decode_update, encode_delta, encode_update, CodecSpec, EncodedUpdate,
};
use fedmlh::model::params::ModelParams;
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};
use fedmlh::util::rng::Rng;

struct RunSpec {
    codec: CodecSpec,
    down_codec: DownCodec,
    resync_every: usize,
    clients: usize,
    per_round: usize,
    rounds: usize,
    workers: usize,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            codec: CodecSpec::Dense,
            down_codec: DownCodec::Dense,
            resync_every: 8,
            clients: 4,
            per_round: 4,
            rounds: 8,
            workers: 1,
        }
    }
}

fn run(spec: RunSpec) -> RunOutput {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = spec.rounds;
    cfg.patience = 0;
    cfg.clients = spec.clients;
    cfg.clients_per_round = spec.per_round;
    cfg.local_epochs = 1;
    cfg.codec = spec.codec;
    cfg.down_codec = spec.down_codec;
    cfg.resync_every = spec.resync_every;
    cfg.workers = spec.workers;
    let data = generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
    let backend = RustBackend::new();
    server::run(
        &cfg,
        scheme.as_ref(),
        &backend,
        &data.train,
        &data.test,
        &part,
    )
    .unwrap()
}

/// Acceptance pin: a `topk:0.1` delta downlink pays ~0.5 bytes per
/// parameter per delta (packed indices + f32 values) against 4 bytes
/// dense, so even with every client's round-0 full resync amortized
/// over 16 rounds the *measured* cumulative ratio clears 5×.
#[test]
fn topk_delta_downlink_compresses_5x_within_accuracy_tolerance() {
    let delta = run(RunSpec {
        down_codec: DownCodec::TopK { frac: 0.1 },
        resync_every: 32,
        rounds: 16,
        ..RunSpec::default()
    });
    let dense = run(RunSpec {
        rounds: 16,
        ..RunSpec::default()
    });

    assert!(
        delta.comm.download_compression() >= 5.0,
        "measured download compression {:.2}x < 5x",
        delta.comm.download_compression()
    );
    // The dense-equivalent side of the meter matches the dense run's
    // actual downlink, so the ratio is anchored, not self-referential.
    assert_eq!(delta.comm.downloaded_dense_equiv(), dense.comm.downloaded());
    // The uplink stayed dense in both runs: identical wire bill.
    assert_eq!(delta.comm.uploaded(), dense.comm.uploaded());
    assert_eq!(delta.comm.upload_compression(), 1.0);

    // Accuracy: the lossy per-client downlink must stay within
    // tolerance of the dense-downlink run — the pending (unshipped)
    // part of each broadcast stays in the client's base delta, so the
    // signal is delayed, not destroyed.
    assert!(
        delta.best.mean_topk() >= dense.best.mean_topk() - 0.15,
        "delta downlink accuracy {:.4} too far below dense {:.4}",
        delta.best.mean_topk(),
        dense.best.mean_topk()
    );
    // …and it genuinely learns (not just "within tolerance of nothing").
    let first = delta.history.records.first().unwrap().accuracy.top1;
    assert!(delta.best.top1 >= first, "no improvement under delta downlink");
    assert!(delta.best.top1 > 0.02, "top1 {} not above chance", delta.best.top1);
}

/// Resync correctness (acceptance criterion): drive the transport
/// facade round by round with drifting globals. A client that sits out
/// k rounds within the staleness window gets a delta against its old
/// base; one past `--resync-every` gets a full payload that lands it
/// *bitwise* on the server's current broadcast base.
#[test]
fn sampled_out_client_resyncs_bitwise_past_the_cap() {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.clients = 3;
    cfg.clients_per_round = 2;
    cfg.down_codec = DownCodec::TopK { frac: 0.2 };
    cfg.resync_every = 2;
    let mut transport = Transport::new(&cfg, 1).unwrap();

    let mut global = ModelParams::init(12, 6, 10, 99);
    let mut rng = Rng::new(1234);
    let mut drift = |g: &ModelParams| {
        let mut out = g.clone();
        for t in out.tensors.iter_mut() {
            for v in t.data_mut() {
                *v += (rng.next_f32() - 0.5) * 0.05;
            }
        }
        out
    };

    // Round 0: everyone syncs (full, bitwise).
    let bcast = transport.broadcast(0, &[0, 1, 2], &[global.clone()]).unwrap();
    for slot in 0..3 {
        assert!(bcast.payload(slot, 0).is_full());
        assert_eq!(bcast.global(slot, 0), &global);
    }
    let client2_base = bcast.global(2, 0).clone();

    // Rounds 1–2: client 2 is sampled out; the others get deltas.
    for round in 1..3 {
        global = drift(&global);
        let bcast = transport.broadcast(round, &[0, 1], &[global.clone()]).unwrap();
        for slot in 0..2 {
            assert!(
                !bcast.payload(slot, 0).is_full(),
                "round {round}: participating client must get a delta"
            );
        }
    }

    // Round 3: client 2's base is 3 versions old (> resync_every = 2) →
    // full dense resync, bitwise at the current broadcast base. Client 0
    // (gap 1) still gets a delta applied onto what it last decoded.
    global = drift(&global);
    let bcast = transport.broadcast(3, &[0, 2], &[global.clone()]).unwrap();
    let p2 = bcast.payload(1, 0); // slot 1 = client 2
    assert!(p2.is_full(), "stale client must get a full resync");
    assert_eq!(
        bcast.global(1, 0),
        &global,
        "resync must land bitwise on the server's broadcast base"
    );
    // The resync payload itself decodes bitwise too (wire-level check),
    // and it is *not* what the client held before.
    assert_eq!(&p2.decode_full(&global).unwrap(), &global);
    assert_ne!(&client2_base, &global);
    let p0 = bcast.payload(0, 0);
    assert!(!p0.is_full(), "fresh client keeps delta service");
    // Deltas are versioned; this round's payloads advertise version 4.
    assert_eq!(p0.version(), 4);
    assert_eq!(p2.version(), 4);
}

/// Satellite pin (q4g delta framing): drive the delta-downlink
/// protocol at the wire level with `q4g:<block>` framed deltas. The
/// server tracks the client's *decoded* replica — not its own exact
/// base — so the lossy int4 deltas compose bitwise on both ends round
/// after round, and the full dense resync that ends the chain lands
/// the client bitwise on the server's current broadcast base.
#[test]
fn q4g_delta_chain_resyncs_bitwise_on_the_dense_payload() {
    let spec = CodecSpec::QuantI4Group { block: 16 };
    let mut global = ModelParams::init(12, 6, 10, 7);
    let n_tensors = global.tensors.len();
    let n = global.num_params();
    let mut rng = Rng::new(0x9d);

    // Initial sync: full dense payload, client lands bitwise.
    let full = encode_update(CodecSpec::Dense, &global, &global).unwrap();
    let mut client = decode_update(&global, &full).unwrap();
    assert_eq!(client, global);
    let mut replica = client.clone();

    // Three rounds of drift shipped as framed q4g deltas against the
    // replica. The client applies what came off the wire; the server
    // applies the same encoding to its replica.
    for round in 0..3 {
        for t in global.tensors.iter_mut() {
            for v in t.data_mut() {
                *v += (rng.next_f32() - 0.5) * 0.05;
            }
        }
        let enc = encode_delta(spec, &replica, &global).unwrap();
        let framed = enc.to_framed_bytes();
        let back = EncodedUpdate::from_framed_bytes(spec, n_tensors, n, &framed).unwrap();
        assert_eq!(back, enc, "round {round}: framed q4g delta round-trips");
        client = apply_delta(&client, &back).unwrap();
        replica = apply_delta(&replica, &enc).unwrap();
        assert_eq!(client, replica, "round {round}: replica tracks the decoded state");
        assert_ne!(client, global, "round {round}: int4 delta is lossy by design");
    }

    // The staleness resync that ends the chain is dense: after it the
    // client (and the server's replica of it) is the broadcast base,
    // bitwise — exactly the contract `DeltaDownlink` promises.
    let resync = encode_update(CodecSpec::Dense, &global, &global).unwrap();
    client = decode_update(&global, &resync).unwrap();
    assert_eq!(client, global, "dense resync lands bitwise after a lossy q4g chain");
}

/// Satellite pin: `RoundRecord`'s per-round byte columns decompose the
/// cumulative meter exactly, for every codec combination on both links
/// — including the per-client delta downlink under partial
/// participation (where different clients pay different byte counts).
#[test]
fn round_byte_columns_sum_to_the_meter_for_all_codec_combos() {
    let uplinks = [
        CodecSpec::Dense,
        CodecSpec::QuantI8,
        CodecSpec::QuantI8Group { block: 64 },
        CodecSpec::QuantI4Group { block: 64 },
    ];
    let downlinks = [
        DownCodec::Dense,
        DownCodec::QuantI8,
        DownCodec::QuantI4Group { block: 64 },
        DownCodec::TopK { frac: 0.2 },
    ];
    for codec in uplinks {
        for down_codec in downlinks {
            let out = run(RunSpec {
                codec,
                down_codec,
                clients: 5,
                per_round: 2,
                rounds: 3,
                ..RunSpec::default()
            });
            let tag = format!("{} × {}", codec.name(), down_codec.name());
            assert_eq!(out.history.records.len(), 3, "{tag}: every round evaluated");
            let down_sum: u64 = out.history.records.iter().map(|r| r.down_bytes).sum();
            let up_sum: u64 = out.history.records.iter().map(|r| r.up_bytes).sum();
            assert_eq!(down_sum, out.comm.downloaded(), "{tag}: down column");
            assert_eq!(up_sum, out.comm.uploaded(), "{tag}: up column");
            assert_eq!(down_sum + up_sum, out.comm.total(), "{tag}: total");
            for rec in &out.history.records {
                assert!(rec.down_bytes > 0 && rec.up_bytes > 0, "{tag}");
            }
        }
    }
}

/// The delta downlink runs on the coordinator thread before the
/// training fan-out, so its per-client state cannot be reordered by
/// worker scheduling: `workers = 4` must be bitwise `workers = 1`.
#[test]
fn delta_downlink_is_worker_count_invariant() {
    let spec = |workers| RunSpec {
        down_codec: DownCodec::TopK { frac: 0.1 },
        clients: 6,
        per_round: 3,
        rounds: 4,
        workers,
        ..RunSpec::default()
    };
    let seq = run(spec(1));
    let par = run(spec(4));
    assert_eq!(seq.final_globals, par.final_globals, "final parameters");
    assert_eq!(seq.comm, par.comm, "comm meters");
    assert_eq!(seq.best, par.best, "best accuracy");
    for (a, b) in seq.history.records.iter().zip(par.history.records.iter()) {
        assert_eq!(a.accuracy, b.accuracy, "round {}", a.round);
        assert_eq!(a.down_bytes, b.down_bytes, "round {}", a.round);
        assert_eq!(a.up_bytes, b.up_bytes, "round {}", a.round);
    }
}
