//! The parallel round engine's contract: `workers = N` is **bitwise
//! identical** to `workers = 1` — same `History` (modulo wall-clock
//! fields), same `CommMeter`, same final global parameters — for every
//! wire codec, with the stateful (error-feedback) transport included:
//! per-`(client, sub-model)` residual slots are touched by exactly one
//! work item per round, so worker scheduling cannot reorder state. A
//! small synthetic FedMLH run (R = 3 sub-models, 8 clients) exercises
//! the full server loop on the pure-rust backend.
//!
//! This file also pins the seed trajectory: `dense` + `--error-feedback
//! off` must stay bitwise identical to the stateless PR 1 pipeline —
//! and because `dense` is lossless, feedback *on* cannot change it
//! either.

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::data::synth::generate_preset;
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::comm::expected_round_bytes;
use fedmlh::federated::server::{self, RunOutput};
use fedmlh::federated::wire::CodecSpec;
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};

fn run_fb(workers: usize, codec: CodecSpec, algo: Algo, error_feedback: bool) -> RunOutput {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = 3;
    cfg.patience = 0;
    cfg.clients = 8;
    cfg.clients_per_round = 4;
    cfg.local_epochs = 1;
    cfg.override_r = 3;
    cfg.workers = workers;
    cfg.codec = codec;
    cfg.error_feedback = error_feedback;
    let data = generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(&cfg, algo, &data.train);
    let backend = RustBackend::new();
    server::run(
        &cfg,
        scheme.as_ref(),
        &backend,
        &data.train,
        &data.test,
        &part,
    )
    .unwrap()
}

fn run(workers: usize, codec: CodecSpec, algo: Algo) -> RunOutput {
    run_fb(workers, codec, algo, false)
}

/// Everything except wall-clock fields must match exactly.
fn assert_bitwise_equal(seq: &RunOutput, par: &RunOutput, tag: &str) {
    assert_eq!(seq.rounds_run, par.rounds_run, "{tag}: rounds_run");
    assert_eq!(seq.n_models, par.n_models, "{tag}: n_models");
    assert_eq!(seq.best, par.best, "{tag}: best accuracy report");
    assert_eq!(seq.best_round, par.best_round, "{tag}: best_round");
    assert_eq!(seq.comm, par.comm, "{tag}: CommMeter");
    assert_eq!(seq.comm_to_best, par.comm_to_best, "{tag}: comm_to_best");
    assert_eq!(
        seq.history.records.len(),
        par.history.records.len(),
        "{tag}: history length"
    );
    for (a, b) in seq.history.records.iter().zip(par.history.records.iter()) {
        assert_eq!(a.round, b.round, "{tag}: round index");
        assert_eq!(a.accuracy, b.accuracy, "{tag}: round {} accuracy", a.round);
        assert_eq!(a.comm_bytes, b.comm_bytes, "{tag}: round {} comm", a.round);
        assert_eq!(
            a.mean_loss.to_bits(),
            b.mean_loss.to_bits(),
            "{tag}: round {} loss ({} vs {})",
            a.round,
            a.mean_loss,
            b.mean_loss
        );
    }
    assert_eq!(
        seq.final_globals, par.final_globals,
        "{tag}: final global parameters"
    );
}

#[test]
fn four_workers_match_sequential_for_every_codec() {
    for codec in [
        CodecSpec::Dense,
        CodecSpec::QuantI8,
        CodecSpec::QuantI8Group { block: 32 },
        CodecSpec::QuantI4Group { block: 32 },
        CodecSpec::TopK { frac: 0.2 },
        CodecSpec::TopKPacked { frac: 0.2 },
    ] {
        let seq = run(1, codec, Algo::FedMlh);
        let par = run(4, codec, Algo::FedMlh);
        assert_eq!(seq.n_models, 3);
        assert_bitwise_equal(&seq, &par, &codec.name());
    }
}

#[test]
fn four_workers_match_sequential_with_error_feedback() {
    // The stateful transport must not break worker-count invariance:
    // residual slots are per-(client, sub-model), one item per slot per
    // round, so scheduling cannot reorder state updates.
    for codec in [
        CodecSpec::QuantI8,
        CodecSpec::QuantI4Group { block: 32 },
        CodecSpec::TopK { frac: 0.1 },
        CodecSpec::TopKPacked { frac: 0.1 },
    ] {
        let seq = run_fb(1, codec, Algo::FedMlh, true);
        let par = run_fb(4, codec, Algo::FedMlh, true);
        assert_bitwise_equal(&seq, &par, &format!("{}+feedback", codec.name()));
    }
}

#[test]
fn dense_feedback_on_is_bitwise_identical_to_off() {
    // dense is lossless → the residual is identically zero → the
    // stateful pipeline must reduce to the stateless seed pipeline
    // bit for bit. This pins the PR 1 trajectory on both settings.
    let off = run_fb(1, CodecSpec::Dense, Algo::FedMlh, false);
    let on = run_fb(1, CodecSpec::Dense, Algo::FedMlh, true);
    assert_bitwise_equal(&off, &on, "dense feedback on/off");
}

#[test]
fn oversubscribed_pool_still_matches() {
    // More workers than (clients × sub-models) work items.
    let seq = run(1, CodecSpec::Dense, Algo::FedMlh);
    let par = run(64, CodecSpec::Dense, Algo::FedMlh);
    assert_bitwise_equal(&seq, &par, "oversubscribed");
}

#[test]
fn fedavg_single_model_parallelizes_too() {
    let seq = run(1, CodecSpec::Dense, Algo::FedAvg);
    let par = run(4, CodecSpec::Dense, Algo::FedAvg);
    assert_eq!(seq.n_models, 1);
    assert_bitwise_equal(&seq, &par, "fedavg");
}

#[test]
fn parallel_dense_comm_matches_closed_form() {
    let par = run(4, CodecSpec::Dense, Algo::FedMlh);
    let per_round = expected_round_bytes(4, par.model_bytes / par.n_models, par.n_models);
    assert_eq!(par.comm.total(), per_round * par.rounds_run as u64);
    assert_eq!(par.comm.upload_compression(), 1.0);
}

#[test]
fn parallel_run_actually_learns() {
    // Guard against the engine silently training nothing: accuracy after
    // 3 rounds must beat the first evaluation.
    let par = run(4, CodecSpec::Dense, Algo::FedMlh);
    let first = par.history.records.first().unwrap().accuracy.top1;
    assert!(
        par.best.top1 >= first,
        "no improvement: {first} -> {}",
        par.best.top1
    );
    assert!(par.best.top1 > 0.02, "top1 {} not above chance", par.best.top1);
}
