//! End-to-end federated training over the pure-rust backend: the whole
//! stack (synth data → non-iid partition → schemes → server loop →
//! decode → metrics) without artifacts, so it runs everywhere.

use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::comm::expected_round_bytes;
use fedmlh::harness::{self, BackendKind, HarnessOpts};

fn quick_cfg(rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = rounds;
    cfg.patience = 0;
    cfg
}

fn opts(rounds: usize) -> HarnessOpts {
    HarnessOpts {
        backend: BackendKind::Rust,
        rounds: Some(rounds),
        ..HarnessOpts::default()
    }
}

#[test]
fn both_algorithms_learn_beyond_chance() {
    let pair = harness::run_pair(&quick_cfg(12), &opts(12)).unwrap();
    // tiny has p = 64 classes; chance top-1 ≈ a few %. Both algorithms
    // must comfortably beat it after 12 rounds.
    assert!(
        pair.fedavg.best.top1 > 0.15,
        "fedavg top1 {}",
        pair.fedavg.best.top1
    );
    assert!(
        pair.fedmlh.best.top1 > 0.15,
        "fedmlh top1 {}",
        pair.fedmlh.best.top1
    );
    // and accuracy must improve over the first evaluation.
    let first = pair.fedmlh.history.records.first().unwrap().accuracy.top1;
    assert!(pair.fedmlh.best.top1 > first);
}

#[test]
fn communication_accounting_is_exact() {
    let cfg = quick_cfg(5);
    let pair = harness::run_pair(&cfg, &opts(5)).unwrap();
    for out in [&pair.fedavg, &pair.fedmlh] {
        let per_round = expected_round_bytes(
            cfg.clients_per_round,
            out.model_bytes / out.n_models,
            out.n_models,
        );
        assert_eq!(out.comm.total(), per_round * out.rounds_run as u64);
        // per-round totals are monotone non-decreasing cumulative sums
        let totals = out.comm.per_round_totals();
        assert_eq!(totals.len(), out.rounds_run);
        assert!(totals.windows(2).all(|w| w[0] <= w[1]));
    }
}

#[test]
fn early_stopping_cuts_rounds() {
    let mut cfg = quick_cfg(60);
    cfg.patience = 3;
    cfg.lr = 1e-9; // effectively frozen → flat accuracy → stop at ~4
    let out = harness::run_algo(&cfg, Algo::FedAvg, &RustBackend::new(), 7).unwrap();
    assert!(
        out.rounds_run < 10,
        "early stopping did not engage: {} rounds",
        out.rounds_run
    );
}

#[test]
fn fedmlh_sub_models_are_independent_streams() {
    // R sub-models must produce a decode that depends on all of them:
    // zeroing one sub-model's logits changes scores.
    let cfg = quick_cfg(3);
    let world = harness::build_world(&cfg);
    let scheme = fedmlh::algo::scheme_for(&cfg, Algo::FedMlh, &world.data.train);
    let backend = RustBackend::new();
    let rows = 4;
    let b = cfg.b();
    let logits: Vec<Vec<f32>> = (0..cfg.r())
        .map(|t| (0..rows * b).map(|i| ((t * 31 + i) as f32).sin()).collect())
        .collect();
    let full = scheme.scores(&logits, rows, &backend).unwrap();
    let mut zeroed = logits.clone();
    zeroed[1].iter_mut().for_each(|v| *v = 0.0);
    let partial = scheme.scores(&zeroed, rows, &backend).unwrap();
    assert_ne!(full, partial);
}

#[test]
fn seed_isolation_changes_everything_deterministically() {
    let cfg = quick_cfg(3);
    let mut o1 = opts(3);
    o1.seed = 1;
    let mut o2 = opts(3);
    o2.seed = 2;
    let a = harness::run_pair(&cfg, &o1).unwrap();
    let b = harness::run_pair(&cfg, &o1).unwrap();
    let c = harness::run_pair(&cfg, &o2).unwrap();
    assert_eq!(a.fedmlh.best.top1, b.fedmlh.best.top1, "same seed must repro");
    assert_ne!(
        (a.fedmlh.best.top1, a.fedavg.best.top1),
        (c.fedmlh.best.top1, c.fedavg.best.top1),
        "different seed must change results"
    );
}

#[test]
fn b_and_r_overrides_flow_through() {
    let mut cfg = quick_cfg(2);
    cfg.override_b = 8;
    cfg.override_r = 3;
    let out = harness::run_algo(&cfg, Algo::FedMlh, &RustBackend::new(), 5).unwrap();
    assert_eq!(out.n_models, 3);
    // each sub-model's last layer is hidden×8 (+ bias 8)
    let per_model = out.model_bytes / out.n_models;
    let expect = (cfg.preset.d * cfg.preset.hidden
        + cfg.preset.hidden
        + cfg.preset.hidden * cfg.preset.hidden
        + cfg.preset.hidden
        + cfg.preset.hidden * 8
        + 8)
        * 4;
    assert_eq!(per_model, expect);
}

#[test]
fn infrequent_accuracy_split_is_consistent() {
    let pair = harness::run_pair(&quick_cfg(6), &opts(6)).unwrap();
    for out in [&pair.fedavg, &pair.fedmlh] {
        for rec in &out.history.records {
            let a = rec.accuracy;
            // freq + infreq decompose the total at every k
            assert!((a.freq1 + a.infreq1 - a.top1).abs() < 1e-9);
            assert!((a.freq3 + a.infreq3 - a.top3).abs() < 1e-9);
            assert!((a.freq5 + a.infreq5 - a.top5).abs() < 1e-9);
            // all in [0, 1]
            for v in [a.top1, a.top3, a.top5, a.freq1, a.infreq1] {
                assert!((0.0..=1.0).contains(&v), "{a:?}");
            }
        }
    }
}

#[test]
fn iid_partition_control_runs() {
    // The iid partitioner must slot into the same server loop.
    let cfg = quick_cfg(3);
    let world_data = fedmlh::data::synth::generate_preset(&cfg.preset, cfg.seed);
    let part = fedmlh::partition::iid::partition(world_data.train.len(), cfg.clients, cfg.seed);
    assert!(part.covers(world_data.train.len()));
    let scheme = fedmlh::algo::scheme_for(&cfg, Algo::FedMlh, &world_data.train);
    let out = fedmlh::federated::server::run(
        &cfg,
        scheme.as_ref(),
        &RustBackend::new(),
        &world_data.train,
        &world_data.test,
        &part,
    )
    .unwrap();
    assert_eq!(out.rounds_run, 3);
}
