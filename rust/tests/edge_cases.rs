//! Edge cases and failure injection across the public API surface.

use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::data::dataset::{batch_ranges, Dataset};
use fedmlh::data::feature_hash::FeatureHasher;
use fedmlh::data::xc_format::parse_xc;
use fedmlh::eval::topk::top_k;
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::batcher::{ClientBatcher, Target};
use fedmlh::harness::{self, BackendKind, HarnessOpts};
use fedmlh::hashing::label_hash::LabelHasher;
use fedmlh::model::params::ModelParams;

#[test]
fn shard_smaller_than_batch_trains_zero_steps() {
    // A client whose shard is below the batch size contributes no full
    // batch — the server must survive (steps = 0, no NaNs).
    let ds = {
        let mut d = Dataset::new(4, 8);
        for i in 0..5 {
            d.push(&[i as f32; 4], &[i as u32 % 8]).unwrap();
        }
        d
    };
    let samples: Vec<usize> = (0..5).collect();
    let mut b = ClientBatcher::new(&ds, &samples, Target::Classes, 16, 1);
    let mut params = ModelParams::init(4, 4, 8, 1);
    let stats = RustBackend::new()
        .local_train(&mut params, &mut b, 3, 0.1)
        .unwrap();
    assert_eq!(stats.steps, 0);
    assert_eq!(stats.mean_loss, 0.0);
    use fedmlh::federated::backend::TrainBackend;
}

#[test]
fn single_client_single_round_degenerate_fl() {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.clients = 1;
    cfg.clients_per_round = 1;
    cfg.rounds = 1;
    cfg.local_epochs = 1;
    let opts = HarnessOpts {
        backend: BackendKind::Rust,
        rounds: Some(1),
        ..HarnessOpts::default()
    };
    let pair = harness::run_pair(&cfg, &opts).unwrap();
    assert_eq!(pair.fedavg.rounds_run, 1);
}

#[test]
fn empty_and_malformed_xc_inputs() {
    // header only, no samples
    let ds = parse_xc("0 5 7\n", 4, 1).unwrap();
    assert_eq!(ds.len(), 0);
    assert_eq!(ds.p(), 7);
    // malformed: non-numeric label
    assert!(parse_xc("1 5 7\nfoo 0:1.0\n", 4, 1).is_err());
    // malformed: feature index out of range is accepted via hashing
    // (raw features are hashed into d_out), but bad pairs are not
    assert!(parse_xc("1 5 7\n1 0-1.0\n", 4, 1).is_err());
}

#[test]
fn xc_roundtrip_format() {
    let text = "2 6 4\n0,2 1:0.5 3:1.5\n1 0:2.0\n";
    let ds = parse_xc(text, 8, 3).unwrap();
    assert_eq!(ds.len(), 2);
    assert_eq!(ds.labels_of(0), &[0, 2]);
    assert_eq!(ds.labels_of(1), &[1]);
    // feature hashing is deterministic given the seed
    let ds2 = parse_xc(text, 8, 3).unwrap();
    assert_eq!(ds.features_of(0), ds2.features_of(0));
}

#[test]
fn top_k_degenerate_inputs() {
    // k larger than the score vector
    assert_eq!(top_k(&[1.0, 2.0], 5).len(), 2);
    // all-equal scores: k distinct indices
    let got = top_k(&[7.0; 10], 3);
    assert_eq!(got.len(), 3);
    let mut sorted = got.clone();
    sorted.dedup();
    assert_eq!(sorted.len(), 3);
    // NaN-free negative scores
    assert_eq!(top_k(&[-3.0, -1.0, -2.0], 1), vec![1]);
}

#[test]
fn batch_ranges_cover_exactly() {
    for (n, b) in [(0usize, 4usize), (3, 4), (4, 4), (9, 4), (100, 7)] {
        let ranges = batch_ranges(n, b);
        let covered: usize = ranges.iter().map(|(s, e)| e - s).sum();
        assert_eq!(covered, n, "n={n} b={b}");
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0, "gap in ranges");
        }
    }
}

#[test]
fn feature_hasher_is_linear() {
    let h = FeatureHasher::new(5, 16);
    let a = vec![(1u32, 2.0f32), (100, -1.0)];
    let b = vec![(7u32, 3.0f32)];
    let mut ab: Vec<(u32, f32)> = a.clone();
    ab.extend(b.clone());
    let ha = h.hash(&a);
    let hb = h.hash(&b);
    let hab = h.hash(&ab);
    for i in 0..16 {
        assert!((hab[i] - ha[i] - hb[i]).abs() < 1e-6);
    }
}

#[test]
fn label_hasher_rejects_out_of_range_table() {
    let h = LabelHasher::new(1, 2, 10, 4);
    let result = std::panic::catch_unwind(|| h.bucket(5, 0));
    assert!(result.is_err(), "table index 5 of 2 must panic");
}

#[test]
fn config_rejects_fast_plus_b_override_semantics() {
    // --fast + B override keeps the Pallas tag (no fast sweep artifacts).
    let mut cfg = ExperimentConfig::preset("eurlex").unwrap();
    cfg.override_b = 500;
    let opts = HarnessOpts {
        fast: true,
        ..HarnessOpts::default()
    };
    let mut c = cfg.clone();
    opts.configure(&mut c);
    assert!(!c.fast_artifacts, "fast must be ignored under a B override");
    assert_eq!(c.artifact_tag(Algo::FedMlh), "eurlex.fedmlh_b500");
}

#[test]
fn zero_lr_fails_validation_and_negative_too() {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.lr = 0.0;
    assert!(cfg.validate().is_err());
    cfg.lr = -1.0;
    assert!(cfg.validate().is_err());
    cfg.lr = f32::NAN;
    assert!(cfg.validate().is_err(), "NaN lr must fail");
}

#[test]
fn dataset_rejects_inconsistent_rows() {
    let mut ds = Dataset::new(4, 10);
    assert!(ds.push(&[0.0; 3], &[1]).is_err(), "wrong feature width");
    assert!(ds.push(&[0.0; 4], &[10]).is_err(), "label out of range");
    assert!(ds.push(&[0.0; 4], &[9]).is_ok());
}
