//! Delta checkpoint delivery (acceptance pin): a chain of `.fmlh`
//! delta checkpoints applied onto its base must reproduce the full
//! checkpoint's **predictions bitwise** — the deployment half of the
//! paper's communication story (ship what changed, not the model).

use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::model::params::ModelParams;
use fedmlh::serve::{Checkpoint, CheckpointCodec, DeltaCodec, InferenceEngine};
use fedmlh::util::rng::Rng;

fn checkpoint(seed: u64) -> Checkpoint {
    let cfg = ExperimentConfig::preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let models: Vec<ModelParams> = (0..cfg.r())
        .map(|j| {
            let mut m =
                ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), seed + j as u64);
            for t in m.tensors.iter_mut() {
                for v in t.data_mut() {
                    *v += (rng.next_f32() - 0.5) * 0.1;
                }
            }
            m
        })
        .collect();
    Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap()
}

/// "Fine-tune" a checkpoint: drift a fraction of its coordinates.
fn drifted(ckpt: &Checkpoint, seed: u64, frac: f64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    let mut out = ckpt.clone();
    for m in out.models.iter_mut() {
        for t in m.tensors.iter_mut() {
            for v in t.data_mut() {
                if (rng.next_f32() as f64) < frac {
                    *v += (rng.next_f32() - 0.5) * 0.05;
                }
            }
        }
    }
    out
}

fn random_batch(d: usize, rows: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..rows * d)
        .map(|_| if rng.bernoulli(0.2) { rng.next_f32() } else { 0.0 })
        .collect()
}

#[test]
fn delta_chain_reproduces_full_checkpoint_predictions_bitwise() {
    let dir = std::env::temp_dir().join(format!("fedmlh_dckpt_{}", std::process::id()));
    let a = checkpoint(1);
    let b = drifted(&a, 2, 0.4);
    let c = drifted(&b, 3, 0.4);

    // Persist the base, the two deltas, and the full result.
    let base_path = dir.join("base.fmlh");
    let d_ab_path = dir.join("d_ab.fmlh");
    let d_bc_path = dir.join("d_bc.fmlh");
    let full_path = dir.join("full.fmlh");
    a.save(&base_path, CheckpointCodec::Dense).unwrap();
    b.delta_against(&a, DeltaCodec::Sparse).unwrap().save(&d_ab_path).unwrap();
    c.delta_against(&b, DeltaCodec::Sparse).unwrap().save(&d_bc_path).unwrap();
    c.save(&full_path, CheckpointCodec::Dense).unwrap();

    // The deltas are the cheap path: at ~40% drift a sparse delta ships
    // ~0.4 of the coordinates at ~5 bytes each (varint gap + exact f32)
    // against the full file's 4 bytes for every coordinate — each delta
    // must come in well under the full checkpoint it replaces.
    let full_bytes = std::fs::metadata(&full_path).unwrap().len();
    for path in [&d_ab_path, &d_bc_path] {
        let delta_bytes = std::fs::metadata(path).unwrap().len();
        assert!(
            4 * delta_bytes < 3 * full_bytes,
            "delta {} is {delta_bytes} bytes, not under 3/4 of the {full_bytes}-byte full file",
            path.display()
        );
    }

    // Chain-apply and compare predictions bitwise against the full file.
    let chained = Checkpoint::load_chain(&base_path, &[d_ab_path, d_bc_path]).unwrap();
    let full = Checkpoint::load(&full_path).unwrap();
    assert_eq!(chained, full, "chained checkpoint must equal the full one bitwise");

    let d = full.meta.d;
    let rows = 5;
    let x = random_batch(d, rows, 7);
    let engine_full = InferenceEngine::new(full).unwrap();
    let engine_chain = InferenceEngine::new(chained).unwrap();
    let s_full = engine_full.scores(&x, rows).unwrap();
    let s_chain = engine_chain.scores(&x, rows).unwrap();
    assert_eq!(s_full.len(), s_chain.len());
    for (i, (a, b)) in s_full.iter().zip(s_chain.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "score {i}: {a} vs {b}");
    }

    std::fs::remove_dir_all(&dir).unwrap();
}
