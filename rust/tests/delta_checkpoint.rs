//! Delta checkpoint delivery (acceptance pin): a chain of `.fmlh`
//! delta checkpoints applied onto its base must reproduce the full
//! checkpoint's **predictions bitwise** — the deployment half of the
//! paper's communication story (ship what changed, not the model).

use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::model::params::ModelParams;
use fedmlh::serve::{Checkpoint, CheckpointCodec, DeltaCheckpoint, DeltaCodec, InferenceEngine};
use fedmlh::util::rng::Rng;

fn checkpoint(seed: u64) -> Checkpoint {
    let cfg = ExperimentConfig::preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let models: Vec<ModelParams> = (0..cfg.r())
        .map(|j| {
            let mut m =
                ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), seed + j as u64);
            for t in m.tensors.iter_mut() {
                for v in t.data_mut() {
                    *v += (rng.next_f32() - 0.5) * 0.1;
                }
            }
            m
        })
        .collect();
    Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap()
}

/// "Fine-tune" a checkpoint: drift a fraction of its coordinates.
fn drifted(ckpt: &Checkpoint, seed: u64, frac: f64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    let mut out = ckpt.clone();
    for m in out.models.iter_mut() {
        for t in m.tensors.iter_mut() {
            for v in t.data_mut() {
                if (rng.next_f32() as f64) < frac {
                    *v += (rng.next_f32() - 0.5) * 0.05;
                }
            }
        }
    }
    out
}

fn random_batch(d: usize, rows: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..rows * d)
        .map(|_| if rng.bernoulli(0.2) { rng.next_f32() } else { 0.0 })
        .collect()
}

#[test]
fn delta_chain_reproduces_full_checkpoint_predictions_bitwise() {
    let dir = std::env::temp_dir().join(format!("fedmlh_dckpt_{}", std::process::id()));
    let a = checkpoint(1);
    let b = drifted(&a, 2, 0.4);
    let c = drifted(&b, 3, 0.4);

    // Persist the base, the two deltas, and the full result.
    let base_path = dir.join("base.fmlh");
    let d_ab_path = dir.join("d_ab.fmlh");
    let d_bc_path = dir.join("d_bc.fmlh");
    let full_path = dir.join("full.fmlh");
    a.save(&base_path, CheckpointCodec::Dense).unwrap();
    b.delta_against(&a, DeltaCodec::Sparse).unwrap().save(&d_ab_path).unwrap();
    c.delta_against(&b, DeltaCodec::Sparse).unwrap().save(&d_bc_path).unwrap();
    c.save(&full_path, CheckpointCodec::Dense).unwrap();

    // The deltas are the cheap path: at ~40% drift a sparse delta ships
    // ~0.4 of the coordinates at ~5 bytes each (varint gap + exact f32)
    // against the full file's 4 bytes for every coordinate — each delta
    // must come in well under the full checkpoint it replaces.
    let full_bytes = std::fs::metadata(&full_path).unwrap().len();
    for path in [&d_ab_path, &d_bc_path] {
        let delta_bytes = std::fs::metadata(path).unwrap().len();
        assert!(
            4 * delta_bytes < 3 * full_bytes,
            "delta {} is {delta_bytes} bytes, not under 3/4 of the {full_bytes}-byte full file",
            path.display()
        );
    }

    // Chain-apply and compare predictions bitwise against the full file.
    let chained = Checkpoint::load_chain(&base_path, &[d_ab_path, d_bc_path]).unwrap();
    let full = Checkpoint::load(&full_path).unwrap();
    assert_eq!(chained, full, "chained checkpoint must equal the full one bitwise");

    let d = full.meta.d;
    let rows = 5;
    let x = random_batch(d, rows, 7);
    let engine_full = InferenceEngine::new(full).unwrap();
    let engine_chain = InferenceEngine::new(chained).unwrap();
    let s_full = engine_full.scores(&x, rows).unwrap();
    let s_chain = engine_chain.scores(&x, rows).unwrap();
    assert_eq!(s_full.len(), s_chain.len());
    for (i, (a, b)) in s_full.iter().zip(s_chain.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "score {i}: {a} vs {b}");
    }

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Hostile files (the fault-tolerance satellite): a checkpoint loader
// that feeds `fedmlh serve` must answer truncation, bit rot, and
// oversized declared shapes with a descriptive `Err` naming the file —
// never a panic, and never an allocation sized by attacker bytes.

/// FNV-1a 64 — recomputed here so a test can forge a *valid* checksum
/// over tampered header bytes and prove the structural guards hold on
/// their own, not just downstream of the checksum.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[test]
fn corrupt_checkpoint_files_err_descriptively_and_never_panic() {
    let dir = std::env::temp_dir().join(format!("fedmlh_badckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let a = checkpoint(21);
    let b = drifted(&a, 22, 0.3);
    let full_path = dir.join("full.fmlh");
    let delta_path = dir.join("delta.fmlh");
    a.save(&full_path, CheckpointCodec::Dense).unwrap();
    b.delta_against(&a, DeltaCodec::Sparse).unwrap().save(&delta_path).unwrap();
    let full = std::fs::read(&full_path).unwrap();
    let delta = std::fs::read(&delta_path).unwrap();

    // Truncation at every layer of the layout — empty, mid-magic,
    // mid-header, mid-payload, one byte short — errs naming the file.
    for cut in [0, 3, 6, full.len() / 2, full.len() - 1] {
        let path = dir.join("trunc.fmlh");
        std::fs::write(&path, &full[..cut]).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("trunc.fmlh"), "cut {cut}: error must name the file: {err}");
    }

    // A single flipped payload bit is a checksum mismatch, not a parse.
    let mut flipped = full.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    let path = dir.join("flip.fmlh");
    std::fs::write(&path, &flipped).unwrap();
    let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
    assert!(
        err.contains("flip.fmlh") && err.contains("checksum"),
        "flipped byte must fail the checksum: {err}"
    );

    // Forge a header that declares d = 2^24 (the dimension cap, so the
    // range guard passes) *with a valid checksum*: the size guard must
    // reject it against the actual file length before the model
    // template is allocated. Offset 8 is `d` (after magic+version+
    // codec+algo).
    let mut huge = full.clone();
    let forged_d = (1u32 << 24).to_le_bytes();
    huge[8..12].copy_from_slice(&forged_d);
    let body_len = huge.len() - 8;
    let sum = fnv1a64(&huge[..body_len]);
    huge[body_len..].copy_from_slice(&sum.to_le_bytes());
    let path = dir.join("huge.fmlh");
    std::fs::write(&path, &huge).unwrap();
    let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
    assert!(
        err.contains("huge.fmlh") && err.contains("declares"),
        "oversized declared shape must hit the size guard: {err}"
    );

    // The two formats reject each other with a pointer to the right
    // loader, not a parse error deep inside the wrong layout.
    let err = format!("{:#}", Checkpoint::load(&delta_path).unwrap_err());
    assert!(err.contains("delta"), "full loader must identify a delta file: {err}");
    let err = format!("{:#}", DeltaCheckpoint::load(&full_path).unwrap_err());
    assert!(err.contains("full checkpoint"), "delta loader must identify a full file: {err}");

    // Delta files get the same treatment: truncations and bit flips.
    for cut in [0, 3, delta.len() / 2, delta.len() - 1] {
        let path = dir.join("trunc_delta.fmlh");
        std::fs::write(&path, &delta[..cut]).unwrap();
        let err = format!("{:#}", DeltaCheckpoint::load(&path).unwrap_err());
        assert!(err.contains("trunc_delta.fmlh"), "cut {cut}: {err}");
    }
    let mut flipped = delta.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x04;
    let path = dir.join("flip_delta.fmlh");
    std::fs::write(&path, &flipped).unwrap();
    let err = format!("{:#}", DeltaCheckpoint::load(&path).unwrap_err());
    assert!(
        err.contains("flip_delta.fmlh") && err.contains("checksum"),
        "flipped delta byte must fail the checksum: {err}"
    );

    std::fs::remove_dir_all(&dir).unwrap();
}
