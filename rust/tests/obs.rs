//! End-to-end guarantees of the observability layer (`fedmlh::obs`):
//! the Prometheus text exposition is format-clean, the Chrome-trace
//! JSON written by `--trace-out` parses and is well-formed, histogram
//! bucket boundaries follow the `v <= upper` convention, and — the
//! load-bearing one — enabling the tracer does not perturb a seeded
//! simulation by a single bit.

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig, ObsConfig};
use fedmlh::federated::sim::run_async;
use fedmlh::federated::{RunOutput, RustBackend};
use fedmlh::obs::metrics::MetricsRegistry;
use fedmlh::obs::trace;
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};
use fedmlh::util::json::Json;

// ------------------------------------------------ Prometheus lint

fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && !s.starts_with(|c: char| c.is_ascii_digit())
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Split a sample line into (metric name, le label if any, value).
fn parse_sample(line: &str) -> (String, Option<String>, f64) {
    let (name_labels, value) = line.rsplit_once(' ').expect("sample has a value");
    let (name, le) = match name_labels.split_once('{') {
        Some((name, rest)) => {
            let labels = rest.strip_suffix('}').expect("labels close");
            let le = labels.split(',').find_map(|kv| {
                kv.strip_prefix("le=\"")
                    .and_then(|v| v.strip_suffix('"'))
                    .map(|v| v.to_string())
            });
            (name.to_string(), le)
        }
        None => (name_labels.to_string(), None),
    };
    let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in '{line}'"));
    (name, le, v)
}

/// Lint a Prometheus text page: valid names, HELP/TYPE announced once
/// per family before its samples, counters named `*_total`, histogram
/// `le` buckets cumulative and capped by `+Inf` == `_count`.
fn lint_prometheus(text: &str) {
    use std::collections::HashMap;
    let mut kinds: HashMap<String, String> = HashMap::new();
    let mut helped: Vec<String> = Vec::new();
    // histogram family -> (le list in order, bucket counts, count sample)
    let mut hist: HashMap<String, (Vec<String>, Vec<f64>, Option<f64>)> = HashMap::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap();
            assert!(valid_metric_name(name), "bad family name in '{line}'");
            assert!(!helped.contains(&name.to_string()), "duplicate HELP for {name}");
            helped.push(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap();
            let kind = it.next().expect("TYPE has a kind");
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind),
                "unknown kind in '{line}'"
            );
            assert!(
                helped.contains(&name.to_string()),
                "TYPE before HELP for {name}"
            );
            assert!(
                kinds.insert(name.to_string(), kind.to_string()).is_none(),
                "duplicate TYPE for {name}"
            );
            continue;
        }
        assert!(!line.starts_with('#'), "unexpected comment '{line}'");
        let (name, le, value) = parse_sample(line);
        assert!(valid_metric_name(&name), "bad sample name in '{line}'");
        // Resolve the family the sample belongs to.
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                name.strip_suffix(suf)
                    .filter(|base| kinds.get(*base).map(String::as_str) == Some("histogram"))
            })
            .unwrap_or(&name)
            .to_string();
        let kind = kinds
            .get(&family)
            .unwrap_or_else(|| panic!("sample '{line}' precedes its TYPE"))
            .clone();
        if kind == "counter" {
            assert!(family.ends_with("_total"), "counter {family} must end _total");
            assert!(value >= 0.0, "counter went negative: '{line}'");
        }
        if kind == "histogram" {
            let entry = hist.entry(family).or_default();
            if name.ends_with("_bucket") {
                entry.0.push(le.expect("bucket sample has le"));
                entry.1.push(value);
            } else if name.ends_with("_count") {
                entry.2 = Some(value);
            }
        }
    }
    for (family, (les, counts, count)) in &hist {
        assert_eq!(les.last().map(String::as_str), Some("+Inf"), "{family} missing +Inf");
        for w in counts.windows(2) {
            assert!(w[0] <= w[1], "{family} buckets not cumulative: {counts:?}");
        }
        assert_eq!(
            counts.last().copied(),
            *count,
            "{family}: +Inf bucket must equal _count"
        );
    }
}

#[test]
fn prometheus_exposition_is_format_clean() {
    let reg = MetricsRegistry::new();
    reg.counter("fedmlh_test_events_total", "Test events.").add(3);
    reg.gauge("fedmlh_test_level", "Test level.").set(1.5);
    reg.counter_with("fedmlh_test_bytes_total", "Bytes by dir.", &[("dir", "down")])
        .add(100);
    reg.counter_with("fedmlh_test_bytes_total", "Bytes by dir.", &[("dir", "up")])
        .add(40);
    let h = reg.histogram("fedmlh_test_latency", "Latency.", &[0.1, 1.0, 10.0]);
    for v in [0.05, 0.5, 0.5, 5.0, 50.0] {
        h.observe(v);
    }
    let page = reg.render_prometheus();
    lint_prometheus(&page);
    assert!(page.contains("fedmlh_test_bytes_total{dir=\"down\"} 100"), "{page}");
    assert!(page.contains("fedmlh_test_latency_bucket{le=\"+Inf\"} 5"), "{page}");
    assert!(page.contains("fedmlh_test_latency_count 5"), "{page}");
}

#[test]
fn global_registry_renders_clean_after_a_run() {
    // A real run populates the global registry (rounds, comm bytes,
    // accuracy, …); whatever ended up in there must lint.
    let cfg = sim_cfg(100, 2, 2, 0.0);
    run(&cfg);
    let page = fedmlh::obs::metrics::global().render_prometheus();
    lint_prometheus(&page);
    assert!(page.contains("fedmlh_sim_aggregations_total"), "{page}");
}

// ------------------------------------------------ histogram buckets

#[test]
fn histogram_boundaries_are_inclusive_upper() {
    let reg = MetricsRegistry::new();
    let h = reg.histogram("fedmlh_test_bounds", "Bounds.", &[1.0, 2.0]);
    h.observe(1.0); // exactly on a boundary → counts in that bucket
    h.observe(2.0);
    h.observe(2.0000001); // just over → overflow bucket
    let buckets = h.buckets();
    assert_eq!(buckets[0], (1.0, 1));
    assert_eq!(buckets[1], (2.0, 2));
    assert_eq!(buckets[2].1, 3);
    assert!(buckets[2].0.is_infinite());
    assert_eq!(h.count(), 3);
}

// ------------------------------------------------ trace JSON

#[test]
fn trace_out_writes_valid_chrome_trace_json() {
    trace::install();
    {
        let _outer = trace::wall_span("obs test outer", 7)
            .map(|g| g.arg("k", Json::num(1.0)));
        let _inner = trace::wall_span("obs test inner", 7);
    }
    trace::sim_span("obs test sim", 3, 1.0, 2.5, vec![("client".to_string(), Json::num(9.0))]);
    trace::sim_instant("obs test mark", 0, 2.5, vec![]);

    let path = std::env::temp_dir().join(format!("fedmlh_obs_trace_{}.json", std::process::id()));
    let obs = ObsConfig::new(Some(path.clone()), "info").unwrap();
    obs.apply();
    obs.export().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let parsed = Json::parse(&text).unwrap();
    let events = parsed
        .get("traceEvents")
        .expect("traceEvents key")
        .as_arr()
        .unwrap();
    assert!(events.len() >= 6, "metadata + our 4 events, got {}", events.len());
    let mut prev_ts = f64::NEG_INFINITY;
    let mut names = Vec::new();
    for ev in events {
        let ph = ev.get("ph").expect("ph").as_str().unwrap();
        names.push(ev.get("name").expect("name").as_str().unwrap().to_string());
        if ph == "M" {
            continue; // metadata has no timestamp
        }
        let ts = ev.get("ts").expect("ts").as_f64().unwrap();
        assert!(ts >= prev_ts, "events sorted by ts: {ts} < {prev_ts}");
        prev_ts = ts;
        let pid = ev.get("pid").expect("pid").as_f64().unwrap();
        assert!(pid == trace::SIM_PID as f64 || pid == trace::WALL_PID as f64);
        match ph {
            "X" => assert!(ev.get("dur").expect("dur").as_f64().unwrap() >= 0.0),
            "i" => assert_eq!(ev.get("s").expect("scope").as_str().unwrap(), "t"),
            other => panic!("unexpected phase '{other}'"),
        }
    }
    for want in ["simulated", "wall-clock", "obs test outer", "obs test sim", "obs test mark"] {
        assert!(names.iter().any(|n| n == want), "missing event '{want}'");
    }
    // The simulated-clock span carries sim time in microseconds.
    let sim_ev = events
        .iter()
        .find(|e| e.get("name").and_then(|n| n.as_str().ok()) == Some("obs test sim"))
        .unwrap();
    assert_eq!(sim_ev.get("ts").unwrap().as_f64().unwrap(), 1.0e6);
    assert_eq!(sim_ev.get("dur").unwrap().as_f64().unwrap(), 1.5e6);
}

// ------------------------------------------------ determinism

fn sim_cfg(registry: usize, buffer: usize, rounds: usize, dropout: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = rounds;
    cfg.patience = 0;
    cfg.clients = 4;
    cfg.clients_per_round = 2;
    cfg.local_epochs = 1;
    cfg.sim.async_mode = true;
    cfg.sim.registry = registry;
    cfg.sim.buffer = buffer;
    cfg.sim.concurrency = 8;
    cfg.sim.dropout = dropout;
    cfg
}

fn run(cfg: &ExperimentConfig) -> RunOutput {
    let data = fedmlh::data::synth::generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(cfg, Algo::FedMlh, &data.train);
    let backend = RustBackend::new();
    run_async(cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap()
}

#[test]
fn tracing_does_not_change_the_simulation() {
    let cfg = sim_cfg(1000, 4, 3, 0.2);
    let baseline = run(&cfg);
    trace::install();
    assert!(trace::enabled());
    let traced = run(&cfg);
    assert_eq!(
        baseline.history.to_csv(),
        traced.history.to_csv(),
        "tracing must be purely observational"
    );
    assert_eq!(baseline.comm.total(), traced.comm.total());
    assert_eq!(baseline.sim, traced.sim);
    for (ga, gb) in baseline.final_globals.iter().zip(traced.final_globals.iter()) {
        for (x, y) in ga.flat_values().iter().zip(gb.flat_values().iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    // …and the traced run actually recorded simulated-clock spans.
    let tracer = trace::tracer().unwrap();
    assert!(!tracer.is_empty(), "traced run must record spans");
}

// ------------------------------------------------ config surface

#[test]
fn obs_config_rejects_unknown_level() {
    assert!(ObsConfig::new(None, "verbose").is_err());
    assert!(ObsConfig::new(None, "debug").is_ok());
    let d = ObsConfig::default();
    assert_eq!(d.log_level, "info");
    assert!(d.trace_out.is_none());
    d.export().unwrap(); // no trace path → no-op
}
