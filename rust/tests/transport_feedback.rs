//! The stateful transport pipeline's end-to-end contract:
//!
//! 1. with an aggressive sparse uplink (`topk`, frac = 0.05), turning
//!    error feedback **on** measurably improves best-round accuracy
//!    over the stateless pipeline — the whole point of carrying the
//!    un-shipped residual across rounds;
//! 2. `dense` on both links with feedback **off** reproduces the seed's
//!    byte accounting exactly (the closed-form Table 4 formula);
//! 3. both links are metered with actual vs dense-equivalent bytes and
//!    per-round down/up columns that decompose the cumulative meter;
//! 4. a compressed downlink (q8 broadcast + server residual folding)
//!    still learns.

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::data::synth::generate_preset;
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::comm::expected_round_bytes;
use fedmlh::federated::server::{self, RunOutput};
use fedmlh::federated::transport::DownCodec;
use fedmlh::federated::wire::CodecSpec;
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};

fn run(
    codec: CodecSpec,
    down_codec: DownCodec,
    error_feedback: bool,
    rounds: usize,
) -> RunOutput {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = rounds;
    cfg.patience = 0;
    cfg.clients = 6;
    cfg.clients_per_round = 3;
    cfg.local_epochs = 1;
    cfg.codec = codec;
    cfg.down_codec = down_codec;
    cfg.error_feedback = error_feedback;
    let data = generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
    let backend = RustBackend::new();
    server::run(
        &cfg,
        scheme.as_ref(),
        &backend,
        &data.train,
        &data.test,
        &part,
    )
    .unwrap()
}

/// Acceptance criterion: at frac = 0.05 the stateless pipeline throws
/// away 95% of every update's coordinates each round, forever; error
/// feedback accumulates them until they ship. Best-round accuracy must
/// reflect that (both runs are fully deterministic — same seed, same
/// data, same sampling — so this is a pinned comparison, not a flaky
/// statistical one).
#[test]
fn error_feedback_improves_aggressive_topk_accuracy() {
    let codec = CodecSpec::TopK { frac: 0.05 };
    let rounds = 12;
    let off = run(codec, DownCodec::Dense, false, rounds);
    let on = run(codec, DownCodec::Dense, true, rounds);
    assert!(
        on.best.mean_topk() > off.best.mean_topk(),
        "feedback must improve best-round accuracy: on {} vs off {}",
        on.best.mean_topk(),
        off.best.mean_topk()
    );
    // The trajectories genuinely diverge (round 1 is identical by
    // construction — the first compress of every slot has no residual).
    assert_ne!(
        on.final_globals, off.final_globals,
        "feedback must change the trained model"
    );
    // Feedback changes *what* is shipped, never *how much*: both runs
    // pay the identical topk wire bill.
    assert_eq!(on.comm.uploaded(), off.comm.uploaded());
    assert_eq!(on.comm.downloaded(), off.comm.downloaded());
}

/// Seed-accounting pin: dense both ways + feedback off is the PR 1 /
/// seed meter, byte for byte (closed-form cross-check).
#[test]
fn dense_no_feedback_reproduces_seed_byte_counts() {
    let rounds = 3;
    let out = run(CodecSpec::Dense, DownCodec::Dense, false, rounds);
    let per_round = expected_round_bytes(3, out.model_bytes / out.n_models, out.n_models);
    assert_eq!(out.comm.total(), per_round * rounds as u64);
    assert_eq!(out.comm.upload_compression(), 1.0);
    assert_eq!(out.comm.download_compression(), 1.0);
    assert_eq!(out.comm.uploaded(), out.comm.uploaded_dense_equiv());
    assert_eq!(out.comm.downloaded(), out.comm.downloaded_dense_equiv());
    // Per-round columns: S clients × R sub-models × model bytes, each way.
    let link = (3 * out.model_bytes) as u64;
    for rec in &out.history.records {
        assert_eq!(rec.down_bytes, link, "round {}", rec.round);
        assert_eq!(rec.up_bytes, link, "round {}", rec.round);
    }
}

/// Two-sided metering under asymmetric compression: sparse uplink,
/// quantized downlink, each link reporting its own ratio.
#[test]
fn per_link_accounting_under_asymmetric_compression() {
    let rounds = 3;
    let out = run(
        CodecSpec::TopK { frac: 0.1 },
        DownCodec::QuantI8,
        true,
        rounds,
    );
    // Uplink: topk ships 4 + 8k bytes per item vs 4n dense.
    assert!(out.comm.uploaded() < out.comm.uploaded_dense_equiv());
    assert!(
        out.comm.upload_compression() > 3.0,
        "topk 10% uplink ratio {}",
        out.comm.upload_compression()
    );
    // Downlink: q8 ships n + 4·n_tensors bytes per item vs 4n dense.
    assert!(out.comm.downloaded() < out.comm.downloaded_dense_equiv());
    assert!(
        out.comm.download_compression() > 3.5,
        "q8 downlink ratio {}",
        out.comm.download_compression()
    );
    // The per-round columns decompose the cumulative meter exactly.
    let mut cumulative = 0u64;
    for rec in &out.history.records {
        assert!(rec.down_bytes > 0 && rec.up_bytes > 0);
        assert!(rec.up_bytes < rec.down_bytes, "topk uplink beats q8 downlink");
        cumulative += rec.down_bytes + rec.up_bytes;
        assert_eq!(cumulative, out.comm.total_at_round(rec.round));
    }
}

/// A lossy broadcast with server-side residual folding must still
/// train: the clients see a quantized global, but the quantization
/// error is folded forward rather than compounding.
#[test]
fn q8_downlink_with_folding_still_learns() {
    let out = run(CodecSpec::Dense, DownCodec::QuantI8, true, 6);
    let first = out.history.records.first().unwrap().accuracy.top1;
    assert!(
        out.best.top1 >= first,
        "no improvement under q8 broadcast: {first} -> {}",
        out.best.top1
    );
    assert!(out.best.top1 > 0.02, "top1 {} not above chance", out.best.top1);
    for rec in &out.history.records {
        assert!(rec.accuracy.top1.is_finite());
        assert!(rec.mean_loss.is_finite());
    }
}
