//! Randomized property tests (in-tree `util::prop` driver) over the
//! system's algebraic invariants.

use std::collections::HashSet;
use std::sync::Arc;

use fedmlh::data::dataset::Dataset;
use fedmlh::eval::decode::sketch_decode;
use fedmlh::eval::topk::top_k;
use fedmlh::federated::aggregate::{aggregate, Weighting};
use fedmlh::hashing::count_sketch::{CountSketch, Estimator};
use fedmlh::hashing::label_hash::LabelHasher;
use fedmlh::model::params::ModelParams;
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};
use fedmlh::util::json::Json;
use fedmlh::util::prop::{check, Gen};

#[test]
fn aggregation_stays_in_convex_hull() {
    check("aggregate convex hull", 30, |g: &mut Gen| {
        let (d, h, out) = (g.usize_in(1, 6), g.usize_in(1, 6), g.usize_in(1, 6));
        let n = g.usize_in(2, 6);
        let models: Vec<ModelParams> = (0..n)
            .map(|i| {
                let mut m = ModelParams::zeros(d, h, out);
                for t in m.tensors.iter_mut() {
                    for v in t.data_mut() {
                        *v = g.f32_in(-3.0, 3.0) + i as f32;
                    }
                }
                m
            })
            .collect();
        let refs: Vec<(&ModelParams, usize)> =
            models.iter().map(|m| (m, g.usize_in(1, 100))).collect();
        for weighting in [Weighting::Uniform, Weighting::BySamples] {
            let avg = aggregate(&refs, weighting).unwrap();
            for (ti, t) in avg.tensors.iter().enumerate() {
                for (vi, &v) in t.data().iter().enumerate() {
                    let lo = models
                        .iter()
                        .map(|m| m.tensors[ti].data()[vi])
                        .fold(f32::INFINITY, f32::min);
                    let hi = models
                        .iter()
                        .map(|m| m.tensors[ti].data()[vi])
                        .fold(f32::NEG_INFINITY, f32::max);
                    assert!(
                        v >= lo - 1e-4 && v <= hi + 1e-4,
                        "avg {v} outside [{lo}, {hi}]"
                    );
                }
            }
        }
    });
}

#[test]
fn aggregation_of_identical_models_is_identity() {
    check("aggregate identity", 20, |g: &mut Gen| {
        let mut m = ModelParams::zeros(3, 4, 5);
        for t in m.tensors.iter_mut() {
            for v in t.data_mut() {
                *v = g.f32_in(-1.0, 1.0);
            }
        }
        let refs: Vec<(&ModelParams, usize)> = (0..4).map(|i| (&m, i + 1)).collect();
        let avg = aggregate(&refs, Weighting::BySamples).unwrap();
        assert!(avg.max_abs_diff(&m).unwrap() < 1e-5);
    });
}

#[test]
fn bucket_labels_equal_brute_force_union() {
    check("bucket label union", 30, |g: &mut Gen| {
        let p = g.usize_in(8, 200);
        let b = g.usize_in(2, 32);
        let r = g.usize_in(1, 5);
        let hasher = LabelHasher::new(g.rng().next_u64(), r, p, b);
        // random positive set
        let n_pos = g.usize_in(1, p.min(12));
        let positives: Vec<u32> = (0..n_pos).map(|_| g.usize_in(0, p - 1) as u32).collect();
        for table in 0..r {
            let mut got = vec![0.0f32; b];
            hasher.bucket_labels_table_into(table, &positives, &mut got);
            // brute force: bucket i is 1 iff some positive class hashes there
            for i in 0..b {
                let want = positives
                    .iter()
                    .any(|&c| hasher.bucket(table, c as usize) == i);
                assert_eq!(got[i] > 0.5, want, "table {table} bucket {i}");
            }
        }
    });
}

#[test]
fn count_sketch_is_unbiased_for_single_heavy_item() {
    check("count sketch recovery", 15, |g: &mut Gen| {
        let buckets = g.usize_in(16, 64);
        let k = g.usize_in(2, 5) | 1; // odd for a clean median
        let mut cs = CountSketch::new(g.rng().next_u64(), k, buckets);
        let heavy = g.usize_in(0, 999) as u64;
        let weight = g.f32_in(5.0, 50.0);
        cs.insert(heavy, weight);
        // light noise
        for _ in 0..buckets / 2 {
            cs.insert(g.usize_in(1000, 2000) as u64, g.f32_in(-0.5, 0.5));
        }
        let est = cs.retrieve(heavy, Estimator::Median);
        assert!(
            (est - weight).abs() < weight * 0.6 + 1.0,
            "heavy {weight} estimated {est}"
        );
    });
}

#[test]
fn sketch_decode_matches_manual_mean() {
    check("decode mean", 25, |g: &mut Gen| {
        let r = g.usize_in(1, 4);
        let rows = g.usize_in(1, 5);
        let b = g.usize_in(2, 10);
        let p = g.usize_in(2, 30);
        let logits = g.vec_f32(r * rows * b, -5.0, 5.0);
        let hasher = LabelHasher::new(g.rng().next_u64(), r, p, b);
        let idx = hasher.index_matrix_i32();
        let scores = sketch_decode(&logits, &idx, r, rows, b, p);
        assert_eq!(scores.len(), rows * p);
        for n in 0..rows {
            for j in 0..p {
                let mut want = 0.0f32;
                for t in 0..r {
                    let bucket = idx[t * p + j] as usize;
                    want += logits[t * rows * b + n * b + bucket];
                }
                want /= r as f32;
                let got = scores[n * p + j];
                assert!((got - want).abs() < 1e-5, "({n},{j}): {got} vs {want}");
            }
        }
    });
}

#[test]
fn top_k_matches_full_sort() {
    check("topk vs sort", 40, |g: &mut Gen| {
        let n = g.usize_in(1, 200);
        let k = g.usize_in(1, 8).min(n);
        let scores = g.vec_f32(n, -100.0, 100.0);
        let got = top_k(&scores, k);
        assert_eq!(got.len(), k.min(n));
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        // compare as score multisets (ties can reorder indices)
        let got_scores: Vec<f32> = got.iter().map(|&i| scores[i]).collect();
        let want_scores: Vec<f32> = order[..k].iter().map(|&i| scores[i]).collect();
        for (a, b) in got_scores.iter().zip(want_scores.iter()) {
            assert_eq!(a, b, "topk scores diverge from sorted prefix");
        }
        // indices must be distinct
        let set: HashSet<usize> = got.iter().copied().collect();
        assert_eq!(set.len(), got.len());
    });
}

#[test]
fn noniid_partition_invariants() {
    check("noniid partition", 8, |g: &mut Gen| {
        let p = g.usize_in(10, 40);
        let n = g.usize_in(60, 300);
        let clients = g.usize_in(2, 8);
        let mut ds = Dataset::new(4, p);
        for _ in 0..n {
            let x = g.vec_f32(4, -1.0, 1.0);
            let l1 = g.usize_in(0, p - 1) as u32;
            let l2 = g.usize_in(0, p - 1) as u32;
            let labels = if l1 == l2 { vec![l1] } else { vec![l1, l2] };
            ds.push(&x, &labels).unwrap();
        }
        let part = noniid(&ds, &NonIidOptions::new(clients), g.rng().next_u64());
        // 1. covers every sample
        assert!(part.covers(n));
        // 2. frequent classes have exactly one owner
        let mut seen = HashSet::new();
        for (c, _) in &part.class_owner {
            assert!(seen.insert(*c), "class {c} owned twice");
        }
        // 3. no client shard contains duplicates
        for shard in &part.clients {
            let set: HashSet<usize> = shard.iter().copied().collect();
            assert_eq!(set.len(), shard.len());
        }
    });
}

#[test]
fn json_roundtrips_harness_values() {
    check("json roundtrip", 20, |g: &mut Gen| {
        let vals: Vec<f64> = (0..g.usize_in(1, 8))
            .map(|_| (g.f64_in(-1e6, 1e6) * 1e3).round() / 1e3)
            .collect();
        let obj = Json::obj(vec![
            ("name", Json::str("run")),
            ("vals", Json::arr_f64(&vals)),
            ("n", Json::num(vals.len() as f64)),
        ]);
        let text = obj.to_string_pretty(2);
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.expect("n").unwrap().as_usize().unwrap(), vals.len());
        let arr = back.expect("vals").unwrap().as_arr().unwrap();
        for (a, b) in arr.iter().zip(vals.iter()) {
            assert!((a.as_f64().unwrap() - b).abs() < 1e-9);
        }
    });
}

#[test]
fn label_hasher_is_deterministic_across_processes() {
    // Fixed seed → fixed index matrix (the server/client broadcast
    // contract of Algorithm 2 line 3 relies on this).
    let a = LabelHasher::new(0xfed, 3, 100, 10).index_matrix_i32();
    let b = LabelHasher::new(0xfed, 3, 100, 10).index_matrix_i32();
    assert_eq!(a, b);
    // and every entry is a valid bucket
    assert!(a.iter().all(|&v| (0..10).contains(&v)));
}

#[test]
fn batcher_target_arc_is_shared_not_cloned() {
    // The hasher behind bucket targets is shared by Arc across R
    // sub-model batchers (memory invariant for large p).
    let hasher = Arc::new(LabelHasher::new(1, 4, 1000, 64));
    let t0 = fedmlh::federated::batcher::Target::Buckets {
        hasher: hasher.clone(),
        table: 0,
    };
    drop(t0);
    assert_eq!(Arc::strong_count(&hasher), 1);
}
