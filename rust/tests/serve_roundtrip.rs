//! The serving subsystem's end-to-end contract:
//!
//! 1. `train → save → load → predict` returns **bitwise** the same
//!    top-k (classes and scores) as the offline evaluation decode on
//!    the same inputs — for the lossless dense checkpoint codec;
//! 2. the q8 checkpoint is ≥ 3.5× smaller than dense `f32` and still
//!    predicts sane labels;
//! 3. corrupt / truncated / wrong-version checkpoint files are
//!    rejected loudly;
//! 4. `fedmlh serve`'s HTTP front end answers `POST /predict` over a
//!    real TCP socket with exactly the engine's top-k, plus working
//!    `/healthz`, `/metrics`, and error paths;
//! 5. `Connection: keep-alive` reuses one TCP connection across
//!    requests (opt-in; requests without the header keep the
//!    close-after-response framing).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::eval::topk::top_k;
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::server;
use fedmlh::harness;
use fedmlh::serve::{
    Checkpoint, CheckpointCodec, InferenceEngine, Predictor, ServeMetrics, ServeOpts, Server,
};
use fedmlh::util::json::Json;

/// Train a quick tiny run and package it with the shared world.
fn trained_checkpoint(algo: Algo) -> (ExperimentConfig, harness::World, Checkpoint) {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = 3;
    cfg.patience = 0;
    cfg.clients = 4;
    cfg.clients_per_round = 2;
    cfg.local_epochs = 1;
    let world = harness::build_world(&cfg);
    let scheme = scheme_for(&cfg, algo, &world.data.train);
    let backend = RustBackend::new();
    let out = server::run(
        &cfg,
        scheme.as_ref(),
        &backend,
        &world.data.train,
        &world.data.test,
        &world.partition,
    )
    .unwrap();
    let ckpt = Checkpoint::from_run(
        &cfg,
        algo,
        world.data.train.d(),
        world.data.train.p(),
        out.final_globals,
    )
    .unwrap();
    (cfg, world, ckpt)
}

/// The offline evaluation's score path for a batch of test samples:
/// backend predict per sub-model → scheme decode (identical code path
/// to `federated::server::evaluate`).
fn offline_scores(
    cfg: &ExperimentConfig,
    world: &harness::World,
    algo: Algo,
    models: &[fedmlh::model::ModelParams],
    idx: &[usize],
) -> Vec<f32> {
    let scheme = scheme_for(cfg, algo, &world.data.train);
    let backend = RustBackend::new();
    let (x, rows) = world.data.test.feature_batch(idx, idx.len());
    let logits: Vec<Vec<f32>> = models
        .iter()
        .map(|m| fedmlh::model::mlp::forward(m, &x, rows))
        .collect();
    scheme.scores(&logits, rows, &backend).unwrap()
}

fn temp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fedmlh_serve_{}_{name}", std::process::id()))
}

#[test]
fn dense_checkpoint_predicts_bitwise_like_offline_eval() {
    let (cfg, world, ckpt) = trained_checkpoint(Algo::FedMlh);
    let p = world.data.train.p();
    let idx: Vec<usize> = (0..8).collect();
    let want = offline_scores(&cfg, &world, Algo::FedMlh, &ckpt.models, &idx);

    let path = temp_path("dense.fmlh");
    ckpt.save(&path, CheckpointCodec::Dense).unwrap();
    let engine = InferenceEngine::new(Checkpoint::load(&path).unwrap()).unwrap();
    let (x, rows) = world.data.test.feature_batch(&idx, idx.len());
    let got = engine.scores(&x, rows).unwrap();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(want.iter()) {
        assert_eq!(g.to_bits(), w.to_bits(), "scores must be bitwise identical");
    }
    // ... and therefore so is every top-k selection.
    for row in 0..rows {
        let served = engine.predict_topk(&x[row * engine.d()..(row + 1) * engine.d()], 1, 5)
            .unwrap()
            .remove(0);
        let offline: Vec<usize> = top_k(&want[row * p..(row + 1) * p], 5);
        let served_classes: Vec<usize> = served.iter().map(|&(c, _)| c as usize).collect();
        assert_eq!(served_classes, offline, "row {row}");
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn fedavg_checkpoint_roundtrips_too() {
    let (cfg, world, ckpt) = trained_checkpoint(Algo::FedAvg);
    let idx: Vec<usize> = (0..4).collect();
    let want = offline_scores(&cfg, &world, Algo::FedAvg, &ckpt.models, &idx);
    let engine =
        InferenceEngine::new(Checkpoint::from_bytes(&ckpt.to_bytes(CheckpointCodec::Dense).unwrap()).unwrap())
            .unwrap();
    let (x, rows) = world.data.test.feature_batch(&idx, idx.len());
    let got = engine.scores(&x, rows).unwrap();
    for (g, w) in got.iter().zip(want.iter()) {
        assert_eq!(g.to_bits(), w.to_bits());
    }
}

#[test]
fn q8_checkpoint_is_much_smaller_and_still_predicts() {
    let (_, world, ckpt) = trained_checkpoint(Algo::FedMlh);
    let dense = ckpt.to_bytes(CheckpointCodec::Dense).unwrap();
    let q8 = ckpt.to_bytes(CheckpointCodec::QuantI8).unwrap();
    let ratio = dense.len() as f64 / q8.len() as f64;
    assert!(ratio >= 3.5, "q8 ratio {ratio:.2} below 3.5x ({} vs {})", q8.len(), dense.len());

    let engine = InferenceEngine::new(Checkpoint::from_bytes(&q8).unwrap()).unwrap();
    let (x, rows) = world.data.test.feature_batch(&[0, 1, 2], 3);
    let topk = engine.predict_topk(&x, rows, 5).unwrap();
    assert_eq!(topk.len(), 3);
    for row in &topk {
        assert_eq!(row.len(), 5);
        for &(c, s) in row {
            assert!((c as usize) < world.data.train.p());
            assert!(s.is_finite());
        }
    }
}

#[test]
fn damaged_checkpoints_are_rejected() {
    let (_, _, ckpt) = trained_checkpoint(Algo::FedMlh);
    let bytes = ckpt.to_bytes(CheckpointCodec::QuantI8).unwrap();

    // corrupt one parameter byte
    let mut corrupt = bytes.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x10;
    let err = Checkpoint::from_bytes(&corrupt).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");

    // truncation at any depth
    for cut in [3usize, 10, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            Checkpoint::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut} must fail"
        );
    }

    // future format version
    let mut future = bytes.clone();
    future[4] = 7;
    future[5] = 0;
    let err = Checkpoint::from_bytes(&future).unwrap_err();
    assert!(err.to_string().contains("version 7"), "{err}");

    // wrong magic
    let mut magic = bytes.clone();
    magic[0] = b'Z';
    let err = Checkpoint::from_bytes(&magic).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");

    // a file that is valid except for extra appended bytes
    let mut padded = bytes;
    padded.extend_from_slice(b"extra");
    assert!(Checkpoint::from_bytes(&padded).is_err());
}

#[test]
fn micro_batched_predictions_match_unbatched() {
    let (_, world, ckpt) = trained_checkpoint(Algo::FedMlh);
    let engine = InferenceEngine::new(ckpt.clone()).unwrap();
    let d = engine.d();
    let (x, _) = world.data.test.feature_batch(&(0..16).collect::<Vec<_>>(), 16);
    let expected: Vec<Vec<(u32, f32)>> = (0..16)
        .map(|row| engine.predict_topk(&x[row * d..(row + 1) * d], 1, 3).unwrap().remove(0))
        .collect();

    let predictor = Arc::new(Predictor::new(
        Arc::new(InferenceEngine::new(ckpt).unwrap()),
        2,
        8,
        Arc::new(ServeMetrics::new()),
    ));
    let mut threads = Vec::new();
    for row in 0..16usize {
        let predictor = predictor.clone();
        let input = x[row * d..(row + 1) * d].to_vec();
        threads.push(std::thread::spawn(move || {
            (row, predictor.predict(input, 3).unwrap())
        }));
    }
    for t in threads {
        let (row, got) = t.join().unwrap();
        assert_eq!(got, expected[row], "row {row}");
    }
}

// ---------------------------------------------------------------- HTTP

/// Minimal HTTP/1.1 client: send one request, read the full response.
fn http_request(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let request = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(request.as_bytes()).unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let body_start = response.find("\r\n\r\n").expect("header terminator") + 4;
    (status, response[body_start..].to_string())
}

/// Read exactly one HTTP response from an open connection: headers,
/// then exactly `Content-Length` body bytes — no EOF framing, so the
/// connection stays usable afterwards. Bytes of a *following* response
/// that arrive in the same read (pipelined replies) land in `carry`
/// and seed the next call. Returns (status, the `Connection` header
/// value, body).
fn read_one_response(conn: &mut TcpStream, carry: &mut Vec<u8>) -> (u16, String, String) {
    let mut buf = std::mem::take(carry);
    let mut chunk = [0u8; 1024];
    let terminator: &[u8] = b"\r\n\r\n";
    let header_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == terminator) {
            break pos;
        }
        let n = conn.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed mid-response");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..header_end].to_vec()).unwrap();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let mut content_length = 0usize;
    let mut connection = String::new();
    for line in head.split("\r\n").skip(1) {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap();
            } else if name.trim().eq_ignore_ascii_case("connection") {
                connection = value.trim().to_string();
            }
        }
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = conn.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    *carry = body.split_off(content_length);
    (status, connection, String::from_utf8(body).unwrap())
}

#[test]
fn http_keep_alive_reuses_one_connection() {
    let (_, world, ckpt) = trained_checkpoint(Algo::FedMlh);
    let server = Server::bind(ckpt, &ServeOpts {
        host: "127.0.0.1".to_string(),
        port: 0,
        workers: 1,
        max_batch: 4,
        ..ServeOpts::default()
    })
    .unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut carry = Vec::new();

    // Several requests over the same connection, mixing endpoints.
    let x = world.data.test.features_of(0);
    let dense_json: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    let predict = format!("{{\"dense\": [{}], \"k\": 3}}", dense_json.join(","));
    for i in 0..3 {
        conn.write_all(
            b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n",
        )
        .unwrap();
        let (status, connection, body) = read_one_response(&mut conn, &mut carry);
        assert_eq!(status, 200, "request {i}: {body}");
        assert_eq!(connection, "keep-alive", "request {i}");
        assert!(body.contains("\"ok\""), "request {i}: {body}");

        let request = format!(
            "POST /predict HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{predict}",
            predict.len()
        );
        conn.write_all(request.as_bytes()).unwrap();
        let (status, connection, body) = read_one_response(&mut conn, &mut carry);
        assert_eq!(status, 200, "predict {i}: {body}");
        assert_eq!(connection, "keep-alive");
        let parsed = Json::parse(&body).unwrap();
        assert_eq!(parsed.expect("topk").unwrap().as_arr().unwrap().len(), 3);
    }

    // Two requests written back-to-back in one segment (legal HTTP/1.1
    // pipelining): bytes over-read past the first request must seed the
    // second request's parse, not be dropped.
    conn.write_all(
        b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n\
          GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n",
    )
    .unwrap();
    for i in 0..2 {
        let (status, connection, body) = read_one_response(&mut conn, &mut carry);
        assert_eq!(status, 200, "pipelined {i}: {body}");
        assert_eq!(connection, "keep-alive", "pipelined {i}");
    }

    // A request *without* the header keeps the historical behavior:
    // answered on the same connection, then the server closes it.
    conn.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let (status, connection, _) = read_one_response(&mut conn, &mut carry);
    assert_eq!(status, 200);
    assert_eq!(connection, "close");
    let mut rest = Vec::new();
    conn.read_to_end(&mut rest).unwrap();
    assert!(
        carry.is_empty() && rest.is_empty(),
        "server must close after a non-keep-alive request without extra bytes"
    );

    handle.stop();
    server_thread.join().unwrap();
}

#[test]
fn oversized_request_bodies_get_413_without_reading_the_body() {
    let (_, _, ckpt) = trained_checkpoint(Algo::FedMlh);
    let server = Server::bind(ckpt, &ServeOpts {
        host: "127.0.0.1".to_string(),
        port: 0,
        workers: 1,
        max_batch: 4,
        max_body_bytes: 64,
        ..ServeOpts::default()
    })
    .unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    // Headers only — the megabyte the header promises is never sent,
    // yet the refusal arrives: the server answers on the declared
    // length alone and closes so the unread bytes can't be misparsed.
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(
        b"POST /predict HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: 1048576\r\n\r\n",
    )
    .unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    assert!(response.starts_with("HTTP/1.1 413"), "{response}");
    assert!(response.contains("Connection: close"), "{response}");
    assert!(response.contains("max-body-bytes"), "{response}");

    // An in-cap request on a fresh connection is served normally.
    let small = "{\"sparse\": [[3, 1.5]], \"k\": 3}";
    let (status, body) = http_request(addr, "POST", "/predict", small);
    assert_eq!(status, 200, "{body}");

    handle.stop();
    server_thread.join().unwrap();
}

#[test]
fn http_server_smoke_test_over_a_real_socket() {
    let (_, world, ckpt) = trained_checkpoint(Algo::FedMlh);
    let engine = InferenceEngine::new(ckpt.clone()).unwrap();
    let opts = ServeOpts {
        host: "127.0.0.1".to_string(),
        port: 0, // ephemeral
        workers: 2,
        max_batch: 8,
        ..ServeOpts::default()
    };
    let server = Server::bind(ckpt, &opts).unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    // healthz reports the checkpoint identity
    let (status, body) = http_request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "{body}");
    let health = Json::parse(&body).unwrap();
    assert_eq!(health.expect("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(health.expect("algo").unwrap().as_str().unwrap(), "fedmlh");
    assert_eq!(health.expect("models").unwrap().as_usize().unwrap(), 2);

    // predict with dense features: bitwise the engine's answer
    let x = world.data.test.features_of(0);
    let dense_json: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    let request = format!("{{\"dense\": [{}], \"k\": 5}}", dense_json.join(","));
    let (status, body) = http_request(addr, "POST", "/predict", &request);
    assert_eq!(status, 200, "{body}");
    let parsed = Json::parse(&body).unwrap();
    let want = engine.predict_topk(x, 1, 5).unwrap().remove(0);
    let got = parsed.expect("topk").unwrap().as_arr().unwrap();
    assert_eq!(got.len(), want.len());
    for (j, &(class, score)) in got.iter().zip(want.iter()) {
        assert_eq!(j.expect("class").unwrap().as_usize().unwrap(), class as usize);
        let served = j.expect("score").unwrap().as_f64().unwrap() as f32;
        assert_eq!(served.to_bits(), score.to_bits(), "score bitwise");
    }

    // predict with a raw sparse input (feature-hashed server-side)
    let (status, body) = http_request(
        addr,
        "POST",
        "/predict",
        "{\"sparse\": [[3, 1.5], [700, -0.25]], \"k\": 3}",
    );
    assert_eq!(status, 200, "{body}");
    let sparse_topk = Json::parse(&body).unwrap();
    assert_eq!(sparse_topk.expect("topk").unwrap().as_arr().unwrap().len(), 3);
    let hashed = engine.hash_features(&[(3, 1.5), (700, -0.25)]);
    let want_sparse = engine.predict_topk(&hashed, 1, 3).unwrap().remove(0);
    let got_sparse = sparse_topk.expect("topk").unwrap().as_arr().unwrap();
    for (j, &(class, _)) in got_sparse.iter().zip(want_sparse.iter()) {
        assert_eq!(j.expect("class").unwrap().as_usize().unwrap(), class as usize);
    }

    // error paths: bad body, wrong dimension, wrong method, unknown path
    let (status, body) = http_request(addr, "POST", "/predict", "not json");
    assert_eq!(status, 400, "{body}");
    let (status, body) = http_request(addr, "POST", "/predict", "{\"dense\": [1.0]}");
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("expects d"), "{body}");
    let (status, _) = http_request(addr, "GET", "/predict", "");
    assert_eq!(status, 405);
    let (status, _) = http_request(addr, "GET", "/nope", "");
    assert_eq!(status, 404);

    // metrics counted the predict requests (2 ok + 2 bad)
    let (status, body) = http_request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let metrics = Json::parse(&body).unwrap();
    assert_eq!(metrics.expect("requests").unwrap().as_usize().unwrap(), 4);
    assert_eq!(metrics.expect("errors").unwrap().as_usize().unwrap(), 2);
    assert!(metrics.expect("batches").unwrap().as_usize().unwrap() >= 2);

    // Prometheus exposition is opt-in via ?format=prometheus: the same
    // counters in text format with the exposition content type (the
    // plain GET above pins the historical JSON contract).
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(
        b"GET /metrics?format=prometheus HTTP/1.1\r\nHost: test\r\nContent-Length: 0\r\n\r\n",
    )
    .unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    assert!(response.starts_with("HTTP/1.1 200"), "{response}");
    assert!(
        response.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8"),
        "{response}"
    );
    let prom = &response[response.find("\r\n\r\n").unwrap() + 4..];
    assert!(prom.contains("# TYPE fedmlh_serve_requests_total counter"), "{prom}");
    assert!(prom.contains("fedmlh_serve_requests_total 4"), "{prom}");
    assert!(prom.contains("fedmlh_serve_errors_total 2"), "{prom}");
    assert!(prom.contains("# TYPE fedmlh_serve_batch_size histogram"), "{prom}");
    assert!(prom.contains("fedmlh_serve_batch_size_bucket{le=\"+Inf\"}"), "{prom}");

    handle.stop();
    server_thread.join().unwrap();
}
