//! Fault-tolerance acceptance pins, end to end:
//!
//! - an injected run (5% corrupt + 2% NaN) with `--robust-agg` never
//!   panics and its accuracy stays within a bounded margin of the clean
//!   run on the same seed;
//! - injected runs are bitwise reproducible for a fixed seed, including
//!   across `--workers` and in the async simulator;
//! - kill-and-resume (`--snapshot-every` + `--resume`) reproduces the
//!   uninterrupted trajectory bitwise — model weights, deterministic
//!   history columns, and the communication meter — even through a
//!   stateful error-feedback transport and active fault injection;
//! - NaN-poisoned client updates leave the global model finite and the
//!   run converging.
//!
//! Wall-clock history columns (`round_seconds`, `train/encode/aggregate
//! _seconds`) are excluded from bitwise comparisons of *sync* runs —
//! they measure the host, not the experiment. CI's kill-and-resume step
//! makes the same cut (`cut -d, -f1-13,15,19`).

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig, InjectConfig, RobustAgg};
use fedmlh::data::synth::generate_preset;
use fedmlh::federated::history::History;
use fedmlh::federated::server;
use fedmlh::federated::wire::CodecSpec;
use fedmlh::federated::{run_async, RunOutput, RustBackend};
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};

fn base_cfg(rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = rounds;
    cfg.patience = 0;
    cfg.clients = 4;
    cfg.clients_per_round = 2;
    cfg.local_epochs = 1;
    cfg
}

fn run(cfg: &ExperimentConfig) -> RunOutput {
    cfg.validate().unwrap();
    let data = generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(cfg, Algo::FedMlh, &data.train);
    let backend = RustBackend::new();
    if cfg.sim.async_mode {
        run_async(cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap()
    } else {
        server::run(cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap()
    }
}

/// The history CSV with the wall-clock columns removed: keeps
/// round..up_bytes (1-13), mean_loss (15) and sim_seconds (19).
fn deterministic_csv(history: &History) -> String {
    history
        .to_csv()
        .lines()
        .map(|line| {
            let f: Vec<&str> = line.split(',').collect();
            assert_eq!(f.len(), 19, "history CSV has 19 columns: {line}");
            let mut keep: Vec<&str> = f[..13].to_vec();
            keep.push(f[14]);
            keep.push(f[18]);
            keep.join(",")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

fn assert_globals_bitwise_equal(a: &RunOutput, b: &RunOutput, tag: &str) {
    assert_eq!(a.final_globals.len(), b.final_globals.len(), "{tag}: sub-model count");
    for (j, (ga, gb)) in a.final_globals.iter().zip(b.final_globals.iter()).enumerate() {
        let (va, vb) = (ga.flat_values(), gb.flat_values());
        assert_eq!(va.len(), vb.len(), "{tag}: sub-model {j} size");
        for (i, (x, y)) in va.iter().zip(vb.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: sub-model {j} weight {i}");
        }
    }
}

fn assert_all_finite(out: &RunOutput, tag: &str) {
    for (j, g) in out.final_globals.iter().enumerate() {
        for t in &g.tensors {
            for &v in t.data() {
                assert!(v.is_finite(), "{tag}: sub-model {j} holds non-finite weight {v}");
            }
        }
    }
    for rec in &out.history.records {
        assert!(
            rec.accuracy.top1.is_finite() && (0.0..=1.0).contains(&rec.accuracy.top1),
            "{tag}: round {} top1 {}",
            rec.round,
            rec.accuracy.top1
        );
        assert!(rec.mean_loss.is_finite(), "{tag}: round {} loss", rec.round);
    }
}

// ---------------------------------------------------------------------
// Pin (a): payload faults under --robust-agg cost bounded accuracy.

#[test]
fn injected_run_survives_with_bounded_accuracy_loss() {
    let clean = run(&base_cfg(6));

    let mut cfg = base_cfg(6);
    cfg.inject = InjectConfig::parse("corrupt:0.05,nan:0.02").unwrap();
    cfg.robust = RobustAgg::parse("norm-clip:10").unwrap();
    let faulty = run(&cfg);

    assert_eq!(faulty.rounds_run, 6, "injection must not shorten the run");
    assert_all_finite(&faulty, "faulty");
    // The run still learns, and lands within a bounded margin of the
    // clean trajectory: corrupt updates are discarded (the survivors
    // carry the round), NaN updates are screened.
    let first = faulty.history.records.first().unwrap().accuracy.top1;
    assert!(faulty.best.top1 > first, "no improvement: {first} -> {}", faulty.best.top1);
    assert!(
        faulty.best.top1 + 0.2 >= clean.best.top1,
        "faulty best {} too far below clean best {}",
        faulty.best.top1,
        clean.best.top1
    );
}

// ---------------------------------------------------------------------
// Pin (b): injected runs are bitwise reproducible — same seed, any
// worker count, sync and async.

#[test]
fn injected_sync_runs_are_bitwise_reproducible_across_workers() {
    let mut cfg = base_cfg(4);
    cfg.inject = InjectConfig::parse("corrupt:0.1,truncate:0.05,nan:0.05,fail:0.2").unwrap();
    cfg.robust = RobustAgg::parse("norm-clip:10").unwrap();

    let a = run(&cfg);
    let b = run(&cfg);
    assert_globals_bitwise_equal(&a, &b, "rerun");
    assert_eq!(deterministic_csv(&a.history), deterministic_csv(&b.history), "rerun CSV");
    assert_eq!(a.comm.total(), b.comm.total(), "rerun comm");

    // Fault fates key on (round, client, sub-model), never on worker
    // scheduling — a different engine width must not move a single bit.
    let mut wide = cfg.clone();
    wide.workers = 4;
    let c = run(&wide);
    assert_globals_bitwise_equal(&a, &c, "workers 1 vs 4");
    assert_eq!(deterministic_csv(&a.history), deterministic_csv(&c.history), "workers CSV");
    assert_eq!(a.comm.total(), c.comm.total(), "workers comm");
}

#[test]
fn injected_async_runs_are_bitwise_reproducible() {
    let mut cfg = base_cfg(3);
    cfg.sim.async_mode = true;
    cfg.sim.registry = 1000;
    cfg.sim.buffer = 4;
    cfg.sim.concurrency = 8;
    cfg.sim.dropout = 0.1;
    cfg.inject = InjectConfig::parse("corrupt:0.1,nan:0.05,fail:0.8").unwrap();
    cfg.robust = RobustAgg::parse("norm-clip:10").unwrap();

    let a = run(&cfg);
    let b = run(&cfg);
    // The async clock is simulated, so the whole CSV is deterministic.
    assert_eq!(a.history.to_csv(), b.history.to_csv(), "async CSV");
    assert_eq!(a.sim, b.sim, "async sim stats");
    assert_globals_bitwise_equal(&a, &b, "async rerun");
    assert_all_finite(&a, "async");

    // At fail:0.8 a dispatch survives all four attempts with p ≈ 0.41,
    // so the retry-then-give-up path must actually fire…
    let s = a.sim.expect("async run reports sim stats");
    assert!(s.failed > 0, "fail:0.8 over {} dispatches lost none", s.dispatched);
    // …and losses never deadlock the round loop.
    assert_eq!(s.aggregations, 3);
}

// ---------------------------------------------------------------------
// Pin (c): kill-and-resume is bitwise equal to never having stopped.

#[test]
fn kill_and_resume_reproduces_the_uninterrupted_trajectory() {
    let dir = std::env::temp_dir().join(format!("fedmlh_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // A deliberately stateful setup: error-feedback residuals on the
    // uplink plus active fault injection, so the snapshot must carry
    // transport state and the fault fates must stay round-aligned.
    let mut full = base_cfg(6);
    full.codec = CodecSpec::QuantI8;
    full.error_feedback = true;
    full.inject = InjectConfig::parse("corrupt:0.05,nan:0.02,fail:0.1").unwrap();
    full.robust = RobustAgg::parse("norm-clip:10").unwrap();
    let uninterrupted = run(&full);

    // First leg: 3 rounds, snapshot written at the cut point…
    let mut first = full.clone();
    first.rounds = 3;
    first.snapshot_every = 3;
    first.snapshot_dir = Some(dir.clone());
    let leg = run(&first);
    assert_eq!(leg.rounds_run, 3);
    assert!(dir.join("state.fmls").is_file(), "snapshot file must exist");

    // …second leg: the same config asked for 6 rounds resumes at 3.
    let mut second = full.clone();
    second.rounds = 6;
    second.snapshot_every = 3;
    second.snapshot_dir = Some(dir.clone());
    let resumed = run(&second);

    assert_eq!(resumed.rounds_run, 6);
    assert_eq!(resumed.history.records.len(), uninterrupted.history.records.len());
    assert_globals_bitwise_equal(&uninterrupted, &resumed, "resume");
    assert_eq!(
        deterministic_csv(&uninterrupted.history),
        deterministic_csv(&resumed.history),
        "resume CSV"
    );
    assert_eq!(uninterrupted.comm.total(), resumed.comm.total(), "resume comm");
    assert_eq!(uninterrupted.comm.uploaded(), resumed.comm.uploaded(), "resume uplink");

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Pin (d): NaN-poisoned updates cannot poison the global model.

#[test]
fn nan_updates_leave_the_global_model_finite_and_learning() {
    // nan:0.25 poisons roughly every fourth (client, sub-model) payload
    // — including entire rounds where both sampled clients are hit, in
    // which case robust aggregation keeps the previous global verbatim.
    let mut cfg = base_cfg(6);
    cfg.inject = InjectConfig::parse("nan:0.25").unwrap();
    cfg.robust = RobustAgg::parse("norm-clip:10").unwrap();
    let out = run(&cfg);

    assert_eq!(out.rounds_run, 6);
    assert_all_finite(&out, "nan-screened");
    let first = out.history.records.first().unwrap().accuracy.top1;
    assert!(
        out.best.top1 > first,
        "screened run must still learn: {first} -> {}",
        out.best.top1
    );
}
