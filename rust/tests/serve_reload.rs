//! Serving control-plane contract, over real TCP sockets:
//!
//! 1. **Zero-downtime hot swap** — a background hammer on `/predict`
//!    sees only 200s while the model is swapped twice under it (full
//!    checkpoint, then a delta chain), and every answer is bitwise one
//!    of the versions' offline top-k (no torn model, no blend).
//! 2. **Delta-chain reloads** — `POST /reload` with `base + [d1, d2]`
//!    reconstructs the chain's head bitwise; wrong-base and
//!    out-of-order chains answer a clean 400 and the previous model
//!    keeps serving (no partial swap).
//! 3. **Canary rollout** — `?canary=<pct>` routes a deterministic
//!    share of traffic to the new version; a version rigged to error
//!    (NaN weights) is auto-rolled-back on the first failed canary
//!    request, a healthy one is auto-promoted after its window.
//! 4. **Health and drain** — `/healthz` reports generation, checksum,
//!    replica health, and `ready`; `POST /quitquitquit` stops the
//!    accept loop and `Server::run` drains and returns.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use fedmlh::config::{Algo, CanaryConfig, ExperimentConfig};
use fedmlh::model::params::ModelParams;
use fedmlh::serve::{
    Checkpoint, CheckpointCodec, DeltaCodec, InferenceEngine, ServeOpts, Server,
};
use fedmlh::util::json::Json;

/// Untrained tiny checkpoint; different seeds give different weights
/// (and therefore distinguishable predictions) with identical metadata,
/// which is what delta chains require.
fn tiny_checkpoint(seed: u64) -> Checkpoint {
    let cfg = ExperimentConfig::preset("tiny").unwrap();
    let models: Vec<ModelParams> = (0..cfg.r())
        .map(|j| ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), seed + j as u64))
        .collect();
    Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap()
}

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fedmlh_reload_{}_{name}", std::process::id()))
}

fn serve_opts() -> ServeOpts {
    ServeOpts {
        host: "127.0.0.1".to_string(),
        port: 0,
        replicas: 2,
        workers: 1,
        max_batch: 4,
        // The latency guard compares micro-latencies of one tiny model
        // against itself — pure scheduler noise in CI. Error-based
        // verdicts are what these tests pin.
        canary: CanaryConfig {
            p99_ratio: 0.0,
            ..CanaryConfig::default()
        },
        ..ServeOpts::default()
    }
}

/// Minimal HTTP/1.1 client: one request per connection, EOF-framed.
fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let request = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(request.as_bytes()).unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let body_start = response.find("\r\n\r\n").expect("header terminator") + 4;
    (status, response[body_start..].to_string())
}

const SPARSE_PREDICT: &str = "{\"sparse\": [[3, 1.5], [700, -0.25]], \"k\": 3}";

/// The offline answer for [`SPARSE_PREDICT`] under one checkpoint:
/// `(class, score bits)` pairs — the bitwise identity served answers
/// are matched against.
fn offline_topk(ckpt: Checkpoint) -> Vec<(usize, u32)> {
    let engine = InferenceEngine::new(ckpt).unwrap();
    let x = engine.hash_features(&[(3, 1.5), (700, -0.25)]);
    engine
        .predict_topk(&x, 1, 3)
        .unwrap()
        .remove(0)
        .into_iter()
        .map(|(c, s)| (c as usize, s.to_bits()))
        .collect()
}

/// Parse a served predict body into the same `(class, score bits)`
/// shape as [`offline_topk`].
fn served_topk(body: &str) -> Vec<(usize, u32)> {
    let parsed = Json::parse(body).unwrap();
    parsed
        .expect("topk")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| {
            let class = j.expect("class").unwrap().as_usize().unwrap();
            let score = j.expect("score").unwrap().as_f64().unwrap() as f32;
            (class, score.to_bits())
        })
        .collect()
}

fn reload_body(base: &Path, deltas: &[&Path]) -> String {
    let mut fields = vec![("checkpoint", Json::str(base.display().to_string()))];
    let arr: Vec<Json> = deltas
        .iter()
        .map(|p| Json::str(p.display().to_string()))
        .collect();
    if !arr.is_empty() {
        fields.push(("deltas", Json::Arr(arr)));
    }
    Json::obj(fields).to_string_pretty(0)
}

fn metrics_reload_count(addr: SocketAddr, key: &str) -> usize {
    let (status, body) = http_request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200, "{body}");
    Json::parse(&body)
        .unwrap()
        .expect("reloads")
        .unwrap()
        .expect(key)
        .unwrap()
        .as_usize()
        .unwrap()
}

fn healthz_generation(addr: SocketAddr) -> usize {
    let (status, body) = http_request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "{body}");
    Json::parse(&body)
        .unwrap()
        .expect("generation")
        .unwrap()
        .as_usize()
        .unwrap()
}

#[test]
fn hot_swap_under_hammer_drops_nothing_and_never_tears() {
    // Three model versions: m1 (startup), m2 (full-checkpoint reload),
    // m3 (delta-chain reload: m1 + d12 + d23).
    let m1 = tiny_checkpoint(100);
    let m2 = tiny_checkpoint(200);
    let m3 = tiny_checkpoint(300);
    let base_path = temp_path("hammer_base.fmlh");
    let m2_path = temp_path("hammer_m2.fmlh");
    let d12_path = temp_path("hammer_d12.fmld");
    let d23_path = temp_path("hammer_d23.fmld");
    m1.save(&base_path, CheckpointCodec::Dense).unwrap();
    m2.save(&m2_path, CheckpointCodec::Dense).unwrap();
    m2.delta_against(&m1, DeltaCodec::Sparse)
        .unwrap()
        .save(&d12_path)
        .unwrap();
    m3.delta_against(&m2, DeltaCodec::Sparse)
        .unwrap()
        .save(&d23_path)
        .unwrap();

    // Every legal answer, bitwise: any served top-k must be exactly
    // one version's offline decode — never a mixture.
    let legal: Vec<Vec<(usize, u32)>> = vec![
        offline_topk(m1.clone()),
        offline_topk(m2),
        offline_topk(m3),
    ];
    assert_ne!(legal[0], legal[1], "seeds must give distinct models");
    assert_ne!(legal[1], legal[2]);

    let server = Server::bind(m1, &serve_opts()).unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    // Background hammer: 4 clients, 40 requests each.
    let mut hammers = Vec::new();
    for _ in 0..4 {
        hammers.push(std::thread::spawn(move || {
            let mut answers = Vec::new();
            for _ in 0..40 {
                answers.push(http_request(addr, "POST", "/predict", SPARSE_PREDICT));
            }
            answers
        }));
    }

    // Two reloads mid-hammer: full checkpoint, then a delta chain.
    std::thread::sleep(std::time::Duration::from_millis(30));
    let (status, body) = http_request(addr, "POST", "/reload", &reload_body(&m2_path, &[]));
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"swapped\""), "{body}");
    std::thread::sleep(std::time::Duration::from_millis(30));
    let (status, body) = http_request(
        addr,
        "POST",
        "/reload",
        &reload_body(&base_path, &[&d12_path, &d23_path]),
    );
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"generation\":3"), "{body}");

    let mut total = 0usize;
    for hammer in hammers {
        for (status, body) in hammer.join().unwrap() {
            assert_eq!(status, 200, "hot swap dropped a request: {body}");
            let got = served_topk(&body);
            assert!(
                legal.contains(&got),
                "served answer matches no version bitwise: {body}"
            );
            total += 1;
        }
    }
    assert_eq!(total, 160);

    // The chain landed: generation 3, serving m3's predictions, and the
    // checksum matches the offline chain application.
    assert_eq!(healthz_generation(addr), 3);
    let (_, body) = http_request(addr, "POST", "/predict", SPARSE_PREDICT);
    assert_eq!(served_topk(&body), legal[2]);
    let offline_chain = Checkpoint::load_chain(&base_path, &[d12_path.clone(), d23_path.clone()])
        .unwrap()
        .state_checksum()
        .unwrap();
    let (_, health) = http_request(addr, "GET", "/healthz", "");
    assert!(
        health.contains(&format!("{offline_chain:016x}")),
        "healthz must report the chain-applied checksum: {health}"
    );
    assert_eq!(metrics_reload_count(addr, "swapped"), 2);

    handle.stop();
    server_thread.join().unwrap();
    for p in [&base_path, &m2_path, &d12_path, &d23_path] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn bad_delta_chains_reject_cleanly_and_keep_serving() {
    let m1 = tiny_checkpoint(400);
    let m2 = tiny_checkpoint(500);
    let m3 = tiny_checkpoint(600);
    let base_path = temp_path("bad_base.fmlh");
    let other_path = temp_path("bad_other.fmlh");
    let d12_path = temp_path("bad_d12.fmld");
    let d23_path = temp_path("bad_d23.fmld");
    m1.save(&base_path, CheckpointCodec::Dense).unwrap();
    m3.save(&other_path, CheckpointCodec::Dense).unwrap();
    m2.delta_against(&m1, DeltaCodec::Sparse)
        .unwrap()
        .save(&d12_path)
        .unwrap();
    m3.delta_against(&m2, DeltaCodec::Sparse)
        .unwrap()
        .save(&d23_path)
        .unwrap();

    let want = offline_topk(m1.clone());
    let server = Server::bind(m1, &serve_opts()).unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    // Wrong base: d23 chains onto m2, not m3.
    let (status, body) = http_request(
        addr,
        "POST",
        "/reload",
        &reload_body(&other_path, &[&d23_path]),
    );
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("chain"), "{body}");

    // Out of order: d23 cannot apply before d12.
    let (status, body) = http_request(
        addr,
        "POST",
        "/reload",
        &reload_body(&base_path, &[&d23_path, &d12_path]),
    );
    assert_eq!(status, 400, "{body}");

    // Missing file and malformed body are 4xx too.
    let missing = temp_path("bad_missing.fmlh");
    let (status, _) = http_request(addr, "POST", "/reload", &reload_body(&missing, &[]));
    assert_eq!(status, 400);
    let (status, _) = http_request(addr, "POST", "/reload", "{\"deltas\": []}");
    assert_eq!(status, 400);

    // No partial swap: still generation 1, still m1's answers, and
    // every rejection counted.
    assert_eq!(healthz_generation(addr), 1);
    let (status, body) = http_request(addr, "POST", "/predict", SPARSE_PREDICT);
    assert_eq!(status, 200, "{body}");
    assert_eq!(served_topk(&body), want);
    assert_eq!(metrics_reload_count(addr, "rejected"), 4);
    assert_eq!(metrics_reload_count(addr, "swapped"), 0);

    handle.stop();
    server_thread.join().unwrap();
    for p in [&base_path, &other_path, &d12_path, &d23_path] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn rigged_canary_rolls_back_automatically() {
    let m1 = tiny_checkpoint(700);
    // Rig the candidate: NaN output biases survive save/load (the
    // format validates structure, not values) and poison every score.
    let mut rigged = tiny_checkpoint(800);
    for m in &mut rigged.models {
        m.tensors[5].data_mut().fill(f32::NAN);
    }
    let rigged_path = temp_path("rigged.fmlh");
    rigged.save(&rigged_path, CheckpointCodec::Dense).unwrap();

    let want = offline_topk(m1.clone());
    let server = Server::bind(m1, &serve_opts()).unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    let (status, body) = http_request(
        addr,
        "POST",
        "/reload?canary=50&window=10",
        &reload_body(&rigged_path, &[]),
    );
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"canary\""), "{body}");
    assert!(body.contains("\"window\":10"), "{body}");

    // Ticket 0 routes to the canary (deterministic split), the rigged
    // model 500s, and the error budget (floor(0.05 × 10) = 0) is
    // immediately exhausted → rollback on the spot.
    let (status, body) = http_request(addr, "POST", "/predict", SPARSE_PREDICT);
    assert_eq!(status, 500, "first request must hit the rigged canary");
    assert!(body.contains("non-finite"), "{body}");

    // Everything after serves the stable version — bitwise.
    for _ in 0..10 {
        let (status, body) = http_request(addr, "POST", "/predict", SPARSE_PREDICT);
        assert_eq!(status, 200, "{body}");
        assert_eq!(served_topk(&body), want);
    }
    assert_eq!(healthz_generation(addr), 1, "rollback must keep generation 1");
    assert_eq!(metrics_reload_count(addr, "rolled_back"), 1);
    let (_, health) = http_request(addr, "GET", "/healthz", "");
    assert!(!health.contains("\"canary\""), "rollout must be retired: {health}");

    handle.stop();
    server_thread.join().unwrap();
    let _ = std::fs::remove_file(&rigged_path);
}

#[test]
fn healthy_canary_promotes_after_its_window() {
    let m1 = tiny_checkpoint(900);
    let m2 = tiny_checkpoint(1000);
    let m2_path = temp_path("promote_m2.fmlh");
    m2.save(&m2_path, CheckpointCodec::Dense).unwrap();
    let want_m1 = offline_topk(m1.clone());
    let want_m2 = offline_topk(m2);

    let server = Server::bind(m1, &serve_opts()).unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    let (status, body) = http_request(
        addr,
        "POST",
        "/reload?canary=50&window=4",
        &reload_body(&m2_path, &[]),
    );
    assert_eq!(status, 200, "{body}");
    assert_eq!(healthz_generation(addr), 1, "not promoted yet");
    let (_, health) = http_request(addr, "GET", "/healthz", "");
    assert!(health.contains("\"canary\""), "{health}");
    assert!(health.contains("\"pct\":50"), "{health}");

    // pct 50 alternates canary/stable; after 8 requests the canary has
    // served its window of 4 clean answers and self-promotes. Every
    // response along the way is one version's bitwise answer.
    for i in 0..8 {
        let (status, body) = http_request(addr, "POST", "/predict", SPARSE_PREDICT);
        assert_eq!(status, 200, "request {i}: {body}");
        let got = served_topk(&body);
        assert!(got == want_m1 || got == want_m2, "request {i}: {body}");
    }
    assert_eq!(healthz_generation(addr), 2, "canary must have promoted");
    assert_eq!(metrics_reload_count(addr, "promoted"), 1);
    assert_eq!(metrics_reload_count(addr, "rolled_back"), 0);

    // Post-promotion traffic is all m2, bitwise.
    for _ in 0..4 {
        let (_, body) = http_request(addr, "POST", "/predict", SPARSE_PREDICT);
        assert_eq!(served_topk(&body), want_m2);
    }

    handle.stop();
    server_thread.join().unwrap();
    let _ = std::fs::remove_file(&m2_path);
}

#[test]
fn healthz_reports_identity_and_replicas() {
    let m1 = tiny_checkpoint(1100);
    let checksum = m1.state_checksum().unwrap();
    let server = Server::bind(m1, &serve_opts()).unwrap();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    let (status, body) = http_request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "{body}");
    let health = Json::parse(&body).unwrap();
    assert_eq!(health.expect("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(health.expect("ready").unwrap(), &Json::Bool(true));
    assert_eq!(health.expect("generation").unwrap().as_usize().unwrap(), 1);
    assert_eq!(health.expect("replicas").unwrap().as_usize().unwrap(), 2);
    assert_eq!(
        health.expect("state_checksum").unwrap().as_str().unwrap(),
        format!("{checksum:016x}")
    );
    let rows = health.expect("replica_health").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        assert_eq!(row.expect("healthy").unwrap(), &Json::Bool(true));
    }

    handle.stop();
    server_thread.join().unwrap();
}

#[test]
fn quitquitquit_drains_and_stops_the_server() {
    let m1 = tiny_checkpoint(1200);
    let mut opts = serve_opts();
    opts.drain = std::time::Duration::from_secs(2);
    let server = Server::bind(m1, &opts).unwrap();
    let control = server.control();
    let handle = server.handle().unwrap();
    let addr = handle.addr();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    let (status, body) = http_request(addr, "POST", "/predict", SPARSE_PREDICT);
    assert_eq!(status, 200, "{body}");

    let (status, body) = http_request(addr, "POST", "/quitquitquit", "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"draining\""), "{body}");

    // The accept loop exits, in-flight work drains, run() returns.
    server_thread.join().unwrap();
    assert!(control.draining());
    let (_, health) = control.health();
    assert!(health.contains("draining"), "{health}");
}
