//! Acceptance pin for the sub-byte codec, end to end: a full federated
//! run shipping `q4g` on **both** links (`--codec q4g --down-codec
//! q4g`) with uplink error feedback must land within a pinned accuracy
//! tolerance of the same run on `q8g`, while paying ≤ 0.55× the q8g
//! byte bill on each link (nibble packing halves the value stream; the
//! per-block scales are shared overhead). The byte assertions are
//! against the *measured* `CommMeter`, so the ratio is what a real
//! deployment would bill, not a back-of-envelope.

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::data::synth::generate_preset;
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::server::{self, RunOutput};
use fedmlh::federated::transport::DownCodec;
use fedmlh::federated::wire::CodecSpec;
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};

fn run(codec: CodecSpec, down_codec: DownCodec) -> RunOutput {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = 10;
    cfg.patience = 0;
    cfg.clients = 4;
    cfg.clients_per_round = 4;
    cfg.local_epochs = 1;
    cfg.codec = codec;
    cfg.down_codec = down_codec;
    cfg.error_feedback = true;
    let data = generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
    let backend = RustBackend::new();
    server::run(
        &cfg,
        scheme.as_ref(),
        &backend,
        &data.train,
        &data.test,
        &part,
    )
    .unwrap()
}

#[test]
fn q4g_both_links_with_feedback_matches_q8g_within_tolerance() {
    let block = 64;
    let q4g = run(
        CodecSpec::QuantI4Group { block },
        DownCodec::QuantI4Group { block },
    );
    let q8g = run(
        CodecSpec::QuantI8Group { block },
        DownCodec::QuantI8Group { block },
    );

    // Accuracy: int4 on both links, with the uplink residual folded
    // back in by error feedback, stays within tolerance of int8.
    assert!(
        q4g.best.mean_topk() >= q8g.best.mean_topk() - 0.15,
        "q4g accuracy {:.4} too far below q8g {:.4}",
        q4g.best.mean_topk(),
        q8g.best.mean_topk()
    );
    // …and it genuinely learns, not just "close to a broken baseline".
    let first = q4g.history.records.first().unwrap().accuracy.top1;
    assert!(q4g.best.top1 >= first, "no improvement under q4g");
    assert!(q4g.best.top1 > 0.02, "top1 {} not above chance", q4g.best.top1);
    assert!(q8g.best.top1 > 0.02, "q8g baseline failed to learn");

    // Bytes, per link: the sub-byte acceptance bound (≤ 0.55× q8g at
    // the same block) holds on the measured meter, both directions.
    let up_ratio = q4g.comm.uploaded() as f64 / q8g.comm.uploaded() as f64;
    assert!(up_ratio <= 0.55, "uplink q4g/q8g = {up_ratio:.3} > 0.55");
    let down_ratio = q4g.comm.downloaded() as f64 / q8g.comm.downloaded() as f64;
    assert!(down_ratio <= 0.55, "downlink q4g/q8g = {down_ratio:.3} > 0.55");
    // And against dense: the headline ~7× uplink compression.
    assert!(
        q4g.comm.upload_compression() > 6.0,
        "q4g upload compression {:.2}x not > 6x",
        q4g.comm.upload_compression()
    );
    // Both runs trained the same schedule: identical dense-equivalent
    // traffic, so the ratios above compare like with like.
    assert_eq!(
        q4g.comm.uploaded_dense_equiv(),
        q8g.comm.uploaded_dense_equiv()
    );
    assert_eq!(q4g.rounds_run, q8g.rounds_run);
}
