//! End-to-end guarantees of the event-driven async simulator
//! (`federated::sim`): bitwise reproducibility for a fixed seed —
//! including across worker counts and through lossy/stateful transports
//! — exact dropout accounting, and million-client registries at
//! O(concurrency) memory.

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::federated::sim::run_async;
use fedmlh::federated::transport::DownCodec;
use fedmlh::federated::wire::CodecSpec;
use fedmlh::federated::{RunOutput, RustBackend};
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};

fn sim_cfg(registry: usize, buffer: usize, rounds: usize, dropout: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = rounds;
    cfg.patience = 0;
    cfg.clients = 4;
    cfg.clients_per_round = 2;
    cfg.local_epochs = 1;
    cfg.sim.async_mode = true;
    cfg.sim.registry = registry;
    cfg.sim.buffer = buffer;
    cfg.sim.concurrency = 8;
    cfg.sim.dropout = dropout;
    cfg
}

fn run(cfg: &ExperimentConfig) -> RunOutput {
    let data = fedmlh::data::synth::generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(cfg, Algo::FedMlh, &data.train);
    let backend = RustBackend::new();
    run_async(cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap()
}

/// Bit-level equality of two runs: history CSV (every column, including
/// the simulated timing ones), communication meter, and final weights.
fn assert_bitwise_equal(a: &RunOutput, b: &RunOutput, tag: &str) {
    assert_eq!(a.history.to_csv(), b.history.to_csv(), "{tag}: history CSV");
    assert_eq!(a.comm.total(), b.comm.total(), "{tag}: comm total");
    assert_eq!(a.rounds_run, b.rounds_run, "{tag}: rounds");
    assert_eq!(a.sim, b.sim, "{tag}: sim stats");
    assert_eq!(a.final_globals.len(), b.final_globals.len());
    for (j, (ga, gb)) in a.final_globals.iter().zip(b.final_globals.iter()).enumerate() {
        let (va, vb) = (ga.flat_values(), gb.flat_values());
        assert_eq!(va.len(), vb.len());
        for (i, (x, y)) in va.iter().zip(vb.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{tag}: sub-model {j} weight {i} differs"
            );
        }
    }
}

#[test]
fn same_seed_is_bitwise_identical() {
    let cfg = sim_cfg(1000, 4, 3, 0.2);
    assert_bitwise_equal(&run(&cfg), &run(&cfg), "dense");

    // …and through a lossy, *stateful* transport (lazy error-feedback
    // slots on the uplink, q8 broadcast on the downlink).
    let mut cfg = sim_cfg(1000, 4, 3, 0.2);
    cfg.codec = CodecSpec::QuantI8;
    cfg.down_codec = DownCodec::QuantI8;
    cfg.error_feedback = true;
    assert_bitwise_equal(&run(&cfg), &run(&cfg), "q8+feedback");
}

#[test]
fn worker_count_does_not_change_the_simulation() {
    let mut a = sim_cfg(1000, 4, 3, 0.2);
    a.workers = 1;
    let mut b = a.clone();
    b.workers = 4;
    assert_bitwise_equal(&run(&a), &run(&b), "workers 1 vs 4");
}

#[test]
fn dropout_is_charged_download_only() {
    let cfg = sim_cfg(1000, 4, 3, 0.5);
    let out = run(&cfg);
    let s = out.sim.expect("async run reports sim stats");
    assert!(s.dropped > 0, "dropout 0.5 must drop someone");
    // Everything dispatched either arrived, dropped, or was still in
    // flight when the round target hit — never more than the window.
    let in_flight = s.dispatched - s.arrived - s.dropped;
    assert!(in_flight <= cfg.sim.concurrency as u64, "in flight {in_flight}");
    // Dense codec: every dispatch downloads exactly one full model set;
    // only arrivals upload one. A dropped client costs download only.
    let model = out.model_bytes as u64;
    assert_eq!(out.comm.downloaded(), s.dispatched * model);
    assert_eq!(out.comm.uploaded(), s.arrived * model);
    assert!(s.dispatched > s.arrived, "drops mean dispatches exceed arrivals");
}

#[test]
fn staleness_is_measured_and_bounded() {
    // Tiny buffer + deep concurrency forces version churn while clients
    // are in flight → nonzero staleness must show up in the stats.
    let mut cfg = sim_cfg(1000, 2, 6, 0.0);
    cfg.sim.concurrency = 16;
    let out = run(&cfg);
    let s = out.sim.unwrap();
    assert_eq!(s.aggregations, 6);
    assert!(s.max_staleness > 0, "deep pipeline must see stale arrivals");
    assert!(s.mean_staleness > 0.0 && s.mean_staleness <= s.max_staleness as f64);
}

#[test]
fn million_client_registry_completes_smoke() {
    let cfg = sim_cfg(1_000_000, 4, 2, 0.0);
    let out = run(&cfg);
    assert_eq!(out.rounds_run, 2);
    let s = out.sim.unwrap();
    assert_eq!(s.aggregations, 2);
    assert!(s.sim_seconds > 0.0);
    // History carries the simulated clock: monotone, positive, and in
    // the CSV as the last column.
    let csv = out.history.to_csv();
    assert!(csv.lines().next().unwrap().ends_with(",sim_seconds"));
    let mut prev = 0.0;
    for rec in &out.history.records {
        assert!(rec.sim_seconds > prev, "sim clock advances");
        prev = rec.sim_seconds;
    }
}

#[test]
fn delta_downlink_rides_the_async_loop() {
    // registry 0 → the 4 partition clients themselves; repeated
    // participation exercises the lazy per-client replica map.
    let mut cfg = sim_cfg(0, 3, 3, 0.0);
    cfg.down_codec = DownCodec::TopK { frac: 0.1 };
    cfg.resync_every = 1_000_000; // deltas whenever a base exists
    let out = run(&cfg);
    assert_eq!(out.rounds_run, 3);
    // First contacts are full resyncs; repeats ship small deltas, so
    // the measured downlink ratio must beat dense.
    assert!(
        out.comm.downloaded() < out.comm.downloaded_dense_equiv(),
        "deltas must undercut dense: {} vs {}",
        out.comm.downloaded(),
        out.comm.downloaded_dense_equiv()
    );
    assert_bitwise_equal(&run(&cfg), &run(&cfg), "delta downlink");
}
