//! Property tests pinning the tiled kernel subsystem
//! (`fedmlh::kernels`) against the frozen naive baseline
//! (`fedmlh::kernels::naive`) across awkward shapes — dimensions that
//! are not multiples of the register tiles, degenerate `m = 1` /
//! `k = 1` cases, all-zero operands, column counts that straddle the
//! fused-SGD block width — plus the sparse-vs-dense layer-1
//! equivalence, run-to-run / batch-split determinism, and the two
//! dispatch contracts: row-sliced intra-kernel parallelism
//! (`kernels::parallel`) and the SIMD bodies (`kernels::simd`) are
//! each **bitwise identical** to the sequential scalar loops.

use fedmlh::kernels::{fused, gemm, naive, parallel, simd, sparse};
use fedmlh::model::mlp;
use fedmlh::model::params::ModelParams;
use fedmlh::util::prop::{check, Gen};
use fedmlh::util::rng::Rng;

/// Shapes chosen to stress tile edges: MR = 4 rows, KB = 4 reduction
/// block, LANES = 8 dot lanes, SGD_COL_BLOCK = 512 columns.
const AWKWARD: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 5, 3),
    (4, 1, 9),
    (3, 8, 1),
    (4, 4, 8),
    (5, 7, 9),
    (8, 16, 8),
    (6, 9, 17),
    (13, 21, 11),
    (2, 3, 530), // crosses the fused-SGD column block once
];

fn approx(a: &[f32], b: &[f32], tol: f32, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!((x - y).abs() <= tol, "{tag}[{i}]: {x} vs {y}");
    }
}

#[test]
fn tiled_gemm_matches_naive_on_awkward_shapes() {
    check("gemm vs naive", AWKWARD.len(), |g: &mut Gen| {
        let (m, k, n) = AWKWARD[g.case];
        let a = g.vec_f32(m * k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -2.0, 2.0);

        let mut want = vec![0.0f32; m * n];
        naive::matmul(&a, &b, &mut want, m, k, n);
        let mut got = vec![f32::NAN; m * n];
        gemm::gemm_nn(&a, &b, &mut got, m, k, n);
        approx(&got, &want, 1e-3, "nn");

        // aᵀ b: reuse a as a [k, m]-shaped operand.
        let at = g.vec_f32(k * m, -2.0, 2.0);
        let mut want_tn = vec![0.0f32; m * n];
        naive::matmul_tn(&at, &b, &mut want_tn, k, m, n);
        let mut got_tn = vec![f32::NAN; m * n];
        gemm::gemm_tn(&at, &b, &mut got_tn, k, m, n);
        approx(&got_tn, &want_tn, 1e-3, "tn");

        // a bᵀ contracts over n: fresh a2 [m, n] and bt [k, n].
        let a2 = g.vec_f32(m * n, -2.0, 2.0);
        let bt = g.vec_f32(k * n, -2.0, 2.0);
        let mut want_nt = vec![0.0f32; m * k];
        naive::matmul_nt(&a2, &bt, &mut want_nt, m, n, k);
        let mut got_nt = vec![f32::NAN; m * k];
        gemm::gemm_nt(&a2, &bt, &mut got_nt, m, n, k);
        approx(&got_nt, &want_nt, 1e-3, "nt");
    });
}

#[test]
fn all_zero_inputs_stay_exactly_zero() {
    for &(m, k, n) in AWKWARD {
        let a = vec![0.0f32; m * k];
        let b = vec![0.0f32; k * n];
        let mut out = vec![f32::NAN; m * n];
        gemm::gemm_nn(&a, &b, &mut out, m, k, n);
        assert!(out.iter().all(|&v| v == 0.0), "nn zeros ({m},{k},{n})");
        let at = vec![0.0f32; k * m];
        let mut out_tn = vec![f32::NAN; m * n];
        gemm::gemm_tn(&at, &b, &mut out_tn, k, m, n);
        assert!(out_tn.iter().all(|&v| v == 0.0), "tn zeros");
        let a2 = vec![0.0f32; m * n];
        let mut out_nt = vec![f32::NAN; m * k];
        gemm::gemm_nt(&a2, &b, &mut out_nt, m, n, k);
        assert!(out_nt.iter().all(|&v| v == 0.0), "nt zeros");
        // fused bias path reduces to broadcast bias rows
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.5 - 1.0).collect();
        let mut biased = vec![f32::NAN; m * n];
        fused::gemm_bias(&a, &b, &bias, &mut biased, m, k, n);
        for row in biased.chunks_exact(n) {
            assert_eq!(row, &bias[..], "bias rows");
        }
    }
}

#[test]
fn fused_bias_relu_matches_naive_pipeline() {
    check("fused bias+relu", 16, |g: &mut Gen| {
        let m = g.usize_in(1, 10);
        let k = g.usize_in(1, 12);
        let n = g.usize_in(1, 20);
        let a = g.vec_f32(m * k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -2.0, 2.0);
        let bias = g.vec_f32(n, -1.0, 1.0);
        let mut want = vec![0.0f32; m * n];
        naive::matmul(&a, &b, &mut want, m, k, n);
        for row in want.chunks_exact_mut(n) {
            for (v, &bv) in row.iter_mut().zip(bias.iter()) {
                *v += bv;
            }
        }
        let mut relu_want = want.clone();
        for v in relu_want.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let mut got = vec![f32::NAN; m * n];
        fused::gemm_bias(&a, &b, &bias, &mut got, m, k, n);
        approx(&got, &want, 1e-4, "gemm_bias");
        let mut got_relu = vec![f32::NAN; m * n];
        fused::gemm_bias_relu(&a, &b, &bias, &mut got_relu, m, k, n);
        approx(&got_relu, &relu_want, 1e-4, "gemm_bias_relu");
    });
}

#[test]
fn fused_tn_sgd_is_bitwise_equal_to_materialized_gradient() {
    // The column-blocked fused update preserves the per-element
    // ascending-k summation order, so it is *exactly* the two-pass
    // result, for every awkward shape including n > SGD_COL_BLOCK.
    check("tn+sgd fusion", AWKWARD.len(), |g: &mut Gen| {
        let (m, k, n) = AWKWARD[g.case];
        let a = g.vec_f32(k * m, -2.0, 2.0);
        let b = g.vec_f32(k * n, -2.0, 2.0);
        let init = g.vec_f32(m * n, -1.0, 1.0);
        let lr = g.f32_in(0.01, 1.0);
        let mut grad = vec![0.0f32; m * n];
        gemm::gemm_tn(&a, &b, &mut grad, k, m, n);
        let mut want = init.clone();
        for (p, &gv) in want.iter_mut().zip(grad.iter()) {
            *p -= lr * gv;
        }
        let mut got = init.clone();
        let mut scratch = vec![0.0f32; fused::sgd_scratch_len(m, n)];
        fused::gemm_tn_sgd(&a, &b, &mut got, lr, k, m, n, &mut scratch);
        assert_eq!(got, want, "({m},{k},{n})");
    });
}

/// Sparse batch whose nonzero values avoid the underflow range, so the
/// dense kernel's skipped `0 · w` terms cannot perturb a ±0 edge and
/// sparse-vs-dense equality is exact.
fn sparse_batch(g: &mut Gen, rows: usize, cols: usize, nnz_per_row: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for _ in 0..nnz_per_row.min(cols) {
            let c = g.usize_in(0, cols);
            let mag = g.f32_in(0.25, 2.0);
            x[r * cols + c] = if g.bool() { mag } else { -mag };
        }
    }
    x
}

#[test]
fn sparse_layer1_forward_is_bitwise_equal_to_dense() {
    check("csr vs dense forward", 20, |g: &mut Gen| {
        let rows = g.usize_in(1, 9);
        let cols = g.usize_in(4, 40);
        let n = g.usize_in(1, 16);
        let x = sparse_batch(g, rows, cols, 2);
        let w = g.vec_f32(cols * n, -1.5, 1.5);
        let bias = g.vec_f32(n, -0.5, 0.5);
        let mut csr = sparse::CsrBatch::new();
        csr.from_dense(&x, rows, cols);
        let mut got = vec![f32::NAN; rows * n];
        sparse::csr_gemm_bias_relu(&csr, &w, &bias, &mut got, n);
        let mut want = vec![f32::NAN; rows * n];
        fused::gemm_bias_relu(&x, &w, &bias, &mut want, rows, cols, n);
        assert_eq!(got, want, "rows {rows} cols {cols} n {n}");
    });
}

#[test]
fn sparse_layer1_gradient_matches_dense() {
    check("csr vs dense tn+sgd", 20, |g: &mut Gen| {
        let rows = g.usize_in(1, 8);
        let cols = g.usize_in(4, 30);
        let n = g.usize_in(1, 12);
        let x = sparse_batch(g, rows, cols, 2);
        let d = g.vec_f32(rows * n, -1.0, 1.0);
        let init = g.vec_f32(cols * n, -1.0, 1.0);
        let lr = 0.3f32;
        let mut csr = sparse::CsrBatch::new();
        csr.from_dense(&x, rows, cols);
        let mut got = init.clone();
        sparse::csr_gemm_tn_sgd(&csr, &d, &mut got, lr, n);
        let mut want = init.clone();
        let mut scratch = vec![0.0f32; fused::sgd_scratch_len(cols, n)];
        fused::gemm_tn_sgd(&x, &d, &mut want, lr, rows, cols, n, &mut scratch);
        // The scatter applies lr·v·dv per nonzero instead of
        // lr·(Σ v·dv); associativity differs, values agree tightly.
        approx(&got, &want, 1e-5, "layer1 grad");
    });
}

#[test]
fn kernels_are_run_to_run_deterministic() {
    let mut g = Gen::new(0xdecaf);
    let (m, k, n) = (7, 13, 530);
    let a = g.vec_f32(m * k, -2.0, 2.0);
    let b = g.vec_f32(k * n, -2.0, 2.0);
    let mut first = vec![0.0f32; m * n];
    let mut second = vec![0.0f32; m * n];
    gemm::gemm_nn(&a, &b, &mut first, m, k, n);
    gemm::gemm_nn(&a, &b, &mut second, m, k, n);
    assert!(first
        .iter()
        .zip(second.iter())
        .all(|(x, y)| x.to_bits() == y.to_bits()));

    // Full train_step twice from identical state ⇒ identical params and
    // bit-identical loss, with a stale (previously used) workspace.
    let params = ModelParams::init(12, 6, 530, 3);
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..4 * 12).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
    let y: Vec<f32> = (0..4 * 530)
        .map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 })
        .collect();
    let mut p1 = params.clone();
    let mut ws = mlp::Workspace::new(&p1, 4);
    let l_warm = mlp::train_step(&mut p1, &mut ws, &x, &y, 0.5);
    let mut p2 = params.clone();
    let l1 = mlp::train_step(&mut p2, &mut ws, &x, &y, 0.5); // reused, now-dirty ws
    let mut p3 = params.clone();
    let mut fresh = mlp::Workspace::new(&p3, 4);
    let l2 = mlp::train_step(&mut p3, &mut fresh, &x, &y, 0.5);
    assert_eq!(l_warm.to_bits(), l1.to_bits());
    assert_eq!(l1.to_bits(), l2.to_bits());
    assert_eq!(p2, p3);
    assert_eq!(p1, p2);
}

#[test]
fn forward_is_batch_split_invariant_at_mixed_density() {
    // Rows of wildly different density in one batch: the whole-batch
    // sparse/dense decision may differ from the per-row decision, and
    // the result must not care.
    let mut g = Gen::new(0xba7c4);
    let (d, h, out) = (24, 5, 7);
    let params = ModelParams::init(d, h, out, 9);
    let rows = 6;
    let mut x = vec![0.0f32; rows * d];
    for (r, row) in x.chunks_exact_mut(d).enumerate() {
        let nnz = match r % 3 {
            0 => 0, // empty row
            1 => 2, // sparse row
            _ => d, // dense row
        };
        for v in row.iter_mut().take(nnz) {
            let mag = g.f32_in(0.25, 2.0);
            *v = if g.bool() { mag } else { -mag };
        }
    }
    let batched = mlp::forward(&params, &x, rows);
    for r in 0..rows {
        let single = mlp::forward(&params, &x[r * d..(r + 1) * d], 1);
        assert_eq!(
            &batched[r * out..(r + 1) * out],
            &single[..],
            "row {r} differs between batched and single forward"
        );
    }
}

#[test]
fn row_sliced_parallel_is_bitwise_equal_to_sequential() {
    // Shapes straddling the `PAR_MIN_FLOPS` floor: below it `plan()`
    // ignores the budget and stays sequential; above it output rows are
    // sliced across 4 threads. Both must be bitwise the 1-thread
    // result — including the uneven final chunk (13 rows over
    // MR-aligned slices) and the rows-capped edge (m = 2 at the floor).
    let shapes = [(5usize, 7usize, 9usize), (2, 1024, 1024), (13, 128, 1536), (48, 128, 512)];
    assert!(48 * 128 * 512 >= parallel::PAR_MIN_FLOPS, "big shape must clear the floor");
    for (m, k, n) in shapes {
        let mut g = Gen::new(0xc0de + (m * k * n) as u64);
        let a = g.vec_f32(m * k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -2.0, 2.0);
        let bias = g.vec_f32(n, -1.0, 1.0);
        let at = g.vec_f32(k * m, -2.0, 2.0);
        let a2 = g.vec_f32(m * n, -2.0, 2.0);
        let init = g.vec_f32(m * n, -1.0, 1.0);
        let tag = format!("({m},{k},{n})");

        // Sequential references (budget = 1, the thread-local default).
        let mut seq_nn = vec![f32::NAN; m * n];
        gemm::gemm_nn(&a, &b, &mut seq_nn, m, k, n);
        let mut seq_relu = vec![f32::NAN; m * n];
        fused::gemm_bias_relu(&a, &b, &bias, &mut seq_relu, m, k, n);
        let mut seq_tn = vec![f32::NAN; m * n];
        gemm::gemm_tn(&at, &b, &mut seq_tn, k, m, n);
        let mut seq_nt = vec![f32::NAN; m * k];
        gemm::gemm_nt(&a2, &b, &mut seq_nt, m, n, k);
        let mut seq_sgd = init.clone();
        let mut scratch = vec![0.0f32; fused::sgd_scratch_len(m, n)];
        fused::gemm_tn_sgd(&at, &b, &mut seq_sgd, 0.3, k, m, n, &mut scratch);
        let mut csr = sparse::CsrBatch::new();
        csr.from_dense(&a, m, k);
        let csr_bias = g.vec_f32(n, -0.5, 0.5);
        let mut seq_csr = vec![f32::NAN; m * n];
        sparse::csr_gemm_bias_relu(&csr, &b, &csr_bias, &mut seq_csr, n);

        // Same calls under a 4-thread budget: bitwise equal.
        let _budget = parallel::set_kernel_threads(4);
        let mut par_nn = vec![f32::NAN; m * n];
        gemm::gemm_nn(&a, &b, &mut par_nn, m, k, n);
        assert_eq!(par_nn, seq_nn, "{tag}: nn");
        let mut par_relu = vec![f32::NAN; m * n];
        fused::gemm_bias_relu(&a, &b, &bias, &mut par_relu, m, k, n);
        assert_eq!(par_relu, seq_relu, "{tag}: bias+relu");
        let mut par_tn = vec![f32::NAN; m * n];
        gemm::gemm_tn(&at, &b, &mut par_tn, k, m, n);
        assert_eq!(par_tn, seq_tn, "{tag}: tn");
        let mut par_nt = vec![f32::NAN; m * k];
        gemm::gemm_nt(&a2, &b, &mut par_nt, m, n, k);
        assert_eq!(par_nt, seq_nt, "{tag}: nt");
        let mut par_sgd = init.clone();
        let mut par_scratch = vec![0.0f32; fused::sgd_scratch_len(m, n)];
        fused::gemm_tn_sgd(&at, &b, &mut par_sgd, 0.3, k, m, n, &mut par_scratch);
        assert_eq!(par_sgd, seq_sgd, "{tag}: tn+sgd");
        let mut par_csr = vec![f32::NAN; m * n];
        sparse::csr_gemm_bias_relu(&csr, &b, &csr_bias, &mut par_csr, n);
        assert_eq!(par_csr, seq_csr, "{tag}: csr forward");
    }
}

#[test]
fn simd_dispatch_is_bitwise_equal_to_forced_scalar() {
    // With `--features simd` on AVX2 hardware this compares the vector
    // bodies against the verbatim scalar loops they replace; in a
    // default build both runs take the scalar path and the test pins
    // `force_scalar` as a no-op. Either way: bitwise equal.
    let (m, k, n) = (13, 21, 530);
    let mut g = Gen::new(0x51d);
    let a = g.vec_f32(m * k, -2.0, 2.0);
    let b = g.vec_f32(k * n, -2.0, 2.0);
    let bias = g.vec_f32(n, -1.0, 1.0);
    let at = g.vec_f32(k * m, -2.0, 2.0);
    let init = g.vec_f32(m * n, -1.0, 1.0);

    let run_all = || {
        let mut nn = vec![f32::NAN; m * n];
        gemm::gemm_nn(&a, &b, &mut nn, m, k, n);
        let mut relu = vec![f32::NAN; m * n];
        fused::gemm_bias_relu(&a, &b, &bias, &mut relu, m, k, n);
        let mut tn = vec![f32::NAN; m * n];
        gemm::gemm_tn(&at, &b, &mut tn, k, m, n);
        let mut sgd = init.clone();
        let mut scratch = vec![0.0f32; fused::sgd_scratch_len(m, n)];
        fused::gemm_tn_sgd(&at, &b, &mut sgd, 0.3, k, m, n, &mut scratch);
        (nn, relu, tn, sgd)
    };

    simd::force_scalar(true);
    assert!(!simd::active(), "force_scalar must pin the scalar path");
    let scalar = run_all();
    simd::force_scalar(false);
    let dispatched = run_all();
    assert_eq!(scalar.0, dispatched.0, "nn (simd compiled: {})", simd::compiled());
    assert_eq!(scalar.1, dispatched.1, "bias+relu");
    assert_eq!(scalar.2, dispatched.2, "tn");
    assert_eq!(scalar.3, dispatched.3, "tn+sgd");

    // The full fused train step, both dispatches, same bits.
    let params = ModelParams::init(24, 8, 530, 5);
    let mut rng = Rng::new(0x1f);
    let x: Vec<f32> = (0..6 * 24).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
    let y: Vec<f32> = (0..6 * 530)
        .map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 })
        .collect();
    simd::force_scalar(true);
    let mut p_scalar = params.clone();
    let mut ws = mlp::Workspace::new(&p_scalar, 6);
    let l_scalar = mlp::train_step(&mut p_scalar, &mut ws, &x, &y, 0.5);
    simd::force_scalar(false);
    let mut p_simd = params.clone();
    let mut ws2 = mlp::Workspace::new(&p_simd, 6);
    let l_simd = mlp::train_step(&mut p_simd, &mut ws2, &x, &y, 0.5);
    assert_eq!(l_scalar.to_bits(), l_simd.to_bits(), "loss bits");
    assert_eq!(p_scalar, p_simd, "params after one step");
}

#[test]
fn sparse_train_step_matches_naive_baseline() {
    // End-to-end: one tiled train_step (CSR layer-1 path engaged) vs
    // the frozen naive step from identical state — parameters must
    // agree to float-reassociation tolerance, loss must agree tightly.
    let mut g = Gen::new(0x5eed);
    let (d, h, out, m) = (32, 8, 530, 6);
    let init = ModelParams::init(d, h, out, 4);
    let x = sparse_batch(&mut g, m, d, 3);
    assert!(x.iter().filter(|v| **v != 0.0).count() * 2 <= m * d);
    let y: Vec<f32> = (0..m * out)
        .map(|_| if g.bool() { 0.0 } else { 1.0 })
        .collect();

    let mut tiled = init.clone();
    let mut ws = mlp::Workspace::new(&tiled, m);
    let tiled_loss = mlp::train_step(&mut tiled, &mut ws, &x, &y, 0.7);

    let mut base = init.clone();
    let mut nws = naive::NaiveWorkspace::new(&base, m);
    let naive_loss = naive::train_step(&mut base, &mut nws, &x, &y, 0.7);

    assert!(
        (tiled_loss - naive_loss).abs() < 1e-5,
        "loss {tiled_loss} vs naive {naive_loss}"
    );
    let drift = tiled.max_abs_diff(&base).unwrap();
    assert!(drift < 1e-4, "param drift vs naive after one step: {drift}");
}
