//! Section-5 theory validated at realistic scale: the bounds hold on
//! the actual preset datasets and partitions the experiments use.

use fedmlh::config::ExperimentConfig;
use fedmlh::harness;
use fedmlh::hashing::label_hash::LabelHasher;
use fedmlh::theory;
use fedmlh::util::prop::check;

#[test]
fn lemma1_bound_holds_on_eurlex_scale_counts() {
    // Zipf-like counts at eurlex scale: every class's bucket bound must
    // be ≤ the Monte-Carlo expectation.
    let cfg = ExperimentConfig::preset("eurlex").unwrap();
    let data = fedmlh::data::synth::generate_preset(&cfg.preset, 11);
    let counts = data.train.class_counts();
    let n_lab: usize = counts.iter().sum();
    for &j in &[0usize, 100, 2000, 3999] {
        let bound = theory::lemma1_lower_bound(counts[j], n_lab, cfg.b());
        let mc = theory::expected_bucket_positives_mc(&counts, j, cfg.b(), 60, 5);
        assert!(
            mc >= bound - 1e-9,
            "class {j}: MC {mc} < bound {bound}"
        );
        // the re-balancing effect: infrequent classes gain a lot
        if counts[j] < 5 {
            assert!(
                bound > 10.0 * (counts[j].max(1)) as f64,
                "class {j} gained too little: {bound} from {}",
                counts[j]
            );
        }
    }
}

#[test]
fn lemma2_paper_table2_configs() {
    // All four paper configurations are collision-safe at δ = 0.05 (the
    // paper's real p values, not just the scaled analogs).
    for &(p, b, r) in &[
        (3993usize, 250usize, 4usize),   // Eurlex
        (30938, 1000, 4),                // Wiki31
        (131073, 4000, 4),               // AMZtitle
        (312330, 5000, 8),               // Wikititle
    ] {
        let bound = theory::collision_union_bound(p, b, r);
        assert!(bound < 0.05, "paper config p={p} B={b} R={r}: {bound}");
        let min_b = theory::lemma2_min_buckets(p, r, 0.05);
        assert!(
            (b as f64) >= min_b * 0.8,
            "paper B={b} far below lemma minimum {min_b:.0} (p={p}, R={r})"
        );
    }
}

#[test]
fn lemma2_mc_tracks_bound_direction() {
    check("lemma2 monotone in B", 5, |g| {
        let p = g.usize_in(30, 120);
        let r = g.usize_in(1, 3);
        let b_small = g.usize_in(2, 8);
        let b_large = b_small * 8;
        let seed = g.rng().next_u64();
        let small = theory::all_table_collision_probability_mc(p, b_small, r, 60, seed);
        let large = theory::all_table_collision_probability_mc(p, b_large, r, 60, seed);
        assert!(
            large <= small + 0.1,
            "collisions did not drop with B: {small} -> {large}"
        );
    });
}

#[test]
fn theorem2_on_all_presets() {
    for name in ["tiny", "eurlex"] {
        let cfg = ExperimentConfig::preset(name).unwrap();
        let world = harness::build_world(&cfg);
        let hasher = LabelHasher::new(cfg.seed, cfg.r(), world.data.train.p(), cfg.b());
        let c = theory::kl_contraction_on_partition(
            &world.data.train,
            &world.partition,
            &hasher,
            1e-3,
        );
        assert!(c.holds(), "{name}: {c:?}");
        assert!(
            c.factor() > 1.2,
            "{name}: expected meaningful contraction, got {:.3}x",
            c.factor()
        );
    }
}

#[test]
fn theorem2_mc_large_random_instances() {
    let (worst, factor) = theory::kl_contraction_mc(400, 50, 150, 99);
    assert!(worst <= 1e-10, "violation {worst}");
    assert!(factor > 1.0);
}

#[test]
fn contraction_grows_as_b_shrinks() {
    // Theorem 2's monotonicity remark: fewer buckets ⇒ more contraction
    // (in expectation over hash draws).
    let cfg = ExperimentConfig::preset("tiny").unwrap();
    let world = harness::build_world(&cfg);
    let p = world.data.train.p();
    let mut factors = Vec::new();
    for b in [32usize, 8, 2] {
        // average over a few hasher draws to smooth hash luck
        let mut f = 0.0;
        for s in 0..5u64 {
            let hasher = LabelHasher::new(1000 + s, 2, p, b);
            let c = theory::kl_contraction_on_partition(
                &world.data.train,
                &world.partition,
                &hasher,
                1e-3,
            );
            f += c.factor() / 5.0;
        }
        factors.push(f);
    }
    assert!(
        factors[0] < factors[2],
        "contraction not increasing as B shrinks: {factors:?}"
    );
}
