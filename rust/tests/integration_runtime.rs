//! XLA-backend integration: the compiled HLO artifacts executed through
//! PJRT must agree with the pure-rust reference across the *whole*
//! federated pipeline, not just single kernels.
//!
//! These tests require `make artifacts`; they skip silently when the
//! manifest is missing so `cargo test` stays green on a fresh checkout.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::harness::{self, BackendKind, HarnessOpts};
use fedmlh::runtime::{RuntimeClient, XlaBackend};

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn available() -> bool {
    // Needs both the compiled PJRT runtime (`xla` cargo feature — the
    // default build ships an API stub whose XlaBackend cannot
    // construct) and the AOT artifacts from `make artifacts`.
    cfg!(feature = "xla") && artifact_dir().join("manifest.json").exists()
}

fn quick_cfg(rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = rounds;
    cfg.patience = 0;
    cfg.clients = 4;
    cfg.clients_per_round = 2;
    cfg.local_epochs = 2;
    cfg
}

fn opts(kind: BackendKind, rounds: usize) -> HarnessOpts {
    HarnessOpts {
        backend: kind,
        artifact_dir: artifact_dir(),
        rounds: Some(rounds),
        ..HarnessOpts::default()
    }
}

#[test]
fn xla_and_rust_backends_agree_end_to_end() {
    if !available() {
        return;
    }
    let cfg = quick_cfg(4);
    let rust = harness::run_pair(&cfg, &opts(BackendKind::Rust, 4)).unwrap();
    let xla = harness::run_pair(&cfg, &opts(BackendKind::Xla, 4)).unwrap();

    // Same data, same partitions, same init, same sampling: the only
    // difference is f32 op ordering inside XLA vs the rust loops, so the
    // accuracy traces must track closely.
    for (r, x) in [(&rust.fedavg, &xla.fedavg), (&rust.fedmlh, &xla.fedmlh)] {
        assert_eq!(r.rounds_run, x.rounds_run);
        assert_eq!(r.comm.total(), x.comm.total());
        for (rr, xr) in r.history.records.iter().zip(x.history.records.iter()) {
            assert!(
                (rr.accuracy.top1 - xr.accuracy.top1).abs() < 0.05,
                "round {}: rust top1 {} vs xla {}",
                rr.round,
                rr.accuracy.top1,
                xr.accuracy.top1
            );
            assert!(
                (rr.mean_loss - xr.mean_loss).abs() < 5e-3,
                "round {}: rust loss {} vs xla {}",
                rr.round,
                rr.mean_loss,
                xr.mean_loss
            );
        }
    }
}

#[test]
fn xla_fedmlh_uses_hlo_decode() {
    if !available() {
        return;
    }
    let cfg = quick_cfg(1);
    let rt = RuntimeClient::new(&artifact_dir()).unwrap();
    let be = XlaBackend::new(rt, &cfg, Algo::FedMlh).unwrap();
    assert!(be.hlo_decode(), "tiny.fedmlh.decode must be compiled in");
}

#[test]
fn xla_b_override_without_artifact_falls_back() {
    if !available() {
        return;
    }
    // tiny ships no sweep artifacts → B override cannot find a train
    // artifact and must fail loudly at backend construction...
    let mut cfg = quick_cfg(1);
    cfg.override_b = 8;
    let rt = RuntimeClient::new(&artifact_dir()).unwrap();
    let err = match XlaBackend::new(rt.clone(), &cfg, Algo::FedMlh) {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("not in manifest"), "{err}");

    // ...while an R override (same sub-model shapes) constructs fine and
    // silently uses the rust decode fallback.
    let mut cfg = quick_cfg(1);
    cfg.override_r = 3;
    let be = XlaBackend::new(rt, &cfg, Algo::FedMlh).unwrap();
    assert!(!be.hlo_decode(), "R=3 decode artifact does not exist for tiny");
}

#[test]
fn compile_cache_is_shared_across_backends() {
    if !available() {
        return;
    }
    let rt = RuntimeClient::new(&artifact_dir()).unwrap();
    let cfg = quick_cfg(1);
    let _a = XlaBackend::new(Rc::clone(&rt), &cfg, Algo::FedAvg).unwrap();
    let n1 = rt.compiled_count();
    let _b = XlaBackend::new(Rc::clone(&rt), &cfg, Algo::FedAvg).unwrap();
    assert_eq!(rt.compiled_count(), n1, "second backend recompiled");
}

#[test]
fn eurlex_artifacts_compile_and_run_one_round() {
    if !available() {
        return;
    }
    // Smoke the realistic preset end to end for a single round (the full
    // 70-round run lives in examples/federated_eurlex.rs).
    let mut cfg = ExperimentConfig::preset("eurlex").unwrap();
    cfg.rounds = 1;
    cfg.patience = 0;
    let out = harness::run_pair(&cfg, &opts(BackendKind::Xla, 1)).unwrap();
    assert_eq!(out.fedmlh.n_models, 4);
    assert!(out.memory_ratio() > 1.0, "eurlex memory ratio {}", out.memory_ratio());
    assert!(out.fedavg.best.top1 >= 0.0);
}
