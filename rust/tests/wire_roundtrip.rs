//! Wire-codec properties, end to end:
//!
//! - `DenseF32` encode/decode is lossless;
//! - `QuantizedI8` round-trips within its per-tensor scale bound;
//! - `TopKDelta` at `frac = 1.0` reconstructs exactly what dense would;
//! - every codec's reported byte count equals the encoded buffer
//!   length fed to `CommMeter`, on random models *and* on a real
//!   federated round, where the compressed codecs must also be
//!   strictly smaller than dense.

use fedmlh::algo::scheme_for;
use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::data::synth::generate_preset;
use fedmlh::federated::backend::RustBackend;
use fedmlh::federated::server::{self, RunOutput};
use fedmlh::federated::wire::{
    apply_delta, decode_update, encode_changed, encode_delta, encode_update, CodecSpec,
    EncodedUpdate,
};
use fedmlh::model::params::{ModelParams, N_PARAMS};
use fedmlh::partition::noniid::{partition as noniid, NonIidOptions};
use fedmlh::util::prop::{check, Gen};

/// Random (global, local) pair with bounded perturbation.
fn random_pair(g: &mut Gen) -> (ModelParams, ModelParams) {
    let (d, h, out) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 12));
    let global = ModelParams::init(d, h, out, g.rng().next_u64());
    let mut local = global.clone();
    for t in local.tensors.iter_mut() {
        for v in t.data_mut() {
            *v += g.f32_in(-0.1, 0.1);
        }
    }
    (global, local)
}

#[test]
fn dense_roundtrip_is_lossless() {
    check("dense lossless", 25, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let enc = encode_update(CodecSpec::Dense, &global, &local).unwrap();
        let back = decode_update(&global, &enc).unwrap();
        assert_eq!(back, local);
        assert_eq!(enc.byte_len(), 4 * local.num_params());
    });
}

#[test]
fn quantized_roundtrip_is_scale_bounded() {
    check("q8 scale bound", 25, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let enc = encode_update(CodecSpec::QuantI8, &global, &local).unwrap();
        let back = decode_update(&global, &enc).unwrap();
        for (t_local, t_back) in local.tensors.iter().zip(back.tensors.iter()) {
            let max_abs = t_local.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = max_abs / 127.0;
            let err = t_local.max_abs_diff(t_back).unwrap();
            assert!(
                err <= 0.5 * scale + 1e-7,
                "quantization error {err} exceeds scale bound {scale}"
            );
        }
    });
}

#[test]
fn topk_full_fraction_equals_dense() {
    check("topk k=100% == dense", 25, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let dense = decode_update(
            &global,
            &encode_update(CodecSpec::Dense, &global, &local).unwrap(),
        )
        .unwrap();
        let topk = decode_update(
            &global,
            &encode_update(CodecSpec::TopK { frac: 1.0 }, &global, &local).unwrap(),
        )
        .unwrap();
        assert_eq!(topk, dense, "full-fraction topk must equal dense exactly");
    });
}

#[test]
fn grouped_quantization_roundtrips_within_per_block_bounds() {
    // The q8g contract (ROADMAP "group-wise" item): every element's
    // reconstruction error is at most half of its *block's* scale — a
    // strictly local bound, unlike q8's per-tensor one.
    check("q8g per-block scale bound", 25, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let block = g.usize_in(1, 16);
        let spec = CodecSpec::QuantI8Group { block };
        let enc = encode_update(spec, &global, &local).unwrap();
        // Wire roundtrip is exact (the payload is already quantized).
        let bytes = enc.to_bytes();
        assert_eq!(enc.byte_len(), bytes.len());
        let back =
            EncodedUpdate::from_bytes(spec, N_PARAMS, global.num_params(), &bytes).unwrap();
        assert_eq!(back, enc);
        // Per-element error ≤ per-block scale / 2.
        let decoded = decode_update(&global, &enc).unwrap();
        for (t_local, t_dec) in local.tensors.iter().zip(decoded.tensors.iter()) {
            let chunks = t_local.data().chunks(block).zip(t_dec.data().chunks(block));
            for (chunk_l, chunk_d) in chunks {
                let scale = chunk_l.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 127.0;
                for (&a, &b) in chunk_l.iter().zip(chunk_d.iter()) {
                    assert!(
                        (a - b).abs() <= 0.5 * scale + 1e-7,
                        "block {block}: err {} vs scale {scale}",
                        (a - b).abs()
                    );
                }
            }
        }
    });
}

#[test]
fn sub_byte_quantization_roundtrips_within_per_block_int4_bounds() {
    // The q4g contract: nibble-packed int4 over [-7, 7] per block, so
    // every element's reconstruction error is at most half its block's
    // scale (max_abs / 7) — coarser than q8g but still strictly local.
    check("q4g per-block scale bound", 25, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let block = g.usize_in(1, 16);
        let spec = CodecSpec::QuantI4Group { block };
        let enc = encode_update(spec, &global, &local).unwrap();
        // Wire roundtrip is exact, and byte accounting is bit-exact
        // ceil-div: the nibble stream pays ceil(n/2) bytes whether the
        // value count is even or odd.
        let bytes = enc.to_bytes();
        assert_eq!(enc.byte_len(), bytes.len());
        let n = global.num_params();
        let n_scales: usize = global
            .tensors
            .iter()
            .map(|t| t.data().len().div_ceil(block))
            .sum();
        assert_eq!(
            bytes.len(),
            4 + 4 * n_scales + n.div_ceil(2),
            "q4g bytes must be header + scales + ceil(n/2) packed nibbles"
        );
        let back =
            EncodedUpdate::from_bytes(spec, N_PARAMS, global.num_params(), &bytes).unwrap();
        assert_eq!(back, enc);
        // Per-element error ≤ per-block int4 scale / 2.
        let decoded = decode_update(&global, &enc).unwrap();
        for (t_local, t_dec) in local.tensors.iter().zip(decoded.tensors.iter()) {
            let chunks = t_local.data().chunks(block).zip(t_dec.data().chunks(block));
            for (chunk_l, chunk_d) in chunks {
                let scale = chunk_l.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 7.0;
                for (&a, &b) in chunk_l.iter().zip(chunk_d.iter()) {
                    assert!(
                        (a - b).abs() <= 0.5 * scale + 1e-7,
                        "block {block}: err {} vs scale {scale}",
                        (a - b).abs()
                    );
                }
            }
        }
    });
}

#[test]
fn q4g_framed_decode_rejects_structural_corruption() {
    // Targeted q4g structural fuzz, on top of the generic checksum
    // fuzz below: truncated scale tables, forged scale-count headers,
    // nonzero padding nibbles on odd value counts, and forged codec /
    // block tags must all come back as Err — never a panic, never a
    // silently different update.
    check("q4g structural fuzz", 50, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let block = g.usize_in(1, 9);
        let spec = CodecSpec::QuantI4Group { block };
        let enc = encode_update(spec, &global, &local).unwrap();
        let bytes = enc.to_bytes();
        let n_values = global.num_params();
        let decode = |b: &[u8]| EncodedUpdate::from_bytes(spec, N_PARAMS, n_values, b);
        assert_eq!(decode(&bytes).unwrap(), enc);

        // Truncation anywhere — inside the scale count, the scale
        // table, or the nibble stream — errs on the exact-length check.
        for _ in 0..4 {
            let cut = g.usize_in(0, bytes.len() - 1);
            assert!(decode(&bytes[..cut]).is_err(), "truncation to {cut} bytes accepted");
        }

        // Forged scale-count header: declaring more blocks than the
        // payload carries must err before anything is allocated off it.
        let mut forged = bytes.clone();
        forged[..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&forged).is_err(), "forged scale count accepted");

        // Odd value count: the final high nibble is padding and must
        // be zero; forging it is corruption, not data.
        if n_values % 2 == 1 {
            let mut padded = bytes.clone();
            let last = padded.len() - 1;
            padded[last] |= 0xf0;
            assert!(decode(&padded).is_err(), "nonzero padding nibble accepted");
        }

        // Forged family tag on the checksummed frame: even with a
        // recomputed (valid) checksum, a q8g tag on a q4g link errs at
        // the tag check.
        let mut framed = enc.to_framed_bytes();
        framed[2] = CodecSpec::QuantI8Group { block }.tag();
        let body_len = framed.len() - 8;
        let sum = {
            // Recompute FNV-1a over the forged body so only the tag—not
            // the checksum—trips the rejection.
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for &b in &framed[..body_len] {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            h
        };
        framed[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(
            EncodedUpdate::from_framed_bytes(spec, N_PARAMS, n_values, &framed).is_err(),
            "forged codec tag with a valid checksum accepted"
        );

        // Same-family block forgery: the raw payload parses under a
        // different block (the scale table is self-describing), but
        // decoding against the model errs on the scale-count check
        // instead of mis-scaling values.
        let other = CodecSpec::QuantI4Group { block: block + 1 };
        if let Ok(misread) = EncodedUpdate::from_bytes(other, N_PARAMS, n_values, &bytes) {
            let want: usize = global
                .tensors
                .iter()
                .map(|t| t.data().len().div_ceil(block + 1))
                .sum();
            if want != global
                .tensors
                .iter()
                .map(|t| t.data().len().div_ceil(block))
                .sum::<usize>()
            {
                assert!(
                    decode_update(&global, &misread).is_err(),
                    "block-forged q4g payload decoded without a scale-count error"
                );
            }
        }
    });
}

#[test]
fn delta_framing_applies_back_to_the_target() {
    // encode_delta/apply_delta on every codec family: sparse replaces,
    // quantized diffs, dense is lossless; encode_changed is bitwise.
    check("delta framing", 25, |g: &mut Gen| {
        let (base, target) = random_pair(g);
        // Lossless paths reconstruct the target exactly.
        for enc in [
            encode_delta(CodecSpec::Dense, &base, &target).unwrap(),
            encode_changed(&base, &target).unwrap(),
        ] {
            assert_eq!(apply_delta(&base, &enc).unwrap(), target);
        }
        // Sparse replacement: selected coordinates land exactly on the
        // target, unselected stay at the base.
        let frac = g.f32_in(0.05, 0.9);
        let enc = encode_delta(CodecSpec::TopKPacked { frac }, &base, &target).unwrap();
        let back = apply_delta(&base, &enc).unwrap();
        let (bf, tf, rf) = (base.flat_values(), target.flat_values(), back.flat_values());
        for i in 0..bf.len() {
            assert!(
                rf[i].to_bits() == tf[i].to_bits() || rf[i].to_bits() == bf[i].to_bits(),
                "coordinate {i} is neither base nor target"
            );
        }
        // Quantized diff: error bounded by the diff magnitude.
        let enc = encode_delta(CodecSpec::QuantI8, &base, &target).unwrap();
        let back = apply_delta(&base, &enc).unwrap();
        let max_diff = bf
            .iter()
            .zip(tf.iter())
            .fold(0.0f32, |m, (b, t)| m.max((t - b).abs()));
        for (t, r) in tf.iter().zip(back.flat_values().iter()) {
            assert!((t - r).abs() <= max_diff / 127.0 * 0.5 + 2e-6);
        }
    });
}

#[test]
fn byte_len_always_equals_encoded_buffer_length() {
    check("byte_len == to_bytes().len()", 25, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let frac = g.f32_in(0.05, 1.0);
        for spec in [
            CodecSpec::Dense,
            CodecSpec::QuantI8,
            CodecSpec::QuantI8Group { block: 16 },
            CodecSpec::QuantI4Group { block: 16 },
            CodecSpec::TopK { frac },
            CodecSpec::TopKPacked { frac },
        ] {
            let enc = encode_update(spec, &global, &local).unwrap();
            let bytes = enc.to_bytes();
            assert_eq!(enc.byte_len(), bytes.len(), "codec {}", enc.codec_name());
            let back =
                EncodedUpdate::from_bytes(spec, N_PARAMS, global.num_params(), &bytes).unwrap();
            assert_eq!(back, enc, "wire roundtrip for {}", enc.codec_name());
        }
    });
}

// ---------------------------------------------------------------------
// Real-round accounting: the meter must be charged exactly the encoded
// payload sizes, and the compressed codecs must beat dense.

fn real_round(codec: CodecSpec) -> (ExperimentConfig, RunOutput) {
    let mut cfg = ExperimentConfig::preset("tiny").unwrap();
    cfg.rounds = 2;
    cfg.patience = 0;
    cfg.clients = 4;
    cfg.clients_per_round = 2;
    cfg.local_epochs = 1;
    cfg.workers = 2; // exercise the engine path while metering
    cfg.codec = codec;
    let data = generate_preset(&cfg.preset, cfg.seed);
    let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
    let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
    let backend = RustBackend::new();
    let out = server::run(
        &cfg,
        scheme.as_ref(),
        &backend,
        &data.train,
        &data.test,
        &part,
    )
    .unwrap();
    (cfg, out)
}

#[test]
fn real_round_metered_bytes_match_codec_payloads() {
    // tiny FedMLH sub-model: out = B buckets.
    let cfg0 = ExperimentConfig::preset("tiny").unwrap();
    let n = cfg0.preset.param_count(cfg0.b());
    let items = |out: &RunOutput| (out.rounds_run * 2 * out.n_models) as u64; // S=2 clients

    let (_, dense) = real_round(CodecSpec::Dense);
    assert_eq!(dense.comm.uploaded(), items(&dense) * (4 * n) as u64);
    assert_eq!(dense.comm.uploaded_dense_equiv(), dense.comm.uploaded());

    let (_, q8) = real_round(CodecSpec::QuantI8);
    assert_eq!(
        q8.comm.uploaded(),
        items(&q8) * (n + 4 * N_PARAMS) as u64,
        "q8 uplink must be exactly n values + one scale per tensor"
    );
    assert!(q8.comm.uploaded() < dense.comm.uploaded());
    assert_eq!(q8.comm.uploaded_dense_equiv(), dense.comm.uploaded());

    let frac = 0.1f32;
    let k = ((n as f64 * frac as f64).ceil() as usize).clamp(1, n);
    let (_, topk) = real_round(CodecSpec::TopK { frac });
    assert_eq!(
        topk.comm.uploaded(),
        items(&topk) * (4 + 8 * k) as u64,
        "topk uplink must be exactly the entry payload"
    );
    assert!(topk.comm.uploaded() < dense.comm.uploaded());

    // q4g: bit-exact ceil-div accounting for the sub-byte payload —
    // u32 scale count + one f32 scale per (tensor-local) block + the
    // nibble stream at exactly ceil(n/2) bytes, odd counts included.
    let block = 64usize;
    let (_, q4g) = real_round(CodecSpec::QuantI4Group { block });
    let probe = ModelParams::init(cfg0.preset.d, cfg0.preset.hidden, cfg0.b(), 0);
    assert_eq!(probe.num_params(), n);
    let n_scales: usize = probe
        .tensors
        .iter()
        .map(|t| t.data().len().div_ceil(block))
        .sum();
    assert_eq!(
        q4g.comm.uploaded(),
        items(&q4g) * (4 + 4 * n_scales + n.div_ceil(2)) as u64,
        "q4g uplink must be exactly header + scales + ceil(n/2) packed bytes"
    );
    assert!(q4g.comm.uploaded() < q8.comm.uploaded());
    assert_eq!(q4g.comm.uploaded_dense_equiv(), dense.comm.uploaded());

    // Downlink stays a dense broadcast for every codec.
    for out in [&dense, &q8, &topk, &q4g] {
        assert_eq!(out.comm.downloaded(), items(out) * (4 * n) as u64);
    }
    // Compression ratio is reported, not guessed.
    assert!(q8.comm.upload_compression() > 3.5);
    assert!(q4g.comm.upload_compression() > 6.0);
    assert!(topk.comm.upload_compression() > 1.5);
}

#[test]
fn packed_topk_reconstructs_identically_and_ships_fewer_bytes() {
    // The entropy-coded index stream must change the wire size only:
    // same selection, same decoded parameters, strictly smaller payload.
    check("topkv == topk semantics", 25, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let frac = g.f32_in(0.05, 0.9);
        let raw = encode_update(CodecSpec::TopK { frac }, &global, &local).unwrap();
        let packed = encode_update(CodecSpec::TopKPacked { frac }, &global, &local).unwrap();
        assert_eq!(
            decode_update(&global, &raw).unwrap(),
            decode_update(&global, &packed).unwrap(),
            "decode must not depend on the index-stream encoding"
        );
        assert!(
            packed.byte_len() < raw.byte_len(),
            "packed {} >= raw {}",
            packed.byte_len(),
            raw.byte_len()
        );
    });
}

#[test]
fn packed_topk_real_round_compresses_beyond_raw_topk() {
    let frac = 0.1f32;
    let (_, raw) = real_round(CodecSpec::TopK { frac });
    let (_, packed) = real_round(CodecSpec::TopKPacked { frac });
    assert!(
        packed.comm.uploaded() < raw.comm.uploaded(),
        "topkv uplink {} >= topk uplink {}",
        packed.comm.uploaded(),
        raw.comm.uploaded()
    );
    assert!(packed.comm.upload_compression() > raw.comm.upload_compression());
}

// ---------------------------------------------------------------------
// Fault-tolerance fuzzing (the `federated::fault` uplink contract):
// hostile bytes come back as a descriptive `Err` — never a panic, and
// never a silently *different* update.

fn fuzz_specs(g: &mut Gen) -> CodecSpec {
    let frac = g.f32_in(0.05, 1.0);
    let specs = [
        CodecSpec::Dense,
        CodecSpec::QuantI8,
        CodecSpec::QuantI8Group { block: 8 },
        CodecSpec::QuantI4Group { block: 8 },
        CodecSpec::TopK { frac },
        CodecSpec::TopKPacked { frac },
    ];
    specs[g.usize_in(0, specs.len() - 1)]
}

#[test]
fn framed_decode_rejects_arbitrary_corruption_without_panicking() {
    check("framed decode rejects corruption", 50, |g: &mut Gen| {
        let (global, local) = random_pair(g);
        let spec = fuzz_specs(g);
        let enc = encode_update(spec, &global, &local).unwrap();
        let framed = enc.to_framed_bytes();
        let decode = |bytes: &[u8]| {
            EncodedUpdate::from_framed_bytes(spec, N_PARAMS, global.num_params(), bytes)
        };

        // The untouched frame round-trips…
        assert_eq!(decode(&framed).unwrap(), enc);

        // …every strict truncation errs…
        for _ in 0..4 {
            let cut = g.usize_in(0, framed.len() - 1);
            assert!(decode(&framed[..cut]).is_err(), "truncation to {cut} bytes accepted");
        }

        // …every single-bit flip errs (FNV-1a's per-byte step is
        // bijective, so one flipped bit always moves the checksum)…
        let pos = g.usize_in(0, framed.len() - 1);
        let bit = g.usize_in(0, 7);
        let mut flipped = framed.clone();
        flipped[pos] ^= 1 << bit;
        assert!(decode(&flipped).is_err(), "flipped bit {bit} of byte {pos} went undetected");

        // …appended garbage errs (declared length is exact)…
        let mut longer = framed.clone();
        longer.push(g.usize_in(0, 255) as u8);
        assert!(decode(&longer).is_err(), "trailing garbage accepted");

        // …and multi-byte smashes either err or reproduce the original
        // exactly (a smash can rewrite a byte to its old value).
        for _ in 0..4 {
            let mut smashed = framed.clone();
            for _ in 0..g.usize_in(1, 8) {
                let pos = g.usize_in(0, smashed.len() - 1);
                smashed[pos] = g.usize_in(0, 255) as u8;
            }
            if let Ok(back) = decode(&smashed) {
                assert_eq!(back, enc, "corrupted frame decoded to a different update");
            }
        }

        // Fully random buffers — including ones declaring pathological
        // payload sizes — err without a payload-sized allocation.
        let len = g.usize_in(0, 256);
        let junk: Vec<u8> = (0..len).map(|_| (g.rng().next_u64() & 0xff) as u8).collect();
        assert!(decode(&junk).is_err(), "random {len}-byte buffer accepted as a frame");
    });
}

#[test]
fn raw_decode_of_random_bytes_never_panics() {
    // The unframed parsers sit *under* the checksum; they still must
    // fail structurally (length/varint checks), not by panicking or
    // allocating off an attacker-declared count.
    check("raw from_bytes never panics", 100, |g: &mut Gen| {
        let spec = fuzz_specs(g);
        let n_values = g.usize_in(1, 64);
        let len = g.usize_in(0, 512);
        let bytes: Vec<u8> = (0..len).map(|_| (g.rng().next_u64() & 0xff) as u8).collect();
        if let Ok(enc) = EncodedUpdate::from_bytes(spec, N_PARAMS, n_values, &bytes) {
            // Structurally valid garbage is acceptable — it must still
            // round-trip through the serializer it claims to be.
            assert_eq!(enc.to_bytes().len(), enc.byte_len());
        }
    });
}

#[test]
fn compressed_runs_still_learn() {
    for codec in [
        CodecSpec::QuantI8,
        CodecSpec::QuantI8Group { block: 64 },
        CodecSpec::QuantI4Group { block: 64 },
        CodecSpec::TopK { frac: 0.25 },
        CodecSpec::TopKPacked { frac: 0.25 },
    ] {
        let (_, out) = real_round(codec);
        assert_eq!(out.rounds_run, 2);
        for rec in &out.history.records {
            assert!(
                rec.accuracy.top1.is_finite() && (0.0..=1.0).contains(&rec.accuracy.top1),
                "codec {} produced top1 {}",
                codec.name(),
                rec.accuracy.top1
            );
            assert!(rec.mean_loss.is_finite());
        }
    }
}
