//! Quickstart: train FedMLH on the `tiny` preset in a few seconds and
//! compare it against the FedAvg baseline — the smallest end-to-end use
//! of the public API.
//!
//! ```text
//! cargo run --release --example quickstart            # xla backend
//! cargo run --release --example quickstart -- rust    # no artifacts needed
//! ```

use anyhow::Result;

use fedmlh::config::{Algo, ExperimentConfig};
use fedmlh::harness::{self, report, BackendKind, HarnessOpts};
use fedmlh::serve::{Checkpoint, CheckpointCodec, InferenceEngine};

fn main() -> Result<()> {
    // 1. Pick a dataset preset and the paper's FL setup (K = 10 clients,
    //    S = 4 sampled per round, E = 5 local epochs).
    let mut cfg = ExperimentConfig::preset("tiny")?;
    cfg.rounds = 15;

    // 2. Choose the backend: compiled HLO artifacts (default) or the
    //    pure-rust reference MLP.
    let backend = match std::env::args().nth(1).as_deref() {
        Some("rust") => BackendKind::Rust,
        _ => BackendKind::Xla,
    };
    let opts = HarnessOpts {
        backend,
        verbose: true,
        ..HarnessOpts::default()
    };

    // 3. Train FedAvg and FedMLH on the same synthetic world with the
    //    same non-iid partition.
    let pair = harness::run_pair(&cfg, &opts)?;

    // 4. Compare them the way the paper's tables do.
    for (name, out) in [("FedAvg", &pair.fedavg), ("FedMLH", &pair.fedmlh)] {
        println!(
            "{name:>7}: best @1 {} @3 {} @5 {}  (round {}, comm {}, model {})",
            report::pct(out.best.top1),
            report::pct(out.best.top3),
            report::pct(out.best.top5),
            out.best_round,
            report::mb(out.comm_to_best),
            report::mb(out.model_bytes as u64),
        );
    }
    println!(
        "communication ratio {:.2}x, rounds ratio {:.2}x",
        pair.cc_ratio(),
        pair.rounds_ratio()
    );

    // 5. The per-round history is available for plotting.
    let last = pair.fedmlh.history.records.last().unwrap();
    println!(
        "fedmlh round {}: mean train loss {:.4}",
        last.round + 1,
        last.mean_loss
    );

    // 6. Persist the trained FedMLH model as a q8 serving checkpoint,
    //    reload it, and answer one prediction through the inference
    //    engine — the same path `fedmlh serve` exposes over HTTP.
    //    `pair.cfg` (not the local `cfg`) carries the seed the run
    //    actually trained with, so the checkpoint's hash tables match.
    let ckpt = Checkpoint::from_run(
        &pair.cfg,
        Algo::FedMlh,
        pair.cfg.preset.d,
        pair.cfg.preset.p,
        pair.fedmlh.final_globals.clone(),
    )?;
    let path = std::env::temp_dir().join("fedmlh_quickstart.fmlh");
    ckpt.save(&path, CheckpointCodec::QuantI8)?;
    let engine = InferenceEngine::new(Checkpoint::load(&path)?)?;
    let world = harness::build_world(&pair.cfg);
    let top = engine.predict_topk(world.data.test.features_of(0), 1, 5)?.remove(0);
    println!(
        "checkpoint {} ({:.2}x smaller than dense f32) → top-5 for test sample 0: {top:?}",
        path.display(),
        ckpt.dense_byte_size() as f64 / std::fs::metadata(&path)?.len() as f64
    );
    Ok(())
}
