//! End-to-end driver (EXPERIMENTS.md §E2E): the full paper pipeline on
//! the EURLex-4K analog — FedAvg vs FedMLH, 10 clients, non-iid
//! frequent-class partition, 70 synchronization rounds with early
//! stopping, executing the AOT HLO artifacts through PJRT.
//!
//! Prints the per-round loss/accuracy trace and the preset's rows of
//! Tables 3–7, and writes the Figure 3/4 series to `results/`.
//!
//! ```text
//! cargo run --release --example federated_eurlex              # full run
//! cargo run --release --example federated_eurlex -- quick     # 8 rounds
//! cargo run --release --example federated_eurlex -- quick rust
//! ```

use anyhow::Result;

use fedmlh::config::ExperimentConfig;
use fedmlh::harness::{self, figures, report, tables, BackendKind, HarnessOpts};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let backend = if args.iter().any(|a| a == "rust") {
        BackendKind::Rust
    } else {
        BackendKind::Xla
    };

    let cfg = ExperimentConfig::preset("eurlex")?;
    let opts = HarnessOpts {
        backend,
        rounds: if quick { Some(8) } else { None },
        verbose: true,
        ..HarnessOpts::default()
    };

    eprintln!(
        "== federated_eurlex: p={} classes, {} train samples, K={} S={} E={}, R={} B={} ==",
        cfg.preset.p,
        cfg.preset.n_train,
        cfg.clients,
        cfg.clients_per_round,
        cfg.local_epochs,
        cfg.r(),
        cfg.b()
    );

    let pair = harness::run_pair(&cfg, &opts)?;

    // Loss/accuracy curve (the paper's Fig. 3, textual form).
    println!("\n-- FedMLH training trace (round, mean loss, mean@k) --");
    for rec in &pair.fedmlh.history.records {
        println!(
            "round {:>3}  loss {:.4}  mean@k {:>6}  @1 {:>6}  infreq@1 {:>6}",
            rec.round + 1,
            rec.mean_loss,
            report::pct(rec.accuracy.mean_topk()),
            report::pct(rec.accuracy.top1),
            report::pct(rec.accuracy.infreq1),
        );
    }

    let pairs = [pair];
    println!("\n{}", tables::all_pair_tables(&pairs));

    let out_dir = std::path::Path::new("results");
    report::write_result(out_dir, "fig3_eurlex.csv", &figures::fig3(&pairs[0]))?;
    report::write_result(out_dir, "tables_eurlex.md", &tables::all_pair_tables(&pairs))?;
    eprintln!("wrote results/fig3_eurlex.csv and results/tables_eurlex.md");

    // The paper's headline shape checks, stated explicitly.
    let p = &pairs[0];
    println!("shape checks (paper's qualitative claims on this testbed):");
    println!(
        "  FedMLH ≥ FedAvg on mean@k:        {} ({} vs {})",
        p.fedmlh.best.mean_topk() >= p.fedavg.best.mean_topk(),
        report::pct(p.fedmlh.best.mean_topk()),
        report::pct(p.fedavg.best.mean_topk())
    );
    println!(
        "  infrequent-class gain dominates:  {} (infreq@1 {} vs {})",
        p.fedmlh.best.infreq1 >= p.fedavg.best.infreq1,
        report::pct(p.fedmlh.best.infreq1),
        report::pct(p.fedavg.best.infreq1)
    );
    println!("  communication ratio > 1:          {} ({:.2}x)", p.cc_ratio() > 1.0, p.cc_ratio());
    println!("  memory ratio > 1:                 {} ({:.2}x)", p.memory_ratio() > 1.0, p.memory_ratio());
    Ok(())
}
