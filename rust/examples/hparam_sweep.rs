//! Figure-5 hyper-parameter sensitivity: sweep the hash-table size B
//! and the number of hash tables R for FedMLH and report accuracy vs
//! memory — the trade-off the paper's Section 6.2 tunes.
//!
//! ```text
//! cargo run --release --example hparam_sweep                 # eurlex, quick
//! cargo run --release --example hparam_sweep -- wiki31 full  # preset, full rounds
//! cargo run --release --example hparam_sweep -- eurlex full rust
//! ```

use anyhow::Result;

use fedmlh::config::ExperimentConfig;
use fedmlh::harness::{figures, report, BackendKind, HarnessOpts};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("eurlex");
    let full = args.iter().any(|a| a == "full");
    let backend = if args.iter().any(|a| a == "rust") {
        BackendKind::Rust
    } else {
        BackendKind::Xla
    };

    let cfg = ExperimentConfig::preset(preset)?;
    let opts = HarnessOpts {
        backend,
        rounds: if full { None } else { Some(10) },
        verbose: true,
        ..HarnessOpts::default()
    };

    // -- Figure 5a/5c: sensitivity to B (R fixed at the preset value)
    let mut b_values = cfg.preset.sweep_b.to_vec();
    b_values.push(cfg.preset.b);
    b_values.sort_unstable();
    anyhow::ensure!(
        !b_values.is_empty(),
        "preset '{preset}' has no B sweep values (only eurlex/wiki31 ship sweep artifacts)"
    );
    println!("== B sweep on '{preset}' (R = {}) ==", cfg.r());
    let b_points = figures::fig5_sweep_b(&cfg, &b_values, &opts)?;
    for pt in &b_points {
        println!(
            "B = {:>5}: @1 {:>6} @3 {:>6} @5 {:>6}  best round {:>3}  model {}",
            pt.value,
            report::pct(pt.top1),
            report::pct(pt.top3),
            report::pct(pt.top5),
            pt.best_round,
            report::mb(pt.model_bytes as u64)
        );
    }

    // -- Figure 5b/5d: sensitivity to R (B fixed at the preset value)
    let mut r_values = cfg.preset.sweep_r.to_vec();
    r_values.push(cfg.preset.r);
    r_values.sort_unstable();
    println!("\n== R sweep on '{preset}' (B = {}) ==", cfg.b());
    let r_points = figures::fig5_sweep_r(&cfg, &r_values, &opts)?;
    for pt in &r_points {
        println!(
            "R = {:>5}: @1 {:>6} @3 {:>6} @5 {:>6}  best round {:>3}  model {}",
            pt.value,
            report::pct(pt.top1),
            report::pct(pt.top3),
            report::pct(pt.top5),
            pt.best_round,
            report::mb(pt.model_bytes as u64)
        );
    }

    let out = std::path::Path::new("results");
    report::write_result(out, &format!("fig5_{preset}_b.csv"), &figures::fig5_csv("B", &b_points))?;
    report::write_result(out, &format!("fig5_{preset}_r.csv"), &figures::fig5_csv("R", &r_points))?;
    eprintln!("wrote results/fig5_{preset}_{{b,r}}.csv");
    Ok(())
}
