//! Non-iid analysis: everything the paper's Section 5 claims about the
//! data, measured on a generated analog dataset —
//!
//! 1. Figure 2a/2b: the power-law class imbalance and the positive mass
//!    carried by infrequent classes,
//! 2. Figure 2c: the frequent-class partition structure,
//! 3. Lemma 1: how many positives a bucket sees vs a raw class,
//! 4. Lemma 2: the collision-safety of the preset's (R, B),
//! 5. Theorem 2: the KL contraction from label hashing, on the real
//!    partition and against an iid control.
//!
//! ```text
//! cargo run --release --example noniid_analysis -- [preset]   # default eurlex
//! ```

use anyhow::Result;

use fedmlh::config::ExperimentConfig;
use fedmlh::data::stats::LabelStats;
use fedmlh::harness::{self, figures, report};
use fedmlh::hashing::label_hash::LabelHasher;
use fedmlh::partition::{divergence, iid};
use fedmlh::theory;

fn main() -> Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "eurlex".into());
    let cfg = ExperimentConfig::preset(&preset)?;
    let world = harness::build_world(&cfg);
    let train = &world.data.train;
    let stats = LabelStats::from_dataset(train);

    println!(
        "== non-iid analysis: '{}' ({}) — p={}, N={}, K={} clients ==\n",
        cfg.preset.name,
        cfg.preset.paper_analog,
        train.p(),
        train.len(),
        cfg.clients
    );

    // -- Fig 2a/2b: class imbalance
    let counts = train.class_counts();
    let nonzero = counts.iter().filter(|&&c| c > 0).count();
    let max_count = counts.iter().max().copied().unwrap_or(0);
    println!("classes with ≥1 positive: {nonzero}/{}", train.p());
    println!("most frequent class count: {max_count}");
    for thr in [1e-4f64, 1e-3, 1e-2] {
        let mass = stats.positive_mass_cdf(&[thr]);
        let frac = stats.freq_cdf(&[thr]);
        println!(
            "norm-freq ≤ {thr:.0e}: {} of classes, carrying {} of positives",
            report::pct(frac[0].y),
            report::pct(mass[0].y)
        );
    }

    // -- Fig 2c: partition structure
    println!("\n-- partition (first 6 clients) --");
    for (k, shard) in world.partition.clients.iter().take(6).enumerate() {
        let owned: Vec<String> = world
            .partition
            .class_owner
            .iter()
            .filter(|(_, o)| *o == k)
            .map(|(c, _)| c.to_string())
            .collect();
        println!(
            "client {k}: {} samples, owns frequent classes [{}]",
            shard.len(),
            owned.join(",")
        );
    }

    // -- Lemma 1
    let n_lab: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..train.p()).collect();
    order.sort_by_key(|&c| counts[c]);
    println!("\n-- Lemma 1: positives per training target (B = {}) --", cfg.b());
    for (tag, j) in [
        ("p10 class", order[train.p() / 10]),
        ("median class", order[train.p() / 2]),
        ("p90 class", order[train.p() * 9 / 10]),
    ] {
        let bound = theory::lemma1_lower_bound(counts[j], n_lab, cfg.b());
        println!(
            "{tag:>13} (id {j}): n_j = {:>4} → bucket bound {:>8.1} ({:.0}x more signal)",
            counts[j],
            bound,
            bound / counts[j].max(1) as f64
        );
    }

    // -- Lemma 2
    let delta = 0.05;
    println!("\n-- Lemma 2: distinguishability at δ = {delta} --");
    println!(
        "min B: {:.1}; preset B = {} (R = {}) → union bound {:.2e}",
        theory::lemma2_min_buckets(train.p(), cfg.r(), delta),
        cfg.b(),
        cfg.r(),
        theory::collision_union_bound(train.p(), cfg.b(), cfg.r())
    );
    let mc = theory::all_table_collision_probability_mc(train.p(), cfg.b(), cfg.r(), 100, cfg.seed);
    println!("MC full-collision frequency over 100 hasher draws: {mc:.3}");

    // -- Theorem 2
    let hasher = LabelHasher::new(cfg.seed, cfg.r(), train.p(), cfg.b());
    let c = theory::kl_contraction_on_partition(train, &world.partition, &hasher, 1e-3);
    println!("\n-- Theorem 2: KL contraction (non-iid partition) --");
    println!(
        "mean pairwise KL: classes {:.4} → buckets {:.4}  (contraction {:.2}x, holds: {})",
        c.kl_classes,
        c.kl_buckets,
        c.factor(),
        c.holds()
    );
    let iid_part = iid::partition(train.len(), cfg.clients, cfg.seed);
    let c_iid = theory::kl_contraction_on_partition(train, &iid_part, &hasher, 1e-3);
    println!(
        "iid control:      classes {:.4} → buckets {:.4}",
        c_iid.kl_classes, c_iid.kl_buckets
    );
    let (_, mean_div) = divergence::mean_pairwise_divergence(train, &world.partition, &hasher, 1e-3);
    println!("per-table bucket divergence on non-iid partition: {mean_div:.4}");

    // -- CSV outputs for plotting
    let out = std::path::Path::new("results");
    report::write_result(out, &format!("fig2a_{preset}.csv"), &figures::fig2a(train))?;
    report::write_result(out, &format!("fig2b_{preset}.csv"), &figures::fig2b(train))?;
    report::write_result(
        out,
        &format!("fig2c_{preset}.csv"),
        &figures::fig2c(train, &world.partition),
    )?;
    eprintln!("\nwrote results/fig2{{a,b,c}}_{preset}.csv");
    Ok(())
}
