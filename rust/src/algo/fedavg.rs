//! FedAvg baseline (McMahan et al., 2017; paper Section 3.1).
//!
//! One global model whose last layer spans all `p` classes, trained on
//! raw multi-hot class labels; inference scores are the logits
//! themselves. This is the comparison baseline of every table in the
//! paper's evaluation.

use anyhow::Result;

use crate::federated::backend::TrainBackend;
use crate::federated::batcher::Target;

use super::LabelScheme;

/// The degenerate one-model scheme.
pub struct FedAvgScheme {
    p: usize,
}

impl FedAvgScheme {
    pub fn new(p: usize) -> Self {
        FedAvgScheme { p }
    }
}

impl LabelScheme for FedAvgScheme {
    fn n_models(&self) -> usize {
        1
    }

    fn out_dim(&self) -> usize {
        self.p
    }

    fn target(&self, j: usize) -> Target {
        assert_eq!(j, 0, "FedAvg has a single model");
        Target::Classes
    }

    fn scores(
        &self,
        logits: &[Vec<f32>],
        rows: usize,
        _backend: &dyn TrainBackend,
    ) -> Result<Vec<f32>> {
        assert_eq!(logits.len(), 1);
        // Logits over classes ARE the scores; truncate padding rows.
        Ok(logits[0][..rows * self.p].to_vec())
    }

    fn name(&self) -> &'static str {
        "fedavg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federated::backend::RustBackend;

    #[test]
    fn passthrough_scores() {
        let s = FedAvgScheme::new(3);
        assert_eq!(s.n_models(), 1);
        assert_eq!(s.out_dim(), 3);
        let logits = vec![vec![1.0, 2.0, 3.0, 9.0, 9.0, 9.0]];
        let backend = RustBackend::new();
        let scores = s.scores(&logits, 1, &backend).unwrap();
        assert_eq!(scores, vec![1.0, 2.0, 3.0]); // padded row dropped
        assert!(matches!(s.target(0), Target::Classes));
    }

    #[test]
    #[should_panic]
    fn rejects_submodel_index() {
        FedAvgScheme::new(3).target(1);
    }
}
