//! The two algorithms under study, expressed as label/score schemes the
//! federated server is generic over.
//!
//! - [`fedavg`] — the baseline: one global model with a full `p`-way
//!   output layer trained on raw class labels.
//! - [`fedmlh`] — the paper's contribution: R sub-models over B-bucket
//!   hashed labels, count-sketch mean decode at inference.

pub mod fedavg;
pub mod fedmlh;

use anyhow::Result;

use crate::config::{Algo, ExperimentConfig};
use crate::data::dataset::Dataset;
use crate::federated::backend::TrainBackend;
use crate::federated::batcher::Target;

/// How an algorithm maps datasets to training targets and sub-model
/// logits to class scores. One implementation per paper baseline.
///
/// `Send + Sync` because the parallel round engine
/// ([`crate::federated::engine::RoundEngine`]) shares the scheme across
/// worker threads when building per-item batchers; both paper schemes
/// are immutable plain data (FedMLH shares its hash tables via `Arc`).
pub trait LabelScheme: Send + Sync {
    /// Number of independently-federated models (1 or R).
    fn n_models(&self) -> usize;

    /// Output width of each model (p or B).
    fn out_dim(&self) -> usize;

    /// Training target for sub-model `j`.
    fn target(&self, j: usize) -> Target;

    /// Combine per-model logits (each flat `[rows, out_dim]`) into class
    /// scores (flat `[rows, p]`).
    fn scores(
        &self,
        logits: &[Vec<f32>],
        rows: usize,
        backend: &dyn TrainBackend,
    ) -> Result<Vec<f32>>;

    fn name(&self) -> &'static str;
}

/// Build the scheme for `algo` under `cfg` (hash functions are drawn
/// from the config seed, mirroring the server broadcast of Algorithm 2).
pub fn scheme_for(
    cfg: &ExperimentConfig,
    algo: Algo,
    ds: &Dataset,
) -> Box<dyn LabelScheme> {
    match algo {
        Algo::FedAvg => Box::new(fedavg::FedAvgScheme::new(ds.p())),
        Algo::FedMlh => Box::new(fedmlh::FedMlhScheme::new(
            cfg.seed,
            cfg.r(),
            ds.p(),
            cfg.b(),
        )),
    }
}
