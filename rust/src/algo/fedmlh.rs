//! FedMLH (the paper's contribution, Section 4 / Algorithm 2).
//!
//! R independent sub-models, each trained against the bucket labels of
//! its own 2-universal hash table over the p classes; at inference the
//! per-class score is the count-sketch *mean* of the R bucket logits the
//! class hashes into (Fig. 1b). The hash tables are drawn once from the
//! run seed — the analog of the server broadcast in Algorithm 2 line 3.

use std::sync::Arc;

use anyhow::Result;

use crate::federated::backend::TrainBackend;
use crate::federated::batcher::Target;
use crate::hashing::label_hash::LabelHasher;
use crate::util::rng::derive_seed;

use super::LabelScheme;

/// Seed-derivation stream for the label hash tables. Shared with the
/// serving checkpoint ([`crate::serve::checkpoint`]) so a reloaded
/// model reconstructs bit-identical tables from the stored seed.
pub const LABEL_HASH_STREAM: u64 = 0x3e_747ab1e5;

/// The [`LabelHasher`] seed a run with root seed `root_seed` draws its
/// tables from (Algorithm 2 line 3's broadcast, as a derived seed).
pub fn label_hash_seed(root_seed: u64) -> u64 {
    derive_seed(root_seed, LABEL_HASH_STREAM)
}

/// R-sub-model scheme with shared hash tables.
pub struct FedMlhScheme {
    hasher: Arc<LabelHasher>,
    /// Cached `[R, p]` class→bucket matrix for the decode path.
    idx: Vec<i32>,
    p: usize,
}

impl FedMlhScheme {
    pub fn new(seed: u64, r: usize, p: usize, b: usize) -> Self {
        let hasher = Arc::new(LabelHasher::new(label_hash_seed(seed), r, p, b));
        let idx = hasher.index_matrix_i32();
        FedMlhScheme { hasher, idx, p }
    }

    pub fn hasher(&self) -> &Arc<LabelHasher> {
        &self.hasher
    }

    pub fn index_matrix(&self) -> &[i32] {
        &self.idx
    }
}

impl LabelScheme for FedMlhScheme {
    fn n_models(&self) -> usize {
        self.hasher.r()
    }

    fn out_dim(&self) -> usize {
        self.hasher.b()
    }

    fn target(&self, j: usize) -> Target {
        assert!(j < self.hasher.r());
        Target::Buckets {
            hasher: self.hasher.clone(),
            table: j,
        }
    }

    fn scores(
        &self,
        logits: &[Vec<f32>],
        rows: usize,
        backend: &dyn TrainBackend,
    ) -> Result<Vec<f32>> {
        let r = self.hasher.r();
        let b = self.hasher.b();
        assert_eq!(logits.len(), r);
        // Flatten [R][rows_padded * B] → [R, rows, B]; the per-model
        // logits may be padded past `rows` — take exactly rows*b each.
        let mut flat = Vec::with_capacity(r * rows * b);
        for table in logits {
            assert!(table.len() >= rows * b);
            flat.extend_from_slice(&table[..rows * b]);
        }
        backend.decode(&flat, &self.idx, r, rows, b, self.p)
    }

    fn name(&self) -> &'static str {
        "fedmlh"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::decode::sketch_decode;
    use crate::federated::backend::RustBackend;
    use crate::util::rng::Rng;

    #[test]
    fn dimensions() {
        let s = FedMlhScheme::new(1, 4, 100, 16);
        assert_eq!(s.n_models(), 4);
        assert_eq!(s.out_dim(), 16);
        assert_eq!(s.index_matrix().len(), 400);
        assert!(matches!(s.target(3), Target::Buckets { table: 3, .. }));
    }

    #[test]
    fn scores_match_direct_decode() {
        let s = FedMlhScheme::new(2, 3, 50, 8);
        let mut rng = Rng::new(4);
        let rows = 2;
        // padded logits: 4 rows worth, only 2 real
        let logits: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..4 * 8).map(|_| rng.next_f32()).collect())
            .collect();
        let backend = RustBackend::new();
        let got = s.scores(&logits, rows, &backend).unwrap();
        let mut flat = Vec::new();
        for t in &logits {
            flat.extend_from_slice(&t[..rows * 8]);
        }
        let want = sketch_decode(&flat, s.index_matrix(), 3, rows, 8, 50);
        assert_eq!(got, want);
    }

    #[test]
    fn seeded_hash_tables_are_stable() {
        let a = FedMlhScheme::new(9, 2, 30, 4);
        let b = FedMlhScheme::new(9, 2, 30, 4);
        assert_eq!(a.index_matrix(), b.index_matrix());
        let c = FedMlhScheme::new(10, 2, 30, 4);
        assert_ne!(a.index_matrix(), c.index_matrix());
    }
}
