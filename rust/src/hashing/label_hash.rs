//! R-table label hashing (paper Algorithm 2, lines 2–7).
//!
//! The server draws R independent 2-universal functions
//! `h_j: {0..p-1} -> {0..B-1}` once and broadcasts them; every client
//! then maps each sample's multi-hot class label vector `y ∈ {0,1}^p`
//! to R multi-hot *bucket* label vectors `z_j ∈ {0,1}^B`, where bucket
//! `i` of table `j` is the union of the labels hashed into it:
//!
//! `z[j][i] = ⋁_{l : h_j(l) = i} y[l]`  (line 6)
//!
//! The class→bucket index matrix (`idx[r][class]`) is also what the
//! count-sketch decode consumes at inference, both in rust
//! ([`crate::eval::decode`]) and in the AOT decode artifact.

use crate::util::rng::{derive_seed, Rng};

use super::universal::UniversalHash;

/// The broadcast hashing state shared by server and all clients.
#[derive(Clone, Debug)]
pub struct LabelHasher {
    /// `tables[r][class] = bucket` — precomputed h_r over all p classes.
    tables: Vec<Vec<u32>>,
    p: usize,
    b: usize,
}

impl LabelHasher {
    /// Draw R independent functions (seeded; every component that needs
    /// the same tables derives the same seed).
    pub fn new(seed: u64, r: usize, p: usize, b: usize) -> Self {
        assert!(r > 0 && p > 0 && b > 0);
        let mut tables = Vec::with_capacity(r);
        for j in 0..r {
            let mut rng = Rng::new(derive_seed(seed, 0x4a5_000 + j as u64));
            let h = UniversalHash::draw(&mut rng, b);
            tables.push((0..p).map(|c| h.hash(c as u64) as u32).collect());
        }
        LabelHasher { tables, p, b }
    }

    pub fn r(&self) -> usize {
        self.tables.len()
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn b(&self) -> usize {
        self.b
    }

    /// Bucket of `class` in table `r`.
    #[inline]
    pub fn bucket(&self, r: usize, class: usize) -> usize {
        self.tables[r][class] as usize
    }

    /// The `[R, p]` int32 index matrix the decode artifact consumes
    /// (row-major).
    pub fn index_matrix_i32(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.r() * self.p);
        for t in &self.tables {
            out.extend(t.iter().map(|&b| b as i32));
        }
        out
    }

    /// Map a sparse positive-class list to the R multi-hot bucket label
    /// vectors, written into `out` (length R*B, zeroed by this call).
    pub fn bucket_labels_into(&self, positives: &[u32], out: &mut [f32]) {
        assert_eq!(out.len(), self.r() * self.b);
        out.fill(0.0);
        for (j, table) in self.tables.iter().enumerate() {
            let row = &mut out[j * self.b..(j + 1) * self.b];
            for &c in positives {
                row[table[c as usize] as usize] = 1.0;
            }
        }
    }

    /// Bucket labels for table `j` only (length B, zeroed by this call).
    pub fn bucket_labels_table_into(&self, j: usize, positives: &[u32], out: &mut [f32]) {
        assert_eq!(out.len(), self.b);
        out.fill(0.0);
        let table = &self.tables[j];
        for &c in positives {
            out[table[c as usize] as usize] = 1.0;
        }
    }

    /// Number of classes landing in each bucket of table `j`
    /// (load statistics; Lemma 1 empirics).
    pub fn bucket_loads(&self, j: usize) -> Vec<usize> {
        let mut loads = vec![0usize; self.b];
        for &b in &self.tables[j] {
            loads[b as usize] += 1;
        }
        loads
    }

    /// Whether any pair of classes collides in *all* tables (the event
    /// Lemma 2 bounds: such a pair is indistinguishable to FedMLH).
    /// O(p²·R) — intended for tests and the theory harness at small p.
    pub fn has_fully_colliding_pair(&self) -> bool {
        for x in 0..self.p {
            for y in (x + 1)..self.p {
                if (0..self.r()).all(|j| self.tables[j][x] == self.tables[j][y]) {
                    return true;
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn deterministic_and_broadcastable() {
        let a = LabelHasher::new(7, 3, 100, 10);
        let b = LabelHasher::new(7, 3, 100, 10);
        assert_eq!(a.index_matrix_i32(), b.index_matrix_i32());
        let c = LabelHasher::new(8, 3, 100, 10);
        assert_ne!(a.index_matrix_i32(), c.index_matrix_i32());
    }

    #[test]
    fn tables_are_independent() {
        let h = LabelHasher::new(7, 2, 1000, 16);
        let same = (0..1000)
            .filter(|&c| h.bucket(0, c) == h.bucket(1, c))
            .count();
        // If tables were identical this would be 1000; independent ≈ 1000/16.
        assert!(same < 150, "tables look identical: {same}");
    }

    #[test]
    fn bucket_labels_are_union_of_hashed_positives() {
        check("bucket labels union", 30, |g| {
            let p = g.usize_in(10, 300);
            let b = g.usize_in(2, 40);
            let r = g.usize_in(1, 5);
            let h = LabelHasher::new(g.rng().next_u64(), r, p, b);
            let n_pos = g.usize_in(1, 10.min(p));
            let positives: Vec<u32> = (0..n_pos)
                .map(|_| g.usize_in(0, p) as u32)
                .collect();
            let mut out = vec![0.0f32; r * b];
            h.bucket_labels_into(&positives, &mut out);
            // brute force union
            for j in 0..r {
                for i in 0..b {
                    let want = positives.iter().any(|&c| h.bucket(j, c as usize) == i);
                    let got = out[j * b + i] > 0.5;
                    assert_eq!(got, want, "table {j} bucket {i}");
                }
            }
        });
    }

    #[test]
    fn per_table_matches_full() {
        let h = LabelHasher::new(3, 4, 200, 25);
        let positives = [1u32, 5, 77, 199];
        let mut full = vec![0.0f32; 4 * 25];
        h.bucket_labels_into(&positives, &mut full);
        for j in 0..4 {
            let mut one = vec![0.0f32; 25];
            h.bucket_labels_table_into(j, &positives, &mut one);
            assert_eq!(&full[j * 25..(j + 1) * 25], &one[..]);
        }
    }

    #[test]
    fn index_matrix_layout() {
        let h = LabelHasher::new(11, 2, 50, 8);
        let m = h.index_matrix_i32();
        assert_eq!(m.len(), 100);
        for c in 0..50 {
            assert_eq!(m[c] as usize, h.bucket(0, c));
            assert_eq!(m[50 + c] as usize, h.bucket(1, c));
        }
    }

    #[test]
    fn bucket_loads_sum_to_p() {
        let h = LabelHasher::new(13, 3, 500, 32);
        for j in 0..3 {
            let loads = h.bucket_loads(j);
            assert_eq!(loads.iter().sum::<usize>(), 500);
        }
    }

    #[test]
    fn full_collision_detection() {
        // R=1: any bucket with >= 2 classes is a fully-colliding pair.
        let h = LabelHasher::new(1, 1, 100, 4);
        assert!(h.has_fully_colliding_pair());
        // Large B, enough tables: collision-free w.h.p. (Lemma 2).
        let h = LabelHasher::new(1, 4, 50, 64);
        assert!(!h.has_fully_colliding_pair());
    }
}
