//! The classic count sketch (paper Section 3.2, Algorithm 1).
//!
//! FedMLH is "count sketch over the label space with learned buckets":
//! this module is the plain data-structure version, kept as a substrate
//! both because the paper's background defines it and because tests use
//! it to validate the mean/median retrieval estimators the decode path
//! inherits.

use crate::util::rng::{derive_seed, Rng};

use super::universal::UniversalHash;

/// Count sketch with K hash tables of R buckets each.
#[derive(Clone, Debug)]
pub struct CountSketch {
    hashes: Vec<UniversalHash>,
    /// `table[k][bucket]` accumulator matrix M.
    table: Vec<Vec<f32>>,
    buckets: usize,
}

/// Retrieval estimator: the paper uses median classically but adopts the
/// mean for FedMLH's log-probability decode ("we may also take the
/// mean ... by the law of large numbers, mean also gives a good central
/// estimate").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Estimator {
    Median,
    Mean,
}

impl CountSketch {
    pub fn new(seed: u64, k: usize, buckets: usize) -> Self {
        assert!(k > 0 && buckets > 0);
        let hashes = (0..k)
            .map(|j| {
                let mut rng = Rng::new(derive_seed(seed, 0xc5_000 + j as u64));
                UniversalHash::draw(&mut rng, buckets)
            })
            .collect();
        CountSketch {
            hashes,
            table: vec![vec![0.0; buckets]; k],
            buckets,
        }
    }

    pub fn k(&self) -> usize {
        self.hashes.len()
    }

    pub fn buckets(&self) -> usize {
        self.buckets
    }

    /// Algorithm 1 line 4: `M[j, h_j(i)] += x_i * s_j(i)`.
    pub fn insert(&mut self, i: u64, x: f32) {
        for (j, h) in self.hashes.iter().enumerate() {
            self.table[j][h.hash(i)] += x * h.sign(i);
        }
    }

    /// Insert a whole vector (index = component).
    pub fn insert_vector(&mut self, xs: &[f32]) {
        for (i, &x) in xs.iter().enumerate() {
            self.insert(i as u64, x);
        }
    }

    /// Algorithm 1 line 6: estimate of x_i.
    pub fn retrieve(&self, i: u64, est: Estimator) -> f32 {
        let mut vals: Vec<f32> = self
            .hashes
            .iter()
            .enumerate()
            .map(|(j, h)| self.table[j][h.hash(i)] * h.sign(i))
            .collect();
        match est {
            Estimator::Mean => vals.iter().sum::<f32>() / vals.len() as f32,
            Estimator::Median => {
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = vals.len();
                if n % 2 == 1 {
                    vals[n / 2]
                } else {
                    0.5 * (vals[n / 2 - 1] + vals[n / 2])
                }
            }
        }
    }

    /// Merge another sketch built with the same seed/k/buckets
    /// (sketches are linear — this is what makes them federable).
    pub fn merge(&mut self, other: &CountSketch) {
        assert_eq!(self.hashes, other.hashes, "incompatible sketches");
        for (mine, theirs) in self.table.iter_mut().zip(other.table.iter()) {
            for (a, b) in mine.iter_mut().zip(theirs.iter()) {
                *a += b;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn exact_when_no_collisions() {
        // One heavy item, huge table: retrieval is exact.
        let mut cs = CountSketch::new(1, 3, 4096);
        cs.insert(42, 7.5);
        assert!((cs.retrieve(42, Estimator::Median) - 7.5).abs() < 1e-6);
        assert!((cs.retrieve(42, Estimator::Mean) - 7.5).abs() < 1e-6);
        assert!(cs.retrieve(43, Estimator::Median).abs() < 1e-6);
    }

    #[test]
    fn recovers_heavy_hitters() {
        let mut cs = CountSketch::new(2, 5, 256);
        let n = 2000usize;
        let mut xs = vec![1.0f32; n];
        xs[17] = 500.0;
        xs[1203] = -400.0;
        cs.insert_vector(&xs);
        let a = cs.retrieve(17, Estimator::Median);
        let b = cs.retrieve(1203, Estimator::Median);
        assert!((a - 500.0).abs() < 50.0, "{a}");
        assert!((b + 400.0).abs() < 50.0, "{b}");
    }

    #[test]
    fn median_estimate_unbiased_on_average() {
        check("cs unbiased", 10, |g| {
            let seed = g.rng().next_u64();
            let mut cs = CountSketch::new(seed, 5, 128);
            let n = 500;
            let xs: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            cs.insert_vector(&xs);
            // average absolute error stays below the l2/ sqrt(B) noise scale
            let l2: f32 = xs.iter().map(|x| x * x).sum::<f32>().sqrt();
            let noise = l2 / (128f32).sqrt();
            let mut err_sum = 0.0f32;
            for i in 0..n {
                err_sum += (cs.retrieve(i as u64, Estimator::Median) - xs[i]).abs();
            }
            let mean_err = err_sum / n as f32;
            assert!(mean_err < 3.0 * noise, "{mean_err} vs {noise}");
        });
    }

    #[test]
    fn sketches_are_linear_under_merge() {
        let seed = 99;
        let mut a = CountSketch::new(seed, 3, 64);
        let mut b = CountSketch::new(seed, 3, 64);
        let mut whole = CountSketch::new(seed, 3, 64);
        let xs: Vec<f32> = (0..100).map(|i| i as f32).collect();
        for (i, &x) in xs.iter().enumerate() {
            whole.insert(i as u64, x);
            if i % 2 == 0 {
                a.insert(i as u64, x);
            } else {
                b.insert(i as u64, x);
            }
        }
        a.merge(&b);
        for i in 0..100u64 {
            assert!(
                (a.retrieve(i, Estimator::Median) - whole.retrieve(i, Estimator::Median)).abs()
                    < 1e-5
            );
        }
    }

    #[test]
    #[should_panic]
    fn merge_rejects_incompatible() {
        let mut a = CountSketch::new(1, 3, 64);
        let b = CountSketch::new(2, 3, 64);
        a.merge(&b);
    }
}
