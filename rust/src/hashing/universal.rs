//! Seeded 2-universal hash family.
//!
//! `h(x) = ((a·x + b) mod P) mod range` with P a Mersenne prime
//! (2^61 − 1), `a` uniform in [1, P), `b` uniform in [0, P). The family
//! is 2-universal: for x ≠ y, Pr[h(x) = h(y)] ≤ 1/range (up to the usual
//! floor bias ≤ range/P, negligible at P ≈ 2^61). FedMLH needs genuine
//! independence *between* the R tables (paper Lemma 2 assumes it), which
//! seeded draws of (a, b) provide.

use crate::util::rng::Rng;

/// The Mersenne prime 2^61 − 1.
pub const P61: u64 = (1 << 61) - 1;

/// One member of the family; also carries a ±1 sign hash (used by the
/// count-sketch substrate; label hashing ignores it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UniversalHash {
    a: u64,
    b: u64,
    sign_a: u64,
    sign_b: u64,
    range: u64,
}

#[inline]
fn mod_p61(x: u128) -> u64 {
    // Fast reduction modulo the Mersenne prime 2^61-1: fold the high
    // bits twice (first fold leaves up to 65 bits, second leaves 62).
    let s = (x & P61 as u128) + (x >> 61);
    let mut s = ((s & P61 as u128) + (s >> 61)) as u64;
    if s >= P61 {
        s -= P61;
    }
    s
}

impl UniversalHash {
    /// Draw a hash function with the given output range from `rng`.
    pub fn draw(rng: &mut Rng, range: usize) -> Self {
        assert!(range > 0, "hash range must be positive");
        let a = 1 + (rng.next_u64() % (P61 - 1));
        let b = rng.next_u64() % P61;
        let sign_a = 1 + (rng.next_u64() % (P61 - 1));
        let sign_b = rng.next_u64() % P61;
        UniversalHash {
            a,
            b,
            sign_a,
            sign_b,
            range: range as u64,
        }
    }

    /// Bucket of `x` in `[0, range)`.
    #[inline]
    pub fn hash(&self, x: u64) -> usize {
        let v = mod_p61(self.a as u128 * x as u128 + self.b as u128);
        (v % self.range) as usize
    }

    /// ±1 sign of `x` (count-sketch sign hash).
    #[inline]
    pub fn sign(&self, x: u64) -> f32 {
        let v = mod_p61(self.sign_a as u128 * x as u128 + self.sign_b as u128);
        if v & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    pub fn range(&self) -> usize {
        self.range as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn deterministic_for_same_seed() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let h1 = UniversalHash::draw(&mut r1, 100);
        let h2 = UniversalHash::draw(&mut r2, 100);
        for x in 0..1000u64 {
            assert_eq!(h1.hash(x), h2.hash(x));
            assert_eq!(h1.sign(x), h2.sign(x));
        }
    }

    #[test]
    fn outputs_in_range() {
        check("hash in range", 30, |g| {
            let range = g.usize_in(1, 5000);
            let h = UniversalHash::draw(g.rng(), range);
            for _ in 0..100 {
                let x = g.rng().next_u64() % 1_000_000;
                assert!(h.hash(x) < range);
                let s = h.sign(x);
                assert!(s == 1.0 || s == -1.0);
            }
        });
    }

    #[test]
    fn buckets_roughly_uniform() {
        let mut rng = Rng::new(99);
        let b = 50;
        let h = UniversalHash::draw(&mut rng, b);
        let mut counts = vec![0usize; b];
        let n = 100_000u64;
        for x in 0..n {
            counts[h.hash(x)] += 1;
        }
        let expect = n as f64 / b as f64;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.15, "bucket {i}: {c} vs {expect}");
        }
    }

    #[test]
    fn collision_rate_near_two_universal_bound() {
        // Empirical pairwise collision probability over random pairs must
        // be ~1/range.
        let mut rng = Rng::new(31);
        let range = 64;
        let mut collisions = 0usize;
        let trials = 30_000;
        for t in 0..trials {
            let h = if t % 100 == 0 {
                UniversalHash::draw(&mut rng, range)
            } else {
                UniversalHash::draw(&mut rng, range)
            };
            let x = rng.next_u64() % 1_000_000;
            let mut y = rng.next_u64() % 1_000_000;
            if y == x {
                y += 1;
            }
            if h.hash(x) == h.hash(y) {
                collisions += 1;
            }
        }
        let rate = collisions as f64 / trials as f64;
        let bound = 1.0 / range as f64;
        assert!(rate < bound * 1.4, "rate {rate} vs bound {bound}");
    }

    #[test]
    fn signs_balanced() {
        let mut rng = Rng::new(8);
        let h = UniversalHash::draw(&mut rng, 10);
        let pos: usize = (0..10_000u64).filter(|&x| h.sign(x) > 0.0).count();
        assert!((4500..5500).contains(&pos), "{pos}");
    }

    #[test]
    fn mod_p61_matches_naive() {
        check("mod p61", 50, |g| {
            let x = (g.rng().next_u64() as u128) * (g.rng().next_u64() as u128 >> 3);
            assert_eq!(mod_p61(x), (x % P61 as u128) as u64);
        });
    }
}
