//! Label hashing: the core mechanism of FedMLH.
//!
//! - [`universal`] — seeded 2-universal hash family `h(x) = ((a·x + b)
//!   mod P) mod B` (paper Algorithm 2, line 2: the *server* draws the R
//!   functions once and broadcasts them, so every client buckets classes
//!   identically).
//! - [`label_hash`] — the R-table class→bucket maps and multi-hot bucket
//!   label construction (Algorithm 2, lines 4–7).
//! - [`count_sketch`] — the classic count sketch of Section 3.2, built as
//!   a standalone substrate (and used by tests to cross-validate the
//!   mean-decode estimator the paper adopts).

pub mod count_sketch;
pub mod label_hash;
pub mod universal;

pub use label_hash::LabelHasher;
pub use universal::UniversalHash;
