//! `fedmlh` — the coordinator CLI: train runs, paper tables/figures,
//! and theory validation, all from the compiled rust binary (python is
//! never touched after `make artifacts`).
//!
//! ```text
//! fedmlh run     --preset eurlex --algo fedmlh --backend xla
//! fedmlh run     --preset eurlex --codec topk --topk-frac 0.05 \
//!                --down-codec q8 --error-feedback on
//!                                # compress both links; error-feedback
//!                                # accumulators keep the dropped signal
//! fedmlh run     --preset eurlex --down-codec topk:0.1 --resync-every 8
//!                                # per-client versioned delta downlink:
//!                                # each client gets a top-k delta vs the
//!                                # base it last decoded; stale clients
//!                                # are dense-resynced
//! fedmlh run     --preset tiny --async --registry 1000000 --buffer 50 \
//!                --concurrency 128 --dropout 0.2
//!                                # event-driven async federation over a
//!                                # million-client virtual registry:
//!                                # staleness-weighted buffered aggregation
//!                                # on a seeded simulated clock (bitwise
//!                                # reproducible, incl. across --workers)
//! fedmlh run     --preset tiny --scenario smoke     # canned async scenarios
//!                                                   # (smoke | million)
//! fedmlh run     --preset tiny --inject corrupt:0.05,nan:0.02 \
//!                --robust-agg norm-clip:10
//!                                # deterministic fault injection (seeded
//!                                # fates; bitwise reproducible) behind
//!                                # defensive aggregation
//! fedmlh run     --preset tiny --snapshot-every 5 --resume snapdir
//!                                # periodic crash-resume snapshots; the
//!                                # same command re-run resumes bitwise
//!                                # from the latest snapshot
//! fedmlh run     --preset eurlex --save model.fmlh  # + persist a serving checkpoint
//! fedmlh run     --preset eurlex --save tuned.fmlh --save-delta base.fmlh
//!                                # write tuned.fmlh as a lossless delta
//!                                # against base.fmlh (ship tiny updates
//!                                # to devices that already hold the base)
//! fedmlh serve   --checkpoint model.fmlh --port 8080 --workers 4
//!                                                   # POST /predict · GET /healthz · GET /metrics
//! fedmlh serve   --checkpoint base.fmlh --delta d1.fmlh,d2.fmlh
//!                                # apply a delta-checkpoint chain at load
//! fedmlh serve   --checkpoint model.fmlh --replicas 3
//!                                # 3 health-tracked predictor replicas
//!                                # sharing one copy of the weights;
//!                                # POST /reload hot-swaps the model
//!                                # (?canary=10 rolls it out to 10% of
//!                                # traffic with auto-promote/rollback)
//! fedmlh tables  --presets eurlex,wiki31            # Tables 3–7
//! fedmlh table1  --presets all                      # dataset stats
//! fedmlh table2  --presets all                      # R and B
//! fedmlh fig2    --preset eurlex                    # label-freq CDFs + partition
//! fedmlh fig3    --preset eurlex                    # accuracy curves CSV
//! fedmlh fig5    --preset eurlex --sweep b          # hyper-param sensitivity
//! fedmlh theory  --preset eurlex                    # Lemma 1/2, Theorem 2
//! fedmlh figasync --sync-history results/run_tiny_fedmlh.csv \
//!                 --async-history results/run_tiny_fedmlh_async.csv
//!                                # sync-vs-async accuracy vs each mode's
//!                                # own clock (measured vs simulated)
//! fedmlh artifacts                                  # list compiled artifacts
//! ```
//!
//! The `serve` path is the deployment half of the paper's story: the
//! hashed model is small enough to ship (q8 checkpoints are ~4× smaller
//! than dense f32), and the count-sketch decode answers `POST /predict`
//! with exactly the offline evaluation's top-k. The serving control
//! plane keeps that true across model updates: `POST /reload` (body
//! `{"checkpoint": path}` or `{"checkpoint": base, "deltas": [...]}`)
//! atomically hot-swaps the model with zero dropped requests;
//! `?canary=<pct>` routes that share of traffic to the new version and
//! auto-promotes after a clean `--canary-window` (or auto-rolls-back on
//! error-rate/latency regression; `?window=<n>` overrides per reload).
//! SIGINT/SIGTERM (or `POST /quitquitquit`) drain gracefully: stop
//! accepting, finish in-flight requests within `--drain-secs`, flush a
//! final metrics snapshot.
//!
//! ## Performance features
//!
//! Build with `--features simd` to enable the AVX2 bodies of the inner
//! kernel loops (`kernels::simd`), runtime-dispatched behind CPU
//! detection with the scalar loops as fallback. The SIMD bodies are
//! written to produce bit-identical results to scalar, so the feature
//! changes speed, never numbers — every determinism pin holds with it
//! on or off. Independently, `--workers N` beyond a round's item count
//! flows down into row-sliced intra-kernel parallelism
//! (`kernels::parallel`), so one big client still fills N cores.
//! Sub-byte compression is available on every link: `--codec
//! q4g[:block]` / `--down-codec q4g[:block]` pack group-wise int4
//! updates two-per-byte (~7–8× smaller than dense), and `--save-codec
//! q4g` does the same for `.fmlh` checkpoints.
//!
//! ## Observability
//!
//! Every training command accepts `--log-level <error|warn|info|debug>`
//! (leveled stderr logging; `--quiet` implies `error`) and
//! `--trace-out <path>`, which records named nested spans — rounds,
//! per-client train/encode, aggregation, evaluation, kernel sections;
//! async runs are stamped on the *simulated* clock — and writes a
//! Chrome-trace-event JSON on exit. Load the file at
//! <https://ui.perfetto.dev> or `chrome://tracing`. Tracing is purely
//! observational: instrumented runs stay bitwise identical.
//!
//! `fedmlh serve` answers `GET /metrics` with JSON (the historical
//! default, now including reload counters and per-version rows) and
//! with Prometheus text exposition at `GET /metrics?format=prometheus`
//! — serve-local request/latency/batch stats plus the process-global
//! metrics registry (per-generation `fedmlh_serve_version_*` and
//! per-replica `fedmlh_serve_replica_*` series, the
//! `fedmlh_serve_reloads_total` / `fedmlh_serve_rollout_transitions_total`
//! counters, and the `fedmlh_serve_generation` gauge) in one scrape.
//! Reloads and rollout transitions also land as spans/instants in
//! `--trace-out` traces when tracing is enabled.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use fedmlh::config::presets::{by_name, paper_presets};
use fedmlh::config::{
    Algo, CanaryConfig, DatasetPreset, ExperimentConfig, InjectConfig, ObsConfig, RobustAgg,
    SimConfig,
};
use fedmlh::federated::sim::Dist;
use fedmlh::federated::transport::DownCodec;
use fedmlh::federated::wire::CodecSpec;
use fedmlh::harness::{self, figures, report, tables, BackendKind, HarnessOpts, PairResult};
use fedmlh::hashing::label_hash::LabelHasher;
use fedmlh::partition::divergence;
use fedmlh::runtime::RuntimeClient;
use fedmlh::serve::{
    Checkpoint, CheckpointCodec, ControlPlane, DeltaCodec, ServeOpts, Server, ServerHandle,
};
use fedmlh::theory;
use fedmlh::util::cli::{Args, Parsed};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        fedmlh::log_error!("{e:#}");
        std::process::exit(1);
    }
}

const COMMANDS: &str = "run, serve, tables, table1, table2, fig2, fig3, fig4, fig5, figasync, theory, artifacts";

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        bail!("usage: fedmlh <command> [flags]\ncommands: {COMMANDS}\n(`fedmlh <command> --help` for flags)");
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        "tables" => cmd_tables(rest),
        "table1" => cmd_table1(rest),
        "table2" => cmd_table2(rest),
        "fig2" => cmd_fig2(rest),
        "fig3" | "fig4" => cmd_fig34(rest),
        "fig5" => cmd_fig5(rest),
        "figasync" => cmd_figasync(rest),
        "theory" => cmd_theory(rest),
        "artifacts" => cmd_artifacts(rest),
        other => bail!("unknown command '{other}'\ncommands: {COMMANDS}"),
    }
}

/// Flags shared by every training command.
fn common_args(args: Args) -> Args {
    args.flag("backend", "xla", "training backend: xla (artifacts) | rust (reference)")
        .flag("artifacts", "artifacts", "artifact directory (manifest.json)")
        .flag("seed", "42", "root seed for data/partition/hashing/sampling")
        .flag("rounds", "0", "override synchronization rounds (0 = preset default 70)")
        .flag("out", "results", "output directory for CSV/markdown")
        .flag("workers", "1", "round-engine worker threads (1 = sequential; results identical)")
        .flag("codec", "dense", "update (client->server) codec: dense | q8 | q8g[:block] | q4g[:block] | topk[:frac] | topkv[:frac]")
        .flag("topk-frac", "0.1", "fraction of coordinates the topk/topkv codecs ship")
        .flag("down-codec", "dense", "broadcast (server->client) codec: dense | q8 | q8g[:block] | q4g[:block] | topk[:frac] | topkv[:frac] (sparse = per-client versioned deltas vs each client's last decoded base)")
        .flag("resync-every", "8", "delta downlink: full dense resync for clients whose base is more than N rounds stale (0 = resync every participation)")
        .flag("error-feedback", "off", "stateful transport (on|off): client error-feedback accumulators + server broadcast-residual folding")
        .flag("trace-out", "", "write a Chrome-trace-event JSON span trace here on exit (open in Perfetto / chrome://tracing)")
        .flag("log-level", "info", "stderr log threshold: error | warn | info | debug")
        .switch("fast", "use the *_fast (jnp-lowered) artifact family — same math, ~7x faster on CPU")
        .switch("quiet", "suppress progress logging (implies --log-level error)")
}

/// Parse the shared observability flags, apply them process-wide (log
/// threshold + tracer install), and hand back the config so the caller
/// can `export()` the trace once its run completes. `--quiet` lowers
/// the threshold to `error` unless `--log-level` says otherwise.
fn obs_from(p: &Parsed) -> Result<ObsConfig> {
    let trace = p.get("trace-out");
    let trace_out = (!trace.is_empty()).then(|| PathBuf::from(trace));
    let level = if p.get_bool("quiet") && p.get("log-level") == "info" {
        "error"
    } else {
        p.get("log-level")
    };
    let obs = ObsConfig::new(trace_out, level)?;
    obs.apply();
    Ok(obs)
}

fn parse_on_off(flag: &str, value: &str) -> Result<bool> {
    match value {
        "on" | "true" => Ok(true),
        "off" | "false" => Ok(false),
        other => bail!("--{flag} must be 'on' or 'off', got '{other}'"),
    }
}

fn opts_from(p: &Parsed) -> Result<HarnessOpts> {
    let rounds = p.get_usize("rounds")?;
    Ok(HarnessOpts {
        backend: BackendKind::parse(p.get("backend"))?,
        artifact_dir: PathBuf::from(p.get("artifacts")),
        out_dir: Some(PathBuf::from(p.get("out"))),
        rounds: if rounds == 0 { None } else { Some(rounds) },
        fast: p.get_bool("fast"),
        seed: p.get_u64("seed")?,
        verbose: !p.get_bool("quiet"),
        workers: p.get_usize("workers")?,
        codec: CodecSpec::parse(p.get("codec"), p.get_f32("topk-frac")?)?,
        down_codec: DownCodec::parse(p.get("down-codec"), p.get_f32("topk-frac")?)?,
        resync_every: p.get_usize("resync-every")?,
        error_feedback: parse_on_off("error-feedback", p.get("error-feedback"))?,
    })
}

/// Assemble the async-sim config: scenario preset first (if any), then
/// explicit flags on top. The CLI parser has no presence detection, so
/// "differs from the declared default" is the override signal — the
/// declared defaults match `SimConfig::default()` exactly.
fn sim_config_from(p: &Parsed) -> Result<SimConfig> {
    let scenario = p.get("scenario");
    let mut sim = if scenario.is_empty() {
        SimConfig::default()
    } else {
        SimConfig::scenario(scenario)?
    };
    sim.async_mode = sim.async_mode || p.get_bool("async");
    if p.get("registry") != "0" {
        sim.registry = p.get_usize("registry")?;
    }
    if p.get("buffer") != "10" {
        sim.buffer = p.get_usize("buffer")?;
    }
    if p.get("concurrency") != "32" {
        sim.concurrency = p.get_usize("concurrency")?;
    }
    if p.get("dropout") != "0" {
        sim.dropout = p.get_f64("dropout")?;
    }
    if p.get("latency-dist") != "lognormal:2,0.7" {
        sim.latency = Dist::parse(p.get("latency-dist"))?;
    }
    if p.get("bandwidth-dist") != "lognormal:20,0.8" {
        sim.bandwidth = Dist::parse(p.get("bandwidth-dist"))?;
    }
    if p.get("staleness-exp") != "0.5" {
        sim.staleness_exp = p.get_f64("staleness-exp")?;
    }
    Ok(sim)
}

fn preset_list(spec: &str) -> Result<Vec<DatasetPreset>> {
    if spec == "all" {
        return Ok(paper_presets());
    }
    spec.split(',').map(|s| by_name(s.trim())).collect()
}

// ---------------------------------------------------------------- run

fn cmd_run(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new("fedmlh run", "train one algorithm end to end"))
        .flag("preset", "eurlex", "dataset preset (tiny|eurlex|wiki31|amztitle|wikititle)")
        .flag("algo", "fedmlh", "fedavg | fedmlh")
        .flag("clients", "10", "total clients K")
        .flag("sampled", "4", "clients per round S")
        .flag("epochs", "5", "local epochs E")
        .flag("lr", "0", "learning rate (0 = preset default)")
        .flag("b", "0", "override buckets per table B (fedmlh)")
        .flag("r", "0", "override hash tables R (fedmlh)")
        .switch("async", "event-driven asynchronous federation: staleness-weighted buffered aggregation (FedBuff-style) on a seeded simulated clock")
        .flag("scenario", "", "canned async scenario: smoke (10k registry) | million (1M registry); explicit sim flags below override it")
        .flag("registry", "0", "async: virtual client registry size (0 = --clients); profiles are derived lazily so memory stays O(--concurrency), not O(registry)")
        .flag("buffer", "10", "async: apply one staleness-weighted aggregation once K client updates have arrived")
        .flag("concurrency", "32", "async: clients kept in flight on the simulated clock")
        .flag("dropout", "0", "async: probability a dispatched client dies mid-round (charged its download only, never uploads)")
        .flag("latency-dist", "lognormal:2,0.7", "async: per-client compute seconds/epoch: fixed:<v> | uniform:<lo>,<hi> | lognormal:<median>,<sigma>")
        .flag("bandwidth-dist", "lognormal:20,0.8", "async: per-client link Mbit/s (down and up drawn independently), same grammar as --latency-dist")
        .flag("staleness-exp", "0.5", "async: staleness discount exponent; an update s versions stale weighs (1+s)^-exp")
        .flag("inject", "none", "deterministic fault injection, e.g. corrupt:0.05,truncate:0.01,nan:0.02,fail:0.1 — fates are drawn from the seeded RNG per (round, client, sub-model), so injected runs are bitwise reproducible")
        .flag("robust-agg", "none", "defensive aggregation: none | norm-clip:<c> (clip each client delta's L2 norm to c) | trimmed:<frac> (coordinate-wise trimmed mean); non-finite updates are screened whenever not 'none'")
        .flag("snapshot-every", "0", "write a crash-resume snapshot into the --resume dir every N rounds (0 = off; synchronous loop only)")
        .flag("resume", "", "snapshot directory: an existing snapshot there resumes the run bitwise from its round; --snapshot-every writes new snapshots into it")
        .flag("save", "", "write the trained model as a serving checkpoint to this path")
        .flag("save-codec", "q8", "full-checkpoint codec: q8 (~4x smaller) | q4g (~7x smaller, group-wise int4) | dense (ignored with --save-delta; see --delta-codec)")
        .flag("save-delta", "", "with --save: write the checkpoint as a delta against this base .fmlh (apply with `fedmlh serve --delta`)")
        .flag("delta-codec", "sparse", "delta payload codec (with --save-delta): sparse (changed coordinates, lossless) | q8diff (quantized difference, ~4x smaller, lossy)")
        .parse(argv)?;
    let obs = obs_from(&p)?;
    let opts = opts_from(&p)?;
    let algo = Algo::parse(p.get("algo"))?;

    let mut cfg = ExperimentConfig::preset(p.get("preset"))?;
    cfg.clients = p.get_usize("clients")?;
    cfg.clients_per_round = p.get_usize("sampled")?;
    cfg.local_epochs = p.get_usize("epochs")?;
    cfg.override_b = p.get_usize("b")?;
    cfg.override_r = p.get_usize("r")?;
    let lr = p.get_f64("lr")? as f32;
    if lr > 0.0 {
        cfg.lr = lr;
    }
    cfg.sim = sim_config_from(&p)?;
    cfg.inject = InjectConfig::parse(p.get("inject"))?;
    cfg.robust = RobustAgg::parse(p.get("robust-agg"))?;
    cfg.snapshot_every = p.get_usize("snapshot-every")?;
    let resume = p.get("resume");
    if !resume.is_empty() {
        cfg.snapshot_dir = Some(PathBuf::from(resume));
    }
    opts.configure(&mut cfg);
    cfg.validate()?;

    let world = harness::build_world(&cfg);
    let rt = match opts.backend {
        BackendKind::Xla => Some(RuntimeClient::new(&opts.artifact_dir)?),
        BackendKind::Rust => None,
    };
    let backend = harness::make_backend(opts.backend, rt.as_ref(), &cfg, algo)?;
    let scheme = fedmlh::algo::scheme_for(&cfg, algo, &world.data.train);
    if opts.verbose {
        fedmlh::log_info!(
            "run: {} on '{}' ({}), K={} S={} E={} rounds≤{} backend={} workers={} codec={} down={} feedback={}",
            algo.name(),
            cfg.preset.name,
            cfg.preset.paper_analog,
            cfg.clients,
            cfg.clients_per_round,
            cfg.local_epochs,
            cfg.rounds,
            backend.name(),
            cfg.workers,
            cfg.codec.name(),
            cfg.down_codec.name(),
            if cfg.error_feedback { "on" } else { "off" }
        );
        if cfg.inject.any() || !matches!(cfg.robust, RobustAgg::None) || cfg.snapshot_every > 0 {
            fedmlh::log_info!(
                "run: fault tolerance: inject={} robust-agg={} snapshot-every={} resume={}",
                cfg.inject,
                cfg.robust.name(),
                cfg.snapshot_every,
                cfg.snapshot_dir
                    .as_ref()
                    .map(|d| d.display().to_string())
                    .unwrap_or_else(|| "-".to_string())
            );
        }
        if cfg.sim.async_mode {
            fedmlh::log_info!(
                "run: async sim: registry={} buffer={} concurrency={} dropout={} latency={} bandwidth={} staleness-exp={}",
                cfg.client_population(),
                cfg.sim.buffer,
                cfg.sim.concurrency,
                cfg.sim.dropout,
                cfg.sim.latency.name(),
                cfg.sim.bandwidth.name(),
                cfg.sim.staleness_exp
            );
        }
    }
    let out = if cfg.sim.async_mode {
        fedmlh::federated::sim::run_async(
            &cfg,
            scheme.as_ref(),
            backend.as_ref(),
            &world.data.train,
            &world.data.test,
            &world.partition,
        )?
    } else {
        fedmlh::federated::server::run(
            &cfg,
            scheme.as_ref(),
            backend.as_ref(),
            &world.data.train,
            &world.data.test,
            &world.partition,
        )?
    };

    println!(
        "preset={} algo={} backend={}",
        cfg.preset.name,
        algo.name(),
        backend.name()
    );
    println!(
        "best @1/@3/@5 = {} / {} / {}  (round {} of {} run)",
        report::pct(out.best.top1),
        report::pct(out.best.top3),
        report::pct(out.best.top5),
        out.best_round,
        out.rounds_run
    );
    println!(
        "comm to best = {}   model bytes/client = {}   mean round = {:.2}s   total = {:.1}s",
        report::mb(out.comm_to_best),
        report::mb(out.model_bytes as u64),
        out.history.mean_round_seconds(),
        out.total_seconds
    );
    println!(
        "uplink: {} actual vs {} dense-equivalent ({:.2}x compression, codec={}, feedback={})",
        report::mb(out.comm.uploaded()),
        report::mb(out.comm.uploaded_dense_equiv()),
        out.comm.upload_compression(),
        cfg.codec.name(),
        if cfg.error_feedback { "on" } else { "off" }
    );
    println!(
        "downlink: {} actual vs {} dense-equivalent ({:.2}x compression, codec={})",
        report::mb(out.comm.downloaded()),
        report::mb(out.comm.downloaded_dense_equiv()),
        out.comm.download_compression(),
        cfg.down_codec.name()
    );
    let timing = out.history.mean_timing();
    println!(
        "round time split: train {:.3}s  encode {:.3}s  aggregate {:.3}s  (mean per evaluated round; train/encode summed over the round's client x sub-model items)",
        timing.train_seconds, timing.encode_seconds, timing.aggregate_seconds
    );
    if let Some(s) = &out.sim {
        println!(
            "async sim: {} dispatched / {} arrived / {} dropped / {} failed over {} aggregations; simulated clock {:.1}s; staleness mean {:.2} max {}",
            s.dispatched,
            s.arrived,
            s.dropped,
            s.failed,
            s.aggregations,
            s.sim_seconds,
            s.mean_staleness,
            s.max_staleness
        );
    }
    if let Some(dir) = &opts.out_dir {
        let name = format!("run_{}_{}.csv", cfg.preset.name, algo.name());
        report::write_result(dir, &name, &out.history.to_csv())?;
        // A Prometheus-format snapshot of the process-global registry —
        // fault counters (`fedmlh_faults_total{kind}`), robust-agg
        // screening, round/comm totals — for offline inspection and CI.
        report::write_result(
            dir,
            "metrics.prom",
            &fedmlh::obs::metrics::global().render_prometheus(),
        )?;
        if opts.verbose {
            fedmlh::log_info!("run: history → {}/{name}", dir.display());
        }
    }
    let save = p.get("save");
    let save_delta = p.get("save-delta");
    if save.is_empty() && !save_delta.is_empty() {
        bail!("--save-delta needs --save <path> for the delta output");
    }
    if !save.is_empty() {
        let ckpt = Checkpoint::from_run(
            &cfg,
            algo,
            world.data.train.d(),
            world.data.train.p(),
            out.final_globals,
        )?;
        let path = PathBuf::from(save);
        if !save_delta.is_empty() {
            let base_path = PathBuf::from(save_delta);
            let base = Checkpoint::load(&base_path)?;
            let codec = DeltaCodec::parse(p.get("delta-codec"))?;
            let delta = ckpt.delta_against(&base, codec)?;
            delta.save(&path)?;
            let size = std::fs::metadata(&path)?.len();
            println!(
                "delta checkpoint → {} ({} bytes vs {} dense f32, {:.2}x, codec={}; apply with `fedmlh serve --checkpoint {} --delta {}`)",
                path.display(),
                size,
                ckpt.dense_byte_size(),
                ckpt.dense_byte_size() as f64 / size as f64,
                codec.name(),
                base_path.display(),
                path.display()
            );
        } else {
            let codec = CheckpointCodec::parse(p.get("save-codec"))?;
            ckpt.save(&path, codec)?;
            let size = std::fs::metadata(&path)?.len();
            println!(
                "checkpoint → {} ({} bytes, codec={}, {:.2}x vs dense f32; load with `fedmlh serve --checkpoint {}`)",
                path.display(),
                size,
                codec.name(),
                ckpt.dense_byte_size() as f64 / size as f64,
                path.display()
            );
        }
    }
    obs.export()?;
    Ok(())
}

/// `fedmlh serve` — load a checkpoint and answer predictions over HTTP,
/// with hot reload (`POST /reload`), canary rollouts (`?canary=<pct>`),
/// replica supervision (`--replicas`), and graceful drain on
/// SIGINT/SIGTERM or `POST /quitquitquit`.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let p = Args::new("fedmlh serve", "serve a trained checkpoint over HTTP")
        .required("checkpoint", "path to a .fmlh checkpoint (from `fedmlh run --save`)")
        .flag("delta", "", "comma-separated delta checkpoints (from `fedmlh run --save-delta`), applied onto --checkpoint in order")
        .flag("host", "127.0.0.1", "interface to bind")
        .flag("port", "8080", "TCP port (0 = ephemeral)")
        .flag("replicas", "1", "predictor replicas per model version (independent health-tracked worker pools over one shared copy of the weights)")
        .flag("workers", "2", "inference worker threads per replica (micro-batch pool)")
        .flag("max-batch", "32", "max requests coalesced into one forward pass")
        .flag("drain-secs", "5", "graceful-shutdown budget: seconds to wait for in-flight requests after SIGINT/SIGTERM or POST /quitquitquit")
        .flag("canary-window", "50", "canary rollout: requests the canary must serve before the promote decision (POST /reload?canary=<pct>; ?window=<n> overrides per reload)")
        .flag("canary-max-error-rate", "0.05", "canary rollout: error rate tolerated inside the window; exceeding the budget rolls back immediately")
        .flag("canary-p99-ratio", "10", "canary rollout: max canary p99 latency as a multiple of stable p99 (0 disables the latency guard)")
        .flag("max-body-bytes", "4194304", "largest accepted request body; a larger declared Content-Length is answered 413 without reading the body")
        .flag("log-level", "info", "stderr log threshold: error | warn | info | debug")
        .parse(argv)?;
    ObsConfig::new(None, p.get("log-level"))?.apply();
    let port = p.get_usize("port")?;
    if port > u16::MAX as usize {
        bail!("--port {port} exceeds 65535");
    }
    let replicas = p.get_usize("replicas")?;
    let workers = p.get_usize("workers")?;
    let max_batch = p.get_usize("max-batch")?;
    if replicas == 0 {
        bail!("replicas must be positive");
    }
    if workers == 0 {
        bail!("workers must be positive");
    }
    if max_batch == 0 {
        bail!("max-batch must be positive");
    }
    let canary = CanaryConfig {
        window: p.get_usize("canary-window")?,
        max_error_rate: p.get_f64("canary-max-error-rate")?,
        p99_ratio: p.get_f64("canary-p99-ratio")?,
    };
    canary.validate()?;
    let base_path = PathBuf::from(p.get("checkpoint"));
    let deltas = p.get("delta");
    let (ckpt, source) = if deltas.is_empty() {
        (
            Checkpoint::load(&base_path)?,
            base_path.display().to_string(),
        )
    } else {
        let paths: Vec<PathBuf> = deltas.split(',').map(|s| PathBuf::from(s.trim())).collect();
        let ckpt = Checkpoint::load_chain(&base_path, &paths)?;
        fedmlh::log_info!(
            "serve: applied {} delta checkpoint(s) onto {}",
            paths.len(),
            base_path.display()
        );
        let source = format!("{} + {} delta(s)", base_path.display(), paths.len());
        (ckpt, source)
    };
    fedmlh::log_info!(
        "serve: {} checkpoint '{}' — {} sub-model(s), d={}, p={}, seed {}",
        ckpt.meta.algo.name(),
        ckpt.meta.preset,
        ckpt.r(),
        ckpt.meta.d,
        ckpt.meta.p,
        ckpt.meta.root_seed
    );
    let max_body_bytes = p.get_usize("max-body-bytes")?;
    if max_body_bytes == 0 {
        bail!("max-body-bytes must be positive");
    }
    let opts = ServeOpts {
        host: p.get("host").to_string(),
        port: port as u16,
        replicas,
        workers,
        max_batch,
        drain: std::time::Duration::from_secs(p.get_u64("drain-secs")?),
        canary,
        max_body_bytes,
    };
    let control = std::sync::Arc::new(ControlPlane::with_initial(ckpt, source, opts)?);
    let server = Server::bind_with(control.clone())?;
    install_signal_watcher(control, server.handle()?);
    fedmlh::log_info!(
        "serve: listening on http://{} ({} replica(s); POST /predict, GET /healthz, GET /metrics — JSON, or ?format=prometheus — POST /reload [?canary=<pct>], POST /quitquitquit)",
        server.local_addr()?,
        replicas
    );
    server.run()
}

/// Graceful-shutdown signal plumbing: a SIGINT/SIGTERM handler flips
/// one flag; a watcher thread notices, starts the control plane's drain
/// (healthz → 503, connections close after their response), and stops
/// the accept loop so [`Server::run`] proceeds to the drain wait and
/// the final metrics flush. Raw `signal(2)` FFI — the offline registry
/// has no signal-handling crate, and an atomic store is async-signal
/// safe.
#[cfg(unix)]
fn install_signal_watcher(control: std::sync::Arc<ControlPlane>, handle: ServerHandle) {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SIGNALED: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_signum: i32) {
        SIGNALED.store(true, Ordering::SeqCst);
    }
    type SigHandler = extern "C" fn(i32);
    extern "C" {
        fn signal(signum: i32, handler: SigHandler) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        let _ = signal(SIGINT, on_signal);
        let _ = signal(SIGTERM, on_signal);
    }
    std::thread::spawn(move || loop {
        if SIGNALED.load(Ordering::SeqCst) {
            fedmlh::log_info!("serve: signal received, draining");
            control.start_drain();
            handle.stop();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    });
}

#[cfg(not(unix))]
fn install_signal_watcher(_control: std::sync::Arc<ControlPlane>, _handle: ServerHandle) {
    // No signal plumbing off unix; POST /quitquitquit still drains.
}

// ----------------------------------------------------------- tables

fn run_pairs(presets: &[DatasetPreset], opts: &HarnessOpts) -> Result<Vec<PairResult>> {
    presets
        .iter()
        .map(|preset| {
            let cfg = ExperimentConfig::new(preset.clone());
            harness::run_pair(&cfg, opts)
        })
        .collect()
}

fn cmd_tables(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new(
        "fedmlh tables",
        "regenerate Tables 3-7 (trains FedAvg+FedMLH per preset)",
    ))
    .flag("presets", "eurlex", "comma-separated presets, or 'all'")
    .parse(argv)?;
    let obs = obs_from(&p)?;
    let opts = opts_from(&p)?;
    let pairs = run_pairs(&preset_list(p.get("presets"))?, &opts)?;
    let text = tables::all_pair_tables(&pairs);
    println!("{text}");
    if let Some(dir) = &opts.out_dir {
        report::write_result(dir, "tables_3_to_7.md", &text)?;
        for pair in &pairs {
            report::write_result(
                dir,
                &format!("fig3_{}.csv", pair.cfg.preset.name),
                &figures::fig3(pair),
            )?;
        }
    }
    obs.export()?;
    Ok(())
}

fn cmd_table1(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new("fedmlh table1", "dataset statistics"))
        .flag("presets", "all", "comma-separated presets, or 'all'")
        .parse(argv)?;
    obs_from(&p)?;
    let presets = preset_list(p.get("presets"))?;
    let text = tables::table1(&presets, p.get_u64("seed")?);
    println!("### Table 1 — dataset statistics (synthetic analogs)\n\n{text}");
    Ok(())
}

fn cmd_table2(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new("fedmlh table2", "FedMLH hyper-parameters"))
        .flag("presets", "all", "comma-separated presets, or 'all'")
        .parse(argv)?;
    obs_from(&p)?;
    let presets = preset_list(p.get("presets"))?;
    println!(
        "### Table 2 — hash tables R and buckets B\n\n{}",
        tables::table2(&presets)
    );
    Ok(())
}

// ---------------------------------------------------------- figures

fn cmd_fig2(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new(
        "fedmlh fig2",
        "label-frequency CDFs (2a/2b) + non-iid partition (2c)",
    ))
    .flag("preset", "eurlex", "dataset preset")
    .parse(argv)?;
    obs_from(&p)?;
    let opts = opts_from(&p)?;
    let mut cfg = ExperimentConfig::preset(p.get("preset"))?;
    opts.configure(&mut cfg);
    let world = harness::build_world(&cfg);

    let a = figures::fig2a(&world.data.train);
    let b = figures::fig2b(&world.data.train);
    let c = figures::fig2c(&world.data.train, &world.partition);
    let dir = opts.out_dir.as_ref().context("--out required")?;
    report::write_result(dir, &format!("fig2a_{}.csv", cfg.preset.name), &a)?;
    report::write_result(dir, &format!("fig2b_{}.csv", cfg.preset.name), &b)?;
    report::write_result(dir, &format!("fig2c_{}.csv", cfg.preset.name), &c)?;
    println!(
        "fig2a/b/c for '{}' → {} ({} / {} / {} rows)",
        cfg.preset.name,
        dir.display(),
        a.lines().count() - 1,
        b.lines().count() - 1,
        c.lines().count() - 1
    );
    // headline: positive mass carried by infrequent classes. The paper
    // reads its curve at norm-freq 1e-4 (≈130 positives at N≈300k); at
    // this testbed's N the equivalent cut is a per-count threshold.
    let stats = fedmlh::data::stats::LabelStats::from_dataset(&world.data.train);
    let n = world.data.train.len() as f64;
    for max_pos in [5.0f64, 20.0] {
        let grid = [max_pos / n];
        let mass = stats.positive_mass_cdf(&grid);
        println!(
            "positive-instance mass from classes with ≤{max_pos:.0} positives: {}",
            report::pct(mass[0].y)
        );
    }
    Ok(())
}

fn cmd_fig34(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new(
        "fedmlh fig3",
        "accuracy curves per round / per comm volume (one pair run)",
    ))
    .flag("preset", "eurlex", "dataset preset")
    .parse(argv)?;
    let obs = obs_from(&p)?;
    let opts = opts_from(&p)?;
    let cfg = ExperimentConfig::preset(p.get("preset"))?;
    let pair = harness::run_pair(&cfg, &opts)?;
    let csv = figures::fig3(&pair);
    let dir = opts.out_dir.as_ref().context("--out required")?;
    report::write_result(dir, &format!("fig3_{}.csv", cfg.preset.name), &csv)?;
    println!(
        "fig3/fig4 series for '{}' → {} ({} rows; x = round or comm_bytes)",
        cfg.preset.name,
        dir.display(),
        csv.lines().count() - 1
    );
    println!(
        "best mean@k: fedmlh {} (round {}) vs fedavg {} (round {})",
        report::pct(pair.fedmlh.best.mean_topk()),
        pair.fedmlh.best_round,
        report::pct(pair.fedavg.best.mean_topk()),
        pair.fedavg.best_round
    );
    obs.export()?;
    Ok(())
}

fn cmd_fig5(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new(
        "fedmlh fig5",
        "FedMLH sensitivity to B (5a/5c) or R (5b/5d)",
    ))
    .flag("preset", "eurlex", "dataset preset")
    .flag("sweep", "b", "which hyper-parameter to sweep: b | r")
    .flag("values", "", "comma-separated sweep values (default: preset sweep list + default)")
    .parse(argv)?;
    let obs = obs_from(&p)?;
    let opts = opts_from(&p)?;
    let cfg = ExperimentConfig::preset(p.get("preset"))?;

    let sweep = p.get("sweep").to_lowercase();
    let mut values: Vec<usize> = if p.get("values").is_empty() {
        let mut v: Vec<usize> = match sweep.as_str() {
            "b" => cfg.preset.sweep_b.to_vec(),
            "r" => cfg.preset.sweep_r.to_vec(),
            other => bail!("--sweep must be b or r, got '{other}'"),
        };
        v.push(if sweep == "b" { cfg.preset.b } else { cfg.preset.r });
        v
    } else {
        p.get("values")
            .split(',')
            .map(|s| s.trim().parse().context("bad --values entry"))
            .collect::<Result<_>>()?
    };
    values.sort_unstable();
    values.dedup();
    if values.is_empty() {
        bail!("no sweep values for preset '{}'", cfg.preset.name);
    }

    let points = if sweep == "b" {
        figures::fig5_sweep_b(&cfg, &values, &opts)?
    } else {
        figures::fig5_sweep_r(&cfg, &values, &opts)?
    };
    let csv = figures::fig5_csv(&sweep.to_uppercase(), &points);
    print!("{csv}");
    if let Some(dir) = &opts.out_dir {
        report::write_result(
            dir,
            &format!("fig5_{}_{}.csv", cfg.preset.name, sweep),
            &csv,
        )?;
    }
    obs.export()?;
    Ok(())
}

/// `fedmlh figasync` — the sync-vs-async wall-clock-vs-accuracy
/// comparison, from two saved history CSVs (one synchronous run, one
/// `--async` run). Sync rows are keyed by cumulative measured round
/// time, async rows by the event loop's simulated clock.
fn cmd_figasync(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "fedmlh figasync",
        "sync-vs-async accuracy-vs-clock comparison from two saved history CSVs",
    )
    .required("sync-history", "history CSV from a synchronous run (e.g. results/run_tiny_fedmlh.csv)")
    .required("async-history", "history CSV from an --async run of the same preset")
    .flag("out", "results", "output directory for the comparison CSV")
    .parse(argv)?;
    let sync_csv = std::fs::read_to_string(p.get("sync-history"))
        .with_context(|| format!("reading --sync-history {}", p.get("sync-history")))?;
    let async_csv = std::fs::read_to_string(p.get("async-history"))
        .with_context(|| format!("reading --async-history {}", p.get("async-history")))?;
    let csv = figures::fig_sync_vs_async(&sync_csv, &async_csv)?;
    let dir = PathBuf::from(p.get("out"));
    report::write_result(&dir, "fig_sync_vs_async.csv", &csv)?;
    println!(
        "sync-vs-async comparison → {}/fig_sync_vs_async.csv ({} rows; clock_seconds is each mode's own timeline)",
        dir.display(),
        csv.trim().lines().count() - 1
    );
    Ok(())
}

// ----------------------------------------------------------- theory

fn cmd_theory(argv: &[String]) -> Result<()> {
    let p = common_args(Args::new(
        "fedmlh theory",
        "validate Lemma 1, Lemma 2 and Theorem 2 on a preset's data",
    ))
    .flag("preset", "eurlex", "dataset preset")
    .flag("trials", "200", "Monte-Carlo trials")
    .parse(argv)?;
    obs_from(&p)?;
    let opts = opts_from(&p)?;
    let mut cfg = ExperimentConfig::preset(p.get("preset"))?;
    opts.configure(&mut cfg);
    let trials = p.get_usize("trials")?;
    let world = harness::build_world(&cfg);
    let train = &world.data.train;
    let (pp, b, r) = (train.p(), cfg.b(), cfg.r());

    println!(
        "## Theory validation — preset '{}' (p={pp}, B={b}, R={r})\n",
        cfg.preset.name
    );

    // Lemma 1: per-class positives vs bucket bound.
    let counts = train.class_counts();
    let n_lab: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..pp).collect();
    order.sort_by_key(|&c| counts[c]);
    for (tag, j) in [("median", order[pp / 2]), ("infrequent", order[pp / 10])] {
        let bound = theory::lemma1_lower_bound(counts[j], n_lab, b);
        let exact = theory::expected_bucket_positives_exact(counts[j], n_lab, b);
        let (mc, se) =
            theory::expected_bucket_positives_mc_stats(&counts, j, b, trials.min(300), cfg.seed);
        println!(
            "Lemma 1 ({tag} class {j}): n_j={}  bound={bound:.1}  exact E={exact:.1}  \
             MC={mc:.1}±{se:.1}  gain={:.1}x  holds={}",
            counts[j],
            exact / (counts[j].max(1)) as f64,
            exact >= bound - 1e-9 && mc + 3.0 * se >= bound
        );
    }

    // Lemma 2: distinguishability.
    let delta = 0.05;
    let min_b = theory::lemma2_min_buckets(pp, r, delta);
    let union = theory::collision_union_bound(pp, b, r);
    let hasher = LabelHasher::new(cfg.seed, r, pp, b);
    println!(
        "\nLemma 2: min B for δ={delta} is {min_b:.1}; configured B={b} → union bound {union:.2e}; \
         this run's tables fully-colliding pair: {}",
        hasher.has_fully_colliding_pair()
    );

    // Theorem 2 on the real partition + MC on random simplexes.
    let c = theory::kl_contraction_on_partition(train, &world.partition, &hasher, 1e-3);
    println!(
        "\nTheorem 2 (real non-iid partition): mean pairwise KL classes={:.4} buckets={:.4} \
         contraction={:.2}x holds={}",
        c.kl_classes,
        c.kl_buckets,
        c.factor(),
        c.holds()
    );
    let (worst, factor) = theory::kl_contraction_mc(pp.min(512), b.min(64), trials, cfg.seed);
    println!(
        "Theorem 2 (MC, {trials} trials): worst KL(ω)-KL(π) = {worst:.2e} (≤0 ⇒ holds), mean contraction {factor:.2}x"
    );

    // Bonus: the iid-vs-noniid divergence gap the partition creates,
    // measured over the *frequent* classes the partitioner assigns
    // (full-p empirical KL is smoothing-noise-dominated at p ≫ shard
    // size; the frequent head is where the designed divergence lives).
    let iid = fedmlh::partition::iid::partition(train.len(), cfg.clients, cfg.seed);
    let freq_ids: Vec<u32> = world.partition.class_owner.iter().map(|(c, _)| *c).collect();
    let freq_kl = |part: &fedmlh::partition::Partition| -> f64 {
        let dists: Vec<Vec<f64>> = part
            .clients
            .iter()
            .map(|shard| {
                let mut counts = vec![1e-3f64; freq_ids.len()];
                for &i in shard.iter() {
                    for &l in train.labels_of(i) {
                        if let Some(slot) = freq_ids.iter().position(|&f| f == l) {
                            counts[slot] += 1.0;
                        }
                    }
                }
                let total: f64 = counts.iter().sum();
                counts.iter().map(|v| v / total).collect()
            })
            .collect();
        let k = dists.len();
        let mut sum = 0.0;
        let mut n = 0usize;
        for a in 0..k {
            for b in 0..k {
                if a != b {
                    sum += divergence::kl(&dists[a], &dists[b]);
                    n += 1;
                }
            }
        }
        sum / n.max(1) as f64
    };
    println!(
        "\nnon-iid partition check (frequent-class KL): non-iid {:.4} vs iid {:.4}",
        freq_kl(&world.partition),
        freq_kl(&iid)
    );
    Ok(())
}

// -------------------------------------------------------- artifacts

fn cmd_artifacts(argv: &[String]) -> Result<()> {
    let p = Args::new("fedmlh artifacts", "list the compiled artifact manifest")
        .flag("artifacts", "artifacts", "artifact directory")
        .parse(argv)?;
    let rt = RuntimeClient::new(&PathBuf::from(p.get("artifacts")))?;
    println!("platform: {}", rt.platform_name());
    let mut t = report::Markdown::new(&["artifact", "kind", "inputs", "entry shapes"]);
    for (key, e) in &rt.manifest().artifacts {
        let main_in = e
            .inputs
            .iter()
            .map(|i| format!("{:?}", i.shape))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(vec![
            key.clone(),
            e.kind.clone(),
            e.inputs.len().to_string(),
            main_in.chars().take(48).collect(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
