//! Cache-blocked, register-tiled GEMM micro-kernels.
//!
//! Three transpose variants cover every matmul the MLP needs. All of
//! them fully overwrite `out` and keep each output element's reduction
//! in a fixed order (see the module docs in [`super`]), so results are
//! independent of batch position and bitwise reproducible run to run.
//!
//! Innermost loops dispatch through [`super::simd`] (AVX2 when the
//! `simd` feature is on and the CPU has it, a verbatim scalar body
//! otherwise — same bits either way), and the public entry points
//! row-slice across scoped threads via [`super::parallel`] when the
//! calling thread has an intra-kernel budget and the call is large
//! enough.

#![allow(clippy::too_many_arguments)]

use super::{parallel, simd};

/// Rows of A processed together by the `nn` kernel (B-row reuse).
pub const MR: usize = 4;
/// Reduction rows processed together by the `tn` kernel.
pub const KB: usize = 4;
/// Independent partial sums per dot product in the `nt` kernel.
pub const LANES: usize = 8;

// The kernel bodies below are hand-unrolled for exactly these block
// widths (a0..a3 / b0..b3, split_at_mut(2 * n)); the constants are
// documentation, not tuning knobs. Retuning requires rewriting the
// unrolled bodies — this assertion makes a lone constant edit fail to
// compile instead of silently mis-computing edge rows.
const _: () = assert!(MR == 4 && KB == 4, "gemm bodies are unrolled for 4-wide blocks");

/// `out[m,n] = a[m,k] @ b[k,n]` (row-major; `out` fully overwritten).
///
/// Equivalent to [`nn_core`] with no bias and no ReLU; the fused
/// variants live in [`super::fused`].
pub fn gemm_nn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    nn_dispatch(a, b, None, out, m, k, n, false);
}

/// Shared `nn` entry point: sequential below the parallel threshold,
/// row-sliced across scoped threads above it. Bitwise identical either
/// way — output rows are independent and the core's per-element order
/// does not depend on row batching.
#[inline]
pub(crate) fn nn_dispatch(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    let threads = parallel::plan(m, m * k * n, MR);
    if threads > 1 {
        parallel::par_nn(a, b, bias, out, m, k, n, relu, threads);
    } else {
        nn_core(a, b, bias, out, m, k, n, relu);
    }
}

/// Shared `nn` micro-kernel: `out = a @ b [+ bias] [then ReLU]`.
///
/// Processes [`MR`] rows of A per pass so each B row is read once per
/// `MR` output rows. Each output element accumulates its k terms in
/// ascending-k order starting from `bias[j]` (or `0.0`), identically in
/// the blocked body and the remainder rows — batched calls are bitwise
/// identical to per-row calls.
#[inline(always)]
pub(crate) fn nn_core(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if let Some(bias) = bias {
        debug_assert_eq!(bias.len(), n);
    }
    let init_row = |row: &mut [f32]| match bias {
        Some(bias) => row.copy_from_slice(bias),
        None => row.fill(0.0),
    };
    let mut i = 0;
    while i + MR <= m {
        let blk = &mut out[i * n..(i + MR) * n];
        for row in blk.chunks_exact_mut(n) {
            init_row(row);
        }
        let (top, bottom) = blk.split_at_mut(2 * n);
        let (o0, o1) = top.split_at_mut(n);
        let (o2, o3) = bottom.split_at_mut(n);
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            let (x0, x1, x2, x3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            simd::quad_axpy(o0, o1, o2, o3, x0, x1, x2, x3, brow);
        }
        if relu {
            for row in [o0, o1, o2, o3] {
                simd::relu(row);
            }
        }
        i += MR;
    }
    while i < m {
        let orow = &mut out[i * n..(i + 1) * n];
        init_row(orow);
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &x) in arow.iter().enumerate() {
            simd::axpy(orow, x, &b[kk * n..(kk + 1) * n]);
        }
        if relu {
            simd::relu(orow);
        }
        i += 1;
    }
}

/// `out[m,n] = a[k,m]ᵀ @ b[k,n]` without materializing aᵀ
/// (`out` fully overwritten).
pub fn gemm_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let threads = parallel::plan(m, k * m * n, 1);
    if threads > 1 {
        parallel::par_tn(a, b, out, k, m, n, threads);
        return;
    }
    out.fill(0.0);
    tn_accumulate_window(a, b, out, k, m, n, 0, m, 0, n);
}

/// Accumulate
/// `out[i,j] += Σ_kk a[kk·m + i0 + i] · b[kk·n + j0 + j]` over the
/// output-row window `[i0, i0 + rows)` and column window
/// `[j0, j0 + nb)`; `out` is `[rows, nb]` and must be pre-initialized
/// by the caller.
///
/// The reduction dimension is blocked by [`KB`], streaming the output
/// window `⌈k / KB⌉` times instead of `k` times; within a block the
/// terms are added one at a time, so each element still accumulates in
/// strict ascending-kk order — independent of both windows, which is
/// what lets [`super::parallel`] row-slice calls bitwise-identically.
#[inline(always)]
pub(crate) fn tn_accumulate_window(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    i0: usize,
    rows: usize,
    j0: usize,
    nb: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), rows * nb);
    debug_assert!(i0 + rows <= m);
    debug_assert!(j0 + nb <= n);
    let mut kk = 0;
    while kk + KB <= k {
        let a0 = &a[kk * m + i0..kk * m + i0 + rows];
        let a1 = &a[(kk + 1) * m + i0..(kk + 1) * m + i0 + rows];
        let a2 = &a[(kk + 2) * m + i0..(kk + 2) * m + i0 + rows];
        let a3 = &a[(kk + 3) * m + i0..(kk + 3) * m + i0 + rows];
        let b0 = &b[kk * n + j0..kk * n + j0 + nb];
        let b1 = &b[(kk + 1) * n + j0..(kk + 1) * n + j0 + nb];
        let b2 = &b[(kk + 2) * n + j0..(kk + 2) * n + j0 + nb];
        let b3 = &b[(kk + 3) * n + j0..(kk + 3) * n + j0 + nb];
        for i in 0..rows {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let orow = &mut out[i * nb..(i + 1) * nb];
            simd::quad_acc(orow, x0, x1, x2, x3, b0, b1, b2, b3);
        }
        kk += KB;
    }
    while kk < k {
        let ar = &a[kk * m + i0..kk * m + i0 + rows];
        let br = &b[kk * n + j0..kk * n + j0 + nb];
        for i in 0..rows {
            simd::axpy(&mut out[i * nb..(i + 1) * nb], ar[i], br);
        }
        kk += 1;
    }
}

/// `out[m,k] = a[m,n] @ b[k,n]ᵀ` without materializing bᵀ
/// (`out` fully overwritten).
///
/// Dot-product shaped: each output element is a length-n reduction, so
/// a single accumulator would serialize on float-add latency. Instead
/// every dot keeps [`LANES`] partial sums (combined in a fixed order at
/// the end) and two A rows share each streamed B row. The lane pattern
/// depends only on `n`, so blocked and remainder rows — and therefore
/// any batch split — produce identical bits.
pub fn gemm_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    let threads = parallel::plan(m, m * n * k, 2);
    if threads > 1 {
        parallel::par_nt(a, b, out, m, n, k, threads);
    } else {
        nt_core(a, b, out, m, n, k);
    }
}

/// The `nt` kernel body on a contiguous row range (row-slicing is
/// bitwise-safe: the 2-row `dot2` pairing and the single-row `dot`
/// produce identical accumulation patterns per output).
pub(crate) fn nt_core(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    let mut i = 0;
    while i + 2 <= m {
        let a0 = &a[i * n..(i + 1) * n];
        let a1 = &a[(i + 1) * n..(i + 2) * n];
        let (o0, o1) = out[i * k..(i + 2) * k].split_at_mut(k);
        for j in 0..k {
            let brow = &b[j * n..(j + 1) * n];
            let (d0, d1) = dot2(a0, a1, brow);
            o0[j] = d0;
            o1[j] = d1;
        }
        i += 2;
    }
    if i < m {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot(arow, &b[j * n..(j + 1) * n]);
        }
    }
}

/// Lane-parallel dot product with a fixed combine order (dispatches
/// through [`super::simd`]; the scalar body there is the original
/// [`LANES`] partial-sum loop, verbatim).
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(a, b)
}

/// Two lane-parallel dots sharing one streamed `b` row; each output
/// uses exactly the same accumulation pattern as [`dot`].
#[inline]
fn dot2(a0: &[f32], a1: &[f32], b: &[f32]) -> (f32, f32) {
    simd::dot2(a0, a1, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nn_matches_hand_computed() {
        // [1 2 3; 4 5 6] @ [1 0; 0 1; 1 1] = [4 5; 10 11]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut out = [9.0f32; 4]; // prefilled garbage must be overwritten
        gemm_nn(&a, &b, &mut out, 2, 3, 2);
        assert_eq!(out, [4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn tn_matches_hand_computed() {
        // aᵀ @ b with a = [1 2; 3 4] (stored [k=2, m=2]) and b = [5; 6].
        // out[i][0] = a[0][i]*5 + a[1][i]*6
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0];
        let mut out = [0.0f32; 2];
        gemm_tn(&a, &b, &mut out, 2, 2, 1);
        assert_eq!(out, [1.0 * 5.0 + 3.0 * 6.0, 2.0 * 5.0 + 4.0 * 6.0]);
    }

    #[test]
    fn nt_matches_hand_computed() {
        // a = [1 2 3], b rows are [1 1 1] and [0 1 0]  ⇒ out = [6, 2]
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 1.0, 1.0, 0.0, 1.0, 0.0];
        let mut out = [0.0f32; 2];
        gemm_nt(&a, &b, &mut out, 1, 3, 2);
        assert_eq!(out, [6.0, 2.0]);
    }

    #[test]
    fn dot_handles_lane_remainders() {
        for n in [0usize, 1, 7, 8, 9, 16, 23] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32).mul_add(0.5, 1.0)).collect();
            let want: f32 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-3, "n={n}");
        }
    }
}
