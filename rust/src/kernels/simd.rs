//! SIMD inner-loop primitives with a bit-identical scalar fallback.
//!
//! Every tiled kernel in [`super::gemm`], [`super::fused`] and
//! [`super::sparse`] funnels its innermost loop through one of the
//! primitives here. Each primitive has two bodies:
//!
//! - a **scalar** body — byte-for-byte the loop the kernels shipped
//!   with, always compiled, and the only body when the `simd` cargo
//!   feature is off or the target is not x86-64;
//! - an **AVX2** body (`simd` feature + `x86_64` + runtime
//!   `is_x86_feature_detected!("avx2")`) that vectorizes across
//!   *independent output elements* only.
//!
//! # Why the AVX2 bodies are bitwise-identical, not "close"
//!
//! The property tests in `tests/kernel_properties.rs` pin every kernel
//! bitwise against the frozen naive baseline, so the SIMD bodies are
//! written to produce *the same bits*, not merely the same ULP
//! neighborhood:
//!
//! - vector lanes map onto **different output elements** (columns `j`
//!   of a GEMM row, or the eight fixed partial-sum lanes [`LANES`]
//!   already present in the scalar `dot`) — never onto a re-associated
//!   reduction;
//! - multiplies and adds stay **separate instructions** — no FMA. A
//!   fused multiply-add skips the intermediate rounding and changes
//!   low bits;
//! - ReLU uses `cmp_lt` + `andnot` rather than `max(0, x)`:
//!   `max` would rewrite `-0.0` to `+0.0` and replace NaN, while the
//!   scalar epilogue (`if *v < 0.0 { *v = 0.0 }`) leaves both alone;
//! - the ReLU backward mask uses an *ordered* `cmp_le` so NaN
//!   activations keep their gradient exactly like the scalar
//!   `if hv <= 0.0` test.
//!
//! [`super::fused::bce_loss_dz`] stays scalar even with `simd` on: it
//! is transcendental (`exp`, `ln_1p`), and any polynomial vector
//! approximation would break the bitwise pin. It is one pass over
//! `[batch, out]` and a small fraction of step time next to the three
//! GEMMs.
//!
//! # Dispatch
//!
//! [`active`] is the single runtime gate: feature compiled in, CPU
//! reports AVX2, and [`force_scalar`] not engaged. `force_scalar` lets
//! one bench binary measure both bodies back to back
//! (`benches/bench_train.rs`); it is a process-global toggle, not a
//! per-call option, so flipping it mid-computation from another thread
//! is a benchmarking error (results would still be correct — both
//! bodies compute identical bits — just meaningless as a timing).

use super::gemm::LANES;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use std::sync::atomic::{AtomicBool, Ordering};

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Whether this build contains the AVX2 bodies at all.
pub fn compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// Pin every primitive to its scalar body (for scalar-vs-simd
/// benchmarking in one binary). No-op when [`compiled`] is false.
pub fn force_scalar(on: bool) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    FORCE_SCALAR.store(on, Ordering::Relaxed);
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    let _ = on;
}

/// Whether the AVX2 bodies will actually run right now.
#[inline]
pub fn active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // `is_x86_feature_detected!` caches its CPUID probe internally,
        // so this is two relaxed atomic loads on the hot path.
        !FORCE_SCALAR.load(Ordering::Relaxed) && std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

// ---------------------------------------------------------------------
// Primitives. Each `pub(crate)` function is the dispatcher; the scalar
// body lives inline in it (and is verbatim the pre-SIMD kernel loop),
// the AVX2 body lives in `avx2::` below.
// ---------------------------------------------------------------------

/// `y[j] += a · x[j]` — the single-row GEMM / CSR-forward inner loop.
#[inline(always)]
pub(crate) fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        unsafe { avx2::axpy(y, a, x) };
        return;
    }
    for (o, &bv) in y.iter_mut().zip(x.iter()) {
        *o += a * bv;
    }
}

/// `y[j] -= a · x[j]` — the SGD parameter / bias / CSR-scatter update.
#[inline(always)]
pub(crate) fn axpy_sub(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        unsafe { avx2::axpy_sub(y, a, x) };
        return;
    }
    for (o, &bv) in y.iter_mut().zip(x.iter()) {
        *o -= a * bv;
    }
}

/// Four simultaneous axpys sharing one streamed `b` row — the
/// [`super::gemm::MR`]-row `nn` micro-kernel inner loop.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn quad_axpy(
    o0: &mut [f32],
    o1: &mut [f32],
    o2: &mut [f32],
    o3: &mut [f32],
    x0: f32,
    x1: f32,
    x2: f32,
    x3: f32,
    b: &[f32],
) {
    let n = b.len();
    debug_assert!(o0.len() == n && o1.len() == n && o2.len() == n && o3.len() == n);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        unsafe { avx2::quad_axpy(o0, o1, o2, o3, x0, x1, x2, x3, b) };
        return;
    }
    for j in 0..n {
        let bv = b[j];
        o0[j] += x0 * bv;
        o1[j] += x1 * bv;
        o2[j] += x2 * bv;
        o3[j] += x3 * bv;
    }
}

/// `o[j] += x0·b0[j] + x1·b1[j] + x2·b2[j] + x3·b3[j]`, each element's
/// four terms added one at a time in that order — the
/// [`super::gemm::KB`]-blocked `tn` inner loop.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn quad_acc(
    o: &mut [f32],
    x0: f32,
    x1: f32,
    x2: f32,
    x3: f32,
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) {
    let n = o.len();
    debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        unsafe { avx2::quad_acc(o, x0, x1, x2, x3, b0, b1, b2, b3) };
        return;
    }
    for j in 0..n {
        let mut acc = o[j];
        acc += x0 * b0[j];
        acc += x1 * b1[j];
        acc += x2 * b2[j];
        acc += x3 * b3[j];
        o[j] = acc;
    }
}

/// Clamp negatives to zero in place; `-0.0` and NaN pass through
/// unchanged (exactly the scalar `if *v < 0.0` epilogue).
#[inline(always)]
pub(crate) fn relu(row: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        unsafe { avx2::relu(row) };
        return;
    }
    for v in row.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Zero `grad[j]` wherever `h[j] <= 0.0` (ordered compare: NaN
/// activations keep their gradient, matching the scalar test).
#[inline(always)]
pub(crate) fn relu_mask(grad: &mut [f32], h: &[f32]) {
    debug_assert_eq!(grad.len(), h.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        unsafe { avx2::relu_mask(grad, h) };
        return;
    }
    for (g, &hv) in grad.iter_mut().zip(h.iter()) {
        if hv <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Lane-parallel dot product: [`LANES`] fixed partial sums over
/// 8-element chunks, combined sequentially, then the scalar tail —
/// the exact accumulation pattern of the original scalar `dot`.
#[inline(always)]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        return unsafe { avx2::dot(a, b) };
    }
    let mut lanes = [0.0f32; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    while let (Some(av), Some(bv)) = (ac.next(), bc.next()) {
        for l in 0..LANES {
            lanes[l] += av[l] * bv[l];
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    combine(&lanes) + tail
}

/// Two lane-parallel dots sharing one streamed `b` row; each output
/// uses exactly the same accumulation pattern as [`dot`].
#[inline(always)]
pub(crate) fn dot2(a0: &[f32], a1: &[f32], b: &[f32]) -> (f32, f32) {
    debug_assert_eq!(a0.len(), b.len());
    debug_assert_eq!(a1.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active() {
        // SAFETY: `active()` verified AVX2 at runtime.
        return unsafe { avx2::dot2(a0, a1, b) };
    }
    let mut l0 = [0.0f32; LANES];
    let mut l1 = [0.0f32; LANES];
    let mut a0c = a0.chunks_exact(LANES);
    let mut a1c = a1.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    while let (Some(x0), Some(x1), Some(y)) = (a0c.next(), a1c.next(), bc.next()) {
        for l in 0..LANES {
            l0[l] += x0[l] * y[l];
            l1[l] += x1[l] * y[l];
        }
    }
    let mut t0 = 0.0f32;
    let mut t1 = 0.0f32;
    for ((&x0, &x1), &y) in a0c
        .remainder()
        .iter()
        .zip(a1c.remainder())
        .zip(bc.remainder())
    {
        t0 += x0 * y;
        t1 += x1 * y;
    }
    (combine(&l0) + t0, combine(&l1) + t1)
}

/// The fixed lane-combine order both bodies share: lanes summed left
/// to right into one accumulator.
#[inline(always)]
fn combine(lanes: &[f32; LANES]) -> f32 {
    let mut acc = 0.0f32;
    for &l in lanes.iter() {
        acc += l;
    }
    acc
}

// ---------------------------------------------------------------------
// AVX2 bodies. Lanes always map onto independent output elements (or
// the LANES fixed partial sums), mul and add stay separate
// instructions, compares are the ordered predicates matching the
// scalar `<` / `<=` — see the module docs for why each choice is what
// keeps the bits identical.
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::{combine, LANES};
    use std::arch::x86_64::*;

    // LANES == 8 == one __m256 of f32s; the dot kernels map the scalar
    // partial-sum lanes one-to-one onto vector lanes.
    const _: () = assert!(LANES == 8, "avx2 dot kernels assume 8 f32 lanes");

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        let av = _mm256_set1_ps(a);
        let mut yc = y.chunks_exact_mut(8);
        let mut xc = x.chunks_exact(8);
        for (yv, xv) in (&mut yc).zip(&mut xc) {
            let r = _mm256_add_ps(
                _mm256_loadu_ps(yv.as_ptr()),
                _mm256_mul_ps(av, _mm256_loadu_ps(xv.as_ptr())),
            );
            _mm256_storeu_ps(yv.as_mut_ptr(), r);
        }
        for (o, &bv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
            *o += a * bv;
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_sub(y: &mut [f32], a: f32, x: &[f32]) {
        let av = _mm256_set1_ps(a);
        let mut yc = y.chunks_exact_mut(8);
        let mut xc = x.chunks_exact(8);
        for (yv, xv) in (&mut yc).zip(&mut xc) {
            let r = _mm256_sub_ps(
                _mm256_loadu_ps(yv.as_ptr()),
                _mm256_mul_ps(av, _mm256_loadu_ps(xv.as_ptr())),
            );
            _mm256_storeu_ps(yv.as_mut_ptr(), r);
        }
        for (o, &bv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
            *o -= a * bv;
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn quad_axpy(
        o0: &mut [f32],
        o1: &mut [f32],
        o2: &mut [f32],
        o3: &mut [f32],
        x0: f32,
        x1: f32,
        x2: f32,
        x3: f32,
        b: &[f32],
    ) {
        let (v0, v1, v2, v3) = (
            _mm256_set1_ps(x0),
            _mm256_set1_ps(x1),
            _mm256_set1_ps(x2),
            _mm256_set1_ps(x3),
        );
        let n = b.len();
        let chunks = n / 8;
        for c in 0..chunks {
            let j = c * 8;
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            for (orow, xv) in [(&mut *o0, v0), (&mut *o1, v1), (&mut *o2, v2), (&mut *o3, v3)] {
                let p = orow.as_mut_ptr().add(j);
                _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), _mm256_mul_ps(xv, bv)));
            }
        }
        for j in chunks * 8..n {
            let bv = b[j];
            o0[j] += x0 * bv;
            o1[j] += x1 * bv;
            o2[j] += x2 * bv;
            o3[j] += x3 * bv;
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn quad_acc(
        o: &mut [f32],
        x0: f32,
        x1: f32,
        x2: f32,
        x3: f32,
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) {
        let (v0, v1, v2, v3) = (
            _mm256_set1_ps(x0),
            _mm256_set1_ps(x1),
            _mm256_set1_ps(x2),
            _mm256_set1_ps(x3),
        );
        let n = o.len();
        let chunks = n / 8;
        for c in 0..chunks {
            let j = c * 8;
            // The four adds stay sequential per element, matching the
            // scalar `acc += xi * bi[j]` chain term for term.
            let mut acc = _mm256_loadu_ps(o.as_ptr().add(j));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(v0, _mm256_loadu_ps(b0.as_ptr().add(j))));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(v1, _mm256_loadu_ps(b1.as_ptr().add(j))));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(v2, _mm256_loadu_ps(b2.as_ptr().add(j))));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(v3, _mm256_loadu_ps(b3.as_ptr().add(j))));
            _mm256_storeu_ps(o.as_mut_ptr().add(j), acc);
        }
        for j in chunks * 8..n {
            let mut acc = o[j];
            acc += x0 * b0[j];
            acc += x1 * b1[j];
            acc += x2 * b2[j];
            acc += x3 * b3[j];
            o[j] = acc;
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn relu(row: &mut [f32]) {
        let zero = _mm256_setzero_ps();
        let mut rc = row.chunks_exact_mut(8);
        for rv in &mut rc {
            let v = _mm256_loadu_ps(rv.as_ptr());
            // Zero exactly the lanes with v < 0.0 (ordered): -0.0 is
            // not < 0.0 and NaN compares false, so both survive — the
            // `max(0, v)` shortcut would rewrite them.
            let neg = _mm256_cmp_ps::<_CMP_LT_OQ>(v, zero);
            _mm256_storeu_ps(rv.as_mut_ptr(), _mm256_andnot_ps(neg, v));
        }
        for v in rc.into_remainder() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn relu_mask(grad: &mut [f32], h: &[f32]) {
        let zero = _mm256_setzero_ps();
        let mut gc = grad.chunks_exact_mut(8);
        let mut hc = h.chunks_exact(8);
        for (gv, hv) in (&mut gc).zip(&mut hc) {
            let g = _mm256_loadu_ps(gv.as_ptr());
            let a = _mm256_loadu_ps(hv.as_ptr());
            // Ordered `h <= 0.0`: NaN compares false and keeps its
            // gradient, exactly like the scalar branch.
            let clamped = _mm256_cmp_ps::<_CMP_LE_OQ>(a, zero);
            _mm256_storeu_ps(gv.as_mut_ptr(), _mm256_andnot_ps(clamped, g));
        }
        for (g, &hv) in gc.into_remainder().iter_mut().zip(hc.remainder()) {
            if hv <= 0.0 {
                *g = 0.0;
            }
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = _mm256_setzero_ps();
        let mut ac = a.chunks_exact(8);
        let mut bc = b.chunks_exact(8);
        for (av, bv) in (&mut ac).zip(&mut bc) {
            acc = _mm256_add_ps(
                acc,
                _mm256_mul_ps(_mm256_loadu_ps(av.as_ptr()), _mm256_loadu_ps(bv.as_ptr())),
            );
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut tail = 0.0f32;
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            tail += x * y;
        }
        combine(&lanes) + tail
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot2(a0: &[f32], a1: &[f32], b: &[f32]) -> (f32, f32) {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut a0c = a0.chunks_exact(8);
        let mut a1c = a1.chunks_exact(8);
        let mut bc = b.chunks_exact(8);
        while let (Some(x0), Some(x1), Some(y)) = (a0c.next(), a1c.next(), bc.next()) {
            let yv = _mm256_loadu_ps(y.as_ptr());
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(x0.as_ptr()), yv));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(x1.as_ptr()), yv));
        }
        let mut l0 = [0.0f32; LANES];
        let mut l1 = [0.0f32; LANES];
        _mm256_storeu_ps(l0.as_mut_ptr(), acc0);
        _mm256_storeu_ps(l1.as_mut_ptr(), acc1);
        let mut t0 = 0.0f32;
        let mut t1 = 0.0f32;
        for ((&x0, &x1), &y) in a0c
            .remainder()
            .iter()
            .zip(a1c.remainder())
            .zip(bc.remainder())
        {
            t0 += x0 * y;
            t1 += x1 * y;
        }
        (combine(&l0) + t0, combine(&l1) + t1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    // With the feature off these tests exercise the scalar bodies (and
    // prove the dispatchers are transparent); with it on, the AVX2
    // bodies must produce the same bits the scalar reference computes
    // here inline.

    #[test]
    fn axpy_matches_scalar_reference_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let x = seq(n, |i| (i as f32 * 0.37).sin());
            let mut y = seq(n, |i| (i as f32 * 0.11).cos());
            let mut want = y.clone();
            for (o, &bv) in want.iter_mut().zip(x.iter()) {
                *o += 1.25 * bv;
            }
            axpy(&mut y, 1.25, &x);
            assert_eq!(y, want, "n={n}");
            let mut y2 = seq(n, |i| (i as f32 * 0.11).cos());
            let mut want2 = y2.clone();
            for (o, &bv) in want2.iter_mut().zip(x.iter()) {
                *o -= 0.4 * bv;
            }
            axpy_sub(&mut y2, 0.4, &x);
            assert_eq!(y2, want2, "n={n}");
        }
    }

    #[test]
    fn quad_kernels_match_scalar_reference_bitwise() {
        for n in [0usize, 1, 8, 13, 40] {
            let b = seq(n, |i| (i as f32 * 0.7).sin());
            let (b0, b1, b2, b3) = (
                seq(n, |i| (i as f32 * 0.3).cos()),
                seq(n, |i| (i as f32 * 0.5).sin()),
                seq(n, |i| (i as f32 * 0.9).cos()),
                seq(n, |i| (i as f32 * 1.1).sin()),
            );
            let (x0, x1, x2, x3) = (0.5f32, -1.5, 2.25, 0.125);
            let mut rows: Vec<Vec<f32>> =
                (0..4).map(|r| seq(n, |i| (i + r) as f32 * 0.01)).collect();
            let mut want = rows.clone();
            for j in 0..n {
                let bv = b[j];
                want[0][j] += x0 * bv;
                want[1][j] += x1 * bv;
                want[2][j] += x2 * bv;
                want[3][j] += x3 * bv;
            }
            let (r0, rest) = rows.split_at_mut(1);
            let (r1, rest) = rest.split_at_mut(1);
            let (r2, r3) = rest.split_at_mut(1);
            quad_axpy(&mut r0[0], &mut r1[0], &mut r2[0], &mut r3[0], x0, x1, x2, x3, &b);
            assert_eq!(rows, want, "quad_axpy n={n}");

            let mut o = seq(n, |i| i as f32 * 0.02 - 0.3);
            let mut want = o.clone();
            for j in 0..n {
                let mut acc = want[j];
                acc += x0 * b0[j];
                acc += x1 * b1[j];
                acc += x2 * b2[j];
                acc += x3 * b3[j];
                want[j] = acc;
            }
            quad_acc(&mut o, x0, x1, x2, x3, &b0, &b1, &b2, &b3);
            assert_eq!(o, want, "quad_acc n={n}");
        }
    }

    #[test]
    fn relu_preserves_negative_zero_and_nan() {
        let mut v = vec![-1.0f32, -0.0, 0.0, 2.5, f32::NAN, -3.0, 1.0, -2.0, 4.0, -0.5];
        relu(&mut v);
        assert_eq!(v[0], 0.0);
        assert!(v[1] == 0.0 && v[1].is_sign_negative(), "-0.0 must survive");
        assert_eq!(&v[2..4], &[0.0, 2.5]);
        assert!(v[4].is_nan(), "NaN must survive (matches scalar `< 0.0`)");
        assert_eq!(&v[5..], &[0.0, 1.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn relu_mask_keeps_nan_activations_gradient() {
        let h = vec![1.0f32, 0.0, -2.0, f32::NAN, 3.0, -0.0, 0.5, 2.0, -1.0];
        let mut g: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        relu_mask(&mut g, &h);
        assert_eq!(g, vec![1.0, 0.0, 0.0, 4.0, 5.0, 0.0, 7.0, 8.0, 0.0]);
    }

    #[test]
    fn dot_kernels_match_the_lane_pattern_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 16, 23, 64, 100] {
            let a0 = seq(n, |i| (i as f32 * 0.21).sin());
            let a1 = seq(n, |i| (i as f32 * 0.83).cos());
            let b = seq(n, |i| (i as f32 * 0.47).sin());
            // Scalar lane reference, written out independently.
            let lane_dot = |a: &[f32]| -> f32 {
                let mut lanes = [0.0f32; LANES];
                let mut ac = a.chunks_exact(LANES);
                let mut bc = b.chunks_exact(LANES);
                while let (Some(av), Some(bv)) = (ac.next(), bc.next()) {
                    for l in 0..LANES {
                        lanes[l] += av[l] * bv[l];
                    }
                }
                let mut tail = 0.0f32;
                for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
                    tail += x * y;
                }
                let mut acc = 0.0f32;
                for &l in lanes.iter() {
                    acc += l;
                }
                acc + tail
            };
            assert_eq!(dot(&a0, &b).to_bits(), lane_dot(&a0).to_bits(), "n={n}");
            let (d0, d1) = dot2(&a0, &a1, &b);
            assert_eq!(d0.to_bits(), lane_dot(&a0).to_bits(), "dot2.0 n={n}");
            assert_eq!(d1.to_bits(), lane_dot(&a1).to_bits(), "dot2.1 n={n}");
        }
    }

    #[test]
    fn force_scalar_roundtrips() {
        // With simd compiled in, both bodies must agree bitwise; with
        // it off this just exercises the toggles as no-ops.
        let a = seq(100, |i| (i as f32 * 0.13).sin());
        let b = seq(100, |i| (i as f32 * 0.29).cos());
        let fast = dot(&a, &b);
        force_scalar(true);
        assert!(!active());
        let slow = dot(&a, &b);
        force_scalar(false);
        assert_eq!(fast.to_bits(), slow.to_bits());
        #[cfg(target_arch = "x86_64")]
        assert_eq!(active(), compiled() && std::arch::is_x86_feature_detected!("avx2"));
    }
}
