//! Intra-step parallel GEMM: row-sliced scoped threads inside one
//! kernel call.
//!
//! `--workers` parallelizes *across* `(client, sub-model)` work items;
//! when a round has fewer items than cores (one huge client, serving a
//! single giant batch), the spare cores used to idle. The drivers here
//! split a single kernel call's **output rows** into contiguous chunks
//! and run each chunk on its own scoped thread:
//!
//! - `nn` (forward): output rows are independent; chunks are aligned to
//!   [`gemm::MR`] so every thread runs the identical 4-row blocked body
//!   the sequential kernel runs.
//! - `nt` (backprop `dz @ wᵀ`): dot-product rows are independent;
//!   chunks align to the 2-row `dot2` pairing.
//! - `tn` / `tn_sgd` (weight gradient + update): **parameter** rows are
//!   independent — each thread owns a contiguous `param` row chunk and
//!   its own slice of the caller's SGD scratch, reading the shared
//!   `a`/`b` operands.
//! - CSR layer-1 forward: batch rows are independent.
//!
//! Not parallelized: the CSR *scatter* update
//! ([`super::sparse::csr_gemm_tn_sgd`] — different batch rows write the
//! same parameter rows, so row-slicing would race and any fix would
//! reorder the scatter sum), and the bias column-sum (a `[n]`-sized
//! reduction in batch order — memory-bound and tiny).
//!
//! # Determinism
//!
//! Every kernel's per-element summation order is independent of how
//! rows are batched (the contract in [`super`]'s docs, pinned by
//! `tests/kernel_properties.rs`), so a row-sliced run is **bitwise
//! identical** to the sequential one at any thread count — there is no
//! reduction across threads at all, each output element is written by
//! exactly one thread. `tests/parallel_determinism.rs` keeps pinning
//! the end-to-end property.
//!
//! # Thread budget
//!
//! The budget is **thread-local** ([`set_kernel_threads`] returns an
//! RAII guard restoring the previous value on drop) because kernels
//! are called from deep inside backends that should not thread a knob
//! through every signature, and because each of the round engine's
//! pool workers needs its own share: the engine sets
//! `workers / pool_threads` inside each worker so intra-step threads ×
//! pool threads ≈ `--workers`. Everything else (serving, eval, tests)
//! inherits 1 on its own thread unless it opts in. Kernel calls below
//! [`PAR_MIN_FLOPS`] stay sequential — at test/toy shapes the spawn
//! cost would dominate and tiny chunks defeat the cache blocking.

use std::cell::Cell;

use super::sparse::CsrBatch;
use super::{fused, gemm, sparse};

thread_local! {
    /// Per-thread intra-kernel thread budget (1 = sequential).
    static KERNEL_THREADS: Cell<usize> = const { Cell::new(1) };
}

/// Smallest kernel (measured as `m·k·n` multiply-adds, or `nnz·n` for
/// CSR) worth splitting across threads: ~2M flops ≈ a millisecond of
/// scalar work, comfortably above scoped-spawn overhead.
pub const PAR_MIN_FLOPS: usize = 1 << 21;

/// The calling thread's intra-kernel thread budget.
pub fn kernel_threads() -> usize {
    KERNEL_THREADS.with(|t| t.get()).max(1)
}

/// Set this thread's budget; dropping the guard restores the previous
/// value (so nested scopes compose). `n = 0` clamps to 1.
pub fn set_kernel_threads(n: usize) -> ThreadBudgetGuard {
    ThreadBudgetGuard {
        prev: KERNEL_THREADS.with(|t| t.replace(n.max(1))),
        _pinned: std::marker::PhantomData,
    }
}

/// RAII guard from [`set_kernel_threads`]; deliberately `!Send` — the
/// budget it restores belongs to the thread that created it.
#[derive(Debug)]
pub struct ThreadBudgetGuard {
    prev: usize,
    _pinned: std::marker::PhantomData<*const ()>,
}

impl Drop for ThreadBudgetGuard {
    fn drop(&mut self) {
        KERNEL_THREADS.with(|t| t.set(self.prev));
    }
}

/// Threads a kernel call should actually use: the global budget,
/// capped by work size and by how many `align`-row chunks exist.
/// Returns 1 (sequential) for small kernels or a budget of 1.
#[inline]
pub(crate) fn plan(rows: usize, flops: usize, align: usize) -> usize {
    let budget = kernel_threads();
    if budget <= 1 || flops < PAR_MIN_FLOPS {
        return 1;
    }
    budget.min(rows.div_ceil(align)).max(1)
}

/// Rows per chunk for `threads` chunks over `rows` rows, rounded up to
/// a multiple of `align` (so every non-final chunk runs the blocked
/// kernel body only).
#[inline]
fn chunk_rows(rows: usize, threads: usize, align: usize) -> usize {
    rows.div_ceil(threads).div_ceil(align) * align
}

/// Row-sliced `nn` forward: `out = a @ b [+ bias] [then ReLU]`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_nn(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
    threads: usize,
) {
    let cr = chunk_rows(m, threads, gemm::MR);
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(cr * n).enumerate() {
            let rows = out_chunk.len() / n;
            let a_sub = &a[ci * cr * k..(ci * cr + rows) * k];
            s.spawn(move || gemm::nn_core(a_sub, b, bias, out_chunk, rows, k, n, relu));
        }
    });
}

/// Row-sliced `nt`: `out[m,k] = a[m,n] @ b[k,n]ᵀ`.
pub(crate) fn par_nt(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    n: usize,
    kdim: usize,
    threads: usize,
) {
    let cr = chunk_rows(m, threads, 2);
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(cr * kdim).enumerate() {
            let rows = out_chunk.len() / kdim;
            let a_sub = &a[ci * cr * n..(ci * cr + rows) * n];
            s.spawn(move || gemm::nt_core(a_sub, b, out_chunk, rows, n, kdim));
        }
    });
}

/// Row-sliced `tn`: `out[m,n] = a[k,m]ᵀ @ b[k,n]` — each thread owns a
/// contiguous output-row window and reads `a` at its row offset.
pub(crate) fn par_tn(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    threads: usize,
) {
    let cr = chunk_rows(m, threads, 1);
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(cr * n).enumerate() {
            let rows = out_chunk.len() / n;
            let i0 = ci * cr;
            s.spawn(move || {
                out_chunk.fill(0.0);
                gemm::tn_accumulate_window(a, b, out_chunk, k, m, n, i0, rows, 0, n);
            });
        }
    });
}

/// Row-sliced fused weight-gradient + SGD update: each thread owns a
/// contiguous `param` row chunk and a matching slice of the caller's
/// scratch, so no two threads ever touch the same scratch or parameter
/// byte.
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_tn_sgd(
    a: &[f32],
    b: &[f32],
    param: &mut [f32],
    lr: f32,
    k: usize,
    m: usize,
    n: usize,
    scratch: &mut [f32],
    threads: usize,
) {
    let nb_max = fused::SGD_COL_BLOCK.min(n);
    let cr = chunk_rows(m, threads, 1);
    let scratch = &mut scratch[..m * nb_max];
    std::thread::scope(|s| {
        for ((ci, param_chunk), scratch_chunk) in param
            .chunks_mut(cr * n)
            .enumerate()
            .zip(scratch.chunks_mut(cr * nb_max))
        {
            let rows = param_chunk.len() / n;
            let i0 = ci * cr;
            s.spawn(move || {
                fused::tn_sgd_rows(a, b, param_chunk, lr, k, m, n, i0, rows, scratch_chunk);
            });
        }
    });
}

/// Row-sliced CSR layer-1 forward: batch rows are independent, each
/// thread scans its own rows' nonzeros.
pub(crate) fn par_csr_forward(
    csr: &CsrBatch,
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    n: usize,
    relu: bool,
    threads: usize,
) {
    let cr = chunk_rows(csr.rows(), threads, 1);
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(cr * n).enumerate() {
            let r0 = ci * cr;
            s.spawn(move || sparse::csr_nn_rows(csr, w, bias, out_chunk, n, relu, r0));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_guard_nests_and_restores() {
        assert_eq!(kernel_threads(), 1);
        {
            let _outer = set_kernel_threads(4);
            assert_eq!(kernel_threads(), 4);
            {
                let _inner = set_kernel_threads(2);
                assert_eq!(kernel_threads(), 2);
            }
            assert_eq!(kernel_threads(), 4);
        }
        assert_eq!(kernel_threads(), 1);
        // 0 clamps to 1 — "disable" never under-flows the budget.
        let _z = set_kernel_threads(0);
        assert_eq!(kernel_threads(), 1);
    }

    #[test]
    fn plan_stays_sequential_below_the_flop_floor() {
        let _g = set_kernel_threads(8);
        assert_eq!(plan(64, PAR_MIN_FLOPS - 1, 4), 1);
        assert_eq!(plan(64, PAR_MIN_FLOPS, 4), 8);
        // Capped by available aligned chunks.
        assert_eq!(plan(8, PAR_MIN_FLOPS, 4), 2);
        assert_eq!(plan(1, PAR_MIN_FLOPS, 4), 1);
    }

    #[test]
    fn chunking_covers_all_rows_with_aligned_chunks() {
        for rows in [1usize, 3, 4, 7, 8, 64, 65, 100] {
            for threads in [1usize, 2, 3, 4, 7] {
                for align in [1usize, 2, 4] {
                    let cr = chunk_rows(rows, threads, align);
                    assert!(cr >= 1 && cr % align == 0, "rows={rows} t={threads} a={align}");
                    assert!(cr * threads >= rows, "chunks must cover every row");
                }
            }
        }
    }
}
