//! CSR sparse-batch kernels for the feature-hashed input layer.
//!
//! Feature hashing maps a handful of raw `(index, value)` pairs into a
//! `d`-wide dense row, so training and serving batches are mostly
//! zeros — the naive dense loops still *scan* all `batch × d` entries.
//! A [`CsrBatch`] holds only the nonzeros, and the layer-1 forward /
//! weight-gradient kernels below scale with `nnz` instead of
//! `batch × d`.
//!
//! Numerics: a CSR row visits its nonzero columns in ascending order —
//! the same order the dense kernels walk the reduction — and the terms
//! it skips are exact zeros, so (absent products that underflow to
//! signed zero) the sparse forward is bitwise identical to the dense
//! one. `tests/kernel_properties.rs` pins this equivalence.

#![allow(clippy::too_many_arguments)]

use super::{parallel, simd};

/// A batch of rows in compressed-sparse-row form, with reusable
/// buffers so the per-step conversion allocates nothing at steady
/// state.
#[derive(Clone, Debug, Default)]
pub struct CsrBatch {
    rows: usize,
    cols: usize,
    /// `rows + 1` offsets into `indices`/`values`.
    indptr: Vec<u32>,
    /// Column of each nonzero, ascending within a row.
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrBatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Nonzero columns and values of row `r`.
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// Rebuild from a dense `[rows, cols]` batch, reusing the internal
    /// buffers.
    pub fn from_dense(&mut self, x: &[f32], rows: usize, cols: usize) {
        let complete = self.try_from_dense(x, rows, cols, usize::MAX);
        debug_assert!(complete);
    }

    /// Rebuild from a dense batch, giving up as soon as the nonzero
    /// count exceeds `max_nnz` (the caller's dense-vs-sparse cutoff).
    /// Returns whether the build completed; on `false` the batch is
    /// left in an unspecified (but safe) state and must not be used.
    pub fn try_from_dense(&mut self, x: &[f32], rows: usize, cols: usize, max_nnz: usize) -> bool {
        assert_eq!(x.len(), rows * cols, "dense batch shape mismatch");
        debug_assert!(cols <= u32::MAX as usize);
        self.rows = rows;
        self.cols = cols;
        self.indptr.clear();
        self.indices.clear();
        self.values.clear();
        self.indptr.push(0);
        for xr in x.chunks_exact(cols) {
            for (c, &v) in xr.iter().enumerate() {
                if v != 0.0 {
                    self.indices.push(c as u32);
                    self.values.push(v);
                }
            }
            if self.values.len() > max_nnz {
                return false;
            }
            self.indptr.push(self.values.len() as u32);
        }
        true
    }
}

/// The nnz threshold below which the sparse path beats the dense one
/// for a batch of `len = rows × cols` entries (density ≤ ½ — each CSR
/// term costs about two dense terms' worth of work).
pub fn sparse_cutoff(len: usize) -> usize {
    len / 2
}

/// `out[rows,n] = csr @ w + bias` (`w` is `[cols, n]` row-major).
pub fn csr_gemm_bias(csr: &CsrBatch, w: &[f32], bias: &[f32], out: &mut [f32], n: usize) {
    csr_nn_dispatch(csr, w, bias, out, n, false);
}

/// `out[rows,n] = relu(csr @ w + bias)` — the fused sparse layer-1
/// forward.
pub fn csr_gemm_bias_relu(csr: &CsrBatch, w: &[f32], bias: &[f32], out: &mut [f32], n: usize) {
    csr_nn_dispatch(csr, w, bias, out, n, true);
}

#[inline(always)]
fn csr_nn_dispatch(csr: &CsrBatch, w: &[f32], bias: &[f32], out: &mut [f32], n: usize, relu: bool) {
    debug_assert_eq!(w.len(), csr.cols * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), csr.rows * n);
    let threads = parallel::plan(csr.rows, csr.nnz() * n, 1);
    if threads > 1 {
        parallel::par_csr_forward(csr, w, bias, out, n, relu, threads);
    } else {
        csr_nn_rows(csr, w, bias, out, n, relu, 0);
    }
}

/// The CSR forward body on the row window starting at `r0`, writing
/// `out` = that window's `[rows, n]` slice. Batch rows are independent
/// (each reads its own nonzeros), so row-slicing is bitwise-safe.
pub(crate) fn csr_nn_rows(
    csr: &CsrBatch,
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    n: usize,
    relu: bool,
    r0: usize,
) {
    for (i, orow) in out.chunks_exact_mut(n).enumerate() {
        orow.copy_from_slice(bias);
        let (idx, vals) = csr.row(r0 + i);
        for (&c, &v) in idx.iter().zip(vals.iter()) {
            simd::axpy(orow, v, &w[c as usize * n..(c as usize + 1) * n]);
        }
        if relu {
            simd::relu(orow);
        }
    }
}

/// Fused sparse weight gradient + SGD update:
/// `w[cols,n] -= lr · (csrᵀ @ d)` with `d` a dense `[rows, n]` matrix —
/// the layer-1 backward as a scatter of rank-1 updates over the
/// batch's nonzeros, costing `nnz × n` instead of `rows × cols × n`.
///
/// Deterministic: nonzeros are visited in (row, ascending column)
/// order, so every parameter row sees its updates in a fixed sequence.
///
/// Deliberately **not** row-sliced by [`super::parallel`]: different
/// batch rows scatter into the *same* parameter rows, so splitting the
/// batch would race (and fixing the race would reorder the scatter
/// sum, breaking the bitwise pin). The inner axpy still vectorizes.
pub fn csr_gemm_tn_sgd(csr: &CsrBatch, d: &[f32], w: &mut [f32], lr: f32, n: usize) {
    debug_assert_eq!(d.len(), csr.rows * n);
    debug_assert_eq!(w.len(), csr.cols * n);
    for (r, drow) in d.chunks_exact(n).enumerate() {
        let (idx, vals) = csr.row(r);
        for (&c, &v) in idx.iter().zip(vals.iter()) {
            let s = lr * v;
            simd::axpy_sub(&mut w[c as usize * n..(c as usize + 1) * n], s, drow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_example() -> (Vec<f32>, usize, usize) {
        // [0 2 0; 1 0 3] — nnz 3 of 6
        (vec![0.0, 2.0, 0.0, 1.0, 0.0, 3.0], 2, 3)
    }

    #[test]
    fn from_dense_roundtrips_structure() {
        let (x, rows, cols) = dense_example();
        let mut csr = CsrBatch::new();
        csr.from_dense(&x, rows, cols);
        assert_eq!((csr.rows(), csr.cols(), csr.nnz()), (2, 3, 3));
        assert_eq!(csr.row(0), (&[1u32][..], &[2.0f32][..]));
        assert_eq!(csr.row(1), (&[0u32, 2][..], &[1.0f32, 3.0][..]));
        // rebuild reuses buffers and fully resets state
        csr.from_dense(&[0.0, 0.0], 1, 2);
        assert_eq!((csr.rows(), csr.nnz()), (1, 0));
        assert_eq!(csr.row(0), (&[][..], &[][..]));
    }

    #[test]
    fn bounded_build_gives_up_past_cutoff() {
        let (x, rows, cols) = dense_example();
        let mut csr = CsrBatch::new();
        assert!(!csr.try_from_dense(&x, rows, cols, 2));
        assert!(csr.try_from_dense(&x, rows, cols, 3));
        assert_eq!(csr.nnz(), 3);
        assert_eq!(sparse_cutoff(rows * cols), 3);
    }

    #[test]
    fn sparse_forward_matches_dense() {
        let (x, rows, cols) = dense_example();
        let n = 2;
        let w: Vec<f32> = (0..cols * n).map(|i| i as f32 * 0.5 - 1.0).collect();
        let bias = vec![0.25f32, -0.5];
        let mut csr = CsrBatch::new();
        csr.from_dense(&x, rows, cols);
        let mut sparse_out = vec![0.0f32; rows * n];
        csr_gemm_bias(&csr, &w, &bias, &mut sparse_out, n);
        let mut dense_out = vec![0.0f32; rows * n];
        crate::kernels::fused::gemm_bias(&x, &w, &bias, &mut dense_out, rows, cols, n);
        assert_eq!(sparse_out, dense_out);
    }

    #[test]
    fn scatter_gradient_matches_dense_tn() {
        let (x, rows, cols) = dense_example();
        let n = 2;
        let d: Vec<f32> = (0..rows * n).map(|i| (i as f32 * 0.9).sin()).collect();
        let lr = 0.1;
        let mut csr = CsrBatch::new();
        csr.from_dense(&x, rows, cols);
        let init: Vec<f32> = (0..cols * n).map(|i| i as f32 * 0.01).collect();
        let mut sparse_w = init.clone();
        csr_gemm_tn_sgd(&csr, &d, &mut sparse_w, lr, n);
        let mut g = vec![0.0f32; cols * n];
        crate::kernels::gemm::gemm_tn(&x, &d, &mut g, rows, cols, n);
        let dense_w: Vec<f32> = init
            .iter()
            .zip(g.iter())
            .map(|(&p, &gv)| p - lr * gv)
            .collect();
        for (s, w) in sparse_w.iter().zip(dense_w.iter()) {
            assert!((s - w).abs() < 1e-6, "{s} vs {w}");
        }
    }
}
