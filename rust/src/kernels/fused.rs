//! Epilogue-fused kernels: whole passes over `[batch, out]`-sized
//! tiles eliminated by doing the adjacent elementwise work inside the
//! matmul sweep (bias + ReLU), inside one shared read of the logits
//! (BCE loss + its gradient), or column-block-wise while the gradient
//! tile is still cache-hot (SGD update, never materializing the full
//! gradient matrix).
//!
//! Same conventions as the rest of [`super`]: outputs are fully
//! overwritten (the `*_sgd` kernels update parameters in place), and
//! summation order is fixed and tiling-independent.

#![allow(clippy::too_many_arguments)]

use super::{gemm, parallel, simd};

/// Column-block width of the fused transpose-matmul + SGD kernel: the
/// gradient is computed `[m, SGD_COL_BLOCK]` columns at a time into a
/// caller scratch and applied to the parameters before moving on.
pub const SGD_COL_BLOCK: usize = 512;

/// Scratch length [`gemm_tn_sgd`] needs for a `[rows, cols]` parameter
/// tile (pass the maxima over every layer to size one shared buffer).
pub fn sgd_scratch_len(rows: usize, cols: usize) -> usize {
    rows * SGD_COL_BLOCK.min(cols)
}

/// `out[m,n] = a[m,k] @ b[k,n] + bias` (bias broadcast over rows).
///
/// The bias seeds the accumulator, so the separate bias pass of the
/// naive pipeline disappears.
pub fn gemm_bias(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    gemm::nn_dispatch(a, b, Some(bias), out, m, k, n, false);
}

/// `out[m,n] = relu(a[m,k] @ b[k,n] + bias)` — the fused hidden-layer
/// forward. ReLU is applied to each 4-row block right after its
/// reduction completes, while the block is still cache-hot.
pub fn gemm_bias_relu(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    gemm::nn_dispatch(a, b, Some(bias), out, m, k, n, true);
}

#[inline]
pub(crate) fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Fused BCE-with-logits loss + gradient: one read of `z`/`y` produces
/// both the numerically-stable mean loss (f64 accumulation in element
/// order — bitwise identical to [`crate::model::mlp::bce_loss`]) and
/// `dz = (sigmoid(z) − y) · scale`.
///
/// Stays scalar even with the `simd` feature on: the loss is
/// transcendental (`exp`, `ln_1p`) and any vector approximation would
/// change bits (see [`super::simd`]'s module docs). One pass over
/// `[batch, out]` — small next to the step's three GEMMs.
pub fn bce_loss_dz(z: &[f32], y: &[f32], scale: f32, dz: &mut [f32]) -> f32 {
    debug_assert_eq!(z.len(), y.len());
    debug_assert_eq!(z.len(), dz.len());
    let mut total = 0.0f64;
    for ((d, &zv), &yv) in dz.iter_mut().zip(z.iter()).zip(y.iter()) {
        total += (zv.max(0.0) - zv * yv + (-zv.abs()).exp().ln_1p()) as f64;
        *d = (sigmoid(zv) - yv) * scale;
    }
    (total / z.len() as f64) as f32
}

/// Zero `grad` wherever the forward activation was clamped. `h` is the
/// **post**-ReLU activation: `h[i] == 0` exactly when the
/// pre-activation was `≤ 0`, so no pre-activation copy needs to exist.
pub fn relu_backward_mask(grad: &mut [f32], h: &[f32]) {
    debug_assert_eq!(grad.len(), h.len());
    simd::relu_mask(grad, h);
}

/// Fused weight gradient + SGD update:
/// `param[m,n] -= lr · (a[k,m]ᵀ @ b[k,n])`.
///
/// Works one [`SGD_COL_BLOCK`]-wide column block at a time: the
/// gradient block is accumulated into `scratch` (k-blocked, ascending-k
/// order per element) and immediately applied to the parameter block —
/// the full `[m, n]` gradient never exists, and the update touches each
/// parameter exactly once. Numerically identical to materializing the
/// gradient and then subtracting `lr · g`.
pub fn gemm_tn_sgd(
    a: &[f32],
    b: &[f32],
    param: &mut [f32],
    lr: f32,
    k: usize,
    m: usize,
    n: usize,
    scratch: &mut [f32],
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(param.len(), m * n);
    let nb_max = SGD_COL_BLOCK.min(n);
    debug_assert!(
        scratch.len() >= m * nb_max,
        "sgd scratch has {} floats, tile [{m},{n}] needs {}",
        scratch.len(),
        m * nb_max
    );
    let threads = parallel::plan(m, k * m * n, 1);
    if threads > 1 {
        parallel::par_tn_sgd(a, b, param, lr, k, m, n, scratch, threads);
    } else {
        tn_sgd_rows(a, b, param, lr, k, m, n, 0, m, scratch);
    }
}

/// [`gemm_tn_sgd`] restricted to the parameter-row window
/// `[i0, i0 + rows)`: `param` is that window's `[rows, n]` slice and
/// `scratch` holds at least `rows · min(SGD_COL_BLOCK, n)` floats. The
/// column-block walk and each element's ascending-k accumulation are
/// unchanged, so any row partition reproduces the sequential bits.
pub(crate) fn tn_sgd_rows(
    a: &[f32],
    b: &[f32],
    param: &mut [f32],
    lr: f32,
    k: usize,
    m: usize,
    n: usize,
    i0: usize,
    rows: usize,
    scratch: &mut [f32],
) {
    debug_assert_eq!(param.len(), rows * n);
    let nb_max = SGD_COL_BLOCK.min(n);
    let mut j0 = 0;
    while j0 < n {
        let nb = nb_max.min(n - j0);
        let g = &mut scratch[..rows * nb];
        g.fill(0.0);
        gemm::tn_accumulate_window(a, b, g, k, m, n, i0, rows, j0, nb);
        for i in 0..rows {
            let prow = &mut param[i * n + j0..i * n + j0 + nb];
            simd::axpy_sub(prow, lr, &g[i * nb..(i + 1) * nb]);
        }
        j0 += nb;
    }
}

/// Fused column-sum + SGD bias update:
/// `bias[n] -= lr · column_sum(grad[m,n])`, applied row by row in batch
/// order (the bias sees `m` sequential updates — the same float
/// operations as the naive two-pass pipeline).
pub fn sgd_bias_colsum(bias: &mut [f32], grad: &[f32], m: usize, n: usize, lr: f32) {
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(grad.len(), m * n);
    for row in grad.chunks_exact(n) {
        simd::axpy_sub(bias, lr, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_relu_fusion_matches_separate_passes() {
        let (m, k, n) = (3, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 1.3).cos()).collect();
        let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.1 - 0.15).collect();
        let mut want = vec![0.0f32; m * n];
        gemm::gemm_nn(&a, &b, &mut want, m, k, n);
        for row in want.chunks_exact_mut(n) {
            for (v, &bv) in row.iter_mut().zip(bias.iter()) {
                *v += bv;
            }
        }
        let mut plain = vec![0.0f32; m * n];
        gemm_bias(&a, &b, &bias, &mut plain, m, k, n);
        for (g, w) in plain.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
        for v in want.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let mut fused = vec![0.0f32; m * n];
        gemm_bias_relu(&a, &b, &bias, &mut fused, m, k, n);
        for (g, w) in fused.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
    }

    #[test]
    fn loss_dz_matches_definitions() {
        let z = [0.0f32, 2.0, -3.0, 0.5];
        let y = [0.0f32, 1.0, 0.0, 1.0];
        let mut dz = [0.0f32; 4];
        let scale = 0.25;
        let loss = bce_loss_dz(&z, &y, scale, &mut dz);
        let want_loss: f32 = z
            .iter()
            .zip(y.iter())
            .map(|(&zv, &yv)| zv.max(0.0) - zv * yv + (-zv.abs()).exp().ln_1p())
            .sum::<f32>()
            / 4.0;
        assert!((loss - want_loss).abs() < 1e-6);
        for i in 0..4 {
            let want = (sigmoid(z[i]) - y[i]) * scale;
            assert!((dz[i] - want).abs() < 1e-7);
        }
    }

    #[test]
    fn tn_sgd_crosses_column_blocks() {
        // n > SGD_COL_BLOCK forces at least two column blocks, with a
        // ragged final block; compare against materialize-then-update.
        let (k, m, n) = (3, 2, SGD_COL_BLOCK + 37);
        let a: Vec<f32> = (0..k * m).map(|i| (i as f32 * 0.31).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.17).cos()).collect();
        let lr = 0.3;
        let mut want = vec![0.1f32; m * n];
        let mut g = vec![0.0f32; m * n];
        gemm::gemm_tn(&a, &b, &mut g, k, m, n);
        for (p, &gv) in want.iter_mut().zip(g.iter()) {
            *p -= lr * gv;
        }
        let mut got = vec![0.1f32; m * n];
        let mut scratch = vec![0.0f32; sgd_scratch_len(m, n)];
        gemm_tn_sgd(&a, &b, &mut got, lr, k, m, n, &mut scratch);
        assert_eq!(got, want);
    }

    #[test]
    fn relu_mask_uses_post_activation() {
        let h = [0.0f32, 1.5, 0.0, 2.0];
        let mut g = [1.0f32, 2.0, 3.0, 4.0];
        relu_backward_mask(&mut g, &h);
        assert_eq!(g, [0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn bias_colsum_matches_naive_order() {
        let grad = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let mut bias = [10.0f32, 20.0];
        sgd_bias_colsum(&mut bias, &grad, 3, 2, 0.5);
        assert_eq!(bias, [10.0 - 0.5 * 9.0, 20.0 - 0.5 * 12.0]);
    }
}
