//! The seed repo's scalar reference kernels, frozen verbatim.
//!
//! This is the pre-tiling `model/mlp.rs` math: single-accumulator
//! `ikj` matmuls with a per-element `== 0.0` skip, separate
//! zero/bias/ReLU/copy passes, and a fully materialized weight
//! gradient. It exists for two jobs:
//!
//! 1. **ground truth** — `tests/kernel_properties.rs` pins every tiled
//!    kernel in [`super::gemm`] / [`super::fused`] / [`super::sparse`]
//!    against these loops across awkward shapes;
//! 2. **baseline** — `benches/bench_train.rs` runs [`train_step`] and
//!    [`forward`] side by side with the tiled path and records the
//!    speedup in `BENCH_train.json`.
//!
//! Do not optimize this module; its value is that it stays naive.

use crate::model::params::ModelParams;

/// `out[m,n] = a[m,k] @ b[k,n]` (row-major, accumulating into zeroed out).
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // ikj loop order: streams through b and out rows contiguously.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,n] = a[k,m]^T @ b[k,n]` (i.e. aᵀb) without materializing aᵀ.
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,k] = a[m,n] @ b[k,n]^T` (i.e. abᵀ) without materializing bᵀ.
pub fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            *o = acc;
        }
    }
}

fn add_bias_rows(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    for row in x.chunks_mut(n) {
        for (v, &b) in row.iter_mut().zip(bias.iter()) {
            *v += b;
        }
    }
}

fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

fn bce_loss(z: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(z.len(), y.len());
    let total: f64 = z
        .iter()
        .zip(y.iter())
        .map(|(&z, &y)| (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64)
        .sum();
    (total / z.len() as f64) as f32
}

fn relu_backward(grad: &mut [f32], preact: &[f32]) {
    for (g, &a) in grad.iter_mut().zip(preact.iter()) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

fn sgd_update(param: &mut [f32], grad: &[f32], lr: f32) {
    for (p, &g) in param.iter_mut().zip(grad.iter()) {
        *p -= lr * g;
    }
}

/// `bias -= lr * column_sum(grad)` for a `[m, n]` gradient.
fn col_sum_update(bias: &mut [f32], grad: &[f32], m: usize, n: usize, lr: f32) {
    for i in 0..m {
        let row = &grad[i * n..(i + 1) * n];
        for (b, &g) in bias.iter_mut().zip(row.iter()) {
            *b -= lr * g;
        }
    }
}

/// The seed `Workspace`: keeps pre-activation copies (`a1`/`a2`) and a
/// materialized weight-gradient buffer, exactly as the naive
/// `train_step` needs. (The seed over-sized `gw` as
/// `max(d,h) × max(h,out)`; sized here to what the three products
/// actually need so the baseline is not penalized on allocation.)
pub struct NaiveWorkspace {
    batch: usize,
    a1: Vec<f32>,
    h1: Vec<f32>,
    a2: Vec<f32>,
    h2: Vec<f32>,
    z: Vec<f32>,
    dz: Vec<f32>,
    dh2: Vec<f32>,
    dh1: Vec<f32>,
    gw: Vec<f32>,
}

impl NaiveWorkspace {
    pub fn new(params: &ModelParams, batch: usize) -> Self {
        let (d, h, out) = (params.d, params.hidden, params.out);
        NaiveWorkspace {
            batch,
            a1: vec![0.0; batch * h],
            h1: vec![0.0; batch * h],
            a2: vec![0.0; batch * h],
            h2: vec![0.0; batch * h],
            z: vec![0.0; batch * out],
            dz: vec![0.0; batch * out],
            dh2: vec![0.0; batch * h],
            dh1: vec![0.0; batch * h],
            gw: vec![0.0; (d * h).max(h * h).max(h * out)],
        }
    }
}

/// The seed forward pass: three fresh `Vec` allocations per call.
pub fn forward(params: &ModelParams, x: &[f32], rows: usize) -> Vec<f32> {
    let (d, h, out) = (params.d, params.hidden, params.out);
    debug_assert_eq!(x.len(), rows * d);
    let mut h1 = vec![0.0f32; rows * h];
    matmul(x, params.w1().data(), &mut h1, rows, d, h);
    add_bias_rows(&mut h1, params.b1().data());
    relu(&mut h1);
    let mut h2 = vec![0.0f32; rows * h];
    matmul(&h1, params.w2().data(), &mut h2, rows, h, h);
    add_bias_rows(&mut h2, params.b2().data());
    relu(&mut h2);
    let mut z = vec![0.0f32; rows * out];
    matmul(&h2, params.w3().data(), &mut z, rows, h, out);
    add_bias_rows(&mut z, params.b3().data());
    z
}

/// The seed SGD minibatch step; returns the pre-update loss.
pub fn train_step(
    params: &mut ModelParams,
    ws: &mut NaiveWorkspace,
    x: &[f32],
    y: &[f32],
    lr: f32,
) -> f32 {
    let (d, h, out) = (params.d, params.hidden, params.out);
    let m = ws.batch;
    debug_assert_eq!(x.len(), m * d);
    debug_assert_eq!(y.len(), m * out);

    // ---- forward (keeping pre-activations for the backward pass)
    matmul(x, params.w1().data(), &mut ws.a1, m, d, h);
    add_bias_rows(&mut ws.a1, params.b1().data());
    ws.h1.copy_from_slice(&ws.a1);
    relu(&mut ws.h1);

    matmul(&ws.h1, params.w2().data(), &mut ws.a2, m, h, h);
    add_bias_rows(&mut ws.a2, params.b2().data());
    ws.h2.copy_from_slice(&ws.a2);
    relu(&mut ws.h2);

    matmul(&ws.h2, params.w3().data(), &mut ws.z, m, h, out);
    add_bias_rows(&mut ws.z, params.b3().data());

    let loss = bce_loss(&ws.z, y);

    // ---- backward
    let scale = 1.0 / (m * out) as f32;
    for ((dz, &z), &yv) in ws.dz.iter_mut().zip(ws.z.iter()).zip(y.iter()) {
        *dz = (sigmoid(z) - yv) * scale;
    }

    // layer 3 — backprop dh2 through the *pre-update* w3, then update.
    matmul_nt(&ws.dz, params.w3().data(), &mut ws.dh2, m, out, h);
    relu_backward(&mut ws.dh2, &ws.a2);
    {
        let gw3 = &mut ws.gw[..h * out];
        matmul_tn(&ws.h2, &ws.dz, gw3, m, h, out);
        sgd_update(params.tensors[4].data_mut(), gw3, lr);
        col_sum_update(params.tensors[5].data_mut(), &ws.dz, m, out, lr);
    }

    // layer 2 — same ordering discipline.
    matmul_nt(&ws.dh2, params.w2().data(), &mut ws.dh1, m, h, h);
    relu_backward(&mut ws.dh1, &ws.a1);
    {
        let gw2 = &mut ws.gw[..h * h];
        matmul_tn(&ws.h1, &ws.dh2, gw2, m, h, h);
        sgd_update(params.tensors[2].data_mut(), gw2, lr);
        col_sum_update(params.tensors[3].data_mut(), &ws.dh2, m, h, lr);
    }

    // layer 1
    {
        let gw1 = &mut ws.gw[..d * h];
        matmul_tn(x, &ws.dh1, gw1, m, d, h);
        sgd_update(params.tensors[0].data_mut(), gw1, lr);
        col_sum_update(params.tensors[1].data_mut(), &ws.dh1, m, h, lr);
    }

    loss
}
