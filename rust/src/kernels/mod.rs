//! Tiled compute kernels for the MLP hot path.
//!
//! Every FedMLH round funnels the same math through this module: each
//! client's `train_step`, every evaluation batch and every serving
//! request is two hidden layers plus one extreme-width output layer of
//! dense matmuls. The naive scalar loops that seeded the repo (kept
//! verbatim in [`naive`] as the property-test and benchmark baseline)
//! spend most of their time re-streaming operands from memory; the
//! kernels here restructure the loops for cache reuse without changing
//! what is computed:
//!
//! - [`gemm`] — register-blocked matmul micro-kernels: `gemm_nn`
//!   processes four output rows per pass so each row of the (wide) B
//!   operand is loaded once per four rows of A instead of once per row;
//!   `gemm_tn` blocks the reduction dimension so the output tile is
//!   streamed k/4 times instead of k times; `gemm_nt` keeps eight
//!   independent partial sums per dot product so the reduction
//!   vectorizes instead of serializing on one accumulator.
//! - [`fused`] — epilogue-fused variants that eliminate whole passes
//!   over `[batch, out]` tiles: matmul+bias+ReLU in one sweep, the BCE
//!   loss and its `sigmoid(z) − y` gradient in one read of the logits,
//!   and the SGD weight update applied column-block-wise while the
//!   just-computed gradient tile is still cache-hot (the gradient is
//!   never materialized at full `[rows, cols]` size).
//! - [`sparse`] — a CSR batch representation for the feature-hashed
//!   input layer: layer-1 forward and its weight gradient scale with
//!   the batch's nonzero count instead of `batch × d`.
//! - [`simd`] — the innermost loops of all of the above, behind one
//!   dispatch layer: a verbatim scalar body (always compiled, the only
//!   body without the `simd` cargo feature) and an AVX2 body that
//!   vectorizes across independent output elements only — no FMA, no
//!   reassociation — so both bodies produce **identical bits** and the
//!   feature can be flipped without perturbing a single pinned test.
//! - [`parallel`] — intra-step parallelism: row-sliced scoped threads
//!   inside one GEMM/CSR call, budgeted per thread by the round engine
//!   (`--workers` beyond the item count flows down here, so a single
//!   huge client saturates cores). Each output element is still
//!   written by exactly one thread in the same order, so any thread
//!   count is bitwise identical to sequential.
//!
//! # Conventions (the whole-module contract)
//!
//! - Operands are row-major `f32` slices; dimensions are passed
//!   explicitly and `debug_assert`ed against slice lengths.
//! - **Every kernel fully overwrites its output** (accumulating
//!   variants say so in their name, e.g. `*_sgd` updates parameters in
//!   place). The seed code's mixed convention — `matmul`/`matmul_tn`
//!   zeroed internally while `matmul_nt` overwrote — is gone.
//! - **Determinism**: every kernel uses a fixed summation order that
//!   depends only on the reduction dimension, never on how the output
//!   is tiled. In particular each forward output element accumulates
//!   its k terms in ascending-k order whether the row is computed in a
//!   4-row block, as a remainder row, or in a different batch — so a
//!   batched forward is bitwise identical to per-row forwards, the
//!   property the serving micro-batcher and the round engine's
//!   parallel-vs-sequential pin (`tests/parallel_determinism.rs`) rely
//!   on.

pub mod fused;
pub mod gemm;
pub mod naive;
pub mod parallel;
pub mod simd;
pub mod sparse;
