//! Dataset presets: scaled analogs of the paper's four extreme
//! classification datasets (Table 1), plus a `tiny` preset for tests.
//!
//! This table MUST stay in sync with `python/compile/variants.py` — the
//! AOT manifest is the source of truth and `runtime::manifest` validates
//! shapes at load time, so drift fails fast rather than silently.
//!
//! Scaling rationale (DESIGN.md §3): the real datasets are unavailable
//! offline, and this testbed is a single CPU core rather than a P100
//! cluster. We preserve the quantities the paper's analysis depends on —
//! power-law label frequencies (Fig 2a), infrequent-class positive mass
//! (Fig 2b), the B/p compression ratio and the non-iid partition — and
//! scale N and p down so full 70-round runs are feasible.

use anyhow::{bail, Result};

/// One dataset configuration (paper Tables 1 and 2).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetPreset {
    pub name: &'static str,
    /// Analog of the paper dataset this preset stands in for.
    pub paper_analog: &'static str,
    /// Hashed feature dimension (the paper's d-tilde; inputs are
    /// feature-hashed before training, Section 6).
    pub d: usize,
    /// Number of classes p.
    pub p: usize,
    /// Training samples N.
    pub n_train: usize,
    /// Held-out test samples.
    pub n_test: usize,
    /// Hidden width of the 2-hidden-layer MLP.
    pub hidden: usize,
    /// FedMLH hash tables / sub-models (paper Table 2).
    pub r: usize,
    /// Buckets per hash table (paper Table 2).
    pub b: usize,
    /// Minibatch size baked into the AOT artifacts.
    pub batch: usize,
    /// Default SGD learning rate.
    pub lr: f32,
    /// Zipf exponent of the label-frequency law (Fig 2a).
    pub zipf_alpha: f64,
    /// Mean positive labels per sample (multi-label).
    pub labels_per_sample: f64,
    /// Figure-5 sweep values for B (artifacts exist for these).
    pub sweep_b: &'static [usize],
    /// Figure-5 sweep values for R (decode artifacts exist for these).
    pub sweep_r: &'static [usize],
}

impl DatasetPreset {
    /// `f32` parameter count of one trained model with output width
    /// `out` (`p` for FedAvg, `B` for one FedMLH sub-model) — the unit
    /// the wire codecs ([`crate::federated::wire`]) compress and the
    /// closed-form Table 4/5 cross-checks start from. Derived from
    /// [`crate::model::params::ModelParams::shapes`] so the layer
    /// layout has a single source of truth.
    pub fn param_count(&self, out: usize) -> usize {
        crate::model::params::ModelParams::shapes(self.d, self.hidden, out)
            .iter()
            .map(|shape| shape.iter().product::<usize>())
            .sum()
    }
}

pub const PRESETS: &[DatasetPreset] = &[
    DatasetPreset {
        name: "tiny",
        paper_analog: "(test only)",
        d: 32,
        p: 64,
        n_train: 512,
        n_test: 128,
        hidden: 16,
        r: 2,
        b: 16,
        batch: 16,
        lr: 0.1,
        zipf_alpha: 1.1,
        labels_per_sample: 3.0,
        sweep_b: &[],
        sweep_r: &[],
    },
    DatasetPreset {
        name: "eurlex",
        paper_analog: "EURLex-4K",
        d: 256,
        p: 4000,
        n_train: 6000,
        n_test: 1500,
        hidden: 128,
        r: 4,
        b: 250,
        batch: 64,
        lr: 32.0,
        zipf_alpha: 1.1,
        labels_per_sample: 5.0,
        sweep_b: &[125, 500, 1000],
        sweep_r: &[2, 8],
    },
    DatasetPreset {
        name: "wiki31",
        paper_analog: "Wiki10-31K",
        d: 512,
        p: 8000,
        n_train: 4000,
        n_test: 1000,
        hidden: 128,
        r: 4,
        b: 500,
        batch: 64,
        lr: 48.0,
        zipf_alpha: 1.05,
        labels_per_sample: 8.0,
        sweep_b: &[250, 1000, 2000],
        sweep_r: &[2, 8],
    },
    DatasetPreset {
        name: "amztitle",
        paper_analog: "LF-AmazonTitle-131K",
        d: 512,
        p: 16384,
        n_train: 8000,
        n_test: 2000,
        hidden: 128,
        r: 4,
        b: 1024,
        batch: 64,
        lr: 64.0,
        zipf_alpha: 1.15,
        labels_per_sample: 3.0,
        sweep_b: &[],
        sweep_r: &[],
    },
    DatasetPreset {
        name: "wikititle",
        paper_analog: "LF-WikiSeeAlsoTitles-320K",
        d: 512,
        p: 32768,
        n_train: 8000,
        n_test: 2000,
        hidden: 128,
        r: 8,
        b: 2048,
        batch: 64,
        lr: 64.0,
        zipf_alpha: 1.2,
        labels_per_sample: 2.5,
        sweep_b: &[],
        sweep_r: &[],
    },
];

/// Look up a preset by name.
pub fn by_name(name: &str) -> Result<DatasetPreset> {
    for p in PRESETS {
        if p.name == name {
            return Ok(p.clone());
        }
    }
    let names: Vec<_> = PRESETS.iter().map(|p| p.name).collect();
    bail!("unknown preset '{name}' (available: {names:?})")
}

/// The four paper datasets, in the paper's column order.
pub fn paper_presets() -> Vec<DatasetPreset> {
    ["eurlex", "wiki31", "amztitle", "wikititle"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolvable() {
        for p in PRESETS {
            assert_eq!(by_name(p.name).unwrap(), *p);
        }
        assert!(by_name("bogus").is_err());
    }

    #[test]
    fn paper_presets_in_column_order() {
        let names: Vec<_> = paper_presets().iter().map(|p| p.name).collect();
        assert_eq!(names, vec!["eurlex", "wiki31", "amztitle", "wikititle"]);
    }

    #[test]
    fn compression_holds_for_every_preset() {
        // FedMLH's premise: R*B << p so the hashed output layer is smaller.
        for p in PRESETS.iter().filter(|p| p.name != "tiny") {
            assert!(p.r * p.b < p.p, "{}: R*B={} >= p={}", p.name, p.r * p.b, p.p);
        }
    }

    #[test]
    fn param_count_matches_model_params() {
        use crate::model::params::ModelParams;
        let p = by_name("tiny").unwrap();
        for out in [p.p, p.b] {
            let m = ModelParams::zeros(p.d, p.hidden, out);
            assert_eq!(p.param_count(out), m.num_params());
        }
    }

    #[test]
    fn batch_divides_reasonably() {
        for p in PRESETS {
            assert!(p.batch > 0 && p.n_test >= p.batch, "{}", p.name);
        }
    }
}
