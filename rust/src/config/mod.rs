//! Experiment configuration: dataset presets (mirroring
//! `python/compile/variants.py`) and the federated-learning setup from
//! the paper's Section 6.

pub mod presets;

use anyhow::{bail, Result};

use crate::federated::sim::Dist;
use crate::federated::transport::DownCodec;
use crate::federated::wire::CodecSpec;

pub use presets::{DatasetPreset, PRESETS};

/// Which algorithm a run trains (paper's two baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// FedAvg with the full p-way output layer (McMahan et al., 2017).
    FedAvg,
    /// Federated Multiple Label Hashing: R sub-models over B buckets.
    FedMlh,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::FedAvg => "fedavg",
            Algo::FedMlh => "fedmlh",
        }
    }

    pub fn parse(s: &str) -> Result<Algo> {
        match s {
            "fedavg" => Ok(Algo::FedAvg),
            "fedmlh" => Ok(Algo::FedMlh),
            other => bail!("unknown algo '{other}' (expected fedavg|fedmlh)"),
        }
    }
}

/// Event-driven simulation setup (CLI: `--async` and friends). Only
/// consulted when `async_mode` is on; the synchronous loop ignores it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimConfig {
    /// Run the buffered-async (FedBuff-style) event-driven simulator
    /// instead of the synchronous sample→train→barrier→aggregate loop.
    pub async_mode: bool,
    /// Virtual client registry size (0 = `clients`). Registry client
    /// `c` trains on data shard `c % clients`, and per-client state is
    /// derived lazily from the seed — memory stays proportional to
    /// in-flight clients, never to the registry.
    pub registry: usize,
    /// Aggregate once this many updates have arrived (FedBuff's K).
    pub buffer: usize,
    /// Clients training/transferring concurrently in simulated time.
    pub concurrency: usize,
    /// Probability a dispatched client drops mid-round (it is charged
    /// its broadcast download but ships nothing back).
    pub dropout: f64,
    /// Per-client compute seconds *per local epoch*, drawn once per
    /// client from this distribution.
    pub latency: Dist,
    /// Per-client link bandwidth in Mbit/s, drawn independently for the
    /// down and up directions.
    pub bandwidth: Dist,
    /// Staleness-weight exponent: an update `s` aggregations stale is
    /// weighted `(1 + s)^-exp` (FedBuff uses 0.5).
    pub staleness_exp: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            async_mode: false,
            registry: 0,
            buffer: 10,
            concurrency: 32,
            dropout: 0.0,
            latency: Dist::LogNormal {
                median: 2.0,
                sigma: 0.7,
            },
            bandwidth: Dist::LogNormal {
                median: 20.0,
                sigma: 0.8,
            },
            staleness_exp: 0.5,
        }
    }
}

impl SimConfig {
    /// Named scenario presets (CLI: `--scenario`); explicit sim flags
    /// override individual fields afterwards.
    pub fn scenario(name: &str) -> Result<SimConfig> {
        let base = SimConfig {
            async_mode: true,
            ..SimConfig::default()
        };
        Ok(match name {
            // Small enough for CI: 10k registry, light dropout.
            "smoke" => SimConfig {
                registry: 10_000,
                buffer: 20,
                concurrency: 40,
                dropout: 0.1,
                ..base
            },
            // The ROADMAP's simulated-million-client target.
            "million" => SimConfig {
                registry: 1_000_000,
                buffer: 50,
                concurrency: 128,
                dropout: 0.2,
                latency: Dist::LogNormal {
                    median: 3.0,
                    sigma: 1.0,
                },
                bandwidth: Dist::LogNormal {
                    median: 10.0,
                    sigma: 1.0,
                },
                ..base
            },
            other => bail!("unknown scenario '{other}' (expected smoke|million)"),
        })
    }

    pub fn validate(&self) -> Result<()> {
        if !self.async_mode {
            return Ok(());
        }
        if self.buffer == 0 {
            bail!("--buffer must be positive");
        }
        if self.concurrency == 0 {
            bail!("--concurrency must be positive");
        }
        if !(0.0..1.0).contains(&self.dropout) {
            bail!("--dropout must be in [0, 1): {}", self.dropout);
        }
        if !(self.staleness_exp >= 0.0) {
            bail!("--staleness-exp must be >= 0: {}", self.staleness_exp);
        }
        self.latency
            .validate()
            .map_err(|e| anyhow::anyhow!("latency distribution: {e}"))?;
        self.bandwidth
            .validate()
            .map_err(|e| anyhow::anyhow!("bandwidth distribution: {e}"))?;
        Ok(())
    }
}

/// Deterministic fault injection rates (CLI: `--inject
/// corrupt:<p>,truncate:<p>,nan:<p>,fail:<p>`). Fates are drawn from
/// the run's seeded RNG per `(round, client, sub-model)` — see
/// [`crate::federated::fault`] — so an injected run is bitwise
/// reproducible for a seed, including across `--workers`. All rates
/// default to zero; a zero-rate config draws *no* RNG values, keeping
/// clean runs byte-identical to pre-injection builds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InjectConfig {
    /// Probability a shipped sub-model payload arrives with a flipped
    /// bit (caught by the frame checksum; the update is discarded).
    pub corrupt: f64,
    /// Probability a shipped payload arrives truncated (discarded).
    pub truncate: f64,
    /// Probability a client's decoded sub-model update is NaN-poisoned
    /// on arrival (screened by `--robust-agg`).
    pub nan: f64,
    /// Probability a client transiently fails to ship anything this
    /// round (the async sim retries with backoff on the simulated
    /// clock; the sync loop drops the client's contribution).
    pub fail: f64,
}

impl InjectConfig {
    /// Parse a comma-separated rate list, e.g. `corrupt:0.05,nan:0.02`.
    /// Unlisted kinds stay at zero; `none` (or an empty string) is the
    /// all-zero config.
    pub fn parse(s: &str) -> Result<InjectConfig> {
        let mut cfg = InjectConfig::default();
        if s.is_empty() || s == "none" {
            return Ok(cfg);
        }
        for part in s.split(',') {
            let (kind, rate) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad --inject entry '{part}' (expected kind:rate)"))?;
            let rate: f64 = rate
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --inject rate in '{part}'"))?;
            match kind {
                "corrupt" => cfg.corrupt = rate,
                "truncate" => cfg.truncate = rate,
                "nan" => cfg.nan = rate,
                "fail" => cfg.fail = rate,
                other => bail!(
                    "unknown --inject kind '{other}' (expected corrupt|truncate|nan|fail)"
                ),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// True when any fault kind has a nonzero rate. The injection hooks
    /// draw no RNG values when this is false, so clean trajectories are
    /// untouched.
    pub fn any(&self) -> bool {
        self.corrupt > 0.0 || self.truncate > 0.0 || self.nan > 0.0 || self.fail > 0.0
    }

    pub fn validate(&self) -> Result<()> {
        for (name, rate) in [
            ("corrupt", self.corrupt),
            ("truncate", self.truncate),
            ("nan", self.nan),
            ("fail", self.fail),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                bail!("--inject {name} rate must be in [0, 1]: {rate}");
            }
        }
        // The per-payload kinds are drawn from one uniform sample over
        // cumulative intervals, so their rates must fit in [0, 1]
        // together.
        let per_payload = self.corrupt + self.truncate + self.nan;
        if per_payload > 1.0 {
            bail!("--inject corrupt+truncate+nan rates sum to {per_payload} > 1");
        }
        Ok(())
    }
}

impl std::fmt::Display for InjectConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if !self.any() {
            return write!(f, "none");
        }
        let mut parts = Vec::new();
        for (name, rate) in [
            ("corrupt", self.corrupt),
            ("truncate", self.truncate),
            ("nan", self.nan),
            ("fail", self.fail),
        ] {
            if rate > 0.0 {
                parts.push(format!("{name}:{rate}"));
            }
        }
        write!(f, "{}", parts.join(","))
    }
}

/// Defensive aggregation policy (CLI: `--robust-agg`). Non-finite
/// sub-model updates are always screened out when a policy other than
/// `None` is active; the variants differ in how surviving outliers are
/// tamed. See [`crate::federated::aggregate::aggregate_robust`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum RobustAgg {
    /// Plain uniform averaging (the seed behaviour; no screening).
    #[default]
    None,
    /// Clip each client delta's L2 norm to `c` before averaging
    /// (Sun et al.'s norm-bounding defence).
    NormClip { c: f64 },
    /// Coordinate-wise trimmed mean: drop the `⌊frac·m⌋` lowest and
    /// highest values per coordinate, average the rest.
    Trimmed { frac: f64 },
}

impl RobustAgg {
    pub fn parse(s: &str) -> Result<RobustAgg> {
        if s == "none" {
            return Ok(RobustAgg::None);
        }
        if let Some(c) = s.strip_prefix("norm-clip:") {
            let c: f64 = c
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --robust-agg norm-clip bound '{c}'"))?;
            return Ok(RobustAgg::NormClip { c });
        }
        if let Some(frac) = s.strip_prefix("trimmed:") {
            let frac: f64 = frac
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --robust-agg trimmed fraction '{frac}'"))?;
            return Ok(RobustAgg::Trimmed { frac });
        }
        bail!("unknown --robust-agg '{s}' (expected none|norm-clip:<c>|trimmed:<frac>)")
    }

    pub fn name(&self) -> String {
        match self {
            RobustAgg::None => "none".to_string(),
            RobustAgg::NormClip { c } => format!("norm-clip:{c}"),
            RobustAgg::Trimmed { frac } => format!("trimmed:{frac}"),
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            RobustAgg::None => Ok(()),
            RobustAgg::NormClip { c } => {
                if !(c.is_finite() && *c > 0.0) {
                    bail!("--robust-agg norm-clip bound must be positive and finite: {c}");
                }
                Ok(())
            }
            RobustAgg::Trimmed { frac } => {
                if !(0.0..0.5).contains(frac) {
                    bail!("--robust-agg trimmed fraction must be in [0, 0.5): {frac}");
                }
                Ok(())
            }
        }
    }
}

/// Canary rollout policy for `fedmlh serve` hot reloads (CLI:
/// `--canary-window` and friends; per-reload overrides via the
/// `POST /reload?canary=<pct>&window=<n>` query). Consulted by
/// [`crate::serve::control::ControlPlane`] when a reload asks for a
/// canary split instead of an immediate swap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CanaryConfig {
    /// Requests the canary version must serve before the verdict
    /// (promote / rollback) is computed.
    pub window: usize,
    /// Maximum tolerated canary error rate over the window; above it
    /// the rollout is rolled back (early, once the failure budget is
    /// exhausted, without waiting for the full window).
    pub max_error_rate: f64,
    /// Latency guard: roll back if the canary's p99 exceeds the stable
    /// version's p99 times this ratio (0 disables the latency check —
    /// useful in tests where tiny-model latencies are noise).
    pub p99_ratio: f64,
}

impl Default for CanaryConfig {
    fn default() -> Self {
        CanaryConfig {
            window: 50,
            max_error_rate: 0.05,
            p99_ratio: 10.0,
        }
    }
}

impl CanaryConfig {
    pub fn validate(&self) -> Result<()> {
        if self.window == 0 {
            bail!("--canary-window must be positive");
        }
        if !(0.0..=1.0).contains(&self.max_error_rate) {
            bail!(
                "--canary-max-error-rate must be in [0, 1]: {}",
                self.max_error_rate
            );
        }
        if self.p99_ratio.is_nan() || self.p99_ratio < 0.0 {
            bail!("--canary-p99-ratio must be >= 0 (0 disables): {}", self.p99_ratio);
        }
        Ok(())
    }
}

/// Observability surface (CLI: `--trace-out`, `--log-level`), shared by
/// `fedmlh run` and `fedmlh serve`. Parsed once at startup and applied
/// through [`ObsConfig::apply`]; the telemetry machinery itself lives in
/// [`crate::obs`].
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Write a Chrome-trace-event JSON file here when the process is
    /// done (`None` = tracing stays disabled, near-zero cost).
    pub trace_out: Option<std::path::PathBuf>,
    /// Log threshold name (`error|warn|info|debug`).
    pub log_level: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace_out: None,
            log_level: "info".to_string(),
        }
    }
}

impl ObsConfig {
    pub fn new(trace_out: Option<std::path::PathBuf>, log_level: &str) -> Result<ObsConfig> {
        if crate::obs::log::Level::parse(log_level).is_none() {
            bail!("unknown --log-level '{log_level}' (expected error|warn|info|debug)");
        }
        Ok(ObsConfig {
            trace_out,
            log_level: log_level.to_string(),
        })
    }

    /// Set the global log threshold and, when a trace path is
    /// configured, install the process-global tracer.
    pub fn apply(&self) {
        if let Some(level) = crate::obs::log::Level::parse(&self.log_level) {
            crate::obs::log::set_level(level);
        }
        if self.trace_out.is_some() {
            crate::obs::trace::install();
        }
    }

    /// Write the collected trace to the configured path (no-op unless
    /// [`ObsConfig::apply`] installed the tracer).
    pub fn export(&self) -> Result<()> {
        if let (Some(path), Some(tracer)) = (&self.trace_out, crate::obs::trace::tracer()) {
            tracer.write_chrome_trace(path)?;
        }
        Ok(())
    }
}

/// Full experiment description. Defaults mirror the paper's FL setup
/// (Section 6): K = 10 clients, S = 4 sampled per round, E = 5 local
/// epochs, T = 70 synchronization rounds, early stopping on the mean of
/// top-1/3/5 accuracy.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub preset: DatasetPreset,
    /// Total number of local clients (paper: 10).
    pub clients: usize,
    /// Clients sampled per synchronization round (paper: 4).
    pub clients_per_round: usize,
    /// Local epochs per round (paper: 5).
    pub local_epochs: usize,
    /// Max synchronization rounds (paper: 70).
    pub rounds: usize,
    /// Early-stop patience in rounds (0 disables early stopping).
    pub patience: usize,
    /// SGD learning rate (input to the AOT train step, not baked in).
    pub lr: f32,
    /// Root seed; every stream (data, partition, hashing, sampling) is
    /// derived from it.
    pub seed: u64,
    /// Evaluate on the test set every `eval_every` rounds.
    pub eval_every: usize,
    /// Override R (hash tables). 0 = preset default.
    pub override_r: usize,
    /// Override B (buckets per table). 0 = preset default.
    pub override_b: usize,
    /// Use the `*_fast` artifact family (identical math lowered through
    /// the pure-jnp ref twins instead of interpret-mode Pallas — ~7×
    /// faster on the CPU PJRT plugin; see DESIGN.md §Perf). Ignored by
    /// the rust backend. Not combinable with `override_b` (no fast
    /// sweep artifacts are emitted).
    pub fast_artifacts: bool,
    /// Worker threads for the round engine's local-training fan-out
    /// (1 = sequential; results are worker-count-invariant either way).
    pub workers: usize,
    /// Wire codec for client→server updates (Table 4 accounting charges
    /// the encoded bytes). `Dense` reproduces the seed accounting.
    pub codec: CodecSpec,
    /// Broadcast (server→client) codec (CLI: `--down-codec`). `Dense`
    /// reproduces the seed's raw-`f32` downlink bit-for-bit; the sparse
    /// codecs select the per-client versioned delta downlink.
    pub down_codec: DownCodec,
    /// Delta-downlink staleness cap (CLI: `--resync-every`): a sampled
    /// client whose base replica is more than this many rounds old gets
    /// a full dense resync instead of a delta (0 = resync on every
    /// participation). Ignored by the non-delta downlink codecs.
    pub resync_every: usize,
    /// Carry compression state across rounds on both links (CLI:
    /// `--error-feedback`): client-side error-feedback accumulators add
    /// the un-shipped uplink residual into the next round's update, and
    /// the server folds the broadcast's quantization error into the
    /// next broadcast. Off = the stateless seed pipeline.
    pub error_feedback: bool,
    /// Event-driven async simulation setup (CLI: `--async`, `--buffer`,
    /// `--dropout`, …). `async_mode = false` (the default) keeps the
    /// synchronous loop and every seed trajectory untouched.
    pub sim: SimConfig,
    /// Deterministic fault injection rates (CLI: `--inject`). All-zero
    /// by default: no fates are drawn and trajectories are untouched.
    pub inject: InjectConfig,
    /// Defensive aggregation policy (CLI: `--robust-agg`).
    pub robust: RobustAgg,
    /// Write a crash-resume snapshot every this many rounds into the
    /// snapshot directory (CLI: `--snapshot-every`; 0 disables).
    /// Sync loop only — the async simulator rejects it.
    pub snapshot_every: usize,
    /// Snapshot directory (CLI: `--resume <dir>`): snapshots are
    /// written here, and if the directory already holds one for this
    /// config, the run resumes from it bitwise.
    pub snapshot_dir: Option<std::path::PathBuf>,
}

impl ExperimentConfig {
    pub fn new(preset: DatasetPreset) -> Self {
        let lr = preset.lr;
        ExperimentConfig {
            preset,
            clients: 10,
            clients_per_round: 4,
            local_epochs: 5,
            rounds: 70,
            patience: 10,
            lr,
            seed: 42,
            eval_every: 1,
            override_r: 0,
            override_b: 0,
            fast_artifacts: false,
            workers: 1,
            codec: CodecSpec::Dense,
            down_codec: DownCodec::Dense,
            resync_every: 8,
            error_feedback: false,
            sim: SimConfig::default(),
            inject: InjectConfig::default(),
            robust: RobustAgg::None,
            snapshot_every: 0,
            snapshot_dir: None,
        }
    }

    /// Look up a named preset ("tiny", "eurlex", ...).
    pub fn preset(name: &str) -> Result<Self> {
        Ok(Self::new(presets::by_name(name)?))
    }

    /// Effective number of hash tables (after overrides).
    pub fn r(&self) -> usize {
        if self.override_r > 0 {
            self.override_r
        } else {
            self.preset.r
        }
    }

    /// Effective buckets per table (after overrides).
    pub fn b(&self) -> usize {
        if self.override_b > 0 {
            self.override_b
        } else {
            self.preset.b
        }
    }

    /// The client population a run addresses: the virtual registry
    /// under the async simulator, the partition's clients otherwise.
    /// Used as the per-item seed stride, so it never shrinks below
    /// `clients` (a registry smaller than the shard count still maps
    /// onto every shard).
    pub fn client_population(&self) -> usize {
        if self.sim.async_mode && self.sim.registry > 0 {
            self.sim.registry.max(self.clients)
        } else {
            self.clients
        }
    }

    /// Output width of one trained model: p for FedAvg, B for a FedMLH
    /// sub-model.
    pub fn out_dim(&self, algo: Algo) -> usize {
        match algo {
            Algo::FedAvg => self.preset.p,
            Algo::FedMlh => self.b(),
        }
    }

    /// The artifact key prefix a run loads, e.g. "eurlex.fedmlh" or
    /// "eurlex.fedmlh_b500" for a Figure-5 sweep point.
    pub fn artifact_tag(&self, algo: Algo) -> String {
        let fast = if self.fast_artifacts { "_fast" } else { "" };
        match algo {
            Algo::FedAvg => format!("{}.fedavg{fast}", self.preset.name),
            Algo::FedMlh => {
                if self.override_b > 0 && self.override_b != self.preset.b {
                    format!("{}.fedmlh_b{}", self.preset.name, self.override_b)
                } else {
                    format!("{}.fedmlh{fast}", self.preset.name)
                }
            }
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 || self.clients_per_round == 0 {
            bail!("clients and clients_per_round must be positive");
        }
        if self.clients_per_round > self.clients {
            bail!(
                "clients_per_round {} > clients {}",
                self.clients_per_round,
                self.clients
            );
        }
        if self.local_epochs == 0 || self.rounds == 0 {
            bail!("local_epochs and rounds must be positive");
        }
        if self.b() == 0 || self.r() == 0 {
            bail!("R and B must be positive");
        }
        if self.b() > self.preset.p {
            bail!("B {} exceeds class count {}", self.b(), self.preset.p);
        }
        if !(self.lr > 0.0) {
            bail!("lr must be positive");
        }
        if self.workers == 0 {
            bail!("workers must be positive (1 = sequential)");
        }
        // Codec parameter bounds live in one place (CodecSpec::validate),
        // shared by CLI parsing and both links here.
        self.codec.validate()?;
        self.down_codec
            .wire_spec()
            .validate()
            .map_err(|e| anyhow::anyhow!("downlink codec: {e}"))?;
        self.sim.validate()?;
        self.inject.validate()?;
        self.robust.validate()?;
        if self.snapshot_every > 0 && self.sim.async_mode {
            bail!("--snapshot-every is sync-loop only (not supported with --async)");
        }
        if self.snapshot_every > 0 && self.snapshot_dir.is_none() {
            bail!("--snapshot-every requires --resume <dir> for the snapshot directory");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_lookup_and_defaults() {
        let cfg = ExperimentConfig::preset("eurlex").unwrap();
        assert_eq!(cfg.clients, 10);
        assert_eq!(cfg.clients_per_round, 4);
        assert_eq!(cfg.local_epochs, 5);
        assert_eq!(cfg.rounds, 70);
        assert_eq!(cfg.r(), 4);
        assert_eq!(cfg.b(), 250);
        assert!(ExperimentConfig::preset("nope").is_err());
    }

    #[test]
    fn out_dim_per_algo() {
        let cfg = ExperimentConfig::preset("eurlex").unwrap();
        assert_eq!(cfg.out_dim(Algo::FedAvg), 4000);
        assert_eq!(cfg.out_dim(Algo::FedMlh), 250);
    }

    #[test]
    fn artifact_tags() {
        let mut cfg = ExperimentConfig::preset("eurlex").unwrap();
        assert_eq!(cfg.artifact_tag(Algo::FedAvg), "eurlex.fedavg");
        assert_eq!(cfg.artifact_tag(Algo::FedMlh), "eurlex.fedmlh");
        cfg.override_b = 500;
        assert_eq!(cfg.artifact_tag(Algo::FedMlh), "eurlex.fedmlh_b500");
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.validate().unwrap();
        cfg.clients_per_round = 99;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.override_b = 10_000_000;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_covers_engine_and_codec() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.codec, CodecSpec::Dense);
        // Transport defaults are the stateless seed pipeline.
        assert_eq!(cfg.down_codec, DownCodec::Dense);
        assert_eq!(cfg.resync_every, 8);
        assert!(!cfg.error_feedback);
        cfg.down_codec = DownCodec::QuantI8;
        cfg.error_feedback = true;
        cfg.validate().unwrap();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        cfg.workers = 8;
        cfg.codec = CodecSpec::TopK { frac: 0.1 };
        cfg.validate().unwrap();
        cfg.codec = CodecSpec::TopK { frac: 1.5 };
        assert!(cfg.validate().is_err());
        cfg.codec = CodecSpec::TopKPacked { frac: 0.1 };
        cfg.validate().unwrap();
        cfg.codec = CodecSpec::TopKPacked { frac: 1.5 };
        assert!(cfg.validate().is_err());
        cfg.codec = CodecSpec::QuantI8Group { block: 64 };
        cfg.validate().unwrap();
        cfg.codec = CodecSpec::QuantI8Group { block: 0 };
        assert!(cfg.validate().is_err());
        cfg.codec = CodecSpec::QuantI8Group { block: 1 << 30 };
        assert!(cfg.validate().is_err(), "q8g block above the wire cap must fail early");
        cfg.codec = CodecSpec::QuantI4Group { block: 64 };
        cfg.validate().unwrap();
        cfg.codec = CodecSpec::QuantI4Group { block: 0 };
        assert!(cfg.validate().is_err());
        cfg.codec = CodecSpec::QuantI4Group { block: 1 << 30 };
        assert!(cfg.validate().is_err(), "q4g block above the wire cap must fail early");
        // Downlink codec parameters are validated too.
        cfg.codec = CodecSpec::Dense;
        cfg.down_codec = DownCodec::TopK { frac: 0.1 };
        cfg.validate().unwrap();
        cfg.down_codec = DownCodec::TopK { frac: 0.0 };
        assert!(cfg.validate().is_err());
        cfg.down_codec = DownCodec::QuantI8Group { block: 0 };
        assert!(cfg.validate().is_err());
        cfg.down_codec = DownCodec::QuantI4Group { block: 0 };
        assert!(cfg.validate().is_err());
        cfg.down_codec = DownCodec::QuantI4Group { block: 32 };
        cfg.validate().unwrap();
        cfg.down_codec = DownCodec::QuantI8Group { block: 32 };
        cfg.resync_every = 0; // "resync every participation" is valid
        cfg.validate().unwrap();
    }

    #[test]
    fn sim_defaults_and_validation() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        assert!(!cfg.sim.async_mode);
        assert_eq!(cfg.client_population(), cfg.clients);
        // sim fields are ignored while async is off
        cfg.sim.buffer = 0;
        cfg.validate().unwrap();
        // ... and enforced once it is on
        cfg.sim.async_mode = true;
        assert!(cfg.validate().is_err(), "buffer 0 must fail");
        cfg.sim.buffer = 4;
        cfg.validate().unwrap();
        cfg.sim.dropout = 1.0;
        assert!(cfg.validate().is_err(), "dropout 1.0 never finishes");
        cfg.sim.dropout = 0.3;
        cfg.sim.registry = 1_000_000;
        cfg.validate().unwrap();
        assert_eq!(cfg.client_population(), 1_000_000);
        cfg.sim.latency = Dist::Fixed { value: 0.0 };
        assert!(cfg.validate().is_err(), "zero latency must fail");
    }

    #[test]
    fn sim_scenarios_resolve() {
        let smoke = SimConfig::scenario("smoke").unwrap();
        assert!(smoke.async_mode);
        assert_eq!(smoke.registry, 10_000);
        let million = SimConfig::scenario("million").unwrap();
        assert_eq!(million.registry, 1_000_000);
        assert!(SimConfig::scenario("nope").is_err());
    }

    #[test]
    fn canary_defaults_and_validation() {
        let mut canary = CanaryConfig::default();
        assert_eq!(canary.window, 50);
        canary.validate().unwrap();
        canary.p99_ratio = 0.0; // disabled latency guard is valid
        canary.validate().unwrap();
        canary.window = 0;
        assert!(canary.validate().is_err(), "window 0 must fail");
        canary.window = 10;
        canary.max_error_rate = 1.5;
        assert!(canary.validate().is_err(), "error rate above 1 must fail");
        canary.max_error_rate = 0.1;
        canary.p99_ratio = -1.0;
        assert!(canary.validate().is_err(), "negative p99 ratio must fail");
    }

    #[test]
    fn inject_parse_and_validation() {
        let none = InjectConfig::parse("none").unwrap();
        assert!(!none.any());
        assert_eq!(none.to_string(), "none");
        let cfg = InjectConfig::parse("corrupt:0.05,nan:0.02").unwrap();
        assert_eq!(cfg.corrupt, 0.05);
        assert_eq!(cfg.nan, 0.02);
        assert_eq!(cfg.truncate, 0.0);
        assert_eq!(cfg.fail, 0.0);
        assert!(cfg.any());
        assert_eq!(cfg.to_string(), "corrupt:0.05,nan:0.02");
        let all = InjectConfig::parse("corrupt:0.1,truncate:0.1,nan:0.1,fail:0.5").unwrap();
        assert!(all.any());
        assert!(InjectConfig::parse("corrupt:2").is_err(), "rate above 1");
        assert!(InjectConfig::parse("corrupt:0.5,nan:0.6").is_err(), "payload rates sum > 1");
        assert!(InjectConfig::parse("frob:0.1").is_err(), "unknown kind");
        assert!(InjectConfig::parse("corrupt").is_err(), "missing rate");
    }

    #[test]
    fn robust_agg_parse_and_validation() {
        assert_eq!(RobustAgg::parse("none").unwrap(), RobustAgg::None);
        let clip = RobustAgg::parse("norm-clip:10").unwrap();
        assert_eq!(clip, RobustAgg::NormClip { c: 10.0 });
        assert_eq!(clip.name(), "norm-clip:10");
        let trim = RobustAgg::parse("trimmed:0.2").unwrap();
        assert_eq!(trim, RobustAgg::Trimmed { frac: 0.2 });
        assert_eq!(trim.name(), "trimmed:0.2");
        assert!(RobustAgg::parse("median").is_err());
        assert!(RobustAgg::NormClip { c: 0.0 }.validate().is_err());
        assert!(RobustAgg::Trimmed { frac: 0.5 }.validate().is_err());
        assert!(RobustAgg::Trimmed { frac: 0.49 }.validate().is_ok());
    }

    #[test]
    fn snapshot_flags_validate() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.snapshot_every = 2;
        assert!(cfg.validate().is_err(), "snapshot-every needs a directory");
        cfg.snapshot_dir = Some(std::path::PathBuf::from("snap"));
        cfg.validate().unwrap();
        cfg.sim.async_mode = true;
        assert!(cfg.validate().is_err(), "snapshots are sync-only");
    }

    #[test]
    fn algo_parse_roundtrip() {
        assert_eq!(Algo::parse("fedavg").unwrap(), Algo::FedAvg);
        assert_eq!(Algo::parse("fedmlh").unwrap(), Algo::FedMlh);
        assert!(Algo::parse("sgd").is_err());
    }
}
