//! # FedMLH — Federated Multiple Label Hashing
//!
//! Production-oriented reproduction of *"Federated Multiple Label Hashing
//! (FedMLH): Communication Efficient Federated Learning on Extreme
//! Classification Tasks"* (Dai, Dun, Tang, Kyrillidis, Shrivastava, 2021).
//!
//! FedMLH hashes the `p` output classes of an extreme multi-label
//! classifier into `R` independent hash tables of `B ≪ p` buckets
//! (a count sketch over the label space), trains one federated sub-model
//! per table against the *bucket* labels, and recovers per-class scores
//! at inference by averaging the `R` bucket log-probabilities each class
//! hashes into. This simultaneously shrinks the model/communication
//! volume and re-balances the class distribution (paper Lemma 1,
//! Theorem 2).
//!
//! ## Architecture (three layers, python never on the training path)
//!
//! - **L3 (this crate)** — the federated coordinator: client sampling,
//!   local-training orchestration, per-sub-model FedAvg aggregation,
//!   communication accounting, non-iid partitioning, evaluation, the
//!   table/figure harness, and the serving subsystem ([`serve`]:
//!   `.fmlh` checkpoints + a micro-batching HTTP inference server).
//!   The pure-rust MLP hot path runs on the tiled compute kernels in
//!   [`kernels`] (blocked GEMM, fused epilogues, CSR sparse-input fast
//!   path) shared by training, evaluation and serving.
//! - **L2** — the MLP forward/backward + SGD step, written in JAX
//!   (`python/compile/model.py`) and AOT-lowered to HLO text.
//! - **L1** — Pallas kernels for the wide output layer, the fused BCE
//!   loss and the count-sketch decode (`python/compile/kernels/`).
//!
//! The rust runtime loads `artifacts/*.hlo.txt` through the PJRT C API
//! (`xla` crate) once and then executes them with buffer-resident
//! parameters; see [`runtime`].
//!
//! ## Quick start
//!
//! ```no_run
//! use fedmlh::config::ExperimentConfig;
//! use fedmlh::federated::backend::RustBackend;
//! use fedmlh::harness::run_algo;
//!
//! let cfg = ExperimentConfig::preset("tiny").unwrap();
//! let backend = RustBackend::new();
//! let out = run_algo(&cfg, fedmlh::config::Algo::FedMlh, &backend, 42).unwrap();
//! println!("best top1 = {:.3}", out.best.top1);
//! ```

pub mod algo;
pub mod bench;
pub mod config;
pub mod data;
pub mod eval;
pub mod federated;
pub mod harness;
pub mod hashing;
pub mod kernels;
pub mod model;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod theory;
pub mod util;
