//! Accuracy metrics: top-1/3/5 precision, overall and split by
//! frequent vs infrequent classes (paper Fig. 3: "top-k
//! frequent/infrequent class accuracy is defined as # of correctly
//! predicted frequent/infrequent class labels / k; the sum of the two is
//! the overall top-k accuracy").

use crate::data::dataset::Dataset;
use crate::data::stats::LabelStats;

use super::topk::top_k;

/// The paper reports @1, @3 and @5.
pub const KS: [usize; 3] = [1, 3, 5];

/// Accuracy numbers for one evaluation pass.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccuracyReport {
    pub top1: f64,
    pub top3: f64,
    pub top5: f64,
    /// Frequent-class share of each top-k accuracy (Fig. 3 middle).
    pub freq1: f64,
    pub freq3: f64,
    pub freq5: f64,
    /// Infrequent-class share (Fig. 3 right). `topk = freqk + infreqk`.
    pub infreq1: f64,
    pub infreq3: f64,
    pub infreq5: f64,
    pub samples: usize,
}

impl AccuracyReport {
    /// Mean of top-1/3/5 — the early-stopping criterion ("the best
    /// accuracy (the average of top 1, 3 and 5 accuracy)").
    pub fn mean_topk(&self) -> f64 {
        (self.top1 + self.top3 + self.top5) / 3.0
    }

    pub fn at(&self, k: usize) -> f64 {
        match k {
            1 => self.top1,
            3 => self.top3,
            5 => self.top5,
            _ => panic!("unsupported k {k}"),
        }
    }
}

/// Streaming evaluator: feed per-sample class scores, read the report.
pub struct Evaluator {
    frequent: Vec<bool>,
    /// per-k accumulators: (hits_total, hits_frequent)
    acc: [(f64, f64); 3],
    samples: usize,
}

impl Evaluator {
    /// `frequent_classes`: how many top classes count as frequent (same
    /// k the partitioner used, so Fig. 3 reflects the partition).
    pub fn new(train_stats: &LabelStats, frequent_classes: usize) -> Self {
        Evaluator {
            frequent: train_stats.frequent_mask(frequent_classes),
            acc: [(0.0, 0.0); 3],
            samples: 0,
        }
    }

    /// Feed one sample's class scores and its positive labels.
    pub fn add_sample(&mut self, scores: &[f32], positives: &[u32]) {
        debug_assert_eq!(scores.len(), self.frequent.len());
        for (slot, &k) in KS.iter().enumerate() {
            let picked = top_k(scores, k);
            let mut hits = 0usize;
            let mut freq_hits = 0usize;
            for &c in &picked {
                if positives.contains(&(c as u32)) {
                    hits += 1;
                    if self.frequent[c] {
                        freq_hits += 1;
                    }
                }
            }
            self.acc[slot].0 += hits as f64 / k as f64;
            self.acc[slot].1 += freq_hits as f64 / k as f64;
        }
        self.samples += 1;
    }

    /// Finalize into a report (averages over samples fed so far).
    pub fn report(&self) -> AccuracyReport {
        let n = self.samples.max(1) as f64;
        let t = |slot: usize| self.acc[slot].0 / n;
        let f = |slot: usize| self.acc[slot].1 / n;
        AccuracyReport {
            top1: t(0),
            top3: t(1),
            top5: t(2),
            freq1: f(0),
            freq3: f(1),
            freq5: f(2),
            infreq1: t(0) - f(0),
            infreq3: t(1) - f(1),
            infreq5: t(2) - f(2),
            samples: self.samples,
        }
    }
}

/// Evaluate dense per-sample score rows against a dataset's labels.
/// `scores` is flat `[n, p]` for samples `idx`.
pub fn evaluate_scores(
    ds: &Dataset,
    idx: &[usize],
    scores: &[f32],
    evaluator: &mut Evaluator,
) {
    let p = ds.p();
    assert_eq!(scores.len(), idx.len() * p);
    for (row, &i) in idx.iter().enumerate() {
        evaluator.add_sample(&scores[row * p..(row + 1) * p], ds.labels_of(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_for(p: usize, counts: &[(usize, usize)]) -> LabelStats {
        let mut c = vec![0usize; p];
        for &(class, count) in counts {
            c[class] = count;
        }
        LabelStats {
            counts: c,
            n_samples: 100,
        }
    }

    #[test]
    fn perfect_and_zero_predictions() {
        let stats = stats_for(10, &[(0, 50), (1, 40)]);
        let mut ev = Evaluator::new(&stats, 2);
        // scores rank class 3 first; positives = {3}
        let mut scores = vec![0.0f32; 10];
        scores[3] = 1.0;
        ev.add_sample(&scores, &[3]);
        let r = ev.report();
        assert!((r.top1 - 1.0).abs() < 1e-12);
        assert!((r.top3 - 1.0 / 3.0).abs() < 1e-12);
        assert!((r.top5 - 0.2).abs() < 1e-12);
        // class 3 is infrequent (frequent = {0,1})
        assert_eq!(r.freq1, 0.0);
        assert!((r.infreq1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn frequent_infrequent_sum_to_total() {
        let stats = stats_for(20, &[(0, 9), (5, 8), (7, 7)]);
        let mut ev = Evaluator::new(&stats, 3);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..50 {
            let scores: Vec<f32> = (0..20).map(|_| rng.next_f32()).collect();
            let positives: Vec<u32> = (0..3).map(|_| rng.below(20) as u32).collect();
            ev.add_sample(&scores, &positives);
        }
        let r = ev.report();
        for (t, f, i) in [
            (r.top1, r.freq1, r.infreq1),
            (r.top3, r.freq3, r.infreq3),
            (r.top5, r.freq5, r.infreq5),
        ] {
            assert!((f + i - t).abs() < 1e-12);
            assert!(f >= 0.0 && i >= 0.0 && t <= 1.0);
        }
        assert_eq!(r.samples, 50);
    }

    #[test]
    fn mean_topk_is_early_stop_criterion() {
        let r = AccuracyReport {
            top1: 0.6,
            top3: 0.3,
            top5: 0.3,
            ..Default::default()
        };
        assert!((r.mean_topk() - 0.4).abs() < 1e-12);
        assert_eq!(r.at(1), 0.6);
    }

    #[test]
    fn evaluate_scores_maps_rows_to_samples() {
        let mut ds = Dataset::new(1, 4);
        ds.push(&[0.0], &[2]).unwrap();
        ds.push(&[0.0], &[0]).unwrap();
        let stats = LabelStats::from_dataset(&ds);
        let mut ev = Evaluator::new(&stats, 1);
        // two rows of scores: row 0 ranks class 2 top (hit), row 1 ranks 3 (miss)
        let scores = vec![0.0, 0.0, 1.0, 0.5, 0.1, 0.0, 0.0, 0.9];
        evaluate_scores(&ds, &[0, 1], &scores, &mut ev);
        let r = ev.report();
        assert!((r.top1 - 0.5).abs() < 1e-12);
    }
}
