//! Evaluation: top-k precision (the paper's metric), the count-sketch
//! decode that recovers class scores from FedMLH sub-model logits, and
//! the frequent/infrequent accuracy split of Figure 3.

pub mod decode;
pub mod metrics;
pub mod topk;

pub use metrics::{AccuracyReport, Evaluator};
