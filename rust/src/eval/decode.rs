//! Count-sketch mean decode in rust (paper Fig. 1b).
//!
//! `scores[n, j] = (1/R) Σ_r logits[r, n, h_r(j)]` — the same math as
//! the L1 `sketch_decode` Pallas kernel. The rust version exists (a) as
//! the fallback when no decode artifact is loaded (RustBackend), and
//! (b) to cross-validate the AOT decode artifact numerically.

/// Decode `logits` (flat `[r, rows, b]`) into class scores
/// (flat `[rows, p]`) using `idx` (flat `[r, p]`, class→bucket).
pub fn sketch_decode(
    logits: &[f32],
    idx: &[i32],
    r: usize,
    rows: usize,
    b: usize,
    p: usize,
) -> Vec<f32> {
    assert_eq!(logits.len(), r * rows * b, "logits shape");
    assert_eq!(idx.len(), r * p, "idx shape");
    let mut scores = vec![0.0f32; rows * p];
    let inv_r = 1.0 / r as f32;
    for t in 0..r {
        let idx_row = &idx[t * p..(t + 1) * p];
        for n in 0..rows {
            let table = &logits[(t * rows + n) * b..(t * rows + n + 1) * b];
            let out = &mut scores[n * p..(n + 1) * p];
            for (o, &bucket) in out.iter_mut().zip(idx_row.iter()) {
                *o += table[bucket as usize] * inv_r;
            }
        }
    }
    scores
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::label_hash::LabelHasher;
    use crate::util::prop::check;

    #[test]
    fn single_table_is_gather() {
        // r=1: score[n,j] = logits[0,n,idx[j]]
        let logits = [1.0f32, 2.0, 3.0, 4.0]; // rows=2, b=2
        let idx = [1i32, 0, 1];
        let scores = sketch_decode(&logits, &idx, 1, 2, 2, 3);
        assert_eq!(scores, vec![2.0, 1.0, 2.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn mean_over_tables() {
        check("decode mean", 30, |g| {
            let r = g.usize_in(1, 6);
            let rows = g.usize_in(1, 5);
            let b = g.usize_in(2, 20);
            let p = g.usize_in(1, 50);
            let logits = g.vec_f32(r * rows * b, -3.0, 3.0);
            let idx: Vec<i32> = (0..r * p).map(|_| g.usize_in(0, b) as i32).collect();
            let scores = sketch_decode(&logits, &idx, r, rows, b, p);
            // brute-force check a few entries
            for probe in 0..5 {
                let n = probe % rows;
                let j = (probe * 13) % p;
                let want: f32 = (0..r)
                    .map(|t| logits[(t * rows + n) * b + idx[t * p + j] as usize])
                    .sum::<f32>()
                    / r as f32;
                let got = scores[n * p + j];
                assert!((got - want).abs() < 1e-5, "{got} vs {want}");
            }
        });
    }

    #[test]
    fn works_with_label_hasher_matrix() {
        let h = LabelHasher::new(3, 2, 20, 4);
        let idx = h.index_matrix_i32();
        let logits = vec![0.5f32; 2 * 1 * 4];
        let scores = sketch_decode(&logits, &idx, 2, 1, 4, 20);
        assert!(scores.iter().all(|&s| (s - 0.5).abs() < 1e-6));
    }

    #[test]
    #[should_panic(expected = "logits shape")]
    fn rejects_bad_shapes() {
        sketch_decode(&[0.0; 4], &[0; 2], 2, 2, 2, 1);
    }
}
