//! Top-k selection over class scores.
//!
//! The paper's metric: "top-k accuracy is measured by the precision of
//! the top k classes with largest predicted log-probability". For
//! extreme p, a full sort per sample is the serving-path bottleneck, so
//! selection uses a bounded binary heap (O(p log k), k ∈ {1,3,5}).

/// Indices of the `k` largest values, in descending value order.
/// Ties break toward the lower index (deterministic).
pub fn top_k(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    // min-heap of (value, Reverse(index)) of size k, implemented on a Vec
    // to avoid pulling in BinaryHeap float-ordering workarounds.
    let mut heap: Vec<(f32, usize)> = Vec::with_capacity(k);

    let worse = |a: (f32, usize), b: (f32, usize)| -> bool {
        // a is worse than b if smaller value, or equal value with higher index
        a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
    };

    for (i, &v) in scores.iter().enumerate() {
        let cand = (v, i);
        if heap.len() < k {
            heap.push(cand);
            heap.sort_by(|x, y| {
                if worse(*x, *y) {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Greater
                }
            });
        } else if worse(heap[0], cand) {
            heap[0] = cand;
            // restore ascending-by-badness order with one pass
            let mut j = 0;
            while j + 1 < k && worse(heap[j + 1], heap[j]) {
                heap.swap(j, j + 1);
                j += 1;
            }
        }
    }
    heap.reverse();
    heap.into_iter().map(|(_, i)| i).collect()
}

/// Precision@k for one sample: |top_k ∩ positives| / k.
pub fn precision_at_k(scores: &[f32], positives: &[u32], k: usize) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let picked = top_k(scores, k);
    let hits = picked
        .iter()
        .filter(|&&i| positives.contains(&(i as u32)))
        .count();
    hits as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn picks_largest_in_order() {
        let scores = [0.1, 0.9, 0.5, 0.7, 0.3];
        assert_eq!(top_k(&scores, 3), vec![1, 3, 2]);
        assert_eq!(top_k(&scores, 1), vec![1]);
        assert_eq!(top_k(&scores, 0), Vec::<usize>::new());
        assert_eq!(top_k(&scores, 99).len(), 5);
    }

    #[test]
    fn tie_break_is_lower_index() {
        let scores = [0.5, 0.5, 0.5];
        assert_eq!(top_k(&scores, 2), vec![0, 1]);
    }

    #[test]
    fn matches_full_sort() {
        check("topk vs sort", 40, |g| {
            let n = g.usize_in(1, 400);
            let k = g.usize_in(1, 10);
            let scores = g.vec_f32(n, -5.0, 5.0);
            let got = top_k(&scores, k);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                scores[b]
                    .partial_cmp(&scores[a])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            order.truncate(k.min(n));
            assert_eq!(got, order);
        });
    }

    #[test]
    fn precision_counts_hits() {
        let scores = [0.9, 0.1, 0.8, 0.2];
        // top-2 = {0, 2}; positives = {2, 3} → 1 hit / 2
        assert!((precision_at_k(&scores, &[2, 3], 2) - 0.5).abs() < 1e-12);
        assert_eq!(precision_at_k(&scores, &[], 2), 0.0);
    }
}
