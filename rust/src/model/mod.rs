//! Host-side model state and the pure-rust reference MLP.
//!
//! - [`params`] — the six parameter tensors of the 2-hidden-layer MLP
//!   (the paper's shared architecture), their initialization, byte
//!   accounting (Table 5) and the flat buffer layout the AOT artifacts
//!   consume.
//! - [`mlp`] — a from-scratch rust implementation of exactly the same
//!   forward/backward/SGD math as the L2 JAX graph. It backs the
//!   [`crate::federated::backend::RustBackend`] used by fast tests, and
//!   cross-validates the AOT artifacts numerically (integration tests).

pub mod mlp;
pub mod params;

pub use params::ModelParams;
