//! Pure-rust reference MLP: the same forward/backward/SGD math as the
//! L2 JAX graph (`python/compile/model.py`), running on the tiled
//! compute kernels in [`crate::kernels`].
//!
//! Two jobs:
//! 1. back the [`crate::federated::backend::RustBackend`] so the whole
//!    federated stack is testable without artifacts, and
//! 2. cross-validate the AOT train step numerically (the integration
//!    tests drive both backends with identical streams and compare
//!    parameters after several rounds).
//!
//! Loss is the numerically-stable mean BCE-with-logits over the full
//! `[batch, out]` tile, matching `kernels/bce.py` exactly (including
//! the 1/(batch·out) gradient scale).
//!
//! # Hot-path structure
//!
//! - The forward pass is two fused matmul+bias+ReLU sweeps plus one
//!   matmul+bias sweep ([`fused::gemm_bias_relu`] / [`fused::gemm_bias`]);
//!   no pre-activation copies exist — ReLU backward masks on the
//!   *post*-activation (`h == 0 ⇔ pre ≤ 0`).
//! - The feature-hashed input layer takes a CSR fast path
//!   ([`crate::kernels::sparse`]) whenever the batch is at most half
//!   nonzero, so layer-1 work scales with nnz instead of `batch × d`.
//! - [`forward_into`] is allocation-free given a caller-held
//!   [`InferScratch`]; [`train_step`] reuses a [`Workspace`] the same
//!   way. Kernels keep a fixed, tiling-independent summation order, so
//!   batched forwards stay bitwise identical to per-row forwards and
//!   runs are deterministic at any worker count.

use crate::kernels::{fused, gemm, sparse};

use super::params::ModelParams;

/// Scratch buffers for one forward/backward pass (reused across steps
/// so the hot loop allocates nothing).
pub struct Workspace {
    batch: usize,
    h1: Vec<f32>,
    h2: Vec<f32>,
    z: Vec<f32>,
    dz: Vec<f32>,
    dh2: Vec<f32>,
    dh1: Vec<f32>,
    /// Column-block scratch for [`fused::gemm_tn_sgd`] — sized for the
    /// largest layer, `max(d,h) × min(SGD_COL_BLOCK, max(h,out))`, not
    /// for a full materialized gradient.
    gw: Vec<f32>,
    csr: sparse::CsrBatch,
}

impl Workspace {
    pub fn new(params: &ModelParams, batch: usize) -> Self {
        let (d, h, out) = (params.d, params.hidden, params.out);
        Workspace {
            batch,
            h1: vec![0.0; batch * h],
            h2: vec![0.0; batch * h],
            z: vec![0.0; batch * out],
            dz: vec![0.0; batch * out],
            dh2: vec![0.0; batch * h],
            dh1: vec![0.0; batch * h],
            gw: vec![0.0; fused::sgd_scratch_len(d.max(h), h.max(out))],
            csr: sparse::CsrBatch::new(),
        }
    }
}

/// Reusable buffers for the inference-only forward pass (the hidden
/// activations plus the CSR conversion of the input batch). Grows to
/// the largest batch it has seen and then stops allocating.
#[derive(Default)]
pub struct InferScratch {
    h1: Vec<f32>,
    h2: Vec<f32>,
    csr: sparse::CsrBatch,
}

impl InferScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Convert one input batch for the layer-1 fast-path decision: fills
/// `scratch`'s CSR buffers and returns whether the sparse path applies
/// (batch at most half nonzero). Callers running several sub-model
/// forwards over the *same* batch (serving, evaluation) call this once
/// and then [`forward_prepared_into`] per model, so the `rows × d`
/// conversion scan is not repeated R times.
pub fn prepare_input(x: &[f32], rows: usize, d: usize, scratch: &mut InferScratch) -> bool {
    debug_assert_eq!(x.len(), rows * d);
    scratch.csr.try_from_dense(x, rows, d, sparse::sparse_cutoff(rows * d))
}

/// Forward pass for `rows` samples (`x` is `[rows, d]` flat) written
/// into the caller's `z` (`[rows, out]` flat) with zero allocations at
/// steady state.
pub fn forward_into(
    params: &ModelParams,
    x: &[f32],
    rows: usize,
    scratch: &mut InferScratch,
    z: &mut [f32],
) {
    let use_sparse = prepare_input(x, rows, params.d, scratch);
    forward_prepared_into(params, x, rows, use_sparse, scratch, z);
}

/// [`forward_into`] with the input conversion hoisted out:
/// `use_sparse` must be [`prepare_input`]'s return for this exact
/// (`x`, `rows`) on this `scratch`.
pub fn forward_prepared_into(
    params: &ModelParams,
    x: &[f32],
    rows: usize,
    use_sparse: bool,
    scratch: &mut InferScratch,
    z: &mut [f32],
) {
    let (d, h, out) = (params.d, params.hidden, params.out);
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(z.len(), rows * out);
    if rows == 0 {
        return;
    }
    if scratch.h1.len() < rows * h {
        scratch.h1.resize(rows * h, 0.0);
    }
    if scratch.h2.len() < rows * h {
        scratch.h2.resize(rows * h, 0.0);
    }
    let h1 = &mut scratch.h1[..rows * h];
    if use_sparse {
        debug_assert_eq!((scratch.csr.rows(), scratch.csr.cols()), (rows, d));
        sparse::csr_gemm_bias_relu(&scratch.csr, params.w1().data(), params.b1().data(), h1, h);
    } else {
        fused::gemm_bias_relu(x, params.w1().data(), params.b1().data(), h1, rows, d, h);
    }
    let h2 = &mut scratch.h2[..rows * h];
    fused::gemm_bias_relu(h1, params.w2().data(), params.b2().data(), h2, rows, h, h);
    fused::gemm_bias(h2, params.w3().data(), params.b3().data(), z, rows, h, out);
}

/// Forward the *same* batch through several sub-models (the FedMLH
/// serving/evaluation shape): one [`prepare_input`] conversion shared
/// by all forwards, one output buffer per model. This is the safe
/// wrapper around the `prepare_input` + [`forward_prepared_into`]
/// pairing invariant — callers never handle `use_sparse` themselves.
pub fn forward_models_into<'a>(
    models: &[ModelParams],
    x: &[f32],
    rows: usize,
    scratch: &mut InferScratch,
    outs: impl IntoIterator<Item = &'a mut [f32]>,
) {
    let Some(first) = models.first() else {
        return;
    };
    let use_sparse = prepare_input(x, rows, first.d, scratch);
    let mut outs = outs.into_iter();
    for m in models {
        let z = outs.next().expect("one output buffer per sub-model");
        forward_prepared_into(m, x, rows, use_sparse, scratch, z);
    }
}

/// Forward pass returning fresh `[rows, out]` logits (convenience
/// wrapper over [`forward_into`]; hot paths hold an [`InferScratch`]
/// and call that directly).
pub fn forward(params: &ModelParams, x: &[f32], rows: usize) -> Vec<f32> {
    let mut z = vec![0.0f32; rows * params.out];
    let mut scratch = InferScratch::new();
    forward_into(params, x, rows, &mut scratch, &mut z);
    z
}

/// Stable mean BCE-with-logits (identical to `kernels/ref.py`).
pub fn bce_loss(z: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(z.len(), y.len());
    let total: f64 = z
        .iter()
        .zip(y.iter())
        .map(|(&z, &y)| (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64)
        .sum();
    (total / z.len() as f64) as f32
}

/// One SGD minibatch step on a full `[batch, d]` batch; returns the
/// pre-update loss (matching the AOT train step's output).
pub fn train_step(
    params: &mut ModelParams,
    ws: &mut Workspace,
    x: &[f32],
    y: &[f32],
    lr: f32,
) -> f32 {
    let (d, h, out) = (params.d, params.hidden, params.out);
    let m = ws.batch;
    debug_assert_eq!(x.len(), m * d);
    debug_assert_eq!(y.len(), m * out);

    // ---- forward (fused bias+ReLU; the post-activations double as the
    // ReLU masks for the backward pass)
    let use_sparse = ws.csr.try_from_dense(x, m, d, sparse::sparse_cutoff(m * d));
    if use_sparse {
        sparse::csr_gemm_bias_relu(&ws.csr, params.w1().data(), params.b1().data(), &mut ws.h1, h);
    } else {
        fused::gemm_bias_relu(x, params.w1().data(), params.b1().data(), &mut ws.h1, m, d, h);
    }
    fused::gemm_bias_relu(&ws.h1, params.w2().data(), params.b2().data(), &mut ws.h2, m, h, h);
    fused::gemm_bias(&ws.h2, params.w3().data(), params.b3().data(), &mut ws.z, m, h, out);

    // ---- loss + dz in one pass over the [batch, out] tile
    let scale = 1.0 / (m * out) as f32;
    let loss = fused::bce_loss_dz(&ws.z, y, scale, &mut ws.dz);

    // layer 3 — backprop dh2 through the *pre-update* w3, then update
    // (updating first would make this SGD-with-stale-gradient, visibly
    // wrong at lr = 1 in the finite-difference test).
    gemm::gemm_nt(&ws.dz, params.w3().data(), &mut ws.dh2, m, out, h);
    fused::relu_backward_mask(&mut ws.dh2, &ws.h2);
    fused::gemm_tn_sgd(&ws.h2, &ws.dz, params.tensors[4].data_mut(), lr, m, h, out, &mut ws.gw);
    fused::sgd_bias_colsum(params.tensors[5].data_mut(), &ws.dz, m, out, lr);

    // layer 2 — same ordering discipline.
    gemm::gemm_nt(&ws.dh2, params.w2().data(), &mut ws.dh1, m, h, h);
    fused::relu_backward_mask(&mut ws.dh1, &ws.h1);
    fused::gemm_tn_sgd(&ws.h1, &ws.dh2, params.tensors[2].data_mut(), lr, m, h, h, &mut ws.gw);
    fused::sgd_bias_colsum(params.tensors[3].data_mut(), &ws.dh2, m, h, lr);

    // layer 1 — the weight gradient is xᵀ dh1; on the sparse path it is
    // applied as a scatter of rank-1 updates over the batch's nonzeros.
    if use_sparse {
        sparse::csr_gemm_tn_sgd(&ws.csr, &ws.dh1, params.tensors[0].data_mut(), lr, h);
    } else {
        fused::gemm_tn_sgd(x, &ws.dh1, params.tensors[0].data_mut(), lr, m, d, h, &mut ws.gw);
    }
    fused::sgd_bias_colsum(params.tensors[1].data_mut(), &ws.dh1, m, h, lr);

    loss
}

/// Convenience wrapper used by tests: loss at (params, x, y).
pub fn loss(params: &ModelParams, x: &[f32], y: &[f32], rows: usize) -> f32 {
    let z = forward(params, x, rows);
    bce_loss(&z, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian_f32(0.0, scale)).collect()
    }

    /// `[rows, d]` batch with `nnz` nonzeros per row (sparse-path data).
    fn sparse_rows(rng: &mut Rng, rows: usize, d: usize, nnz: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; rows * d];
        for r in 0..rows {
            for _ in 0..nnz {
                let c = rng.below(d);
                x[r * d + c] = rng.gaussian_f32(0.0, 1.0);
            }
        }
        x
    }

    #[test]
    fn bce_matches_closed_forms() {
        // z=0 → log 2 regardless of y
        assert!((bce_loss(&[0.0], &[0.0]) - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((bce_loss(&[0.0], &[1.0]) - std::f32::consts::LN_2).abs() < 1e-6);
        // large positive logit with y=1 → ~0; with y=0 → ~z
        assert!(bce_loss(&[30.0], &[1.0]) < 1e-6);
        assert!((bce_loss(&[30.0], &[0.0]) - 30.0).abs() < 1e-3);
        // stability at extremes
        assert!(bce_loss(&[80.0, -80.0], &[0.0, 1.0]).is_finite());
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::new(5);
        let (d, h, out, m) = (4, 3, 5, 2);
        let params = {
            let mut p = ModelParams::init(d, h, out, 1);
            // nonzero biases to exercise their gradients
            for t in [1, 3, 5] {
                for v in p.tensors[t].data_mut() {
                    *v = rng.gaussian_f32(0.0, 0.1);
                }
            }
            p
        };
        let x = rand_vec(&mut rng, m * d, 1.0);
        let y: Vec<f32> = (0..m * out)
            .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
            .collect();

        // analytic step with lr=1: delta = -grad
        let mut stepped = params.clone();
        let mut ws = Workspace::new(&stepped, m);
        train_step(&mut stepped, &mut ws, &x, &y, 1.0);

        // finite differences on a sample of coordinates of every tensor
        let eps = 1e-3f32;
        for ti in 0..6 {
            let len = params.tensors[ti].len();
            for probe in 0..3.min(len) {
                let idx = (probe * 7919) % len;
                let mut plus = params.clone();
                plus.tensors[ti].data_mut()[idx] += eps;
                let mut minus = params.clone();
                minus.tensors[ti].data_mut()[idx] -= eps;
                let fd = (loss(&plus, &x, &y, m) - loss(&minus, &x, &y, m)) / (2.0 * eps);
                let analytic = params.tensors[ti].data()[idx] - stepped.tensors[ti].data()[idx];
                assert!(
                    (fd - analytic).abs() < 2e-3,
                    "tensor {ti} idx {idx}: fd {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn sparse_path_gradient_matches_finite_differences() {
        // Same probe as above but with a batch sparse enough to take
        // the CSR layer-1 path (2 nonzeros of d=16 per row).
        let mut rng = Rng::new(17);
        let (d, h, out, m) = (16, 4, 6, 3);
        let params = ModelParams::init(d, h, out, 2);
        let x = sparse_rows(&mut rng, m, d, 2);
        assert!(x.iter().filter(|v| **v != 0.0).count() * 2 <= m * d);
        let y: Vec<f32> = (0..m * out)
            .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
            .collect();
        let mut stepped = params.clone();
        let mut ws = Workspace::new(&stepped, m);
        train_step(&mut stepped, &mut ws, &x, &y, 1.0);
        let eps = 1e-3f32;
        for ti in 0..6 {
            let len = params.tensors[ti].len();
            for probe in 0..3.min(len) {
                let idx = (probe * 7919) % len;
                let mut plus = params.clone();
                plus.tensors[ti].data_mut()[idx] += eps;
                let mut minus = params.clone();
                minus.tensors[ti].data_mut()[idx] -= eps;
                let fd = (loss(&plus, &x, &y, m) - loss(&minus, &x, &y, m)) / (2.0 * eps);
                let analytic = params.tensors[ti].data()[idx] - stepped.tensors[ti].data()[idx];
                assert!(
                    (fd - analytic).abs() < 2e-3,
                    "tensor {ti} idx {idx}: fd {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Rng::new(9);
        let (d, h, out, m) = (8, 6, 12, 16);
        let mut params = ModelParams::init(d, h, out, 2);
        let x = rand_vec(&mut rng, m * d, 1.0);
        let y: Vec<f32> = (0..m * out)
            .map(|_| if rng.bernoulli(0.2) { 1.0 } else { 0.0 })
            .collect();
        let mut ws = Workspace::new(&params, m);
        let first = loss(&params, &x, &y, m);
        let mut last = first;
        for _ in 0..50 {
            last = train_step(&mut params, &mut ws, &x, &y, 1.0);
        }
        assert!(last < first * 0.8, "{first} -> {last}");
    }

    #[test]
    fn forward_batch_consistency() {
        // forward on a 2-row batch equals per-row forwards
        let params = ModelParams::init(5, 4, 6, 3);
        let mut rng = Rng::new(2);
        let x = rand_vec(&mut rng, 2 * 5, 1.0);
        let z = forward(&params, &x, 2);
        let z0 = forward(&params, &x[0..5], 1);
        let z1 = forward(&params, &x[5..10], 1);
        assert_eq!(&z[0..6], &z0[..]);
        assert_eq!(&z[6..12], &z1[..]);
    }

    #[test]
    fn forward_into_matches_forward_and_reuses_scratch() {
        let params = ModelParams::init(7, 5, 9, 4);
        let mut rng = Rng::new(3);
        let mut scratch = InferScratch::new();
        for rows in [3usize, 1, 6] {
            let x = rand_vec(&mut rng, rows * 7, 1.0);
            let mut z = vec![f32::NAN; rows * 9];
            forward_into(&params, &x, rows, &mut scratch, &mut z);
            assert_eq!(z, forward(&params, &x, rows), "rows={rows}");
        }
    }
}
