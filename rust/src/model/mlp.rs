//! Pure-rust reference MLP: the same forward/backward/SGD math as the
//! L2 JAX graph (`python/compile/model.py`), implemented from scratch.
//!
//! Two jobs:
//! 1. back the [`crate::federated::backend::RustBackend`] so the whole
//!    federated stack is testable without artifacts, and
//! 2. cross-validate the AOT train step numerically (the integration
//!    tests drive both backends with identical streams and compare
//!    parameters after several rounds).
//!
//! Loss is the numerically-stable mean BCE-with-logits over the full
//! `[batch, out]` tile, matching `kernels/bce.py` exactly (including the
//! 1/(batch·out) gradient scale).

use crate::util::tensor::Tensor;

use super::params::ModelParams;

/// `out[m,n] = a[m,k] @ b[k,n]` (row-major, accumulating into zeroed out).
fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // ikj loop order: streams through b and out rows contiguously.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,n] = a[k,m]^T @ b[k,n]` (i.e. aᵀb) without materializing aᵀ.
fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,k] = a[m,n] @ b[k,n]^T` (i.e. abᵀ) without materializing bᵀ.
fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            *o = acc;
        }
    }
}

fn add_bias_rows(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    for row in x.chunks_mut(n) {
        for (v, &b) in row.iter_mut().zip(bias.iter()) {
            *v += b;
        }
    }
}

fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Scratch buffers for one forward/backward pass (reused across steps so
/// the hot loop allocates nothing).
pub struct Workspace {
    batch: usize,
    a1: Vec<f32>,
    h1: Vec<f32>,
    a2: Vec<f32>,
    h2: Vec<f32>,
    z: Vec<f32>,
    dz: Vec<f32>,
    dh2: Vec<f32>,
    dh1: Vec<f32>,
    gw: Vec<f32>,
}

impl Workspace {
    pub fn new(params: &ModelParams, batch: usize) -> Self {
        let (h, out) = (params.hidden, params.out);
        Workspace {
            batch,
            a1: vec![0.0; batch * h],
            h1: vec![0.0; batch * h],
            a2: vec![0.0; batch * h],
            h2: vec![0.0; batch * h],
            z: vec![0.0; batch * out],
            dz: vec![0.0; batch * out],
            dh2: vec![0.0; batch * h],
            dh1: vec![0.0; batch * h],
            gw: vec![0.0; params.d.max(h) * h.max(out)],
        }
    }
}

/// Forward pass: logits for `rows` samples (`x` is `[rows, d]` flat).
/// Returns the flat `[rows, out]` logits.
pub fn forward(params: &ModelParams, x: &[f32], rows: usize) -> Vec<f32> {
    let (d, h, out) = (params.d, params.hidden, params.out);
    debug_assert_eq!(x.len(), rows * d);
    let mut h1 = vec![0.0f32; rows * h];
    matmul(x, params.w1().data(), &mut h1, rows, d, h);
    add_bias_rows(&mut h1, params.b1().data());
    relu(&mut h1);
    let mut h2 = vec![0.0f32; rows * h];
    matmul(&h1, params.w2().data(), &mut h2, rows, h, h);
    add_bias_rows(&mut h2, params.b2().data());
    relu(&mut h2);
    let mut z = vec![0.0f32; rows * out];
    matmul(&h2, params.w3().data(), &mut z, rows, h, out);
    add_bias_rows(&mut z, params.b3().data());
    z
}

/// Stable mean BCE-with-logits (identical to `kernels/ref.py`).
pub fn bce_loss(z: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(z.len(), y.len());
    let total: f64 = z
        .iter()
        .zip(y.iter())
        .map(|(&z, &y)| {
            (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64
        })
        .sum();
    (total / z.len() as f64) as f32
}

/// One SGD minibatch step on a full `[batch, d]` batch; returns the
/// pre-update loss (matching the AOT train step's output).
pub fn train_step(
    params: &mut ModelParams,
    ws: &mut Workspace,
    x: &[f32],
    y: &[f32],
    lr: f32,
) -> f32 {
    let (d, h, out) = (params.d, params.hidden, params.out);
    let m = ws.batch;
    debug_assert_eq!(x.len(), m * d);
    debug_assert_eq!(y.len(), m * out);

    // ---- forward (keeping pre-activations for the backward pass)
    matmul(x, params.w1().data(), &mut ws.a1, m, d, h);
    add_bias_rows(&mut ws.a1, params.b1().data());
    ws.h1.copy_from_slice(&ws.a1);
    relu(&mut ws.h1);

    matmul(&ws.h1, params.w2().data(), &mut ws.a2, m, h, h);
    add_bias_rows(&mut ws.a2, params.b2().data());
    ws.h2.copy_from_slice(&ws.a2);
    relu(&mut ws.h2);

    matmul(&ws.h2, params.w3().data(), &mut ws.z, m, h, out);
    add_bias_rows(&mut ws.z, params.b3().data());

    let loss = bce_loss(&ws.z, y);

    // ---- backward
    let scale = 1.0 / (m * out) as f32;
    for ((dz, &z), &yv) in ws.dz.iter_mut().zip(ws.z.iter()).zip(y.iter()) {
        *dz = (sigmoid(z) - yv) * scale;
    }

    // layer 3 — backprop dh2 through the *pre-update* w3, then update
    // (updating first would make this SGD-with-stale-gradient, visibly
    // wrong at lr = 1 in the finite-difference test).
    matmul_nt(&ws.dz, params.w3().data(), &mut ws.dh2, m, out, h);
    relu_backward(&mut ws.dh2, &ws.a2);
    {
        let gw3 = &mut ws.gw[..h * out];
        matmul_tn(&ws.h2, &ws.dz, gw3, m, h, out);
        sgd_update(params.tensors[4].data_mut(), gw3, lr);
        col_sum_update(params.tensors[5].data_mut(), &ws.dz, m, out, lr);
    }

    // layer 2 — same ordering discipline.
    matmul_nt(&ws.dh2, params.w2().data(), &mut ws.dh1, m, h, h);
    relu_backward(&mut ws.dh1, &ws.a1);
    {
        let gw2 = &mut ws.gw[..h * h];
        matmul_tn(&ws.h1, &ws.dh2, gw2, m, h, h);
        sgd_update(params.tensors[2].data_mut(), gw2, lr);
        col_sum_update(params.tensors[3].data_mut(), &ws.dh2, m, h, lr);
    }

    // layer 1
    {
        let gw1 = &mut ws.gw[..d * h];
        matmul_tn(x, &ws.dh1, gw1, m, d, h);
        sgd_update(params.tensors[0].data_mut(), gw1, lr);
        col_sum_update(params.tensors[1].data_mut(), &ws.dh1, m, h, lr);
    }

    loss
}

fn relu_backward(grad: &mut [f32], preact: &[f32]) {
    for (g, &a) in grad.iter_mut().zip(preact.iter()) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

fn sgd_update(param: &mut [f32], grad: &[f32], lr: f32) {
    for (p, &g) in param.iter_mut().zip(grad.iter()) {
        *p -= lr * g;
    }
}

/// `bias -= lr * column_sum(grad)` for a `[m, n]` gradient.
fn col_sum_update(bias: &mut [f32], grad: &[f32], m: usize, n: usize, lr: f32) {
    for i in 0..m {
        let row = &grad[i * n..(i + 1) * n];
        for (b, &g) in bias.iter_mut().zip(row.iter()) {
            *b -= lr * g;
        }
    }
}

/// Convenience wrapper used by tests: loss at (params, x, y).
pub fn loss(params: &ModelParams, x: &[f32], y: &[f32], rows: usize) -> f32 {
    let z = forward(params, x, rows);
    bce_loss(&z, y)
}

#[allow(dead_code)]
pub(crate) fn matmul_for_tests(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    let mut out = Tensor::zeros(&[m, n]);
    matmul(a.data(), b.data(), out.data_mut(), m, k, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian_f32(0.0, scale)).collect()
    }

    #[test]
    fn matmul_variants_agree() {
        check("matmul variants", 20, |g| {
            let m = g.usize_in(1, 12);
            let k = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let a = g.vec_f32(m * k, -2.0, 2.0);
            let b = g.vec_f32(k * n, -2.0, 2.0);
            let mut c = vec![0.0; m * n];
            matmul(&a, &b, &mut c, m, k, n);
            // naive reference
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                    assert!((c[i * n + j] - want).abs() < 1e-3);
                }
            }
            // a^T b via matmul_tn on a^T stored as a
            let mut at = vec![0.0; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a[i * k + kk];
                }
            }
            let mut c2 = vec![0.0; m * n];
            matmul_tn(&at, &b, &mut c2, k, m, n);
            for (x, y) in c.iter().zip(c2.iter()) {
                assert!((x - y).abs() < 1e-3);
            }
            // a b^T via matmul_nt with b^T stored as b
            let mut bt = vec![0.0; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b[kk * n + j];
                }
            }
            let mut c3 = vec![0.0; m * n];
            matmul_nt(&a, &bt, &mut c3, m, k, n);
            for (x, y) in c.iter().zip(c3.iter()) {
                assert!((x - y).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn bce_matches_closed_forms() {
        // z=0 → log 2 regardless of y
        assert!((bce_loss(&[0.0], &[0.0]) - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((bce_loss(&[0.0], &[1.0]) - std::f32::consts::LN_2).abs() < 1e-6);
        // large positive logit with y=1 → ~0; with y=0 → ~z
        assert!(bce_loss(&[30.0], &[1.0]) < 1e-6);
        assert!((bce_loss(&[30.0], &[0.0]) - 30.0).abs() < 1e-3);
        // stability at extremes
        assert!(bce_loss(&[80.0, -80.0], &[0.0, 1.0]).is_finite());
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::new(5);
        let (d, h, out, m) = (4, 3, 5, 2);
        let params = {
            let mut p = ModelParams::init(d, h, out, 1);
            // nonzero biases to exercise their gradients
            for t in [1, 3, 5] {
                for v in p.tensors[t].data_mut() {
                    *v = rng.gaussian_f32(0.0, 0.1);
                }
            }
            p
        };
        let x = rand_vec(&mut rng, m * d, 1.0);
        let y: Vec<f32> = (0..m * out)
            .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
            .collect();

        // analytic step with lr=1: delta = -grad
        let mut stepped = params.clone();
        let mut ws = Workspace::new(&stepped, m);
        train_step(&mut stepped, &mut ws, &x, &y, 1.0);

        // finite differences on a sample of coordinates of every tensor
        let eps = 1e-3f32;
        for ti in 0..6 {
            let len = params.tensors[ti].len();
            for probe in 0..3.min(len) {
                let idx = (probe * 7919) % len;
                let mut plus = params.clone();
                plus.tensors[ti].data_mut()[idx] += eps;
                let mut minus = params.clone();
                minus.tensors[ti].data_mut()[idx] -= eps;
                let fd = (loss(&plus, &x, &y, m) - loss(&minus, &x, &y, m)) / (2.0 * eps);
                let analytic = params.tensors[ti].data()[idx] - stepped.tensors[ti].data()[idx];
                assert!(
                    (fd - analytic).abs() < 2e-3,
                    "tensor {ti} idx {idx}: fd {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Rng::new(9);
        let (d, h, out, m) = (8, 6, 12, 16);
        let mut params = ModelParams::init(d, h, out, 2);
        let x = rand_vec(&mut rng, m * d, 1.0);
        let y: Vec<f32> = (0..m * out)
            .map(|_| if rng.bernoulli(0.2) { 1.0 } else { 0.0 })
            .collect();
        let mut ws = Workspace::new(&params, m);
        let first = loss(&params, &x, &y, m);
        let mut last = first;
        for _ in 0..50 {
            last = train_step(&mut params, &mut ws, &x, &y, 1.0);
        }
        assert!(last < first * 0.8, "{first} -> {last}");
    }

    #[test]
    fn forward_batch_consistency() {
        // forward on a 2-row batch equals per-row forwards
        let params = ModelParams::init(5, 4, 6, 3);
        let mut rng = Rng::new(2);
        let x = rand_vec(&mut rng, 2 * 5, 1.0);
        let z = forward(&params, &x, 2);
        let z0 = forward(&params, &x[0..5], 1);
        let z1 = forward(&params, &x[5..10], 1);
        assert_eq!(&z[0..6], &z0[..]);
        assert_eq!(&z[6..12], &z1[..]);
    }
}

