//! The 2-hidden-layer MLP's parameter set (paper Section 6 "Baselines":
//! "Both algorithms use the same MLP network (with two hidden layers)
//! for each dataset, besides the last fully connected layer").
//!
//! Tensor order `w1, b1, w2, b2, w3, b3` is a contract shared with the
//! AOT artifacts (`python/compile/model.py::PARAM_NAMES`) — the train
//! step takes them as its first six inputs and returns them as its
//! first six outputs, in this order.

use anyhow::{bail, Result};

use crate::util::rng::{derive_seed, Rng};
use crate::util::tensor::Tensor;

/// Number of parameter tensors.
pub const N_PARAMS: usize = 6;

/// The MLP parameters: input dim `d`, hidden width `h`, output width
/// `out` (p for FedAvg, B for one FedMLH sub-model).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelParams {
    pub d: usize,
    pub hidden: usize,
    pub out: usize,
    /// w1 [d,h], b1 [h], w2 [h,h], b2 [h], w3 [h,out], b3 [out]
    pub tensors: Vec<Tensor>,
}

impl ModelParams {
    /// Parameter tensor shapes for (d, hidden, out).
    pub fn shapes(d: usize, hidden: usize, out: usize) -> [Vec<usize>; N_PARAMS] {
        [
            vec![d, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, out],
            vec![out],
        ]
    }

    /// Zero-initialized (aggregation accumulators).
    pub fn zeros(d: usize, hidden: usize, out: usize) -> Self {
        let tensors = Self::shapes(d, hidden, out)
            .iter()
            .map(|s| Tensor::zeros(s))
            .collect();
        ModelParams {
            d,
            hidden,
            out,
            tensors,
        }
    }

    /// He-uniform weight init (U[-√(6/fan_in), +√(6/fan_in)]), zero
    /// biases — the same scheme as `python/compile/model.py::init_params`.
    pub fn init(d: usize, hidden: usize, out: usize, seed: u64) -> Self {
        let mut p = Self::zeros(d, hidden, out);
        for (i, t) in p.tensors.iter_mut().enumerate() {
            if t.shape().len() == 2 {
                let fan_in = t.shape()[0] as f32;
                let bound = (6.0 / fan_in).sqrt();
                let mut rng = Rng::new(derive_seed(seed, 0x1417 + i as u64));
                for v in t.data_mut() {
                    *v = rng.range_f64(-bound as f64, bound as f64) as f32;
                }
            }
        }
        p
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Bytes of one full model copy (f32) — the unit of Table 5 (memory)
    /// and Table 4 (per-sync communication volume is one copy up + one
    /// copy down per selected client, per sub-model).
    pub fn byte_size(&self) -> usize {
        self.tensors.iter().map(|t| t.byte_size()).sum()
    }

    /// `self = Σ scale_i · others_i` is built by repeated [`Self::accumulate`];
    /// this zeroes the accumulator first.
    pub fn zero_(&mut self) {
        for t in self.tensors.iter_mut() {
            t.fill(0.0);
        }
    }

    /// `self += other * scale` (FedAvg aggregation primitive).
    pub fn accumulate(&mut self, other: &ModelParams, scale: f32) -> Result<()> {
        if (self.d, self.hidden, self.out) != (other.d, other.hidden, other.out) {
            bail!(
                "param shape mismatch ({},{},{}) vs ({},{},{})",
                self.d,
                self.hidden,
                self.out,
                other.d,
                other.hidden,
                other.out
            );
        }
        for (a, b) in self.tensors.iter_mut().zip(other.tensors.iter()) {
            a.axpy(b, scale)?;
        }
        Ok(())
    }

    /// All parameter values as one flat vector in tensor order
    /// `w1, b1, w2, b2, w3, b3` — the view the update wire codecs
    /// ([`crate::federated::wire`]) encode and the PJRT buffers consume.
    pub fn flat_values(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_params());
        for t in &self.tensors {
            out.extend_from_slice(t.data());
        }
        out
    }

    /// Overwrite every tensor from a flat value buffer (the inverse of
    /// [`Self::flat_values`]; length-checked).
    pub fn set_from_flat(&mut self, values: &[f32]) -> Result<()> {
        if values.len() != self.num_params() {
            bail!(
                "flat buffer has {} values, model ({},{},{}) needs {}",
                values.len(),
                self.d,
                self.hidden,
                self.out,
                self.num_params()
            );
        }
        let mut off = 0;
        for t in self.tensors.iter_mut() {
            let len = t.len();
            t.data_mut().copy_from_slice(&values[off..off + len]);
            off += len;
        }
        Ok(())
    }

    /// Max |Δ| across all tensors (numeric cross-checks).
    pub fn max_abs_diff(&self, other: &ModelParams) -> Result<f32> {
        let mut m = 0.0f32;
        for (a, b) in self.tensors.iter().zip(other.tensors.iter()) {
            m = m.max(a.max_abs_diff(b)?);
        }
        Ok(m)
    }

    pub fn w1(&self) -> &Tensor {
        &self.tensors[0]
    }
    pub fn b1(&self) -> &Tensor {
        &self.tensors[1]
    }
    pub fn w2(&self) -> &Tensor {
        &self.tensors[2]
    }
    pub fn b2(&self) -> &Tensor {
        &self.tensors[3]
    }
    pub fn w3(&self) -> &Tensor {
        &self.tensors[4]
    }
    pub fn b3(&self) -> &Tensor {
        &self.tensors[5]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_counts() {
        let p = ModelParams::zeros(10, 4, 7);
        assert_eq!(p.num_params(), 10 * 4 + 4 + 16 + 4 + 4 * 7 + 7);
        assert_eq!(p.byte_size(), p.num_params() * 4);
        assert_eq!(p.w3().shape(), &[4, 7]);
    }

    #[test]
    fn init_deterministic_and_bounded() {
        let a = ModelParams::init(20, 8, 30, 3);
        let b = ModelParams::init(20, 8, 30, 3);
        assert_eq!(a, b);
        let c = ModelParams::init(20, 8, 30, 4);
        assert!(a.max_abs_diff(&c).unwrap() > 0.0);
        // He bound for w1: sqrt(6/20)
        let bound = (6.0f32 / 20.0).sqrt();
        for &v in a.w1().data() {
            assert!(v.abs() <= bound);
        }
        // biases zero
        assert!(a.b1().data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn accumulate_weighted_average() {
        let mut acc = ModelParams::zeros(2, 2, 2);
        let mut a = ModelParams::zeros(2, 2, 2);
        let mut b = ModelParams::zeros(2, 2, 2);
        a.tensors[0].fill(1.0);
        b.tensors[0].fill(3.0);
        acc.accumulate(&a, 0.5).unwrap();
        acc.accumulate(&b, 0.5).unwrap();
        assert!(acc.tensors[0].data().iter().all(|&v| (v - 2.0).abs() < 1e-6));
        let wrong = ModelParams::zeros(3, 2, 2);
        assert!(acc.accumulate(&wrong, 1.0).is_err());
    }

    #[test]
    fn flat_roundtrip_preserves_every_tensor() {
        let a = ModelParams::init(6, 4, 9, 11);
        let flat = a.flat_values();
        assert_eq!(flat.len(), a.num_params());
        let mut b = ModelParams::zeros(6, 4, 9);
        b.set_from_flat(&flat).unwrap();
        assert_eq!(a, b);
        // length mismatch is rejected
        assert!(b.set_from_flat(&flat[..flat.len() - 1]).is_err());
    }

    #[test]
    fn memory_ratio_matches_paper_structure() {
        // Table 5 mechanism: FedAvg holds one p-output model; FedMLH
        // holds R B-output models. Check the ratio formula on eurlex dims.
        let fedavg = ModelParams::zeros(256, 128, 4000);
        let sub = ModelParams::zeros(256, 128, 250);
        let ratio = fedavg.byte_size() as f64 / (4 * sub.byte_size()) as f64;
        assert!(ratio > 1.0, "FedMLH should be smaller: ratio {ratio}");
    }
}
