//! Tables 1–7 of the paper, regenerated from measured runs.
//!
//! Layout mirrors the paper: one column per dataset, FedMLH rows first
//! with the absolute-improvement delta in parentheses (Table 3), ratio
//! rows FedAvg-over-FedMLH (Tables 4–7). Absolute numbers come from this
//! testbed (synthetic analogs on CPU — DESIGN.md §3); the *shape* is the
//! reproduction target.

use crate::config::DatasetPreset;
use crate::data::synth::{generate, SynthSpec};

use super::report::{mb, pct_with_delta, pct, ratio, Markdown};
use super::PairResult;

/// Table 1 — dataset statistics (d, d̃, p, N), measured from the
/// generated analog datasets.
pub fn table1(presets: &[DatasetPreset], seed: u64) -> String {
    let mut header = vec!["".to_string()];
    header.extend(presets.iter().map(|p| p.name.to_string()));
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Markdown::new(&href);

    let specs: Vec<SynthSpec> = presets.iter().map(SynthSpec::from_preset).collect();
    let datas: Vec<_> = specs.iter().map(|s| generate(s, seed)).collect();

    let mut row = |label: &str, f: &dyn Fn(usize) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend((0..presets.len()).map(f));
        t.row(cells);
    };
    row("d (raw)", &|i| specs[i].raw_dim.to_string());
    row("d~ (hashed)", &|i| presets[i].d.to_string());
    row("p (classes)", &|i| presets[i].p.to_string());
    row("N (train)", &|i| datas[i].train.len().to_string());
    row("positives", &|i| datas[i].train.total_positives().to_string());
    row("paper analog", &|i| presets[i].paper_analog.to_string());
    t.render()
}

/// Table 2 — FedMLH hyper-parameters (R hash tables, B buckets).
pub fn table2(presets: &[DatasetPreset]) -> String {
    let mut header = vec!["".to_string()];
    header.extend(presets.iter().map(|p| p.name.to_string()));
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Markdown::new(&href);
    let mut r_row = vec!["R".to_string()];
    r_row.extend(presets.iter().map(|p| p.r.to_string()));
    t.row(r_row);
    let mut b_row = vec!["B".to_string()];
    b_row.extend(presets.iter().map(|p| p.b.to_string()));
    t.row(b_row);
    let mut c_row = vec!["p/B".to_string()];
    c_row.extend(presets.iter().map(|p| format!("{:.0}", p.p as f64 / p.b as f64)));
    t.row(c_row);
    t.render()
}

fn pair_header(pairs: &[PairResult], first: &str) -> Vec<String> {
    let mut h = vec![first.to_string()];
    h.extend(pairs.iter().map(|p| p.cfg.preset.name.to_string()));
    h
}

/// Table 3 — top-1/3/5 prediction accuracy, FedMLH (with absolute delta
/// over FedAvg) then FedAvg.
pub fn table3(pairs: &[PairResult]) -> String {
    let header = pair_header(pairs, "algo @k");
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Markdown::new(&href);
    for k in [1usize, 3, 5] {
        let mut cells = vec![format!("FedMLH @{k}")];
        cells.extend(
            pairs
                .iter()
                .map(|p| pct_with_delta(p.fedmlh.best.at(k), p.fedavg.best.at(k))),
        );
        t.row(cells);
    }
    for k in [1usize, 3, 5] {
        let mut cells = vec![format!("FedAvg @{k}")];
        cells.extend(pairs.iter().map(|p| pct(p.fedavg.best.at(k))));
        t.row(cells);
    }
    t.render()
}

/// Table 4 — communication volume (all clients, both directions) until
/// best accuracy, plus the CC ratio.
pub fn table4(pairs: &[PairResult]) -> String {
    let header = pair_header(pairs, "");
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Markdown::new(&href);
    let mut m = vec!["FedMLH".to_string()];
    m.extend(pairs.iter().map(|p| mb(p.fedmlh.comm_to_best)));
    t.row(m);
    let mut a = vec!["FedAvg".to_string()];
    a.extend(pairs.iter().map(|p| mb(p.fedavg.comm_to_best)));
    t.row(a);
    let mut r = vec!["CC Ratio".to_string()];
    r.extend(pairs.iter().map(|p| ratio(p.cc_ratio())));
    t.row(r);
    t.render()
}

/// Table 5 — per-client model memory and the memory ratio.
pub fn table5(pairs: &[PairResult]) -> String {
    let header = pair_header(pairs, "");
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Markdown::new(&href);
    let mut m = vec!["FedMLH".to_string()];
    m.extend(pairs.iter().map(|p| mb(p.fedmlh.model_bytes as u64)));
    t.row(m);
    let mut a = vec!["FedAvg".to_string()];
    a.extend(pairs.iter().map(|p| mb(p.fedavg.model_bytes as u64)));
    t.row(a);
    let mut r = vec!["Memory Ratio".to_string()];
    r.extend(pairs.iter().map(|p| ratio(p.memory_ratio())));
    t.row(r);
    t.render()
}

/// Table 6 — synchronization rounds to best accuracy and the ratio.
pub fn table6(pairs: &[PairResult]) -> String {
    let header = pair_header(pairs, "");
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Markdown::new(&href);
    let mut m = vec!["FedMLH".to_string()];
    m.extend(pairs.iter().map(|p| p.fedmlh.best_round.to_string()));
    t.row(m);
    let mut a = vec!["FedAvg".to_string()];
    a.extend(pairs.iter().map(|p| p.fedavg.best_round.to_string()));
    t.row(a);
    let mut r = vec!["Rounds Ratio".to_string()];
    r.extend(pairs.iter().map(|p| ratio(p.rounds_ratio())));
    t.row(r);
    // Sharper convergence read when both algorithms are still improving
    // at the round cap: how early FedMLH reaches FedAvg's final best.
    let mut m2 = vec!["FedMLH reaches FedAvg-best at".to_string()];
    m2.extend(pairs.iter().map(|p| {
        p.fedmlh_rounds_to_match_fedavg_best()
            .map(|r| r.to_string())
            .unwrap_or_else(|| "—".to_string())
    }));
    t.row(m2);
    t.render()
}

/// Table 7 — wall-clock time of one synchronization round and the ratio.
pub fn table7(pairs: &[PairResult]) -> String {
    let header = pair_header(pairs, "");
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Markdown::new(&href);
    let mut m = vec!["FedMLH".to_string()];
    m.extend(
        pairs
            .iter()
            .map(|p| format!("{:.2}s", p.fedmlh.history.mean_round_seconds())),
    );
    t.row(m);
    let mut a = vec!["FedAvg".to_string()];
    a.extend(
        pairs
            .iter()
            .map(|p| format!("{:.2}s", p.fedavg.history.mean_round_seconds())),
    );
    t.row(a);
    let mut r = vec!["Time Ratio".to_string()];
    r.extend(pairs.iter().map(|p| ratio(p.time_ratio())));
    t.row(r);
    t.render()
}

/// All pair-derived tables (3–7) in paper order — one run, five tables.
pub fn all_pair_tables(pairs: &[PairResult]) -> String {
    format!(
        "### Table 3 — top-k accuracy\n\n{}\n### Table 4 — communication volume to best accuracy\n\n{}\n### Table 5 — per-client model memory\n\n{}\n### Table 6 — rounds to best accuracy\n\n{}\n### Table 7 — wall-clock per synchronization round\n\n{}",
        table3(pairs),
        table4(pairs),
        table5(pairs),
        table6(pairs),
        table7(pairs)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::ExperimentConfig;
    use crate::harness::{run_pair, HarnessOpts};

    fn tiny_pair() -> PairResult {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.clients = 4;
        cfg.clients_per_round = 2;
        cfg.local_epochs = 1;
        let opts = HarnessOpts {
            rounds: Some(2),
            ..HarnessOpts::default()
        };
        run_pair(&cfg, &opts).unwrap()
    }

    #[test]
    fn table1_and_2_render() {
        let presets = vec![by_name("tiny").unwrap()];
        let t1 = table1(&presets, 1);
        assert!(t1.contains("p (classes)") && t1.contains("64"), "{t1}");
        let t2 = table2(&presets);
        assert!(t2.contains("R") && t2.contains("16"), "{t2}");
    }

    #[test]
    fn pair_tables_render() {
        let pair = tiny_pair();
        let pairs = vec![pair];
        for (i, s) in [
            table3(&pairs),
            table4(&pairs),
            table5(&pairs),
            table6(&pairs),
            table7(&pairs),
        ]
        .iter()
        .enumerate()
        {
            assert!(s.contains("tiny"), "table {} missing preset: {s}", i + 3);
            assert!(s.contains("FedMLH") && s.contains("FedAvg"));
        }
        let all = all_pair_tables(&pairs);
        assert!(all.contains("Table 3") && all.contains("Table 7"));
    }
}
