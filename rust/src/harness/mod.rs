//! The experiment harness: regenerates every table and figure in the
//! paper's evaluation section (see DESIGN.md §6 for the full index).
//!
//! One [`run_pair`] trains FedAvg and FedMLH under identical conditions
//! (same synthetic dataset, same non-iid partition, same FL setup);
//! Tables 3–7 and Figures 3–4 are different projections of that pair,
//! so the CLI runs the pair once and formats everything from it.
//!
//! - [`tables`] — Tables 1–7 as markdown (paper layout, measured values).
//! - [`figures`] — Figures 2–5 as CSV series (plot-ready).
//! - [`report`] — markdown/CSV formatting + `results/` persistence.

pub mod figures;
pub mod report;
pub mod tables;

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::{Algo, ExperimentConfig};
use crate::data::synth::{generate_preset, SynthData};
use crate::federated::backend::{RustBackend, TrainBackend};
use crate::federated::server::{self, RunOutput};
use crate::federated::transport::DownCodec;
use crate::federated::wire::CodecSpec;
use crate::partition::noniid::{partition as noniid_partition, NonIidOptions};
use crate::partition::Partition;
use crate::runtime::{RuntimeClient, XlaBackend, DEFAULT_ARTIFACT_DIR};

/// Which compute substrate executes training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust reference MLP (no artifacts needed; CI/test default).
    Rust,
    /// Compiled HLO artifacts on the PJRT CPU client (production path).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "rust" => Ok(BackendKind::Rust),
            "xla" => Ok(BackendKind::Xla),
            other => bail!("unknown backend '{other}' (expected rust|xla)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Rust => "rust",
            BackendKind::Xla => "xla",
        }
    }
}

/// Harness-level options shared by the CLI, examples and benches.
#[derive(Clone, Debug)]
pub struct HarnessOpts {
    pub backend: BackendKind,
    pub artifact_dir: PathBuf,
    /// Write CSV/markdown outputs under this directory when set.
    pub out_dir: Option<PathBuf>,
    /// Override the number of synchronization rounds (quick runs).
    pub rounds: Option<usize>,
    /// Route the xla backend through the `*_fast` artifact family
    /// (jnp-lowered twins; see `ExperimentConfig::fast_artifacts`).
    pub fast: bool,
    pub seed: u64,
    pub verbose: bool,
    /// Round-engine worker threads (`ExperimentConfig::workers`).
    pub workers: usize,
    /// Update wire codec (`ExperimentConfig::codec`).
    pub codec: CodecSpec,
    /// Broadcast codec (`ExperimentConfig::down_codec`).
    pub down_codec: DownCodec,
    /// Delta-downlink staleness cap (`ExperimentConfig::resync_every`).
    pub resync_every: usize,
    /// Stateful transport: error-feedback accumulators + broadcast
    /// residual folding (`ExperimentConfig::error_feedback`).
    pub error_feedback: bool,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        HarnessOpts {
            backend: BackendKind::Rust,
            artifact_dir: PathBuf::from(DEFAULT_ARTIFACT_DIR),
            out_dir: None,
            rounds: None,
            fast: false,
            seed: 42,
            verbose: false,
            workers: 1,
            codec: CodecSpec::Dense,
            down_codec: DownCodec::Dense,
            resync_every: 8,
            error_feedback: false,
        }
    }
}

impl HarnessOpts {
    /// Apply the overrides to a preset config.
    pub fn configure(&self, cfg: &mut ExperimentConfig) {
        cfg.seed = self.seed;
        if let Some(r) = self.rounds {
            cfg.rounds = r;
        }
        // B-sweep overrides have no fast artifacts; keep the Pallas tag.
        if self.fast && cfg.override_b == 0 {
            cfg.fast_artifacts = true;
        }
        cfg.workers = self.workers;
        cfg.codec = self.codec;
        cfg.down_codec = self.down_codec;
        cfg.resync_every = self.resync_every;
        cfg.error_feedback = self.error_feedback;
    }
}

/// The shared world of one comparison: dataset + non-iid partition.
pub struct World {
    pub data: SynthData,
    pub partition: Partition,
}

/// Generate the dataset and the frequent-class non-iid partition
/// (paper Section 6 "Non-iid data partition", Fig. 2c) for a config.
pub fn build_world(cfg: &ExperimentConfig) -> World {
    let data = generate_preset(&cfg.preset, cfg.seed);
    let partition = noniid_partition(
        &data.train,
        &NonIidOptions::new(cfg.clients),
        cfg.seed,
    );
    World { data, partition }
}

/// Build the training backend for `cfg` × `algo`. The `rt` client is
/// shared across backends so each artifact compiles once per process.
pub fn make_backend(
    kind: BackendKind,
    rt: Option<&Rc<RuntimeClient>>,
    cfg: &ExperimentConfig,
    algo: Algo,
) -> Result<Box<dyn TrainBackend>> {
    match kind {
        BackendKind::Rust => Ok(Box::new(RustBackend::with_batch(cfg.preset.batch))),
        BackendKind::Xla => {
            let rt = match rt {
                Some(rt) => rt.clone(),
                None => RuntimeClient::new(&PathBuf::from(DEFAULT_ARTIFACT_DIR))?,
            };
            Ok(Box::new(XlaBackend::new(rt, cfg, algo)?))
        }
    }
}

/// Train one algorithm end to end on a fresh world seeded by `seed`.
/// This is the library's one-call entrypoint (see the crate example).
pub fn run_algo(
    cfg: &ExperimentConfig,
    algo: Algo,
    backend: &dyn TrainBackend,
    seed: u64,
) -> Result<RunOutput> {
    let mut cfg = cfg.clone();
    cfg.seed = seed;
    let world = build_world(&cfg);
    let scheme = crate::algo::scheme_for(&cfg, algo, &world.data.train);
    server::run(
        &cfg,
        scheme.as_ref(),
        backend,
        &world.data.train,
        &world.data.test,
        &world.partition,
    )
}

/// FedAvg + FedMLH trained under identical conditions — the input to
/// Tables 3–7 and Figures 3–4.
pub struct PairResult {
    pub cfg: ExperimentConfig,
    pub fedavg: RunOutput,
    pub fedmlh: RunOutput,
}

impl PairResult {
    /// Communication-cost ratio (Table 4's "CC Ratio"): FedAvg over
    /// FedMLH, bytes to best accuracy.
    pub fn cc_ratio(&self) -> f64 {
        self.fedavg.comm_to_best as f64 / (self.fedmlh.comm_to_best.max(1)) as f64
    }

    /// Memory ratio (Table 5): per-client model bytes, FedAvg / FedMLH.
    pub fn memory_ratio(&self) -> f64 {
        self.fedavg.model_bytes as f64 / self.fedmlh.model_bytes.max(1) as f64
    }

    /// Rounds-to-best ratio (Table 6).
    pub fn rounds_ratio(&self) -> f64 {
        self.fedavg.best_round as f64 / self.fedmlh.best_round.max(1) as f64
    }

    /// First round (1-based) at which FedMLH's mean top-k accuracy
    /// reaches FedAvg's *best* — the convergence-speed comparison that
    /// stays meaningful when both algorithms are still improving at the
    /// round cap (Table 6's mechanism). `None` if FedMLH never gets
    /// there.
    pub fn fedmlh_rounds_to_match_fedavg_best(&self) -> Option<usize> {
        let target = self.fedavg.best.mean_topk();
        self.fedmlh
            .history
            .records
            .iter()
            .find(|r| r.accuracy.mean_topk() >= target)
            .map(|r| r.round + 1)
    }

    /// Per-round wall-clock ratio (Table 7).
    pub fn time_ratio(&self) -> f64 {
        let avg = self.fedavg.history.mean_round_seconds();
        let mlh = self.fedmlh.history.mean_round_seconds();
        if mlh > 0.0 {
            avg / mlh
        } else {
            f64::NAN
        }
    }
}

/// Run the FedAvg/FedMLH pair for one preset config on the same world.
pub fn run_pair(cfg: &ExperimentConfig, opts: &HarnessOpts) -> Result<PairResult> {
    let mut cfg = cfg.clone();
    opts.configure(&mut cfg);
    cfg.validate()?;
    let world = build_world(&cfg);

    let rt = match opts.backend {
        BackendKind::Xla => Some(RuntimeClient::new(&opts.artifact_dir)?),
        BackendKind::Rust => None,
    };

    let mut outs = Vec::with_capacity(2);
    for algo in [Algo::FedAvg, Algo::FedMlh] {
        if opts.verbose {
            crate::log_info!(
                "harness: {} × {} on preset '{}' ({} backend, ≤{} rounds)…",
                algo.name(),
                cfg.preset.paper_analog,
                cfg.preset.name,
                opts.backend.name(),
                cfg.rounds
            );
        }
        let backend = make_backend(opts.backend, rt.as_ref(), &cfg, algo)?;
        let scheme = crate::algo::scheme_for(&cfg, algo, &world.data.train);
        let out = server::run(
            &cfg,
            scheme.as_ref(),
            backend.as_ref(),
            &world.data.train,
            &world.data.test,
            &world.partition,
        )?;
        if opts.verbose {
            crate::log_info!(
                "harness:   best mean@k {:.4} at round {} ({} rounds run, {:.1}s)",
                out.best.mean_topk(),
                out.best_round,
                out.rounds_run,
                out.total_seconds
            );
        }
        outs.push(out);
    }
    let fedmlh = outs.pop().unwrap();
    let fedavg = outs.pop().unwrap();
    Ok(PairResult {
        cfg,
        fedavg,
        fedmlh,
    })
}

/// Run FedMLH alone (hyper-parameter sweeps, Figure 5).
pub fn run_fedmlh_only(cfg: &ExperimentConfig, opts: &HarnessOpts) -> Result<RunOutput> {
    let mut cfg = cfg.clone();
    opts.configure(&mut cfg);
    cfg.validate()?;
    let world = build_world(&cfg);
    let rt = match opts.backend {
        BackendKind::Xla => Some(RuntimeClient::new(&opts.artifact_dir)?),
        BackendKind::Rust => None,
    };
    let backend = make_backend(opts.backend, rt.as_ref(), &cfg, Algo::FedMlh)?;
    let scheme = crate::algo::scheme_for(&cfg, Algo::FedMlh, &world.data.train);
    server::run(
        &cfg,
        scheme.as_ref(),
        backend.as_ref(),
        &world.data.train,
        &world.data.test,
        &world.partition,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> HarnessOpts {
        HarnessOpts {
            rounds: Some(3),
            ..HarnessOpts::default()
        }
    }

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.clients = 4;
        cfg.clients_per_round = 2;
        cfg.local_epochs = 1;
        cfg.patience = 0;
        cfg
    }

    #[test]
    fn pair_runs_and_ratios_are_sane() {
        let pair = run_pair(&quick_cfg(), &quick_opts()).unwrap();
        // tiny's p = 64 is too small for the Table-5 effect (hidden
        // layers dominate); the > 1 ratios are asserted on the eurlex+
        // presets by the harness integration test. Here: finite + sane.
        assert!(pair.memory_ratio() > 0.0 && pair.memory_ratio().is_finite());
        assert!(pair.cc_ratio() > 0.0 && pair.cc_ratio().is_finite());
        assert!(pair.fedavg.rounds_run == 3 && pair.fedmlh.rounds_run == 3);
    }

    #[test]
    fn run_algo_matches_doc_example() {
        let cfg = quick_cfg();
        let backend = RustBackend::new();
        let mut cfg2 = cfg.clone();
        cfg2.rounds = 2;
        let out = run_algo(&cfg2, Algo::FedMlh, &backend, 42).unwrap();
        assert!(out.best.top1 >= 0.0 && out.best.top1 <= 1.0);
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("rust").unwrap(), BackendKind::Rust);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn deterministic_pairs() {
        let a = run_pair(&quick_cfg(), &quick_opts()).unwrap();
        let b = run_pair(&quick_cfg(), &quick_opts()).unwrap();
        assert_eq!(a.fedavg.best.top1, b.fedavg.best.top1);
        assert_eq!(a.fedmlh.best.top1, b.fedmlh.best.top1);
    }
}
