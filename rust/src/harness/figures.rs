//! Figures 2–5 of the paper as CSV series (plot-ready: every figure is
//! a set of (x, series…) rows the paper draws as lines/bars).

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::data::dataset::Dataset;
use crate::data::stats::LabelStats;
use crate::federated::history::History;
use crate::partition::Partition;

use super::report::Csv;
use super::{run_fedmlh_only, HarnessOpts, PairResult};

/// Figure 2a — CDF of the normalized positive-instance frequency
/// (power-law class imbalance).
pub fn fig2a(ds: &Dataset) -> String {
    let stats = LabelStats::from_dataset(ds);
    let grid = LabelStats::log_grid();
    let mut csv = Csv::new(&["norm_freq", "cdf"]);
    for pt in stats.freq_cdf(&grid) {
        csv.row(&[format!("{:.3e}", pt.x), format!("{:.6}", pt.y)]);
    }
    csv.render()
}

/// Figure 2b — proportion of positive instances contributed by classes
/// below each normalized frequency (the "infrequent classes carry ~70%
/// of positives" curve).
pub fn fig2b(ds: &Dataset) -> String {
    let stats = LabelStats::from_dataset(ds);
    let grid = LabelStats::log_grid();
    let mut csv = Csv::new(&["norm_freq", "positive_mass"]);
    for pt in stats.positive_mass_cdf(&grid) {
        csv.row(&[format!("{:.3e}", pt.x), format!("{:.6}", pt.y)]);
    }
    csv.render()
}

/// Figure 2c — the non-iid partition: per (client, frequent class)
/// sample counts (the paper's colored bar chart).
pub fn fig2c(ds: &Dataset, part: &Partition) -> String {
    let mut csv = Csv::new(&["client", "frequent_class", "samples"]);
    for (client, shard) in part.clients.iter().enumerate() {
        // count per frequent class on this client
        for (slot, &(class, _owner)) in part.class_owner.iter().enumerate() {
            let count = shard
                .iter()
                .filter(|&&i| ds.labels_of(i).contains(&class))
                .count();
            if count > 0 {
                csv.row(&[
                    client.to_string(),
                    format!("f{slot}"),
                    count.to_string(),
                ]);
            }
        }
    }
    csv.render()
}

fn history_rows(csv: &mut Csv, algo: &str, h: &History) {
    for rec in &h.records {
        let a = &rec.accuracy;
        csv.row(&[
            algo.to_string(),
            (rec.round + 1).to_string(),
            format!("{:.6}", a.top1),
            format!("{:.6}", a.top3),
            format!("{:.6}", a.top5),
            format!("{:.6}", a.freq1),
            format!("{:.6}", a.freq3),
            format!("{:.6}", a.freq5),
            format!("{:.6}", a.infreq1),
            format!("{:.6}", a.infreq3),
            format!("{:.6}", a.infreq5),
            rec.comm_bytes.to_string(),
            format!("{:.4}", rec.round_seconds),
            format!("{:.6}", rec.mean_loss),
        ]);
    }
}

const CURVE_HEADER: [&str; 14] = [
    "algo", "round", "top1", "top3", "top5", "freq1", "freq3", "freq5", "infreq1", "infreq3",
    "infreq5", "comm_bytes", "round_seconds", "mean_loss",
];

/// Figure 3 — accuracy curves (total / frequent / infrequent) per round
/// for both algorithms, from one pair run.
pub fn fig3(pair: &PairResult) -> String {
    let mut csv = Csv::new(&CURVE_HEADER);
    history_rows(&mut csv, "fedmlh", &pair.fedmlh.history);
    history_rows(&mut csv, "fedavg", &pair.fedavg.history);
    csv.render()
}

/// Figure 4 — test accuracy vs cumulative communication volume. The
/// same series as Fig. 3 keyed by `comm_bytes` instead of `round`; we
/// emit one CSV and let the plot choose the x column, exactly like the
/// paper reuses the training trace.
pub fn fig4(pair: &PairResult) -> String {
    fig3(pair)
}

/// One Figure-5 sweep point.
#[derive(Debug)]
pub struct SweepPoint {
    /// The swept value (B or R).
    pub value: usize,
    pub top1: f64,
    pub top3: f64,
    pub top5: f64,
    pub best_round: usize,
    pub model_bytes: usize,
}

/// Figure 5a/5c — FedMLH sensitivity to hash-table size B (R fixed).
pub fn fig5_sweep_b(
    cfg: &ExperimentConfig,
    values: &[usize],
    opts: &HarnessOpts,
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::with_capacity(values.len());
    for &b in values {
        let mut c = cfg.clone();
        c.override_b = b;
        let run = run_fedmlh_only(&c, opts)?;
        out.push(SweepPoint {
            value: b,
            top1: run.best.top1,
            top3: run.best.top3,
            top5: run.best.top5,
            best_round: run.best_round,
            model_bytes: run.model_bytes,
        });
    }
    Ok(out)
}

/// Figure 5b/5d — FedMLH sensitivity to the number of hash tables R
/// (B fixed).
pub fn fig5_sweep_r(
    cfg: &ExperimentConfig,
    values: &[usize],
    opts: &HarnessOpts,
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::with_capacity(values.len());
    for &r in values {
        let mut c = cfg.clone();
        c.override_r = r;
        let run = run_fedmlh_only(&c, opts)?;
        out.push(SweepPoint {
            value: r,
            top1: run.best.top1,
            top3: run.best.top3,
            top5: run.best.top5,
            best_round: run.best_round,
            model_bytes: run.model_bytes,
        });
    }
    Ok(out)
}

/// Sync-vs-async wall-clock-vs-accuracy comparison, driven from two
/// saved history CSVs (one synchronous run, one `--async` run of the
/// same preset). Each mode gets a `clock_seconds` x-axis on its own
/// terms: the synchronous run's clock is the *cumulative* measured
/// per-round wall time, the async run's is the simulated clock the
/// event loop stamped into `sim_seconds` — so the figure shows which
/// mode reaches a given accuracy sooner on the timeline it actually
/// experiences.
pub fn fig_sync_vs_async(sync_csv: &str, async_csv: &str) -> Result<String> {
    let sync_h = History::parse_csv(sync_csv)?;
    let async_h = History::parse_csv(async_csv)?;
    let mut csv = Csv::new(&[
        "mode",
        "round",
        "clock_seconds",
        "top1",
        "top3",
        "top5",
        "comm_bytes",
    ]);
    let mut clock = 0.0f64;
    for rec in &sync_h.records {
        clock += rec.round_seconds;
        comparison_row(&mut csv, "sync", rec, clock);
    }
    for rec in &async_h.records {
        comparison_row(&mut csv, "async", rec, rec.sim_seconds);
    }
    Ok(csv.render())
}

fn comparison_row(
    csv: &mut Csv,
    mode: &str,
    rec: &crate::federated::history::RoundRecord,
    clock: f64,
) {
    csv.row(&[
        mode.to_string(),
        (rec.round + 1).to_string(),
        format!("{clock:.4}"),
        format!("{:.6}", rec.accuracy.top1),
        format!("{:.6}", rec.accuracy.top3),
        format!("{:.6}", rec.accuracy.top5),
        rec.comm_bytes.to_string(),
    ]);
}

/// Render sweep points as CSV (`param` column is "B" or "R").
pub fn fig5_csv(param: &str, points: &[SweepPoint]) -> String {
    let mut csv = Csv::new(&["param", "value", "top1", "top3", "top5", "best_round", "model_bytes"]);
    for p in points {
        csv.row(&[
            param.to_string(),
            p.value.to_string(),
            format!("{:.6}", p.top1),
            format!("{:.6}", p.top3),
            format!("{:.6}", p.top5),
            p.best_round.to_string(),
            p.model_bytes.to_string(),
        ]);
    }
    csv.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate_preset;
    use crate::harness::{build_world, run_pair, BackendKind};

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.clients = 4;
        cfg.clients_per_round = 2;
        cfg.local_epochs = 1;
        cfg
    }

    fn quick_opts() -> HarnessOpts {
        HarnessOpts {
            backend: BackendKind::Rust,
            rounds: Some(2),
            ..HarnessOpts::default()
        }
    }

    #[test]
    fn fig2_series_have_rows() {
        let data = generate_preset(&quick_cfg().preset, 1);
        let a = fig2a(&data.train);
        let b = fig2b(&data.train);
        assert!(a.lines().count() > 5, "{a}");
        assert!(b.lines().count() > 5, "{b}");
        // CDFs end at 1
        let last = a.lines().last().unwrap();
        assert!(last.ends_with("1.000000"), "{last}");
    }

    #[test]
    fn fig2c_counts_match_partition() {
        let cfg = quick_cfg();
        let world = build_world(&cfg);
        let csv = fig2c(&world.data.train, &world.partition);
        assert!(csv.lines().count() > 1, "{csv}");
    }

    #[test]
    fn fig3_has_both_algos() {
        let pair = run_pair(&quick_cfg(), &quick_opts()).unwrap();
        let csv = fig3(&pair);
        assert!(csv.contains("fedmlh") && csv.contains("fedavg"));
        // 2 rounds × 2 algos + header
        assert_eq!(csv.trim().lines().count(), 1 + 4);
    }

    #[test]
    fn sync_vs_async_comparison_uses_each_modes_clock() {
        use crate::eval::metrics::AccuracyReport;
        use crate::federated::history::{History, RoundRecord, RoundTiming};
        let mk = |round: usize, top1: f64, secs: f64, sim: f64| RoundRecord {
            round,
            accuracy: AccuracyReport {
                top1,
                top3: top1,
                top5: top1,
                ..Default::default()
            },
            comm_bytes: (round as u64 + 1) * 1000,
            down_bytes: 600,
            up_bytes: 400,
            round_seconds: secs,
            mean_loss: 0.5,
            timing: RoundTiming::default(),
            sim_seconds: sim,
        };
        let mut sync_h = History::new();
        sync_h.push(mk(0, 0.1, 2.0, 0.0));
        sync_h.push(mk(1, 0.2, 3.0, 0.0));
        let mut async_h = History::new();
        async_h.push(mk(0, 0.15, 0.0, 40.0));
        async_h.push(mk(1, 0.25, 0.0, 90.0));
        let csv = fig_sync_vs_async(&sync_h.to_csv(), &async_h.to_csv()).unwrap();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 1 + 4);
        assert_eq!(
            lines[0],
            "mode,round,clock_seconds,top1,top3,top5,comm_bytes"
        );
        // Sync clock accumulates measured round seconds: 2.0 then 5.0.
        assert!(lines[1].starts_with("sync,1,2.0000,0.100000"), "{}", lines[1]);
        assert!(lines[2].starts_with("sync,2,5.0000,0.200000"), "{}", lines[2]);
        // Async clock is the simulated timeline, verbatim.
        assert!(lines[3].starts_with("async,1,40.0000,0.150000"), "{}", lines[3]);
        assert!(lines[4].starts_with("async,2,90.0000,0.250000"), "{}", lines[4]);
        // Malformed history propagates as an error.
        assert!(fig_sync_vs_async("bogus", &async_h.to_csv()).is_err());
    }

    #[test]
    fn fig5_sweeps_run() {
        let cfg = quick_cfg();
        let pts = fig5_sweep_b(&cfg, &[8, 32], &quick_opts()).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].model_bytes < pts[1].model_bytes);
        let csv = fig5_csv("B", &pts);
        assert!(csv.contains("B,8"), "{csv}");
        let pts_r = fig5_sweep_r(&cfg, &[1, 3], &quick_opts()).unwrap();
        assert!(pts_r[0].model_bytes < pts_r[1].model_bytes);
    }
}
