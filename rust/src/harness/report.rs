//! Markdown/CSV formatting and `results/` persistence for the harness.

use std::path::Path;

use anyhow::{Context, Result};

/// A simple pipe-table builder (GitHub-flavoured markdown).
pub struct Markdown {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Markdown {
    pub fn new(header: &[&str]) -> Self {
        Markdown {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths.iter())
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let dashes: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|", dashes.join("-|-")));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// A CSV builder (no quoting needed: all cells are numbers/identifiers).
pub struct Csv {
    lines: Vec<String>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv {
            lines: vec![header.join(",")],
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        self.lines.push(cells.join(","));
        self
    }

    pub fn render(&self) -> String {
        let mut s = self.lines.join("\n");
        s.push('\n');
        s
    }

    pub fn len(&self) -> usize {
        self.lines.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Write a harness output file under `out_dir` (created on demand).
pub fn write_result(out_dir: &Path, name: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join(name);
    std::fs::write(&path, content).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

// -- number formatting shared by tables --------------------------------

/// `0.5931` → `"59.3%"`.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", 100.0 * v)
}

/// `0.5931, 0.5031` → `"59.3% (+9.0%)"` (Table 3 cell layout).
pub fn pct_with_delta(ours: f64, baseline: f64) -> String {
    format!(
        "{} ({}{:.1}%)",
        pct(ours),
        if ours >= baseline { "+" } else { "" },
        100.0 * (ours - baseline)
    )
}

/// `2.41` → `"2.41x"`.
pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

/// Bytes with the unit the paper uses (Mb/Gb decimal).
pub fn mb(bytes: u64) -> String {
    let mbv = bytes as f64 / 1e6;
    if mbv >= 1000.0 {
        format!("{:.1} Gb", mbv / 1000.0)
    } else {
        format!("{mbv:.1} Mb")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Markdown::new(&["name", "v"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| name      | v |"), "{s}");
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn markdown_rejects_ragged_rows() {
        Markdown::new(&["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut c = Csv::new(&["x", "y"]);
        c.row(&["1".into(), "2".into()]);
        assert_eq!(c.render(), "x,y\n1,2\n");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.593), "59.3%");
        assert_eq!(pct_with_delta(0.593, 0.503), "59.3% (+9.0%)");
        assert_eq!(ratio(18.754), "18.75x");
        assert_eq!(mb(199_700_000), "199.7 Mb");
        assert_eq!(mb(7_200_000_000), "7.2 Gb");
    }

    #[test]
    fn write_result_creates_dirs() {
        let dir = std::env::temp_dir().join("fedmlh_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_result(&dir, "t.csv", "a,b\n").unwrap();
        assert_eq!(std::fs::read_to_string(dir.join("t.csv")).unwrap(), "a,b\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
