//! Hot-reload building blocks for the serve control plane: what to
//! load ([`ReloadSpec`]) and the immutable serving unit a reload
//! produces ([`ModelVersion`] — one decoded checkpoint behind N
//! health-tracked predictor [`Replica`]s).
//!
//! A `ModelVersion` is built entirely off the request path: the
//! checkpoint is loaded (full `.fmlh`, or a delta chain via
//! [`Checkpoint::load_chain`]), decoded into one shared
//! [`InferenceEngine`], and fronted by `--replicas` independent
//! [`Predictor`] worker pools over that engine (the weights are never
//! duplicated). Only after everything is up does
//! [`super::control::ControlPlane`] swap an `Arc<ModelVersion>` into
//! the routing state — an in-flight request holding the old `Arc`
//! keeps the old pools alive until it answers, so no request is
//! dropped or ever sees a torn model. Any load/decode failure happens
//! before the swap and leaves the previous version serving.
//!
//! Replica health is consecutive-failure based: a replica that failed
//! its last [`UNHEALTHY_AFTER`] requests is skipped by the round-robin
//! pick, except that every [`PROBE_EVERY`]-th request probes its slot
//! anyway so a recovered replica re-enters rotation without an
//! operator action.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::obs::metrics::{global, Counter};
use crate::util::json::Json;

use super::checkpoint::{Checkpoint, CheckpointMeta};
use super::http::ServeOpts;
use super::infer::{InferenceEngine, Predictor, ScoredClass};
use super::metrics::ServeMetrics;

/// A replica is skipped by the healthy-preferring pick once this many
/// requests in a row have failed on it.
pub const UNHEALTHY_AFTER: u32 = 3;
/// Every N-th pick goes to the plain round-robin slot even if that
/// replica is unhealthy, giving it traffic to recover on.
const PROBE_EVERY: usize = 16;

/// What `POST /reload` asks the control plane to load: a full
/// checkpoint, or a base plus an ordered delta chain.
#[derive(Clone, Debug, PartialEq)]
pub struct ReloadSpec {
    /// Full `.fmlh` checkpoint (or the chain's base when `deltas` is
    /// non-empty).
    pub checkpoint: PathBuf,
    /// FMLD delta checkpoints, applied in order on top of `checkpoint`.
    pub deltas: Vec<PathBuf>,
}

impl ReloadSpec {
    /// Parse a reload request body:
    /// `{"checkpoint": "path.fmlh", "deltas": ["d1.fmld", …]}`
    /// (`deltas` optional).
    pub fn from_json(body: &[u8]) -> Result<ReloadSpec> {
        let text = std::str::from_utf8(body).context("reload body is not utf-8")?;
        let req = Json::parse(text).context("reload body is not valid JSON")?;
        let checkpoint = req
            .get("checkpoint")
            .context("reload body must name a 'checkpoint' path")?
            .as_str()
            .context("'checkpoint' must be a string path")?
            .to_string();
        let mut deltas = Vec::new();
        if let Some(list) = req.get("deltas") {
            let arr = list.as_arr().context("'deltas' must be an array of paths")?;
            for (i, item) in arr.iter().enumerate() {
                let path = item
                    .as_str()
                    .with_context(|| format!("'deltas'[{i}] must be a string path"))?;
                deltas.push(PathBuf::from(path));
            }
        }
        Ok(ReloadSpec {
            checkpoint: PathBuf::from(checkpoint),
            deltas,
        })
    }

    /// Load the checkpoint (chain-applying deltas in order). Every
    /// failure — missing file, wrong base checksum, out-of-order chain
    /// — surfaces here, before anything is swapped.
    pub fn load(&self) -> Result<Checkpoint> {
        if self.deltas.is_empty() {
            Checkpoint::load(&self.checkpoint)
        } else {
            Checkpoint::load_chain(&self.checkpoint, &self.deltas)
        }
    }

    /// Provenance string stored on the built version and reported by
    /// `GET /healthz`.
    pub fn describe(&self) -> String {
        if self.deltas.is_empty() {
            self.checkpoint.display().to_string()
        } else {
            format!(
                "{} + {} delta(s)",
                self.checkpoint.display(),
                self.deltas.len()
            )
        }
    }
}

/// One predictor pool plus its health/accounting state. All replicas
/// of a version share one [`InferenceEngine`]; what a replica adds is
/// an independent worker pool and queue, so a wedged or failing pool
/// can be routed around.
pub struct Replica {
    pub id: usize,
    predictor: Predictor,
    /// Consecutive failures; reset to 0 by any success.
    fails: AtomicU32,
    requests: AtomicU64,
    errors: AtomicU64,
    /// Global-registry mirrors, labeled `{generation, replica}`.
    obs_requests: Arc<Counter>,
    obs_errors: Arc<Counter>,
}

impl Replica {
    /// Healthy = fewer than [`UNHEALTHY_AFTER`] consecutive failures.
    pub fn healthy(&self) -> bool {
        self.fails.load(Ordering::Relaxed) < UNHEALTHY_AFTER
    }

    fn record(&self, ok: bool) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.obs_requests.inc();
        if ok {
            self.fails.store(0, Ordering::Relaxed);
        } else {
            self.fails.fetch_add(1, Ordering::Relaxed);
            self.errors.fetch_add(1, Ordering::Relaxed);
            self.obs_errors.inc();
        }
    }
}

/// One fully-decoded model generation: shared engine, N replicas,
/// per-version stats. Immutable once built; the control plane swaps
/// `Arc<ModelVersion>`s, never mutates one in place.
pub struct ModelVersion {
    /// Monotone generation number (1 = the checkpoint the server
    /// started with).
    pub generation: u64,
    /// Where the weights came from (path, or "base + N delta(s)").
    pub source: String,
    /// [`Checkpoint::state_checksum`] of the loaded weights.
    pub state_checksum: u64,
    engine: Arc<InferenceEngine>,
    replicas: Vec<Replica>,
    next: AtomicUsize,
    /// Per-version request/latency stats (authoritative for this
    /// process; the obs-registry mirrors below are global and shared
    /// across every server in the process, e.g. under `cargo test`).
    pub stats: ServeMetrics,
    obs_requests: Arc<Counter>,
    obs_errors: Arc<Counter>,
}

impl ModelVersion {
    /// Decode a loaded checkpoint into a serving unit: one engine,
    /// `opts.replicas` predictor pools. Batch accounting flows into
    /// `totals` (the process-lifetime [`ServeMetrics`]) so the
    /// historical `/metrics` contract spans reloads.
    pub fn build(
        ckpt: Checkpoint,
        generation: u64,
        source: String,
        opts: &ServeOpts,
        totals: &Arc<ServeMetrics>,
    ) -> Result<ModelVersion> {
        let state_checksum = ckpt.state_checksum()?;
        let engine = Arc::new(InferenceEngine::new(ckpt)?);
        let reg = global();
        let gen_label = generation.to_string();
        let obs_requests = reg.counter_with(
            "fedmlh_serve_version_requests_total",
            "Predict requests routed to a model generation.",
            &[("generation", &gen_label)],
        );
        let obs_errors = reg.counter_with(
            "fedmlh_serve_version_errors_total",
            "Failed predict requests, by model generation.",
            &[("generation", &gen_label)],
        );
        let replicas = (0..opts.replicas.max(1))
            .map(|id| {
                let rid = id.to_string();
                Replica {
                    id,
                    predictor: Predictor::new(
                        engine.clone(),
                        opts.workers,
                        opts.max_batch,
                        totals.clone(),
                    ),
                    fails: AtomicU32::new(0),
                    requests: AtomicU64::new(0),
                    errors: AtomicU64::new(0),
                    obs_requests: reg.counter_with(
                        "fedmlh_serve_replica_requests_total",
                        "Predict requests handled, by model generation and replica.",
                        &[("generation", &gen_label), ("replica", &rid)],
                    ),
                    obs_errors: reg.counter_with(
                        "fedmlh_serve_replica_errors_total",
                        "Failed predict requests, by model generation and replica.",
                        &[("generation", &gen_label), ("replica", &rid)],
                    ),
                }
            })
            .collect();
        Ok(ModelVersion {
            generation,
            source,
            state_checksum,
            engine,
            replicas,
            next: AtomicUsize::new(0),
            stats: ServeMetrics::new(),
            obs_requests,
            obs_errors,
        })
    }

    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    pub fn meta(&self) -> &CheckpointMeta {
        self.engine.meta()
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Round-robin over healthy replicas (every [`PROBE_EVERY`]-th
    /// pick takes the plain slot regardless of health; with every
    /// replica unhealthy the plain slot serves too — degraded beats
    /// down).
    fn pick_replica(&self) -> &Replica {
        let n = self.replicas.len();
        let ticket = self.next.fetch_add(1, Ordering::Relaxed);
        let start = ticket % n;
        if ticket % PROBE_EVERY != 0 {
            for off in 0..n {
                let r = &self.replicas[(start + off) % n];
                if r.healthy() {
                    return r;
                }
            }
        }
        &self.replicas[start]
    }

    /// Route one prediction through a replica, recording health and
    /// per-version/per-replica counters. Non-finite scores (a diverged
    /// or corrupt model) are a server fault, not an answer.
    pub fn predict(&self, x: Vec<f32>, k: usize) -> Result<Vec<ScoredClass>> {
        let replica = self.pick_replica();
        let result = replica.predictor.predict(x, k);
        let ok = matches!(&result, Ok(topk) if topk.iter().all(|&(_, s)| s.is_finite()));
        replica.record(ok);
        self.obs_requests.inc();
        if !ok {
            self.obs_errors.inc();
        }
        match result {
            Ok(topk) => {
                if ok {
                    Ok(topk)
                } else {
                    bail!("model produced non-finite scores")
                }
            }
            Err(e) => Err(e),
        }
    }

    /// Per-replica health rows for `GET /healthz`.
    pub fn replica_health(&self) -> Json {
        Json::Arr(
            self.replicas
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("replica", Json::num(r.id as f64)),
                        ("healthy", Json::Bool(r.healthy())),
                        (
                            "requests",
                            Json::num(r.requests.load(Ordering::Relaxed) as f64),
                        ),
                        ("errors", Json::num(r.errors.load(Ordering::Relaxed) as f64)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, ExperimentConfig};
    use crate::model::params::ModelParams;

    fn tiny_checkpoint() -> Checkpoint {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let models: Vec<ModelParams> = (0..cfg.r())
            .map(|j| ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), 10 + j as u64))
            .collect();
        Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap()
    }

    fn opts(replicas: usize) -> ServeOpts {
        ServeOpts {
            replicas,
            workers: 1,
            max_batch: 4,
            ..ServeOpts::default()
        }
    }

    #[test]
    fn reload_spec_parses_and_describes() {
        let spec = ReloadSpec::from_json(br#"{"checkpoint": "m.fmlh"}"#).unwrap();
        assert_eq!(spec.checkpoint, PathBuf::from("m.fmlh"));
        assert!(spec.deltas.is_empty());
        assert_eq!(spec.describe(), "m.fmlh");

        let spec =
            ReloadSpec::from_json(br#"{"checkpoint": "b.fmlh", "deltas": ["d1", "d2"]}"#).unwrap();
        assert_eq!(spec.deltas.len(), 2);
        assert_eq!(spec.describe(), "b.fmlh + 2 delta(s)");

        assert!(ReloadSpec::from_json(b"not json").is_err());
        assert!(ReloadSpec::from_json(br#"{"deltas": []}"#).is_err(), "checkpoint is required");
        assert!(ReloadSpec::from_json(br#"{"checkpoint": 7}"#).is_err());
        assert!(ReloadSpec::from_json(br#"{"checkpoint": "c", "deltas": [1]}"#).is_err());
    }

    #[test]
    fn version_predicts_like_the_engine_across_replicas() {
        let ckpt = tiny_checkpoint();
        let totals = Arc::new(ServeMetrics::new());
        let version =
            ModelVersion::build(ckpt, 1, "test".into(), &opts(3), &totals).unwrap();
        assert_eq!(version.n_replicas(), 3);
        let d = version.engine().d();
        let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
        let want = version.engine().predict_topk(&x, 1, 5).unwrap().remove(0);
        // Round-robin walks every replica; each must answer bitwise
        // identically (they share the one engine).
        for _ in 0..6 {
            assert_eq!(version.predict(x.clone(), 5).unwrap(), want);
        }
        // Batch accounting landed in the shared totals.
        assert_eq!(totals.snapshot().batched_rows, 6);
    }

    #[test]
    fn poisoned_model_fails_requests_and_flips_health() {
        let mut ckpt = tiny_checkpoint();
        for m in &mut ckpt.models {
            m.tensors[5].data_mut().fill(f32::NAN);
        }
        let totals = Arc::new(ServeMetrics::new());
        let version =
            ModelVersion::build(ckpt, 1, "poisoned".into(), &opts(1), &totals).unwrap();
        let d = version.engine().d();
        for _ in 0..UNHEALTHY_AFTER {
            let err = version.predict(vec![0.1; d], 3).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{err}");
        }
        let health = version.replica_health();
        let rows = health.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("healthy").unwrap(), &Json::Bool(false));
        assert_eq!(
            rows[0].get("errors").unwrap().as_usize().unwrap(),
            UNHEALTHY_AFTER as usize
        );
    }
}
