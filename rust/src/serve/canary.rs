//! Canary rollout state: route a percentage of traffic to a freshly
//! loaded [`ModelVersion`], watch its error rate and tail latency over
//! a configurable window, and decide — promote or roll back — without
//! an operator in the loop.
//!
//! The traffic split is deterministic, not random: ticket `t` goes to
//! the canary iff `(t * pct) % 100 < pct`, which spreads canary picks
//! evenly through the request stream (pct 50 alternates versions; pct
//! 1 sends every 100th request) instead of clustering them. Only
//! requests that actually reached a predictor count toward the verdict
//! — a client sending malformed JSON says nothing about the model.
//!
//! The verdict is computed after each canary-served request:
//!
//! * **Rollback (early)** the moment the error budget
//!   `floor(max_error_rate × window)` is exhausted — a model rigged to
//!   error is evicted after a handful of requests, not a full window.
//! * **Promote** once `window` requests have been served within the
//!   error budget, provided the canary's p99 latency stays within
//!   `p99_ratio ×` the stable version's p99 (`p99_ratio` 0 disables
//!   the latency guard).
//! * **Pending** otherwise.
//!
//! [`CanaryRollout::try_decide`] is a one-shot gate (compare-and-swap)
//! so concurrent request threads cannot apply the verdict twice.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::CanaryConfig;

use super::metrics::MetricsSnapshot;
use super::reload::ModelVersion;

/// What the watcher concluded about an in-flight canary.
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    /// Keep routing; not enough evidence yet.
    Pending,
    /// The canary met the bar over the full window.
    Promote,
    /// The canary regressed; the reason is operator-readable.
    Rollback(String),
}

/// An in-flight canary: the candidate version plus its routing state
/// and verdict accounting.
pub struct CanaryRollout {
    /// The candidate model version receiving `pct`% of traffic.
    pub version: Arc<ModelVersion>,
    /// Traffic percentage routed to the canary (1..=99).
    pub pct: u64,
    /// Decision policy (window / error budget / latency guard).
    pub policy: CanaryConfig,
    tickets: AtomicU64,
    served: AtomicU64,
    errors: AtomicU64,
    decided: AtomicBool,
}

impl CanaryRollout {
    pub fn new(version: Arc<ModelVersion>, pct: u64, policy: CanaryConfig) -> CanaryRollout {
        CanaryRollout {
            version,
            pct,
            policy,
            tickets: AtomicU64::new(0),
            served: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            decided: AtomicBool::new(false),
        }
    }

    /// Draw the next routing ticket: `true` = this request goes to the
    /// canary. Deterministic Bresenham-style split (see module docs).
    pub fn take_ticket(&self) -> bool {
        let t = self.tickets.fetch_add(1, Ordering::Relaxed);
        (t % 100) * self.pct % 100 < self.pct
    }

    /// Record the outcome of one canary-served prediction.
    pub fn note(&self, ok: bool) {
        self.served.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Canary-served request count so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Canary-served failures so far.
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Requests the canary may fail within the window before rollback.
    fn error_budget(&self) -> u64 {
        (self.policy.max_error_rate * self.policy.window as f64).floor() as u64
    }

    /// Evaluate the rollout against the stable version's snapshot.
    pub fn verdict(&self, stable: &MetricsSnapshot) -> Verdict {
        let served = self.served();
        let errors = self.errors();
        let budget = self.error_budget();
        if errors > budget {
            return Verdict::Rollback(format!(
                "canary error budget exhausted: {errors} errors in {served} requests \
                 (budget {budget} per {} window)",
                self.policy.window
            ));
        }
        if served < self.policy.window as u64 {
            return Verdict::Pending;
        }
        if self.policy.p99_ratio > 0.0 && stable.p99_us > 0 {
            let canary_p99 = self.version.stats.snapshot().p99_us;
            let limit = stable.p99_us as f64 * self.policy.p99_ratio;
            if canary_p99 as f64 > limit {
                return Verdict::Rollback(format!(
                    "canary p99 {canary_p99}us exceeds {:.0}us ({}x stable p99 {}us)",
                    limit, self.policy.p99_ratio, stable.p99_us
                ));
            }
        }
        Verdict::Promote
    }

    /// One-shot gate: the first caller gets `true` and must apply the
    /// verdict; everyone after gets `false`.
    pub fn try_decide(&self) -> bool {
        self.decided
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
    }

    /// Whether a verdict has already been applied (or is being applied).
    pub fn decided(&self) -> bool {
        self.decided.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, ExperimentConfig};
    use crate::model::params::ModelParams;
    use crate::serve::checkpoint::Checkpoint;
    use crate::serve::http::ServeOpts;
    use crate::serve::metrics::ServeMetrics;
    use std::time::Duration;

    fn tiny_version() -> Arc<ModelVersion> {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let models: Vec<ModelParams> = (0..cfg.r())
            .map(|j| ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), 40 + j as u64))
            .collect();
        let ckpt =
            Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap();
        let opts = ServeOpts {
            workers: 1,
            max_batch: 4,
            ..ServeOpts::default()
        };
        let totals = Arc::new(ServeMetrics::new());
        Arc::new(ModelVersion::build(ckpt, 2, "canary-test".into(), &opts, &totals).unwrap())
    }

    fn policy(window: usize, max_error_rate: f64, p99_ratio: f64) -> CanaryConfig {
        CanaryConfig {
            window,
            max_error_rate,
            p99_ratio,
        }
    }

    #[test]
    fn ticket_split_is_even() {
        for pct in [1u64, 10, 50, 99] {
            let rollout = CanaryRollout::new(tiny_version(), pct, policy(10, 0.1, 0.0));
            let canary = (0..100).filter(|_| rollout.take_ticket()).count() as u64;
            assert_eq!(canary, pct, "pct {pct} must route exactly {pct}/100");
            // pct 50 must alternate, not front-load.
            if pct == 50 {
                let first10: Vec<bool> = (0..10).map(|_| rollout.take_ticket()).collect();
                assert_eq!(first10.iter().filter(|&&c| c).count(), 5);
            }
        }
    }

    #[test]
    fn verdict_rolls_back_early_on_errors() {
        // window 10, 10% tolerated → budget floor(1.0) = 1 error.
        let rollout = CanaryRollout::new(tiny_version(), 50, policy(10, 0.1, 0.0));
        let stable = ServeMetrics::new().snapshot();
        assert_eq!(rollout.verdict(&stable), Verdict::Pending);
        rollout.note(false);
        assert_eq!(rollout.verdict(&stable), Verdict::Pending, "within budget");
        rollout.note(false);
        // 2 errors > budget 1 → rollback after only 2 requests.
        assert!(matches!(rollout.verdict(&stable), Verdict::Rollback(_)));
    }

    #[test]
    fn verdict_promotes_after_a_clean_window() {
        let rollout = CanaryRollout::new(tiny_version(), 50, policy(5, 0.2, 0.0));
        let stable = ServeMetrics::new().snapshot();
        for _ in 0..4 {
            rollout.note(true);
        }
        assert_eq!(rollout.verdict(&stable), Verdict::Pending);
        rollout.note(true);
        assert_eq!(rollout.verdict(&stable), Verdict::Promote);
    }

    #[test]
    fn verdict_rolls_back_on_latency_regression() {
        let rollout = CanaryRollout::new(tiny_version(), 50, policy(3, 0.5, 2.0));
        // Canary answers take ~1000us; stable served at ~10us.
        for _ in 0..3 {
            rollout.note(true);
            rollout
                .version
                .stats
                .record_request(Duration::from_micros(1000), true);
        }
        let stable_metrics = ServeMetrics::new();
        stable_metrics.record_request(Duration::from_micros(10), true);
        assert!(matches!(
            rollout.verdict(&stable_metrics.snapshot()),
            Verdict::Rollback(_)
        ));
        // With the guard disabled the same numbers promote.
        let relaxed = CanaryRollout::new(rollout.version.clone(), 50, policy(3, 0.5, 0.0));
        for _ in 0..3 {
            relaxed.note(true);
        }
        assert_eq!(relaxed.verdict(&stable_metrics.snapshot()), Verdict::Promote);
    }

    #[test]
    fn decide_gate_is_one_shot() {
        let rollout = CanaryRollout::new(tiny_version(), 10, policy(5, 0.1, 0.0));
        assert!(!rollout.decided());
        assert!(rollout.try_decide());
        assert!(!rollout.try_decide(), "second decider must lose the race");
        assert!(rollout.decided());
    }
}
