//! Dependency-light HTTP/1.1 serving front end over
//! [`std::net::TcpListener`] (the offline registry has no hyper/axum;
//! the protocol subset here — request line, headers, Content-Length
//! body, opt-in keep-alive — is what every load balancer and `curl`
//! speak).
//!
//! Connection reuse: a client that sends `Connection: keep-alive` gets
//! the connection held open and can issue further requests on it (each
//! request under the same `REQUEST_DEADLINE` as before), up to
//! `MAX_REQUESTS_PER_CONN` per connection — the final allowed
//! response answers `Connection: close` so well-behaved clients
//! re-connect instead of stalling. Requests without the header keep the
//! historical close-after-response behavior (clients written against
//! it frame responses by EOF), and malformed requests always close.
//! Back-to-back (pipelined) requests are buffered and served strictly
//! in sequence — bytes read past one request's body seed the next
//! request's parse instead of being dropped.
//!
//! Endpoints:
//!
//! - `POST /predict` — body `{"dense": [f32; d], "k": 5}` or
//!   `{"sparse": [[index, value], …], "k": 5}`; responds
//!   `{"topk": [{"class": c, "score": s}, …], "k": k}`. Raw sparse
//!   inputs are feature-hashed with the checkpoint's stored seed —
//!   exactly the training-time map.
//! - `GET /healthz` — checkpoint identity + pool shape, for probes.
//! - `GET /metrics` — request count, p50/p99 latency, batch-size
//!   histogram ([`super::metrics`]) as JSON;
//!   `GET /metrics?format=prometheus` serves the same data (plus the
//!   process-global [`crate::obs::metrics`] registry) in the Prometheus
//!   text exposition format for scrapers.
//!
//! One OS thread per connection parses and responds; prediction work
//! is handed to the shared [`Predictor`] pool, which coalesces
//! concurrent requests into batched forward passes. JSON number
//! round-tripping is exact for `f32` scores (shortest-representation
//! printing), so a served top-k is bitwise the offline decode's.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::checkpoint::Checkpoint;
use super::infer::{InferenceEngine, Predictor, ScoredClass};
use super::metrics::ServeMetrics;

/// Server configuration (CLI: `fedmlh serve`).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Interface to bind, e.g. "127.0.0.1" or "0.0.0.0".
    pub host: String,
    /// TCP port (0 = ephemeral, reported by [`Server::local_addr`]).
    pub port: u16,
    /// Inference worker threads.
    pub workers: usize,
    /// Max rows coalesced into one forward pass.
    pub max_batch: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            host: "127.0.0.1".to_string(),
            port: 8080,
            workers: 2,
            max_batch: 32,
        }
    }
}

/// Default top-k when a predict request does not specify `k`.
const DEFAULT_K: usize = 5;
/// Request size guards.
const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;
/// Whole-request wall-clock budget. The per-read socket timeout resets
/// on every received byte, so without this a client dripping one byte
/// per interval would pin its handler thread forever (slow-loris). On a
/// kept-alive connection the budget restarts per request, so it also
/// bounds idle time between requests.
const REQUEST_DEADLINE: Duration = Duration::from_secs(30);
/// Upper bound on requests served over one kept-alive connection — a
/// single client cannot pin its handler thread forever.
const MAX_REQUESTS_PER_CONN: usize = 100;

/// Shared per-connection state.
struct ServeCtx {
    predictor: Predictor,
    metrics: Arc<ServeMetrics>,
    /// Pre-rendered `GET /healthz` body.
    health: String,
}

/// The accept loop plus its inference pool.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<ServeCtx>,
    stop: Arc<AtomicBool>,
}

/// Remote control for a running [`Server`] (tests, signal handlers).
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Ask the accept loop to exit (and poke it loose from `accept`).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the blocking accept with one throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Server {
    /// Load the pool from a checkpoint and bind the listening socket.
    pub fn bind(ckpt: Checkpoint, opts: &ServeOpts) -> Result<Server> {
        let metrics = Arc::new(ServeMetrics::new());
        let engine = InferenceEngine::new(ckpt)?;
        let meta = engine.meta();
        let health = Json::obj(vec![
            ("status", Json::str("ok")),
            ("algo", Json::str(meta.algo.name())),
            ("preset", Json::str(meta.preset.clone())),
            ("models", Json::num(engine.n_models() as f64)),
            ("p", Json::num(meta.p as f64)),
            ("d", Json::num(meta.d as f64)),
            ("out_dim", Json::num(meta.out_dim as f64)),
            ("workers", Json::num(opts.workers.max(1) as f64)),
            ("max_batch", Json::num(opts.max_batch.max(1) as f64)),
        ])
        .to_string_pretty(0);
        let predictor = Predictor::new(engine, opts.workers, opts.max_batch, metrics.clone());
        let listener = TcpListener::bind((opts.host.as_str(), opts.port))
            .with_context(|| format!("binding {}:{}", opts.host, opts.port))?;
        Ok(Server {
            listener,
            ctx: Arc::new(ServeCtx {
                predictor,
                metrics,
                health,
            }),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle {
            stop: self.stop.clone(),
            addr: self.local_addr()?,
        })
    }

    /// Serve until [`ServerHandle::stop`] is called. Each accepted
    /// connection gets its own detached handler thread.
    pub fn run(self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(mut conn) => {
                    let ctx = self.ctx.clone();
                    std::thread::spawn(move || handle_connection(&mut conn, &ctx));
                }
                Err(e) => {
                    // Persistent accept errors (e.g. fd exhaustion under
                    // a connection flood) would otherwise busy-spin this
                    // loop at 100% CPU; back off briefly before retrying.
                    crate::log_warn!("serve: accept error: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        Ok(())
    }
}

fn handle_connection(conn: &mut TcpStream, ctx: &ServeCtx) {
    // A client that stops *reading* would otherwise block write_all in
    // respond() forever once the response outgrows the send buffer.
    let _ = conn.set_write_timeout(Some(REQUEST_DEADLINE));
    // Bytes read past the end of one request (a client is allowed to
    // send the next request without waiting for the response) are
    // carried into the next read_request call instead of dropped.
    let mut carry = Vec::new();
    for served in 1..=MAX_REQUESTS_PER_CONN {
        let req = match read_request(conn, &mut carry) {
            Ok(Some(parts)) => parts,
            // Clean close (or idle timeout) between keep-alive requests.
            Ok(None) => return,
            Err(e) => {
                let _ = respond(
                    conn,
                    400,
                    "Bad Request",
                    CT_JSON,
                    &error_body(&format!("{e:#}")),
                    false,
                );
                return;
            }
        };
        let Request {
            method,
            path,
            query,
            body,
            keep_alive: client_keep_alive,
        } = req;
        let keep_alive = client_keep_alive && served < MAX_REQUESTS_PER_CONN;
        let t0 = Instant::now();
        let (status, reason, content_type, body) = route(ctx, &method, &path, &query, &body);
        if method == "POST" && path == "/predict" {
            ctx.metrics.record_request(t0.elapsed(), status == 200);
        }
        if respond(conn, status, reason, content_type, &body, keep_alive).is_err() || !keep_alive {
            return;
        }
    }
}

/// JSON content type (default for every endpoint).
const CT_JSON: &str = "application/json";
/// Prometheus text exposition content type.
const CT_PROM: &str = "text/plain; version=0.0.4; charset=utf-8";

fn route(
    ctx: &ServeCtx,
    method: &str,
    path: &str,
    query: &str,
    body: &[u8],
) -> (u16, &'static str, &'static str, String) {
    match (method, path) {
        ("GET", "/healthz") => (200, "OK", CT_JSON, ctx.health.clone()),
        // Plain `/metrics` stays JSON (the historical contract);
        // `?format=prometheus` serves the text exposition format,
        // appending the process-global training/sim registry so one
        // scrape covers both the serve window and run-level counters.
        ("GET", "/metrics") => {
            if query.split('&').any(|kv| kv == "format=prometheus") {
                let mut text = ctx.metrics.snapshot().to_prometheus();
                text.push_str(&crate::obs::metrics::global().render_prometheus());
                (200, "OK", CT_PROM, text)
            } else {
                (
                    200,
                    "OK",
                    CT_JSON,
                    ctx.metrics.snapshot().to_json().to_string_pretty(2),
                )
            }
        }
        // Parse failures are the client's fault (400); a predictor that
        // cannot answer a well-formed request is ours (500), so load
        // balancers and alerting see a server fault, not a bad request.
        ("POST", "/predict") => match parse_predict(ctx, body) {
            Err(e) => (400, "Bad Request", CT_JSON, error_body(&format!("{e:#}"))),
            Ok((x, k)) => match ctx.predictor.predict(x, k) {
                // Non-finite scores (diverged dense checkpoint, or
                // finite-but-extreme inputs overflowing the forward
                // pass) would serialize as the illegal JSON tokens
                // NaN/inf — report a server fault instead.
                Ok(topk) if topk.iter().all(|&(_, s)| s.is_finite()) => {
                    (200, "OK", CT_JSON, predict_body(&topk, k))
                }
                Ok(_) => (
                    500,
                    "Internal Server Error",
                    CT_JSON,
                    error_body("model produced non-finite scores"),
                ),
                Err(e) => (
                    500,
                    "Internal Server Error",
                    CT_JSON,
                    error_body(&format!("{e:#}")),
                ),
            },
        },
        (_, "/predict") | (_, "/healthz") | (_, "/metrics") => (
            405,
            "Method Not Allowed",
            CT_JSON,
            error_body("use POST /predict, GET /healthz, GET /metrics"),
        ),
        _ => (
            404,
            "Not Found",
            CT_JSON,
            error_body("unknown path (endpoints: /predict, /healthz, /metrics)"),
        ),
    }
}

/// Parse a predict request body into a dense feature row and a `k`.
fn parse_predict(ctx: &ServeCtx, body: &[u8]) -> Result<(Vec<f32>, usize)> {
    let text = std::str::from_utf8(body).context("request body is not utf-8")?;
    let req = Json::parse(text).context("request body is not valid JSON")?;
    let k = match req.get("k") {
        Some(j) => {
            let k = j.as_usize().context("'k' must be a non-negative integer")?;
            if k == 0 || k > ctx.predictor.engine().p() {
                bail!("'k' must be in 1..={}", ctx.predictor.engine().p());
            }
            k
        }
        None => DEFAULT_K.min(ctx.predictor.engine().p()),
    };
    let x = parse_features(ctx.predictor.engine(), &req)?;
    Ok((x, k))
}

/// Extract the dense feature row from `{"dense": …}` or `{"sparse": …}`.
fn parse_features(engine: &InferenceEngine, req: &Json) -> Result<Vec<f32>> {
    if let Some(dense) = req.get("dense") {
        let arr = dense.as_arr().context("'dense' must be an array")?;
        if arr.len() != engine.d() {
            bail!("'dense' has {} values, model expects d = {}", arr.len(), engine.d());
        }
        return arr
            .iter()
            .map(|j| {
                let v = j.as_f64().context("'dense' entries must be numbers")? as f32;
                if !v.is_finite() {
                    // Non-finite inputs would flow through to NaN/inf
                    // scores, which serialize as invalid JSON.
                    bail!("'dense' entries must be finite");
                }
                Ok(v)
            })
            .collect();
    }
    if let Some(sparse) = req.get("sparse") {
        let pairs = sparse.as_arr().context("'sparse' must be an array of [index, value]")?;
        let mut out = Vec::with_capacity(pairs.len());
        for pair in pairs {
            let pair = pair.as_arr().context("'sparse' entries must be [index, value]")?;
            if pair.len() != 2 {
                bail!("'sparse' entries must be [index, value] pairs");
            }
            let idx = pair[0].as_usize().context("sparse index must be a non-negative integer")?;
            let idx = u32::try_from(idx).context("sparse index exceeds u32")?;
            let val = pair[1].as_f64().context("sparse value must be a number")? as f32;
            if !val.is_finite() {
                bail!("sparse values must be finite");
            }
            out.push((idx, val));
        }
        return Ok(engine.hash_features(&out));
    }
    bail!("request must contain 'dense' ([f32; d]) or 'sparse' ([[index, value], …])")
}

fn predict_body(topk: &[ScoredClass], k: usize) -> String {
    let arr = Json::Arr(
        topk.iter()
            .map(|&(class, score)| {
                Json::obj(vec![
                    ("class", Json::num(class as f64)),
                    ("score", Json::num(score as f64)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![("k", Json::num(k as f64)), ("topk", arr)]).to_string_pretty(0)
}

fn error_body(message: &str) -> String {
    Json::obj(vec![("error", Json::str(message))]).to_string_pretty(0)
}

/// One parsed HTTP request plus its connection-reuse intent.
struct Request {
    method: String,
    path: String,
    /// Raw query string (without the `?`); empty when absent.
    query: String,
    body: Vec<u8>,
    /// The client asked for `Connection: keep-alive` (reuse is opt-in:
    /// absent or any other value means close after this response).
    keep_alive: bool,
}

/// Read one HTTP/1.1 request. `carry` holds bytes already read past
/// the previous request on this connection (in) and receives any bytes
/// read past this one (out), so back-to-back requests in one TCP
/// segment are served in sequence rather than dropped. `Ok(None)` is a
/// clean end of connection: the peer closed (or idled past the read
/// deadline) without sending a single byte of a next request.
fn read_request(conn: &mut TcpStream, carry: &mut Vec<u8>) -> Result<Option<Request>> {
    let deadline = Instant::now() + REQUEST_DEADLINE;
    let mut buf = std::mem::take(carry);
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        // RFC 7230 §3.5: ignore empty line(s) before the request-line —
        // clients may send a stray CRLF after a previous request's body.
        while buf.starts_with(b"\r\n") {
            buf.drain(..2);
        }
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            bail!("request headers exceed {MAX_HEADER_BYTES} bytes");
        }
        cap_read_timeout(conn, deadline)?;
        let n = match conn.read(&mut chunk) {
            Ok(n) => n,
            // An idle kept-alive connection timing out before the next
            // request starts is a clean close, not a bad request.
            Err(e)
                if buf.is_empty()
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                return Ok(None);
            }
            Err(e) => return Err(e).context("reading request"),
        };
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            bail!("connection closed before the request was complete");
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..header_end]).context("request head is not utf-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .context("empty request line")?
        .to_ascii_uppercase();
    let path = parts.next().context("request line has no path")?.to_string();
    // Routing matches on the bare path; the query string rides along
    // separately (e.g. `/metrics?format=prometheus`).
    let (path, query) = match path.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (path, String::new()),
    };

    let mut content_length = 0usize;
    let mut keep_alive = false;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .context("invalid Content-Length header")?;
            } else if name.eq_ignore_ascii_case("connection") {
                keep_alive = value.trim().eq_ignore_ascii_case("keep-alive");
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("request body exceeds {MAX_BODY_BYTES} bytes");
    }

    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        cap_read_timeout(conn, deadline)?;
        let n = conn.read(&mut chunk).context("reading request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    *carry = body.split_off(content_length);
    Ok(Some(Request {
        method,
        path,
        query,
        body,
        keep_alive,
    }))
}

fn respond(
    conn: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes())?;
    conn.write_all(body.as_bytes())?;
    conn.flush()
}

/// Shrink the socket read timeout to the time left before `deadline`,
/// so a blocking read cannot overshoot the whole-request budget (a
/// fixed per-read timeout would let a byte-dripping client hold the
/// thread for deadline + timeout).
fn cap_read_timeout(conn: &TcpStream, deadline: Instant) -> Result<()> {
    let remaining = deadline.saturating_duration_since(Instant::now());
    if remaining.is_zero() {
        bail!("request did not complete within {REQUEST_DEADLINE:?}");
    }
    let _ = conn.set_read_timeout(Some(remaining));
    Ok(())
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search() {
        assert_eq!(find_subslice(b"abcd\r\n\r\nrest", b"\r\n\r\n"), Some(4));
        assert_eq!(find_subslice(b"abc", b"\r\n\r\n"), None);
        assert_eq!(find_subslice(b"", b"\r\n\r\n"), None);
    }

    #[test]
    fn bodies_are_valid_json() {
        let err = error_body("boom \"quoted\"");
        assert_eq!(
            Json::parse(&err).unwrap().expect("error").unwrap().as_str().unwrap(),
            "boom \"quoted\""
        );
        let body = predict_body(&[(3, 1.5), (0, -0.25)], 2);
        let parsed = Json::parse(&body).unwrap();
        assert_eq!(parsed.expect("k").unwrap().as_usize().unwrap(), 2);
        let arr = parsed.expect("topk").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].expect("class").unwrap().as_usize().unwrap(), 3);
        assert_eq!(arr[0].expect("score").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn scores_roundtrip_json_bitwise() {
        // Shortest-representation f64 printing makes f32 scores exact
        // across serialize → parse — the property the bitwise serve
        // acceptance rests on.
        let values = [1.0f32, -0.1, 3.14159265, f32::MIN_POSITIVE, 1e30, -7.25e-12];
        let body = predict_body(
            &values.iter().enumerate().map(|(i, &v)| (i as u32, v)).collect::<Vec<_>>(),
            values.len(),
        );
        let parsed = Json::parse(&body).unwrap();
        let arr = parsed.expect("topk").unwrap().as_arr().unwrap();
        for (i, &want) in values.iter().enumerate() {
            let got = arr[i].expect("score").unwrap().as_f64().unwrap() as f32;
            assert_eq!(got.to_bits(), want.to_bits(), "value {want}");
        }
    }
}
