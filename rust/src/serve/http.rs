//! Dependency-light HTTP/1.1 serving front end over
//! [`std::net::TcpListener`] (the offline registry has no hyper/axum;
//! the protocol subset here — request line, headers, Content-Length
//! body, opt-in keep-alive — is what every load balancer and `curl`
//! speak).
//!
//! Connection reuse: a client that sends `Connection: keep-alive` gets
//! the connection held open and can issue further requests on it (each
//! request under the same `REQUEST_DEADLINE` as before), up to
//! `MAX_REQUESTS_PER_CONN` per connection — the final allowed
//! response answers `Connection: close` so well-behaved clients
//! re-connect instead of stalling. Requests without the header keep the
//! historical close-after-response behavior (clients written against
//! it frame responses by EOF), and malformed requests always close.
//! Back-to-back (pipelined) requests are buffered and served strictly
//! in sequence — bytes read past one request's body seed the next
//! request's parse instead of being dropped. A request declaring a body
//! larger than `ServeOpts::max_body_bytes` (CLI `--max-body-bytes`) is
//! refused with 413 before a byte of the body is read.
//!
//! Endpoints (routing is delegated to the
//! [`ControlPlane`](super::control::ControlPlane)):
//!
//! - `POST /predict` — body `{"dense": [f32; d], "k": 5}` or
//!   `{"sparse": [[index, value], …], "k": 5}`; responds
//!   `{"topk": [{"class": c, "score": s}, …], "k": k}`. Raw sparse
//!   inputs are feature-hashed with the checkpoint's stored seed —
//!   exactly the training-time map. Served by the current stable model
//!   version (or the canary, for its traffic share).
//! - `GET /healthz` — loaded checkpoint identity, generation, replica
//!   health, and a `ready` flag; 503 until the first model loads and
//!   while draining.
//! - `GET /metrics` — process-lifetime request count, p50/p99 latency,
//!   batch-size histogram ([`super::metrics`]) plus reload counters and
//!   per-version rows, as JSON; `GET /metrics?format=prometheus` serves
//!   the same data (plus the process-global [`crate::obs::metrics`]
//!   registry, which carries the per-generation and per-replica series)
//!   in the Prometheus text exposition format for scrapers.
//! - `POST /reload` — body `{"checkpoint": path}` or
//!   `{"checkpoint": base, "deltas": [d1, d2, …]}`; atomically hot-swaps
//!   the model (`?canary=<pct>` starts a watched canary rollout instead,
//!   `?window=<n>` overrides its decision window).
//! - `POST /quitquitquit` — begin graceful shutdown: stop accepting,
//!   drain in-flight requests, flush a final metrics snapshot (the
//!   test-friendly twin of SIGTERM).
//!
//! One OS thread per connection parses and responds; prediction work
//! is handed to the routed version's replica [`Predictor`] pools, which
//! coalesce concurrent requests into batched forward passes. JSON
//! number round-tripping is exact for `f32` scores
//! (shortest-representation printing), so a served top-k is bitwise the
//! offline decode's — before and after a hot swap.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::CanaryConfig;
use crate::util::json::Json;

use super::checkpoint::Checkpoint;
use super::control::ControlPlane;
use super::infer::{InferenceEngine, ScoredClass};

/// Server configuration (CLI: `fedmlh serve`).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Interface to bind, e.g. "127.0.0.1" or "0.0.0.0".
    pub host: String,
    /// TCP port (0 = ephemeral, reported by [`Server::local_addr`]).
    pub port: u16,
    /// Predictor replicas per model version (each with its own worker
    /// pool, sharing one copy of the weights).
    pub replicas: usize,
    /// Inference worker threads per replica.
    pub workers: usize,
    /// Max rows coalesced into one forward pass.
    pub max_batch: usize,
    /// Graceful-shutdown budget: how long to wait for in-flight
    /// requests after the accept loop stops.
    pub drain: Duration,
    /// Default canary rollout policy (per-reload `window=` overrides).
    pub canary: CanaryConfig,
    /// Largest accepted request body; a larger declared Content-Length
    /// is answered 413 without reading the body (CLI:
    /// `--max-body-bytes`).
    pub max_body_bytes: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            host: "127.0.0.1".to_string(),
            port: 8080,
            replicas: 1,
            workers: 2,
            max_batch: 32,
            drain: Duration::from_secs(5),
            canary: CanaryConfig::default(),
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
        }
    }
}

/// Default top-k when a predict request does not specify `k`.
const DEFAULT_K: usize = 5;
/// Request size guards. Headers have a fixed cap; the body cap is
/// configurable (`ServeOpts::max_body_bytes`) with this default.
const MAX_HEADER_BYTES: usize = 64 * 1024;
pub const DEFAULT_MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
/// Whole-request wall-clock budget. The per-read socket timeout resets
/// on every received byte, so without this a client dripping one byte
/// per interval would pin its handler thread forever (slow-loris). On a
/// kept-alive connection the budget restarts per request, so it also
/// bounds idle time between requests.
const REQUEST_DEADLINE: Duration = Duration::from_secs(30);
/// Upper bound on requests served over one kept-alive connection — a
/// single client cannot pin its handler thread forever.
const MAX_REQUESTS_PER_CONN: usize = 100;

/// Shared per-connection state.
struct ServeCtx {
    control: Arc<ControlPlane>,
    /// Requests currently being routed or responded to (drain gate).
    active: AtomicUsize,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

/// The accept loop plus its control plane.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<ServeCtx>,
    stop: Arc<AtomicBool>,
}

/// Remote control for a running [`Server`] (tests, signal handlers).
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Ask the accept loop to exit (and poke it loose from `accept`).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the blocking accept with one throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Server {
    /// Load the pool from a checkpoint and bind the listening socket.
    pub fn bind(ckpt: Checkpoint, opts: &ServeOpts) -> Result<Server> {
        let control = Arc::new(ControlPlane::with_initial(
            ckpt,
            "startup".to_string(),
            opts.clone(),
        )?);
        Server::bind_with(control)
    }

    /// Bind the listening socket for an existing control plane (the
    /// CLI path, which records the real checkpoint path as the source).
    pub fn bind_with(control: Arc<ControlPlane>) -> Result<Server> {
        let opts = control.opts().clone();
        let listener = TcpListener::bind((opts.host.as_str(), opts.port))
            .with_context(|| format!("binding {}:{}", opts.host, opts.port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        Ok(Server {
            listener,
            ctx: Arc::new(ServeCtx {
                control,
                active: AtomicUsize::new(0),
                stop: stop.clone(),
                addr,
            }),
            stop,
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle {
            stop: self.stop.clone(),
            addr: self.local_addr()?,
        })
    }

    /// The control plane behind this server (reload, drain, metrics).
    pub fn control(&self) -> Arc<ControlPlane> {
        self.ctx.control.clone()
    }

    /// Serve until [`ServerHandle::stop`] is called (or the control
    /// plane starts draining via `/quitquitquit` or a signal handler).
    /// Each accepted connection gets its own detached handler thread.
    /// When stopping through a drain, waits for in-flight requests up
    /// to the configured drain deadline and flushes a final metrics
    /// snapshot before returning.
    pub fn run(self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(mut conn) => {
                    let ctx = self.ctx.clone();
                    std::thread::spawn(move || handle_connection(&mut conn, &ctx));
                }
                Err(e) => {
                    // Persistent accept errors (e.g. fd exhaustion under
                    // a connection flood) would otherwise busy-spin this
                    // loop at 100% CPU; back off briefly before retrying.
                    crate::log_warn!("serve: accept error: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        if self.ctx.control.draining() {
            let deadline = Instant::now() + self.ctx.control.opts().drain;
            while self.ctx.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            let leftover = self.ctx.active.load(Ordering::SeqCst);
            if leftover > 0 {
                crate::log_warn!(
                    "serve: drain deadline reached with {leftover} request(s) in flight"
                );
            }
            self.ctx.control.flush_final_snapshot();
        }
        Ok(())
    }
}

fn handle_connection(conn: &mut TcpStream, ctx: &ServeCtx) {
    // A client that stops *reading* would otherwise block write_all in
    // respond() forever once the response outgrows the send buffer.
    let _ = conn.set_write_timeout(Some(REQUEST_DEADLINE));
    // Bytes read past the end of one request (a client is allowed to
    // send the next request without waiting for the response) are
    // carried into the next read_request call instead of dropped.
    let mut carry = Vec::new();
    let max_body = ctx.control.opts().max_body_bytes;
    for served in 1..=MAX_REQUESTS_PER_CONN {
        let req = match read_request(conn, &mut carry, max_body) {
            Ok(ReadOutcome::Request(parts)) => parts,
            // Clean close (or idle timeout) between keep-alive requests.
            Ok(ReadOutcome::Closed) => return,
            // An oversized declared body gets its own status — the body
            // is never read, and the connection closes so the unread
            // bytes can't be misparsed as a next request.
            Ok(ReadOutcome::BodyTooLarge { declared }) => {
                let _ = respond(
                    conn,
                    413,
                    reason(413),
                    CT_JSON,
                    &error_body(&format!(
                        "request body of {declared} bytes exceeds the {max_body}-byte cap \
                         (--max-body-bytes)"
                    )),
                    false,
                );
                return;
            }
            Err(e) => {
                let _ = respond(
                    conn,
                    400,
                    "Bad Request",
                    CT_JSON,
                    &error_body(&format!("{e:#}")),
                    false,
                );
                return;
            }
        };
        let Request {
            method,
            path,
            query,
            body,
            keep_alive: client_keep_alive,
        } = req;
        let t0 = Instant::now();
        ctx.active.fetch_add(1, Ordering::SeqCst);
        let (status, content_type, body) = route(ctx, &method, &path, &query, &body);
        if method == "POST" && path == "/predict" {
            ctx.control
                .totals()
                .record_request(t0.elapsed(), status == 200);
        }
        // A draining server answers the request it already accepted but
        // closes the connection, steering keep-alive clients away.
        let keep_alive =
            client_keep_alive && served < MAX_REQUESTS_PER_CONN && !ctx.control.draining();
        let sent = respond(conn, status, reason(status), content_type, &body, keep_alive);
        ctx.active.fetch_sub(1, Ordering::SeqCst);
        if sent.is_err() || !keep_alive {
            return;
        }
    }
}

/// JSON content type (default for every endpoint).
const CT_JSON: &str = "application/json";
/// Prometheus text exposition content type.
const CT_PROM: &str = "text/plain; version=0.0.4; charset=utf-8";

fn route(
    ctx: &ServeCtx,
    method: &str,
    path: &str,
    query: &str,
    body: &[u8],
) -> (u16, &'static str, String) {
    match (method, path) {
        ("GET", "/healthz") => {
            let (status, body) = ctx.control.health();
            (status, CT_JSON, body)
        }
        // Plain `/metrics` stays JSON (the historical contract);
        // `?format=prometheus` serves the text exposition format,
        // appending the process-global training/sim registry so one
        // scrape covers the serve window, the per-version/per-replica
        // series, and run-level counters.
        ("GET", "/metrics") => {
            if query_get(query, "format") == Some("prometheus") {
                (200, CT_PROM, ctx.control.metrics_prometheus())
            } else {
                (200, CT_JSON, ctx.control.metrics_json())
            }
        }
        ("POST", "/predict") => {
            let (status, body) = ctx.control.predict_http(body);
            (status, CT_JSON, body)
        }
        ("POST", "/reload") => {
            let (status, body) = ctx.control.handle_reload(query, body);
            (status, CT_JSON, body)
        }
        ("POST", "/quitquitquit") => {
            ctx.control.start_drain();
            ctx.stop.store(true, Ordering::SeqCst);
            // Poke the accept loop loose so run() proceeds to the drain
            // wait without needing another client connection.
            let _ = TcpStream::connect(ctx.addr);
            (
                200,
                CT_JSON,
                Json::obj(vec![("status", Json::str("draining"))]).to_string_pretty(0),
            )
        }
        (_, "/predict")
        | (_, "/healthz")
        | (_, "/metrics")
        | (_, "/reload")
        | (_, "/quitquitquit") => (
            405,
            CT_JSON,
            error_body(
                "use POST /predict, GET /healthz, GET /metrics, POST /reload, \
                 POST /quitquitquit",
            ),
        ),
        _ => (
            404,
            CT_JSON,
            error_body(
                "unknown path (endpoints: /predict, /healthz, /metrics, /reload, /quitquitquit)",
            ),
        ),
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Look up `key` in a raw query string (`a=1&b=2`); first match wins.
pub(crate) fn query_get<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// Parse a predict request body into a dense feature row and a `k`,
/// validated against `engine`'s dimensions.
pub(crate) fn parse_predict(engine: &InferenceEngine, body: &[u8]) -> Result<(Vec<f32>, usize)> {
    let text = std::str::from_utf8(body).context("request body is not utf-8")?;
    let req = Json::parse(text).context("request body is not valid JSON")?;
    let k = match req.get("k") {
        Some(j) => {
            let k = j.as_usize().context("'k' must be a non-negative integer")?;
            if k == 0 || k > engine.p() {
                bail!("'k' must be in 1..={}", engine.p());
            }
            k
        }
        None => DEFAULT_K.min(engine.p()),
    };
    let x = parse_features(engine, &req)?;
    Ok((x, k))
}

/// Extract the dense feature row from `{"dense": …}` or `{"sparse": …}`.
fn parse_features(engine: &InferenceEngine, req: &Json) -> Result<Vec<f32>> {
    if let Some(dense) = req.get("dense") {
        let arr = dense.as_arr().context("'dense' must be an array")?;
        if arr.len() != engine.d() {
            bail!("'dense' has {} values, model expects d = {}", arr.len(), engine.d());
        }
        return arr
            .iter()
            .map(|j| {
                let v = j.as_f64().context("'dense' entries must be numbers")? as f32;
                if !v.is_finite() {
                    // Non-finite inputs would flow through to NaN/inf
                    // scores, which serialize as invalid JSON.
                    bail!("'dense' entries must be finite");
                }
                Ok(v)
            })
            .collect();
    }
    if let Some(sparse) = req.get("sparse") {
        let pairs = sparse.as_arr().context("'sparse' must be an array of [index, value]")?;
        let mut out = Vec::with_capacity(pairs.len());
        for pair in pairs {
            let pair = pair.as_arr().context("'sparse' entries must be [index, value]")?;
            if pair.len() != 2 {
                bail!("'sparse' entries must be [index, value] pairs");
            }
            let idx = pair[0].as_usize().context("sparse index must be a non-negative integer")?;
            let idx = u32::try_from(idx).context("sparse index exceeds u32")?;
            let val = pair[1].as_f64().context("sparse value must be a number")? as f32;
            if !val.is_finite() {
                bail!("sparse values must be finite");
            }
            out.push((idx, val));
        }
        return Ok(engine.hash_features(&out));
    }
    bail!("request must contain 'dense' ([f32; d]) or 'sparse' ([[index, value], …])")
}

pub(crate) fn predict_body(topk: &[ScoredClass], k: usize) -> String {
    let arr = Json::Arr(
        topk.iter()
            .map(|&(class, score)| {
                Json::obj(vec![
                    ("class", Json::num(class as f64)),
                    ("score", Json::num(score as f64)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![("k", Json::num(k as f64)), ("topk", arr)]).to_string_pretty(0)
}

pub(crate) fn error_body(message: &str) -> String {
    Json::obj(vec![("error", Json::str(message))]).to_string_pretty(0)
}

/// One parsed HTTP request plus its connection-reuse intent.
struct Request {
    method: String,
    path: String,
    /// Raw query string (without the `?`); empty when absent.
    query: String,
    body: Vec<u8>,
    /// The client asked for `Connection: keep-alive` (reuse is opt-in:
    /// absent or any other value means close after this response).
    keep_alive: bool,
}

/// What reading one request produced. `Closed` is a clean end of
/// connection (the peer closed, or idled past the read deadline,
/// without sending a byte of a next request); `BodyTooLarge` is
/// separated from the error channel so the caller can answer 413
/// instead of the generic 400.
enum ReadOutcome {
    Request(Request),
    Closed,
    BodyTooLarge { declared: usize },
}

/// Read one HTTP/1.1 request under the whole-request deadline (headers
/// *and* body — `/reload` and `/predict` bodies alike cannot drip past
/// `REQUEST_DEADLINE`). `carry` holds bytes already read past the
/// previous request on this connection (in) and receives any bytes
/// read past this one (out), so back-to-back requests in one TCP
/// segment are served in sequence rather than dropped.
fn read_request(
    conn: &mut TcpStream,
    carry: &mut Vec<u8>,
    max_body: usize,
) -> Result<ReadOutcome> {
    let deadline = Instant::now() + REQUEST_DEADLINE;
    let mut buf = std::mem::take(carry);
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        // RFC 7230 §3.5: ignore empty line(s) before the request-line —
        // clients may send a stray CRLF after a previous request's body.
        while buf.starts_with(b"\r\n") {
            buf.drain(..2);
        }
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            bail!("request headers exceed {MAX_HEADER_BYTES} bytes");
        }
        cap_read_timeout(conn, deadline)?;
        let n = match conn.read(&mut chunk) {
            Ok(n) => n,
            // An idle kept-alive connection timing out before the next
            // request starts is a clean close, not a bad request.
            Err(e)
                if buf.is_empty()
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                return Ok(ReadOutcome::Closed);
            }
            Err(e) => return Err(e).context("reading request"),
        };
        if n == 0 {
            if buf.is_empty() {
                return Ok(ReadOutcome::Closed);
            }
            bail!("connection closed before the request was complete");
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..header_end]).context("request head is not utf-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .context("empty request line")?
        .to_ascii_uppercase();
    let path = parts.next().context("request line has no path")?.to_string();
    // Routing matches on the bare path; the query string rides along
    // separately (e.g. `/metrics?format=prometheus`).
    let (path, query) = match path.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (path, String::new()),
    };

    let mut content_length = 0usize;
    let mut keep_alive = false;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .context("invalid Content-Length header")?;
            } else if name.eq_ignore_ascii_case("connection") {
                keep_alive = value.trim().eq_ignore_ascii_case("keep-alive");
            }
        }
    }
    if content_length > max_body {
        return Ok(ReadOutcome::BodyTooLarge {
            declared: content_length,
        });
    }

    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        cap_read_timeout(conn, deadline)?;
        let n = conn.read(&mut chunk).context("reading request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    *carry = body.split_off(content_length);
    Ok(ReadOutcome::Request(Request {
        method,
        path,
        query,
        body,
        keep_alive,
    }))
}

fn respond(
    conn: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes())?;
    conn.write_all(body.as_bytes())?;
    conn.flush()
}

/// Shrink the socket read timeout to the time left before `deadline`,
/// so a blocking read cannot overshoot the whole-request budget (a
/// fixed per-read timeout would let a byte-dripping client hold the
/// thread for deadline + timeout).
fn cap_read_timeout(conn: &TcpStream, deadline: Instant) -> Result<()> {
    let remaining = deadline.saturating_duration_since(Instant::now());
    if remaining.is_zero() {
        bail!("request did not complete within {REQUEST_DEADLINE:?}");
    }
    let _ = conn.set_read_timeout(Some(remaining));
    Ok(())
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search() {
        assert_eq!(find_subslice(b"abcd\r\n\r\nrest", b"\r\n\r\n"), Some(4));
        assert_eq!(find_subslice(b"abc", b"\r\n\r\n"), None);
        assert_eq!(find_subslice(b"", b"\r\n\r\n"), None);
    }

    #[test]
    fn query_lookup() {
        assert_eq!(query_get("format=prometheus", "format"), Some("prometheus"));
        assert_eq!(query_get("canary=10&window=5", "window"), Some("5"));
        assert_eq!(query_get("canary=10&window=5", "canary"), Some("10"));
        assert_eq!(query_get("", "format"), None);
        assert_eq!(query_get("format", "format"), None, "bare key has no value");
    }

    #[test]
    fn bodies_are_valid_json() {
        let err = error_body("boom \"quoted\"");
        assert_eq!(
            Json::parse(&err).unwrap().expect("error").unwrap().as_str().unwrap(),
            "boom \"quoted\""
        );
        let body = predict_body(&[(3, 1.5), (0, -0.25)], 2);
        let parsed = Json::parse(&body).unwrap();
        assert_eq!(parsed.expect("k").unwrap().as_usize().unwrap(), 2);
        let arr = parsed.expect("topk").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].expect("class").unwrap().as_usize().unwrap(), 3);
        assert_eq!(arr[0].expect("score").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn scores_roundtrip_json_bitwise() {
        // Shortest-representation f64 printing makes f32 scores exact
        // across serialize → parse — the property the bitwise serve
        // acceptance rests on.
        let values = [1.0f32, -0.1, 3.14159265, f32::MIN_POSITIVE, 1e30, -7.25e-12];
        let body = predict_body(
            &values.iter().enumerate().map(|(i, &v)| (i as u32, v)).collect::<Vec<_>>(),
            values.len(),
        );
        let parsed = Json::parse(&body).unwrap();
        let arr = parsed.expect("topk").unwrap().as_arr().unwrap();
        for (i, &want) in values.iter().enumerate() {
            let got = arr[i].expect("score").unwrap().as_f64().unwrap() as f32;
            assert_eq!(got.to_bits(), want.to_bits(), "value {want}");
        }
    }
}
