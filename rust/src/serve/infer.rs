//! The serving inference path: checkpoint → class scores → top-k.
//!
//! [`InferenceEngine`] is the pure computation — feature-hash a raw
//! sparse input ([`FeatureHasher`], same derived seed as training),
//! run [`mlp::forward_into`] across all R sub-models, count-sketch-
//! decode ([`sketch_decode`]) to per-class scores, select top-k. Every
//! row is independent in all three stages, so batching N requests into
//! one forward pass is **bitwise identical** to N single-row passes —
//! the property the micro-batcher relies on and
//! `tests/serve_roundtrip.rs` pins against the offline eval decode.
//! Each inference worker owns a persistent [`ScoreScratch`] (hidden
//! activations, CSR conversion, the flat `[R, rows, B]` logit slab),
//! so the steady-state forward path allocates nothing per batch.
//!
//! [`Predictor`] adds the concurrency layer, reusing the round
//! engine's fan-out idiom (workers pulling from a shared queue): HTTP
//! handler threads enqueue single-row jobs; a pool of `workers`
//! inference threads drains up to `max_batch` queued jobs at a time
//! and answers them all with one coalesced forward pass. Under
//! concurrent load the queue depth — not a timer — sets the batch
//! size, so an idle server still answers in one row's latency.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::config::Algo;
use crate::data::feature_hash::FeatureHasher;
use crate::eval::decode::sketch_decode;
use crate::eval::topk::top_k;
use crate::hashing::label_hash::LabelHasher;
use crate::model::mlp;
use crate::model::params::ModelParams;

use super::checkpoint::{Checkpoint, CheckpointMeta};
use super::metrics::ServeMetrics;

/// One predicted class with its decoded score.
pub type ScoredClass = (u32, f32);

/// Count-sketch decode state (absent for fedavg checkpoints, whose
/// logits are already class scores).
struct Decoder {
    /// `[R, p]` class→bucket matrix, row-major.
    idx: Vec<i32>,
    r: usize,
    b: usize,
}

/// The stateless (after construction) serving computation.
pub struct InferenceEngine {
    meta: CheckpointMeta,
    models: Vec<ModelParams>,
    decoder: Option<Decoder>,
    feature: FeatureHasher,
}

impl InferenceEngine {
    /// Build the engine from a loaded checkpoint, reconstructing the
    /// label hash tables and feature-hash function from the stored
    /// derived seeds.
    pub fn new(ckpt: Checkpoint) -> Result<InferenceEngine> {
        let meta = ckpt.meta.clone();
        let decoder = match meta.algo {
            Algo::FedAvg => None,
            Algo::FedMlh => {
                let hasher =
                    LabelHasher::new(meta.hash_seed, ckpt.r(), meta.p, meta.out_dim);
                Some(Decoder {
                    idx: hasher.index_matrix_i32(),
                    r: ckpt.r(),
                    b: meta.out_dim,
                })
            }
        };
        let feature = FeatureHasher::new(meta.feat_seed, meta.d);
        Ok(InferenceEngine {
            meta,
            models: ckpt.models,
            decoder,
            feature,
        })
    }

    pub fn meta(&self) -> &CheckpointMeta {
        &self.meta
    }

    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    /// Feature-hashed input dimension each row must have.
    pub fn d(&self) -> usize {
        self.meta.d
    }

    /// Number of classes in the decoded score vector.
    pub fn p(&self) -> usize {
        self.meta.p
    }

    /// Hash a raw sparse `(index, value)` input into a dense `d`-row —
    /// the same map training applied to its inputs.
    pub fn hash_features(&self, sparse: &[(u32, f32)]) -> Vec<f32> {
        self.feature.hash(sparse)
    }

    /// Class scores for a flat `[rows, d]` batch → flat `[rows, p]`.
    /// Convenience form of [`Self::scores_with`] that pays one scratch
    /// allocation; hot paths (the [`Predictor`] workers) hold a
    /// [`ScoreScratch`] and call `scores_with` directly.
    pub fn scores(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let mut scratch = ScoreScratch::new();
        self.scores_with(x, rows, &mut scratch)
    }

    /// Class scores for a flat `[rows, d]` batch → flat `[rows, p]`,
    /// reusing the caller's scratch: all R sub-model forwards write
    /// into one persistent logit slab via [`mlp::forward_into`] instead
    /// of allocating `h1`/`h2`/`z` per sub-model per call.
    pub fn scores_with(
        &self,
        x: &[f32],
        rows: usize,
        scratch: &mut ScoreScratch,
    ) -> Result<Vec<f32>> {
        if x.len() != rows * self.meta.d {
            bail!(
                "input is {} values, expected rows {} × d {}",
                x.len(),
                rows,
                self.meta.d
            );
        }
        if rows == 0 {
            return Ok(Vec::new());
        }
        match &self.decoder {
            Some(dec) => {
                let slab = dec.r * rows * dec.b;
                if scratch.logits.len() < slab {
                    scratch.logits.resize(slab, 0.0);
                }
                let flat = &mut scratch.logits[..slab];
                // One input conversion shared by all R sub-model
                // forwards — not R scans of the same dense batch.
                mlp::forward_models_into(
                    &self.models,
                    x,
                    rows,
                    &mut scratch.infer,
                    flat.chunks_exact_mut(rows * dec.b),
                );
                Ok(sketch_decode(flat, &dec.idx, dec.r, rows, dec.b, self.meta.p))
            }
            None => {
                let m = &self.models[0];
                let mut z = vec![0.0f32; rows * m.out];
                mlp::forward_into(m, x, rows, &mut scratch.infer, &mut z);
                Ok(z)
            }
        }
    }

    /// Top-`k` classes per row, best first, with their scores.
    pub fn predict_topk(
        &self,
        x: &[f32],
        rows: usize,
        k: usize,
    ) -> Result<Vec<Vec<ScoredClass>>> {
        let scores = self.scores(x, rows)?;
        let p = self.meta.p;
        Ok((0..rows)
            .map(|n| {
                let row = &scores[n * p..(n + 1) * p];
                top_k(row, k)
                    .into_iter()
                    .map(|i| (i as u32, row[i]))
                    .collect()
            })
            .collect())
    }
}

/// Per-worker reusable buffers for [`InferenceEngine::scores_with`]:
/// the MLP forward scratch plus the flat `[R, rows, B]` logit slab the
/// R sub-model forwards write into. Grows to the largest coalesced
/// batch seen, then the forward path stops allocating — the returned
/// score vector itself is the one remaining per-call allocation (in
/// both the decode and the passthrough branch).
#[derive(Default)]
pub struct ScoreScratch {
    infer: mlp::InferScratch,
    logits: Vec<f32>,
}

impl ScoreScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One queued prediction request.
struct Job {
    /// Dense feature row, length `d`.
    x: Vec<f32>,
    k: usize,
    done: mpsc::Sender<Result<Vec<ScoredClass>>>,
}

struct Shared {
    engine: Arc<InferenceEngine>,
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    max_batch: usize,
    metrics: Arc<ServeMetrics>,
}

/// Micro-batching worker pool over an [`InferenceEngine`].
pub struct Predictor {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Predictor {
    /// Spawn `workers` inference threads (min 1) that coalesce up to
    /// `max_batch` queued requests (min 1) per forward pass. The
    /// engine is shared (`Arc`) so multiple replicas can serve the
    /// same weights without duplicating them.
    pub fn new(
        engine: Arc<InferenceEngine>,
        workers: usize,
        max_batch: usize,
        metrics: Arc<ServeMetrics>,
    ) -> Predictor {
        let shared = Arc::new(Shared {
            engine,
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            max_batch: max_batch.max(1),
            metrics,
        });
        let workers = (0..workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Predictor { shared, workers }
    }

    pub fn engine(&self) -> &InferenceEngine {
        &self.shared.engine
    }

    /// Block until the pool answers: enqueue one dense row, wake a
    /// worker, wait for the coalesced forward pass that covers it.
    pub fn predict(&self, x: Vec<f32>, k: usize) -> Result<Vec<ScoredClass>> {
        let d = self.shared.engine.d();
        if x.len() != d {
            bail!("input has {} features, model expects {d}", x.len());
        }
        if self.shared.shutdown.load(Ordering::SeqCst) {
            bail!("predictor is shut down");
        }
        let (done, rx) = mpsc::channel();
        {
            let mut queue = self.shared.queue.lock().unwrap();
            queue.push_back(Job { x, k, done });
        }
        self.shared.available.notify_one();
        rx.recv()
            .map_err(|_| anyhow!("inference worker dropped the request"))?
    }
}

impl Drop for Predictor {
    /// Graceful shutdown: workers drain every queued job, then exit.
    fn drop(&mut self) {
        {
            // Store under the queue lock: a worker that saw `false` is
            // already inside `wait()` by the time we can acquire the
            // lock, so the notify below cannot be lost.
            let _queue = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.available.notify_all();
        for handle in std::mem::take(&mut self.workers) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let d = shared.engine.d();
    let p = shared.engine.p();
    // Persistent per-worker buffers: the coalesced input batch and the
    // engine's forward scratch both reach a steady size and stay there.
    let mut scratch = ScoreScratch::new();
    let mut x: Vec<f32> = Vec::new();
    loop {
        // Wait for work; exit only once shut down *and* drained.
        let jobs: Vec<Job> = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if !queue.is_empty() {
                    let take = queue.len().min(shared.max_batch);
                    break queue.drain(..take).collect();
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                queue = shared.available.wait(queue).unwrap();
            }
        };

        let rows = jobs.len();
        shared.metrics.record_batch(rows);
        x.clear();
        x.reserve(rows * d);
        for job in &jobs {
            x.extend_from_slice(&job.x);
        }
        match shared.engine.scores_with(&x, rows, &mut scratch) {
            Ok(scores) => {
                for (row, job) in jobs.iter().enumerate() {
                    let slice = &scores[row * p..(row + 1) * p];
                    let picked = top_k(slice, job.k)
                        .into_iter()
                        .map(|i| (i as u32, slice[i]))
                        .collect();
                    // A receiver that gave up is not an error here.
                    let _ = job.done.send(Ok(picked));
                }
            }
            Err(e) => {
                for job in &jobs {
                    let _ = job.done.send(Err(anyhow!("inference failed: {e}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::util::rng::Rng;

    fn tiny_engine(algo: Algo) -> InferenceEngine {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let (n_models, out) = match algo {
            Algo::FedAvg => (1, cfg.preset.p),
            Algo::FedMlh => (cfg.r(), cfg.b()),
        };
        let models: Vec<ModelParams> = (0..n_models)
            .map(|j| ModelParams::init(cfg.preset.d, cfg.preset.hidden, out, 10 + j as u64))
            .collect();
        let ckpt =
            Checkpoint::from_run(&cfg, algo, cfg.preset.d, cfg.preset.p, models).unwrap();
        InferenceEngine::new(ckpt).unwrap()
    }

    fn random_rows(d: usize, rows: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn batched_scores_equal_single_row_scores() {
        for algo in [Algo::FedMlh, Algo::FedAvg] {
            let engine = tiny_engine(algo);
            let (d, p) = (engine.d(), engine.p());
            let x = random_rows(d, 4, 3);
            let batched = engine.scores(&x, 4).unwrap();
            for row in 0..4 {
                let single = engine.scores(&x[row * d..(row + 1) * d], 1).unwrap();
                assert_eq!(
                    &batched[row * p..(row + 1) * p],
                    &single[..],
                    "{} row {row}",
                    algo.name()
                );
            }
        }
    }

    #[test]
    fn fedmlh_scores_match_scheme_decode() {
        // The serving decode must be the same math the offline eval
        // runs: forward every sub-model, count-sketch mean over the
        // scheme's index matrix.
        let engine = tiny_engine(Algo::FedMlh);
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let scheme =
            crate::algo::fedmlh::FedMlhScheme::new(cfg.seed, cfg.r(), cfg.preset.p, cfg.b());
        let x = random_rows(engine.d(), 2, 9);
        let logits: Vec<f32> = engine
            .models
            .iter()
            .flat_map(|m| mlp::forward(m, &x, 2))
            .collect();
        let want = sketch_decode(&logits, scheme.index_matrix(), cfg.r(), 2, cfg.b(), cfg.preset.p);
        assert_eq!(engine.scores(&x, 2).unwrap(), want);
    }

    #[test]
    fn scores_with_reused_scratch_matches_fresh() {
        // The worker path (one ScoreScratch across many batches of
        // varying size) must be bitwise identical to fresh-scratch
        // calls, including after the slab has grown past the need.
        for algo in [Algo::FedMlh, Algo::FedAvg] {
            let engine = tiny_engine(algo);
            let d = engine.d();
            let mut scratch = ScoreScratch::new();
            for (seed, rows) in [(21u64, 5usize), (22, 1), (23, 3)] {
                let x = random_rows(d, rows, seed);
                let got = engine.scores_with(&x, rows, &mut scratch).unwrap();
                let want = engine.scores(&x, rows).unwrap();
                assert_eq!(got, want, "{} rows {rows}", algo.name());
            }
        }
    }

    #[test]
    fn topk_is_sorted_and_sized() {
        let engine = tiny_engine(Algo::FedMlh);
        let x = random_rows(engine.d(), 3, 5);
        let out = engine.predict_topk(&x, 3, 5).unwrap();
        assert_eq!(out.len(), 3);
        for row in &out {
            assert_eq!(row.len(), 5);
            for pair in row.windows(2) {
                assert!(pair[0].1 >= pair[1].1, "descending scores");
            }
            for &(c, _) in row {
                assert!((c as usize) < engine.p());
            }
        }
    }

    #[test]
    fn shape_errors_are_rejected() {
        let engine = tiny_engine(Algo::FedMlh);
        assert!(engine.scores(&[0.0; 7], 1).is_err());
        assert!(engine.scores(&[], 0).unwrap().is_empty());
    }

    #[test]
    fn predictor_answers_like_the_engine() {
        let engine = tiny_engine(Algo::FedMlh);
        let x = random_rows(engine.d(), 1, 11);
        let want = engine.predict_topk(&x, 1, 5).unwrap().remove(0);
        let metrics = Arc::new(ServeMetrics::new());
        let predictor =
            Predictor::new(Arc::new(tiny_engine(Algo::FedMlh)), 2, 8, metrics.clone());
        for _ in 0..3 {
            let got = predictor.predict(x.clone(), 5).unwrap();
            assert_eq!(got, want);
        }
        let snap = metrics.snapshot();
        assert!(snap.batches >= 1);
        assert_eq!(snap.batched_rows, 3);
        // wrong input width is rejected before it reaches the queue
        assert!(predictor.predict(vec![0.0; 3], 5).is_err());
    }

    #[test]
    fn predictor_coalesces_under_concurrency() {
        let metrics = Arc::new(ServeMetrics::new());
        let predictor = Arc::new(Predictor::new(
            Arc::new(tiny_engine(Algo::FedMlh)),
            1,
            32,
            metrics.clone(),
        ));
        let d = predictor.engine().d();
        let n_requests = 24;
        let mut threads = Vec::new();
        for t in 0..n_requests {
            let predictor = predictor.clone();
            let x = random_rows(d, 1, 100 + t as u64);
            threads.push(std::thread::spawn(move || {
                predictor.predict(x, 3).unwrap().len()
            }));
        }
        for t in threads {
            assert_eq!(t.join().unwrap(), 3);
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.batched_rows, n_requests as u64);
        // with a single worker and concurrent senders, at least one
        // forward pass must have covered multiple requests... unless
        // the scheduler fully serialized us, so only assert the row
        // accounting and that batches never exceed requests.
        assert!(snap.batches >= 1 && snap.batches <= n_requests as u64);
    }

    #[test]
    fn sparse_hashing_matches_training_feature_map() {
        let engine = tiny_engine(Algo::FedMlh);
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let reference = FeatureHasher::new(
            crate::data::synth::feature_hash_seed(cfg.seed),
            cfg.preset.d,
        );
        let sparse = [(3u32, 1.5f32), (100, -0.25), (77, 2.0)];
        assert_eq!(engine.hash_features(&sparse), reference.hash(&sparse));
    }
}
