//! The serving subsystem: persist a trained run, answer predictions,
//! and keep answering them across model updates.
//!
//! Training compresses the label space so the model is small enough to
//! ship and run everywhere; this module is where that pays off
//! (deployment cost, not just training cost — the communication-
//! efficiency surveys' point). Layers:
//!
//! - [`checkpoint`] — the versioned `.fmlh` binary format: R trained
//!   sub-models (dense `f32` or wire-codec q8, ~4× smaller), the
//!   derived hash seeds that reconstruct the [`crate::hashing`] tables
//!   bit-identically, and the experiment metadata. Written by
//!   `fedmlh run --save`, checksummed, corruption-rejecting. Plus
//!   **delta checkpoints** (`FMLD`, `fedmlh run --save-delta`): what
//!   changed vs a base checkpoint a device already holds, chain-applied
//!   at load (`fedmlh serve --delta d1,d2`) — downlink-compressed
//!   checkpoint *delivery*, reusing the training wire's delta framing.
//! - [`infer`] — [`infer::InferenceEngine`] (feature-hash → R-model
//!   forward → count-sketch decode → top-k; batching-invariant) and
//!   [`infer::Predictor`], a worker pool that coalesces concurrent
//!   requests into one forward pass (micro-batching). The engine is
//!   `Arc`-shared so any number of replicas serve one copy of the
//!   weights.
//! - [`reload`] + [`canary`] + [`control`] — the control plane.
//!   [`reload::ModelVersion`] wraps one decoded checkpoint (full or
//!   delta chain) behind `--replicas` health-tracked predictor pools;
//!   [`control::ControlPlane`] hot-swaps versions atomically
//!   (`POST /reload`) with zero dropped requests, runs
//!   [`canary::CanaryRollout`]s (`?canary=<pct>`) that auto-promote or
//!   auto-roll-back on error-rate/latency evidence, and drives graceful
//!   drain on shutdown.
//! - [`http`] — `fedmlh serve`: a `std::net` HTTP front end exposing
//!   `POST /predict`, `GET /healthz`, `GET /metrics`, `POST /reload`,
//!   and `POST /quitquitquit` ([`metrics`]: request count, p50/p99
//!   latency, batch histogram). `/metrics` answers JSON by default (the
//!   historical contract) and Prometheus text exposition at
//!   `?format=prometheus`, which also folds in the process-global
//!   [`crate::obs::metrics`] registry (per-generation / per-replica
//!   series, reload and rollout counters).
//!
//! End to end: `fedmlh run --preset eurlex --save m.fmlh` then
//! `fedmlh serve --checkpoint m.fmlh --port 8080 --workers 4
//! --replicas 2`, then `curl -XPOST :8080/reload -d
//! '{"checkpoint":"m.fmlh","deltas":["d1.fmld"]}'` to pick up new
//! weights without dropping a request.

pub mod canary;
pub mod checkpoint;
pub mod control;
pub mod http;
pub mod infer;
pub mod metrics;
pub mod reload;

pub use canary::{CanaryRollout, Verdict};
pub use checkpoint::{Checkpoint, CheckpointCodec, CheckpointMeta, DeltaCheckpoint, DeltaCodec};
pub use control::{ControlPlane, ReloadOutcome};
pub use http::{Server, ServeOpts, ServerHandle};
pub use infer::{InferenceEngine, Predictor};
pub use metrics::{MetricsSnapshot, ServeMetrics};
pub use reload::{ModelVersion, ReloadSpec};
