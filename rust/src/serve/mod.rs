//! The serving subsystem: persist a trained run, answer predictions.
//!
//! Training compresses the label space so the model is small enough to
//! ship and run everywhere; this module is where that pays off
//! (deployment cost, not just training cost — the communication-
//! efficiency surveys' point). Three layers:
//!
//! - [`checkpoint`] — the versioned `.fmlh` binary format: R trained
//!   sub-models (dense `f32` or wire-codec q8, ~4× smaller), the
//!   derived hash seeds that reconstruct the [`crate::hashing`] tables
//!   bit-identically, and the experiment metadata. Written by
//!   `fedmlh run --save`, checksummed, corruption-rejecting. Plus
//!   **delta checkpoints** (`FMLD`, `fedmlh run --save-delta`): what
//!   changed vs a base checkpoint a device already holds, chain-applied
//!   at load (`fedmlh serve --delta d1,d2`) — downlink-compressed
//!   checkpoint *delivery*, reusing the training wire's delta framing.
//! - [`infer`] — [`infer::InferenceEngine`] (feature-hash → R-model
//!   forward → count-sketch decode → top-k; batching-invariant) and
//!   [`infer::Predictor`], a worker pool that coalesces concurrent
//!   requests into one forward pass (micro-batching).
//! - [`http`] — `fedmlh serve`: a `std::net` HTTP front end exposing
//!   `POST /predict`, `GET /healthz` and `GET /metrics`
//!   ([`metrics`]: request count, p50/p99 latency, batch histogram).
//!   `/metrics` answers JSON by default (the historical contract) and
//!   Prometheus text exposition at `?format=prometheus`, which also
//!   folds in the process-global [`crate::obs::metrics`] registry.
//!
//! End to end: `fedmlh run --preset eurlex --save m.fmlh` then
//! `fedmlh serve --checkpoint m.fmlh --port 8080 --workers 4`.

pub mod checkpoint;
pub mod http;
pub mod infer;
pub mod metrics;

pub use checkpoint::{Checkpoint, CheckpointCodec, CheckpointMeta, DeltaCheckpoint, DeltaCodec};
pub use http::{Server, ServeOpts, ServerHandle};
pub use infer::{InferenceEngine, Predictor};
pub use metrics::{MetricsSnapshot, ServeMetrics};
