//! The serving control plane: atomic hot reload, a multi-replica
//! routing state, and canary rollout with auto-promote/rollback.
//!
//! [`ControlPlane`] owns everything mutable about *which model is
//! serving*: a [`RouterState`] (the stable [`ModelVersion`] plus an
//! optional in-flight [`CanaryRollout`]) behind one `RwLock`. Request
//! threads take the read lock only long enough to clone two `Arc`s;
//! reloads take the write lock only for the pointer swap. Everything
//! expensive — reading the checkpoint (full `.fmlh` or delta chain),
//! decoding it into an [`super::InferenceEngine`], spawning replica
//! predictor pools — happens *before* the lock, so a reload never
//! stalls the predict path and a failed reload leaves the previous
//! version serving untouched. In-flight requests hold their version's
//! `Arc`, so an old version's worker pools stay alive until the last
//! request on them answers: zero dropped requests across a swap.
//!
//! `POST /reload` semantics (body `{"checkpoint": …, "deltas": […]}`):
//!
//! * no `canary` query param (or `canary=100`) — immediate atomic swap.
//! * `canary=<1..=99>` — the new version serves that share of traffic
//!   while [`CanaryRollout`] watches its error rate and p99 latency;
//!   it is auto-promoted after a clean window and auto-rolled-back the
//!   moment the error budget is exhausted (`window=<n>` overrides the
//!   configured window per reload). A reload during an active canary
//!   supersedes it.
//!
//! Observability: reload outcomes, rollout transitions, and the
//! serving generation are mirrored into the process-global
//! [`crate::obs::metrics`] registry (`fedmlh_serve_reloads_total`,
//! `fedmlh_serve_rollout_transitions_total`, `fedmlh_serve_generation`,
//! plus per-generation/per-replica request counters registered by
//! [`ModelVersion`]); each transition is also a wall-clock trace
//! instant and every reload a traced span. The control plane's own
//! atomic counters — not the global registry — back the JSON
//! `/metrics` response, because the global registry is shared by every
//! server in the process (e.g. across `cargo test` servers) while the
//! JSON contract is per-instance.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::CanaryConfig;
use crate::obs::metrics::{global, Counter, Gauge};
use crate::obs::trace::{wall_instant, wall_span};
use crate::util::json::Json;

use super::canary::{CanaryRollout, Verdict};
use super::checkpoint::Checkpoint;
use super::http::{error_body, parse_predict, predict_body, query_get, ServeOpts};
use super::metrics::ServeMetrics;
use super::reload::{ModelVersion, ReloadSpec};

/// Wall-clock trace lane for control-plane spans and instants.
const CONTROL_TID: u64 = 90;

/// What is currently serving: the promoted version plus (at most) one
/// in-flight canary. Swapped wholesale under the write lock.
#[derive(Default)]
struct RouterState {
    stable: Option<Arc<ModelVersion>>,
    canary: Option<Arc<CanaryRollout>>,
}

/// The outcome of a successful `POST /reload`.
#[derive(Clone, Debug, PartialEq)]
pub enum ReloadOutcome {
    /// The new version was swapped in immediately.
    Swapped { generation: u64 },
    /// The new version is serving `pct`% of traffic under watch.
    CanaryStarted {
        generation: u64,
        pct: u64,
        window: usize,
    },
}

impl ReloadOutcome {
    pub fn to_json(&self) -> Json {
        match self {
            ReloadOutcome::Swapped { generation } => Json::obj(vec![
                ("status", Json::str("swapped")),
                ("generation", Json::num(*generation as f64)),
            ]),
            ReloadOutcome::CanaryStarted {
                generation,
                pct,
                window,
            } => Json::obj(vec![
                ("status", Json::str("canary")),
                ("generation", Json::num(*generation as f64)),
                ("pct", Json::num(*pct as f64)),
                ("window", Json::num(*window as f64)),
            ]),
        }
    }
}

/// Supervisor for the serving path: version routing, hot reload,
/// canary decisions, draining, and the `/metrics` aggregation.
pub struct ControlPlane {
    opts: ServeOpts,
    state: RwLock<RouterState>,
    /// Monotone generation allocator (1 = the startup checkpoint).
    next_gen: AtomicU64,
    /// Process-lifetime serve stats: every `/predict` request and every
    /// coalesced batch from every version land here, so the historical
    /// JSON `/metrics` contract (monotone requests/errors/batches)
    /// holds across reloads.
    totals: Arc<ServeMetrics>,
    draining: AtomicBool,
    // Per-instance reload accounting (authoritative for JSON).
    swapped: AtomicU64,
    canary_started: AtomicU64,
    promoted: AtomicU64,
    rolled_back: AtomicU64,
    rejected: AtomicU64,
    superseded: AtomicU64,
    // Global-registry mirrors (Prometheus).
    obs_swapped: Arc<Counter>,
    obs_canary: Arc<Counter>,
    obs_rejected: Arc<Counter>,
    obs_generation: Arc<Gauge>,
}

impl ControlPlane {
    /// An empty (not-ready) control plane: `/healthz` answers 503 and
    /// `/predict` 503 until the first model is installed.
    pub fn new(opts: ServeOpts) -> Result<ControlPlane> {
        opts.canary.validate()?;
        let reg = global();
        let reload_counter = |result: &str| {
            reg.counter_with(
                "fedmlh_serve_reloads_total",
                "Model reload operations, by outcome.",
                &[("result", result)],
            )
        };
        let obs_swapped = reload_counter("swapped");
        let obs_canary = reload_counter("canary");
        let obs_rejected = reload_counter("rejected");
        // Pre-register the transition variants a scrape should always
        // see (a zero is informative; an absent family is not).
        for to in ["canary", "promoted", "rolled_back", "swapped"] {
            transition_counter(to);
        }
        let obs_generation = reg.gauge(
            "fedmlh_serve_generation",
            "Model generation currently serving stable traffic.",
        );
        obs_generation.set(0.0);
        Ok(ControlPlane {
            opts,
            state: RwLock::new(RouterState::default()),
            next_gen: AtomicU64::new(0),
            totals: Arc::new(ServeMetrics::new()),
            draining: AtomicBool::new(false),
            swapped: AtomicU64::new(0),
            canary_started: AtomicU64::new(0),
            promoted: AtomicU64::new(0),
            rolled_back: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            superseded: AtomicU64::new(0),
            obs_swapped,
            obs_canary,
            obs_rejected,
            obs_generation,
        })
    }

    /// Control plane pre-loaded with a startup checkpoint (generation
    /// 1): the `fedmlh serve --checkpoint` path.
    pub fn with_initial(ckpt: Checkpoint, source: String, opts: ServeOpts) -> Result<ControlPlane> {
        let control = ControlPlane::new(opts)?;
        let generation = control.next_gen.fetch_add(1, Ordering::SeqCst) + 1;
        let version = Arc::new(ModelVersion::build(
            ckpt,
            generation,
            source,
            &control.opts,
            &control.totals,
        )?);
        control.install_stable(version);
        Ok(control)
    }

    pub fn opts(&self) -> &ServeOpts {
        &self.opts
    }

    /// Process-lifetime serve stats (shared with the HTTP layer's
    /// request accounting and every replica's batch accounting).
    pub fn totals(&self) -> &Arc<ServeMetrics> {
        &self.totals
    }

    /// Whether a first model has been fully loaded.
    pub fn ready(&self) -> bool {
        self.state.read().unwrap().stable.is_some()
    }

    /// Generation serving stable traffic (0 before the first load).
    pub fn generation(&self) -> u64 {
        self.state
            .read()
            .unwrap()
            .stable
            .as_ref()
            .map_or(0, |v| v.generation)
    }

    /// The stable version, if one is installed (test hook).
    pub fn stable(&self) -> Option<Arc<ModelVersion>> {
        self.state.read().unwrap().stable.clone()
    }

    /// Enter draining: `/healthz` flips to 503, responses close their
    /// connections, and [`super::Server::run`] waits for in-flight
    /// requests (up to the drain deadline) before returning.
    pub fn start_drain(&self) {
        if !self.draining.swap(true, Ordering::SeqCst) {
            crate::log_info!(
                "serve: draining (deadline {:.1}s)",
                self.opts.drain.as_secs_f64()
            );
        }
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Log the final metrics snapshot (graceful-shutdown flush).
    pub fn flush_final_snapshot(&self) {
        crate::log_info!(
            "serve: final metrics snapshot: {}",
            self.totals.snapshot().to_json().to_string_pretty(0)
        );
    }

    // ---- reload ---------------------------------------------------------

    /// Full `POST /reload` handling: body spec, `canary=`/`window=`
    /// query overrides, load, build, swap-or-canary. Every failure is
    /// a 400 and leaves the serving state untouched.
    pub fn handle_reload(&self, query: &str, body: &[u8]) -> (u16, String) {
        let spec = match ReloadSpec::from_json(body) {
            Ok(spec) => spec,
            Err(e) => {
                self.note_rejected();
                return (400, error_body(&format!("{e:#}")));
            }
        };
        let canary_pct = match query_get(query, "canary").map(str::parse::<u64>) {
            None => None,
            Some(Ok(pct)) => Some(pct),
            Some(Err(_)) => {
                self.note_rejected();
                return (400, error_body("'canary' must be an integer percentage"));
            }
        };
        let window = match query_get(query, "window").map(str::parse::<usize>) {
            None => None,
            Some(Ok(w)) => Some(w),
            Some(Err(_)) => {
                self.note_rejected();
                return (400, error_body("'window' must be a non-negative integer"));
            }
        };
        match self.reload(&spec, canary_pct, window) {
            Ok(outcome) => (200, outcome.to_json().to_string_pretty(0)),
            Err(e) => (400, error_body(&format!("{e:#}"))),
        }
    }

    /// Load `spec` and either swap it in atomically (`canary_pct`
    /// `None` or `Some(100)`) or start a canary rollout at that
    /// percentage. Failures reject the reload without touching the
    /// serving state.
    pub fn reload(
        &self,
        spec: &ReloadSpec,
        canary_pct: Option<u64>,
        window: Option<usize>,
    ) -> Result<ReloadOutcome> {
        let result = self.try_reload(spec, canary_pct, window);
        match &result {
            Ok(outcome) => {
                crate::log_info!("serve: reload {}: {:?}", spec.describe(), outcome);
            }
            Err(e) => {
                self.note_rejected();
                crate::log_warn!("serve: reload {} rejected: {e:#}", spec.describe());
            }
        }
        result
    }

    fn try_reload(
        &self,
        spec: &ReloadSpec,
        canary_pct: Option<u64>,
        window: Option<usize>,
    ) -> Result<ReloadOutcome> {
        let _span = wall_span("serve_reload", CONTROL_TID)
            .map(|s| s.arg("source", Json::str(spec.describe())));
        let pct = match canary_pct {
            None | Some(100) => None,
            Some(pct) if (1..=99).contains(&pct) => Some(pct),
            Some(pct) => bail!("canary percentage must be in 1..=100, got {pct}"),
        };
        let policy = CanaryConfig {
            window: window.unwrap_or(self.opts.canary.window),
            ..self.opts.canary
        };
        policy.validate()?;
        // Everything fallible and slow happens here, off the serving
        // path and before any state changes.
        let ckpt = spec.load()?;
        let generation = self.next_gen.fetch_add(1, Ordering::SeqCst) + 1;
        let version = Arc::new(ModelVersion::build(
            ckpt,
            generation,
            spec.describe(),
            &self.opts,
            &self.totals,
        )?);
        match pct {
            Some(pct) if self.ready() => {
                let rollout = Arc::new(CanaryRollout::new(version, pct, policy));
                let old = {
                    let mut state = self.state.write().unwrap();
                    state.canary.replace(rollout.clone())
                };
                if let Some(old) = old.filter(|c| !c.decided()) {
                    self.note_superseded(&old);
                }
                self.canary_started.fetch_add(1, Ordering::Relaxed);
                self.obs_canary.inc();
                self.transition("canary", generation);
                Ok(ReloadOutcome::CanaryStarted {
                    generation,
                    pct,
                    window: policy.window,
                })
            }
            // A canary with no stable version to split against (first
            // load) degenerates to a swap.
            _ => {
                self.install_stable(version);
                self.swapped.fetch_add(1, Ordering::Relaxed);
                self.obs_swapped.inc();
                Ok(ReloadOutcome::Swapped { generation })
            }
        }
    }

    /// Atomically make `version` the stable serving version, retiring
    /// any in-flight canary.
    fn install_stable(&self, version: Arc<ModelVersion>) {
        let old_canary = {
            let mut state = self.state.write().unwrap();
            let old = state.canary.take();
            state.stable = Some(version.clone());
            old
        };
        if let Some(old) = old_canary.filter(|c| !c.decided()) {
            self.note_superseded(&old);
        }
        self.obs_generation.set(version.generation as f64);
        self.transition("swapped", version.generation);
    }

    fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        self.obs_rejected.inc();
    }

    fn note_superseded(&self, old: &Arc<CanaryRollout>) {
        self.superseded.fetch_add(1, Ordering::Relaxed);
        self.transition("superseded", old.version.generation);
    }

    fn transition(&self, to: &str, generation: u64) {
        transition_counter(to).inc();
        wall_instant(
            &format!("rollout_{to}"),
            CONTROL_TID,
            vec![("generation".to_string(), Json::num(generation as f64))],
        );
        crate::log_info!("serve: rollout transition to {to} (generation {generation})");
    }

    // ---- predict routing ------------------------------------------------

    /// Route one `POST /predict`: pick the version (canary split when a
    /// rollout is active), parse against its engine, predict through a
    /// replica, and feed the canary verdict. Returns `(status, body)`.
    pub fn predict_http(&self, body: &[u8]) -> (u16, String) {
        let (stable, canary) = {
            let state = self.state.read().unwrap();
            (state.stable.clone(), state.canary.clone())
        };
        let Some(stable) = stable else {
            return (503, error_body("no model loaded yet"));
        };
        let active = canary.filter(|c| !c.decided());
        let (version, canary_route) = match active {
            Some(c) if c.take_ticket() => (c.version.clone(), Some(c)),
            _ => (stable, None),
        };
        // Parse failures are the client's fault and say nothing about
        // the model: they count toward neither replica health nor the
        // canary verdict.
        let (x, k) = match parse_predict(version.engine(), body) {
            Ok(parsed) => parsed,
            Err(e) => return (400, error_body(&format!("{e:#}"))),
        };
        let t0 = Instant::now();
        let result = version.predict(x, k);
        let ok = result.is_ok();
        version.stats.record_request(t0.elapsed(), ok);
        if let Some(rollout) = &canary_route {
            rollout.note(ok);
            self.maybe_decide(rollout);
        }
        match result {
            Ok(topk) => (200, predict_body(&topk, k)),
            Err(e) => (500, error_body(&format!("{e:#}"))),
        }
    }

    /// Evaluate the canary verdict and, exactly once, apply it: swap
    /// the canary to stable (promote) or drop it (rollback). The write
    /// lock guards against a concurrent reload having superseded this
    /// rollout in the meantime.
    fn maybe_decide(&self, rollout: &Arc<CanaryRollout>) {
        let stable_snapshot = {
            let state = self.state.read().unwrap();
            match &state.stable {
                Some(stable) => stable.stats.snapshot(),
                None => return,
            }
        };
        let verdict = rollout.verdict(&stable_snapshot);
        if verdict == Verdict::Pending || !rollout.try_decide() {
            return;
        }
        let still_installed = {
            let mut state = self.state.write().unwrap();
            let installed = state
                .canary
                .as_ref()
                .is_some_and(|c| Arc::ptr_eq(c, rollout));
            if installed {
                state.canary = None;
                if verdict == Verdict::Promote {
                    state.stable = Some(rollout.version.clone());
                }
            }
            installed
        };
        if !still_installed {
            return;
        }
        match verdict {
            Verdict::Promote => {
                self.promoted.fetch_add(1, Ordering::Relaxed);
                self.obs_generation.set(rollout.version.generation as f64);
                self.transition("promoted", rollout.version.generation);
            }
            Verdict::Rollback(reason) => {
                self.rolled_back.fetch_add(1, Ordering::Relaxed);
                self.transition("rolled_back", rollout.version.generation);
                crate::log_warn!(
                    "serve: canary generation {} rolled back: {reason}",
                    rollout.version.generation
                );
            }
            Verdict::Pending => unreachable!("pending verdicts return above"),
        }
    }

    // ---- health and metrics ---------------------------------------------

    /// `GET /healthz`: 503 with `ready: false` until the first model is
    /// loaded (and again while draining); otherwise the loaded
    /// checkpoint's identity, generation, and per-replica health.
    pub fn health(&self) -> (u16, String) {
        if self.draining() {
            let body = Json::obj(vec![
                ("status", Json::str("draining")),
                ("ready", Json::Bool(false)),
            ]);
            return (503, body.to_string_pretty(0));
        }
        let state = self.state.read().unwrap();
        let Some(version) = &state.stable else {
            let body = Json::obj(vec![
                ("status", Json::str("loading")),
                ("ready", Json::Bool(false)),
            ]);
            return (503, body.to_string_pretty(0));
        };
        let meta = version.meta();
        let mut fields = vec![
            ("status", Json::str("ok")),
            ("ready", Json::Bool(true)),
            ("algo", Json::str(meta.algo.name())),
            ("preset", Json::str(meta.preset.clone())),
            ("models", Json::num(version.engine().n_models() as f64)),
            ("p", Json::num(meta.p as f64)),
            ("d", Json::num(meta.d as f64)),
            ("out_dim", Json::num(meta.out_dim as f64)),
            ("workers", Json::num(self.opts.workers.max(1) as f64)),
            ("max_batch", Json::num(self.opts.max_batch.max(1) as f64)),
            ("generation", Json::num(version.generation as f64)),
            ("checkpoint", Json::str(version.source.clone())),
            (
                "state_checksum",
                Json::str(format!("{:016x}", version.state_checksum)),
            ),
            ("replicas", Json::num(version.n_replicas() as f64)),
            ("replica_health", version.replica_health()),
        ];
        if let Some(rollout) = state.canary.as_ref().filter(|c| !c.decided()) {
            fields.push((
                "canary",
                Json::obj(vec![
                    ("generation", Json::num(rollout.version.generation as f64)),
                    ("pct", Json::num(rollout.pct as f64)),
                    ("window", Json::num(rollout.policy.window as f64)),
                    ("served", Json::num(rollout.served() as f64)),
                    ("errors", Json::num(rollout.errors() as f64)),
                ]),
            ));
        }
        (200, Json::obj(fields).to_string_pretty(0))
    }

    /// `GET /metrics` (JSON): the historical process-lifetime contract
    /// (requests/errors/latency/batches at the top level) plus the
    /// control plane's generation, reload counters, and per-version
    /// rows.
    pub fn metrics_json(&self) -> String {
        let Json::Obj(mut map) = self.totals.snapshot().to_json() else {
            unreachable!("snapshot JSON is an object");
        };
        map.insert(
            "generation".to_string(),
            Json::num(self.generation() as f64),
        );
        let count = |c: &AtomicU64| Json::num(c.load(Ordering::Relaxed) as f64);
        map.insert(
            "reloads".to_string(),
            Json::obj(vec![
                ("swapped", count(&self.swapped)),
                ("canary_started", count(&self.canary_started)),
                ("promoted", count(&self.promoted)),
                ("rolled_back", count(&self.rolled_back)),
                ("rejected", count(&self.rejected)),
                ("superseded", count(&self.superseded)),
            ]),
        );
        let state = self.state.read().unwrap();
        let mut versions = Vec::new();
        if let Some(stable) = &state.stable {
            versions.push(version_row(stable, "stable"));
        }
        if let Some(rollout) = state.canary.as_ref().filter(|c| !c.decided()) {
            versions.push(version_row(&rollout.version, "canary"));
        }
        map.insert("versions".to_string(), Json::Arr(versions));
        Json::Obj(map).to_string_pretty(2)
    }

    /// `GET /metrics?format=prometheus`: the process-lifetime serve
    /// family plus the global registry (which carries the labeled
    /// per-generation/per-replica series and the reload/rollout
    /// counters). Per-version latency percentiles are published as
    /// gauges at scrape time.
    pub fn metrics_prometheus(&self) -> String {
        {
            let state = self.state.read().unwrap();
            if let Some(stable) = &state.stable {
                publish_version_latency(stable);
            }
            if let Some(rollout) = state.canary.as_ref().filter(|c| !c.decided()) {
                publish_version_latency(&rollout.version);
            }
        }
        let mut text = self.totals.snapshot().to_prometheus();
        text.push_str(&global().render_prometheus());
        text
    }
}

fn transition_counter(to: &str) -> Arc<Counter> {
    global().counter_with(
        "fedmlh_serve_rollout_transitions_total",
        "Serve rollout state transitions, by target state.",
        &[("to", to)],
    )
}

fn version_row(version: &ModelVersion, role: &str) -> Json {
    let Json::Obj(mut map) = version.stats.snapshot().to_json_brief() else {
        unreachable!("brief snapshot JSON is an object");
    };
    map.insert(
        "generation".to_string(),
        Json::num(version.generation as f64),
    );
    map.insert("role".to_string(), Json::str(role));
    map.insert("checkpoint".to_string(), Json::str(version.source.clone()));
    Json::Obj(map)
}

/// Publish a version's latency percentiles as labeled gauges (set at
/// scrape time; gauges are idempotent to re-register).
fn publish_version_latency(version: &ModelVersion) {
    let snap = version.stats.snapshot();
    let gen_label = version.generation.to_string();
    let reg = global();
    reg.gauge_with(
        "fedmlh_serve_version_latency_p50_us",
        "Median prediction latency by model generation (microseconds).",
        &[("generation", &gen_label)],
    )
    .set(snap.p50_us as f64);
    reg.gauge_with(
        "fedmlh_serve_version_latency_p99_us",
        "99th-percentile prediction latency by model generation (microseconds).",
        &[("generation", &gen_label)],
    )
    .set(snap.p99_us as f64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, ExperimentConfig};
    use crate::model::params::ModelParams;
    use crate::serve::checkpoint::CheckpointCodec;

    fn tiny_checkpoint(seed: u64) -> Checkpoint {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let models: Vec<ModelParams> = (0..cfg.r())
            .map(|j| {
                ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), seed + j as u64)
            })
            .collect();
        Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap()
    }

    fn opts() -> ServeOpts {
        ServeOpts {
            workers: 1,
            max_batch: 4,
            ..ServeOpts::default()
        }
    }

    fn temp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fedmlh-control-{}-{name}", std::process::id()))
    }

    fn predict_sparse(control: &ControlPlane) -> (u16, String) {
        control.predict_http(br#"{"sparse": [[3, 1.5]], "k": 3}"#)
    }

    #[test]
    fn not_ready_until_first_load_then_ready() {
        let control = ControlPlane::new(opts()).unwrap();
        assert!(!control.ready());
        assert_eq!(control.generation(), 0);
        let (status, body) = control.health();
        assert_eq!(status, 503);
        assert!(body.contains("\"ready\":false"), "{body}");
        let (status, _) = predict_sparse(&control);
        assert_eq!(status, 503);

        let path = temp_path("first.fmlh");
        tiny_checkpoint(7).save(&path, CheckpointCodec::Dense).unwrap();
        let spec = ReloadSpec {
            checkpoint: path.clone(),
            deltas: vec![],
        };
        let outcome = control.reload(&spec, None, None).unwrap();
        assert_eq!(outcome, ReloadOutcome::Swapped { generation: 1 });
        assert!(control.ready());
        let (status, body) = control.health();
        assert_eq!(status, 200, "healthz must be 200 once loaded");
        assert!(body.contains("\"ready\":true"), "{body}");
        assert!(body.contains("\"generation\":1"), "{body}");
        let (status, _) = predict_sparse(&control);
        assert_eq!(status, 200);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failed_reload_keeps_previous_version() {
        let control =
            ControlPlane::with_initial(tiny_checkpoint(7), "seed".into(), opts()).unwrap();
        assert_eq!(control.generation(), 1);
        let before = control.stable().unwrap().state_checksum;
        let spec = ReloadSpec {
            checkpoint: temp_path("missing.fmlh"),
            deltas: vec![],
        };
        assert!(control.reload(&spec, None, None).is_err());
        assert_eq!(control.generation(), 1, "generation unchanged after a failed reload");
        assert_eq!(control.stable().unwrap().state_checksum, before);
        let metrics = Json::parse(&control.metrics_json()).unwrap();
        let reloads = metrics.expect("reloads").unwrap();
        assert_eq!(reloads.expect("rejected").unwrap().as_usize().unwrap(), 1);
        assert_eq!(reloads.expect("swapped").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn swap_changes_answers_to_the_new_model() {
        let control =
            ControlPlane::with_initial(tiny_checkpoint(7), "seed".into(), opts()).unwrap();
        let path = temp_path("next.fmlh");
        tiny_checkpoint(99).save(&path, CheckpointCodec::Dense).unwrap();
        let spec = ReloadSpec {
            checkpoint: path.clone(),
            deltas: vec![],
        };
        let outcome = control.reload(&spec, Some(100), None).unwrap();
        assert_eq!(outcome, ReloadOutcome::Swapped { generation: 2 });
        assert_eq!(control.generation(), 2);
        // The swapped-in engine answers, and the checksum tracks the
        // new weights.
        let want = Checkpoint::load(&path).unwrap().state_checksum().unwrap();
        assert_eq!(control.stable().unwrap().state_checksum, want);
        let (status, _) = predict_sparse(&control);
        assert_eq!(status, 200);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_canary_percentages_are_rejected() {
        let control =
            ControlPlane::with_initial(tiny_checkpoint(7), "seed".into(), opts()).unwrap();
        let path = temp_path("pct.fmlh");
        tiny_checkpoint(8).save(&path, CheckpointCodec::Dense).unwrap();
        let spec = ReloadSpec {
            checkpoint: path.clone(),
            deltas: vec![],
        };
        assert!(control.reload(&spec, Some(0), None).is_err());
        assert!(control.reload(&spec, Some(101), None).is_err());
        assert_eq!(control.generation(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reload_http_surface_rejects_bad_requests() {
        let control =
            ControlPlane::with_initial(tiny_checkpoint(7), "seed".into(), opts()).unwrap();
        let (status, body) = control.handle_reload("", b"not json");
        assert_eq!(status, 400);
        assert!(body.contains("error"), "{body}");
        let (status, _) = control.handle_reload("canary=abc", br#"{"checkpoint": "x"}"#);
        assert_eq!(status, 400);
        let (status, _) = control.handle_reload("window=-1", br#"{"checkpoint": "x"}"#);
        assert_eq!(status, 400);
        // All three were counted as rejected without touching state.
        let metrics = Json::parse(&control.metrics_json()).unwrap();
        let rejected = metrics
            .expect("reloads")
            .unwrap()
            .expect("rejected")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(rejected, 3);
        assert_eq!(control.generation(), 1);
    }
}
