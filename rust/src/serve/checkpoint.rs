//! The `.fmlh` checkpoint format — a trained run, persisted.
//!
//! A checkpoint carries everything `fedmlh serve` needs to answer a
//! prediction without rerunning training: the R hashed sub-models
//! ([`ModelParams`]), the seeds that reconstruct the [`LabelHasher`]
//! tables and the feature-hash function (both are *derived seeds*, so
//! the tables come back bit-identical — the serving analog of
//! Algorithm 2's broadcast), and the experiment metadata (`d`, `B`,
//! `p`, preset) the decoder needs.
//!
//! ## Wire layout (little-endian)
//!
//! ```text
//! magic      4 × u8   "FMLH"
//! version    u16      format version (this build reads VERSION)
//! codec      u8       0 = dense f32, 1 = q8 (per-tensor int8 + scales),
//!                     2 = q4g (group-wise int4, two values per byte)
//! algo       u8       0 = fedavg, 1 = fedmlh
//! d,hidden,  4 × u32  model dims (out = p for fedavg, B for fedmlh)
//! out,p
//! n_models   u32      R (1 for fedavg)
//! hash_seed  u64      LabelHasher seed (fedmlh decode tables)
//! feat_seed  u64      FeatureHasher seed (raw sparse → dense d)
//! root_seed  u64      experiment root seed (provenance)
//! preset     u16 len + utf-8 bytes
//! models     R × (u32 payload len + payload)
//! checksum   u64      FNV-1a over every preceding byte
//! ```
//!
//! Model payloads reuse the [`crate::federated::wire`] codecs: `q8` is
//! the same per-tensor symmetric int8 encoding clients upload with, so
//! a q8 checkpoint is ~4× smaller than dense `f32` (1 byte + amortized
//! scale per parameter vs 4), and `q4g` is the group-wise int4 wire
//! codec (two values per byte, per-block scales, ~7–8× smaller than
//! dense at the default block). Corruption anywhere flips the checksum;
//! truncation, wrong magic and future versions all fail loudly —
//! pinned by `tests/serve_roundtrip.rs`.
//!
//! ## Delta checkpoints (`FMLD`)
//!
//! The deployment half of the paper's communication story: a device
//! that already holds a base checkpoint should not re-download the
//! whole model after a fine-tune — it should download what *changed*.
//! A [`DeltaCheckpoint`] carries, per sub-model, a
//! [`crate::federated::wire`] delta payload against the base
//! ([`DeltaCodec::Sparse`]: every changed coordinate, exact — applying
//! reproduces the full checkpoint **bitwise**; [`DeltaCodec::QuantI8Diff`]:
//! int8-quantized difference, ~4× smaller than a dense diff), plus the
//! [`Checkpoint::state_checksum`] of the state it applies onto, so a
//! chain (`base → d1 → d2 → …`) fails loudly when applied out of order
//! or onto the wrong base. Written by `fedmlh run --save x.fmlh
//! --save-delta base.fmlh`, applied by [`Checkpoint::load_chain`]
//! (`fedmlh serve --delta`). Layout mirrors the full checkpoint with
//! `FMLD` magic and a `u64` base checksum between the preset name and
//! the model payloads.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{Algo, ExperimentConfig};
use crate::federated::wire::{
    apply_delta, decode_update, encode_changed, encode_delta, encode_update, CodecSpec,
    EncodedUpdate,
};
use crate::model::params::ModelParams;

/// File magic: the first four bytes of every checkpoint.
pub const MAGIC: [u8; 4] = *b"FMLH";

/// Format version this build writes and reads.
pub const VERSION: u16 = 1;

/// File magic of a delta checkpoint.
pub const DELTA_MAGIC: [u8; 4] = *b"FMLD";

/// Delta format version this build writes and reads.
pub const DELTA_VERSION: u16 = 1;

/// Upper bound on sub-model count (corruption guard, far above any R).
const MAX_MODELS: usize = 4096;

/// Upper bound on any single model dimension (corruption guard: keeps
/// a crafted header from driving `ModelParams::zeros` into a huge
/// allocation before the payload sizes are cross-checked).
const MAX_DIM: usize = 1 << 24;

/// How model parameters are encoded inside the checkpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckpointCodec {
    /// Raw `f32` parameters — lossless, 4 bytes per parameter.
    Dense,
    /// Per-tensor symmetric int8 ([`CodecSpec::QuantI8`]) — ~4× smaller.
    QuantI8,
    /// Group-wise int4 ([`CodecSpec::QuantI4Group`] at the default
    /// block) — two values per byte, ~7–8× smaller than dense.
    QuantI4Group,
}

impl CheckpointCodec {
    pub fn parse(name: &str) -> Result<CheckpointCodec> {
        match name {
            "dense" | "f32" => Ok(CheckpointCodec::Dense),
            "q8" | "quant" => Ok(CheckpointCodec::QuantI8),
            "q4g" => Ok(CheckpointCodec::QuantI4Group),
            other => bail!("unknown checkpoint codec '{other}' (expected q8|q4g|dense)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CheckpointCodec::Dense => "dense",
            CheckpointCodec::QuantI8 => "q8",
            CheckpointCodec::QuantI4Group => "q4g",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            CheckpointCodec::Dense => 0,
            CheckpointCodec::QuantI8 => 1,
            CheckpointCodec::QuantI4Group => 2,
        }
    }

    fn from_tag(tag: u8) -> Result<CheckpointCodec> {
        match tag {
            0 => Ok(CheckpointCodec::Dense),
            1 => Ok(CheckpointCodec::QuantI8),
            2 => Ok(CheckpointCodec::QuantI4Group),
            other => bail!("unknown checkpoint codec tag {other}"),
        }
    }

    /// The wire codec that encodes/decodes the model payloads.
    fn wire_spec(&self) -> CodecSpec {
        match self {
            CheckpointCodec::Dense => CodecSpec::Dense,
            CheckpointCodec::QuantI8 => CodecSpec::QuantI8,
            CheckpointCodec::QuantI4Group => CodecSpec::QuantI4Group {
                block: crate::federated::wire::DEFAULT_Q4G_BLOCK,
            },
        }
    }

    /// Smallest possible payload bytes per parameter value under this
    /// codec, as a (numerator, denominator) byte fraction — the
    /// corruption guard in [`Checkpoint::from_bytes`] uses it to bound
    /// declared model sizes against the file size. Sub-byte codecs
    /// (q4g) store two values per byte; everything else ≥ 1 byte each.
    fn min_bytes_for(&self, n_values: usize) -> usize {
        match self {
            CheckpointCodec::QuantI4Group => n_values.div_ceil(2),
            CheckpointCodec::Dense | CheckpointCodec::QuantI8 => n_values,
        }
    }
}

fn algo_tag(algo: Algo) -> u8 {
    match algo {
        Algo::FedAvg => 0,
        Algo::FedMlh => 1,
    }
}

fn algo_from_tag(tag: u8) -> Result<Algo> {
    match tag {
        0 => Ok(Algo::FedAvg),
        1 => Ok(Algo::FedMlh),
        other => bail!("unknown checkpoint algo tag {other}"),
    }
}

/// Everything about a checkpoint except the parameters themselves.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    pub algo: Algo,
    /// Preset name the run trained on (provenance; not load-bearing).
    pub preset: String,
    /// Feature-hashed input dimension.
    pub d: usize,
    /// Hidden width of the 2-hidden-layer MLP.
    pub hidden: usize,
    /// Output width of each sub-model (p for fedavg, B for fedmlh).
    pub out_dim: usize,
    /// Number of classes the decode recovers.
    pub p: usize,
    /// [`crate::hashing::LabelHasher`] seed (already derived).
    pub hash_seed: u64,
    /// [`crate::data::feature_hash::FeatureHasher`] seed (already derived).
    pub feat_seed: u64,
    /// Root experiment seed (provenance).
    pub root_seed: u64,
}

/// A loaded (or about-to-be-saved) trained model.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub meta: CheckpointMeta,
    /// The R trained global sub-models (1 for fedavg).
    pub models: Vec<ModelParams>,
}

impl Checkpoint {
    /// Build and shape-validate a checkpoint.
    pub fn new(meta: CheckpointMeta, models: Vec<ModelParams>) -> Result<Checkpoint> {
        if models.is_empty() {
            bail!("checkpoint needs at least one model");
        }
        if models.len() > MAX_MODELS {
            bail!("checkpoint has {} models (cap {MAX_MODELS})", models.len());
        }
        for (j, m) in models.iter().enumerate() {
            if (m.d, m.hidden, m.out) != (meta.d, meta.hidden, meta.out_dim) {
                bail!(
                    "model {j} shape ({},{},{}) != checkpoint meta ({},{},{})",
                    m.d,
                    m.hidden,
                    m.out,
                    meta.d,
                    meta.hidden,
                    meta.out_dim
                );
            }
        }
        match meta.algo {
            Algo::FedAvg => {
                if models.len() != 1 || meta.out_dim != meta.p {
                    bail!(
                        "fedavg checkpoint must have 1 model with out == p (got {} models, out {} vs p {})",
                        models.len(),
                        meta.out_dim,
                        meta.p
                    );
                }
            }
            Algo::FedMlh => {
                if meta.out_dim > meta.p {
                    bail!(
                        "fedmlh checkpoint has B {} > p {}",
                        meta.out_dim,
                        meta.p
                    );
                }
            }
        }
        Ok(Checkpoint { meta, models })
    }

    /// Package a finished training run (`RunOutput::final_globals`).
    /// `d`/`p` come from the trained dataset; the hash seeds are derived
    /// from `cfg.seed` through the same streams training used.
    pub fn from_run(
        cfg: &ExperimentConfig,
        algo: Algo,
        d: usize,
        p: usize,
        models: Vec<ModelParams>,
    ) -> Result<Checkpoint> {
        let out_dim = models.first().map(|m| m.out).unwrap_or(0);
        let meta = CheckpointMeta {
            algo,
            preset: cfg.preset.name.to_string(),
            d,
            hidden: cfg.preset.hidden,
            out_dim,
            p,
            hash_seed: crate::algo::fedmlh::label_hash_seed(cfg.seed),
            feat_seed: crate::data::synth::feature_hash_seed(cfg.seed),
            root_seed: cfg.seed,
        };
        Checkpoint::new(meta, models)
    }

    /// Number of sub-models (R for fedmlh, 1 for fedavg).
    pub fn r(&self) -> usize {
        self.models.len()
    }

    /// Bytes all models would occupy as raw dense `f32` (the codec
    /// compression baseline).
    pub fn dense_byte_size(&self) -> usize {
        self.models.iter().map(|m| m.byte_size()).sum()
    }

    /// Serialize to the checkpoint wire layout (module docs).
    pub fn to_bytes(&self, codec: CheckpointCodec) -> Result<Vec<u8>> {
        let m = &self.meta;
        let mut out = Vec::with_capacity(64 + self.dense_byte_size() / 2);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(codec.tag());
        out.push(algo_tag(m.algo));
        for dim in [m.d, m.hidden, m.out_dim, m.p, self.models.len()] {
            let v = u32::try_from(dim).context("checkpoint dimension exceeds u32")?;
            out.extend_from_slice(&v.to_le_bytes());
        }
        for seed in [m.hash_seed, m.feat_seed, m.root_seed] {
            out.extend_from_slice(&seed.to_le_bytes());
        }
        let preset = m.preset.as_bytes();
        let preset_len = u16::try_from(preset.len()).context("preset name too long")?;
        out.extend_from_slice(&preset_len.to_le_bytes());
        out.extend_from_slice(preset);
        for model in &self.models {
            // Encoding a model "against itself" reuses the uplink codecs
            // verbatim: dense/q8 never look at the reference values,
            // only its shape.
            let payload = encode_update(codec.wire_spec(), model, model)?.to_bytes();
            let len = u32::try_from(payload.len()).context("model payload exceeds u32")?;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&payload);
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        Ok(out)
    }

    /// Parse and validate a serialized checkpoint.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < MAGIC.len() + 2 {
            bail!("checkpoint truncated: {} bytes", bytes.len());
        }
        if bytes[..4] == DELTA_MAGIC {
            bail!(
                "this is a delta checkpoint — apply it onto its base \
                 (`fedmlh serve --delta` / Checkpoint::load_chain)"
            );
        }
        if bytes[..4] != MAGIC {
            bail!("not a FedMLH checkpoint (bad magic)");
        }
        let version = u16::from_le_bytes([bytes[4], bytes[5]]);
        if version != VERSION {
            bail!("unsupported checkpoint version {version} (this build reads {VERSION})");
        }
        if bytes.len() < MAGIC.len() + 2 + 8 {
            bail!("checkpoint truncated: {} bytes", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        let got = fnv1a64(body);
        if got != want {
            bail!("checkpoint checksum mismatch (corrupt or truncated file)");
        }

        let mut r = Reader {
            bytes: body,
            pos: 6, // past magic + version
        };
        let codec = CheckpointCodec::from_tag(r.u8()?)?;
        let algo = algo_from_tag(r.u8()?)?;
        let d = r.u32_as_usize()?;
        let hidden = r.u32_as_usize()?;
        let out_dim = r.u32_as_usize()?;
        let p = r.u32_as_usize()?;
        let n_models = r.u32_as_usize()?;
        for (name, v) in [("d", d), ("hidden", hidden), ("out", out_dim), ("p", p)] {
            if v == 0 || v > MAX_DIM {
                bail!("checkpoint dimension {name} = {v} out of range (1..={MAX_DIM})");
            }
        }
        if n_models == 0 || n_models > MAX_MODELS {
            bail!("checkpoint has {n_models} models (cap {MAX_MODELS})");
        }
        let hash_seed = r.u64()?;
        let feat_seed = r.u64()?;
        let root_seed = r.u64()?;
        let preset_len = r.u16()? as usize;
        let preset = String::from_utf8(r.take(preset_len)?.to_vec())
            .context("checkpoint preset name is not utf-8")?;

        // Every codec stores a known minimum number of payload bytes per
        // parameter (1 for dense/q8, half for sub-byte q4g), so a declared
        // model larger than the file is corrupt — reject it *before* the
        // template allocation (with dims ≤ MAX_DIM the products below
        // stay far inside usize, so this arithmetic cannot overflow).
        let n_values: usize = ModelParams::shapes(d, hidden, out_dim)
            .iter()
            .map(|shape| shape.iter().product::<usize>())
            .sum();
        if codec.min_bytes_for(n_values).saturating_mul(n_models) > body.len() {
            bail!(
                "checkpoint declares {n_models} × {n_values} parameters but the file has only {} bytes",
                body.len()
            );
        }
        let template = ModelParams::zeros(d, hidden, out_dim);
        debug_assert_eq!(template.num_params(), n_values);
        let mut models = Vec::with_capacity(n_models);
        for j in 0..n_models {
            let payload_len = r.u32_as_usize()?;
            let payload = r.take(payload_len)?;
            let enc = EncodedUpdate::from_bytes(
                codec.wire_spec(),
                template.tensors.len(),
                n_values,
                payload,
            )
            .with_context(|| format!("decoding checkpoint model {j}"))?;
            models.push(decode_update(&template, &enc)?);
        }
        if r.pos != body.len() {
            bail!(
                "checkpoint has {} trailing bytes after the last model",
                body.len() - r.pos
            );
        }
        Checkpoint::new(
            CheckpointMeta {
                algo,
                preset,
                d,
                hidden,
                out_dim,
                p,
                hash_seed,
                feat_seed,
                root_seed,
            },
            models,
        )
    }

    /// Write to `path` (parent directories created on demand).
    pub fn save(&self, path: &Path, codec: CheckpointCodec) -> Result<()> {
        let bytes = self.to_bytes(codec)?;
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(path, &bytes)
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Read and validate a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("parsing checkpoint {}", path.display()))
    }

    /// Checksum of the canonical (dense) serialization. Identifies a
    /// model *state* independent of the on-disk codec, so a delta can
    /// chain onto either a loaded file or the result of a previous
    /// delta.
    pub fn state_checksum(&self) -> Result<u64> {
        Ok(fnv1a64(&self.to_bytes(CheckpointCodec::Dense)?))
    }

    /// Express `self` as a delta checkpoint against `base` (the same
    /// run lineage: identical metadata, shapes and sub-model count).
    pub fn delta_against(&self, base: &Checkpoint, codec: DeltaCodec) -> Result<DeltaCheckpoint> {
        if self.meta != base.meta {
            bail!(
                "delta checkpoint: metadata differs from base \
                 (base preset '{}' d={} out={} R={}, this preset '{}' d={} out={} R={})",
                base.meta.preset,
                base.meta.d,
                base.meta.out_dim,
                base.r(),
                self.meta.preset,
                self.meta.d,
                self.meta.out_dim,
                self.r()
            );
        }
        if self.models.len() != base.models.len() {
            bail!(
                "delta checkpoint: {} models vs base's {}",
                self.models.len(),
                base.models.len()
            );
        }
        let deltas = self
            .models
            .iter()
            .zip(base.models.iter())
            .map(|(model, base_model)| match codec {
                DeltaCodec::Sparse => encode_changed(base_model, model),
                DeltaCodec::QuantI8Diff => encode_delta(CodecSpec::QuantI8, base_model, model),
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(DeltaCheckpoint {
            meta: self.meta.clone(),
            base_checksum: base.state_checksum()?,
            codec,
            deltas,
        })
    }

    /// Load `base` and apply the `deltas` chain in order — the delivery
    /// path of `fedmlh serve --checkpoint base --delta d1,d2,…`.
    pub fn load_chain(base: &Path, deltas: &[PathBuf]) -> Result<Checkpoint> {
        let mut ckpt = Checkpoint::load(base)?;
        for path in deltas {
            let delta = DeltaCheckpoint::load(path)?;
            ckpt = delta
                .apply(&ckpt)
                .with_context(|| format!("applying delta checkpoint {}", path.display()))?;
        }
        Ok(ckpt)
    }
}

/// How a [`DeltaCheckpoint`]'s per-model payloads are encoded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaCodec {
    /// Every coordinate whose `f32` bits changed, exact — lossless:
    /// applying reproduces the full checkpoint (and therefore its
    /// predictions) bit for bit.
    Sparse,
    /// Per-tensor int8-quantized difference — ~4× smaller than a dense
    /// diff, lossy within the diff's per-tensor scale bound.
    QuantI8Diff,
}

impl DeltaCodec {
    /// Parse a CLI name (`fedmlh run --delta-codec`).
    pub fn parse(name: &str) -> Result<DeltaCodec> {
        match name {
            "sparse" => Ok(DeltaCodec::Sparse),
            "q8diff" | "q8" => Ok(DeltaCodec::QuantI8Diff),
            other => bail!("unknown delta checkpoint codec '{other}' (expected sparse|q8diff)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeltaCodec::Sparse => "sparse",
            DeltaCodec::QuantI8Diff => "q8diff",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            DeltaCodec::Sparse => 0,
            DeltaCodec::QuantI8Diff => 1,
        }
    }

    fn from_tag(tag: u8) -> Result<DeltaCodec> {
        match tag {
            0 => Ok(DeltaCodec::Sparse),
            1 => Ok(DeltaCodec::QuantI8Diff),
            other => bail!("unknown delta checkpoint codec tag {other}"),
        }
    }

    /// The wire codec the payloads parse with (the fraction of the
    /// sparse spec is irrelevant to parsing).
    fn wire_spec(&self) -> CodecSpec {
        match self {
            DeltaCodec::Sparse => CodecSpec::TopKPacked { frac: 1.0 },
            DeltaCodec::QuantI8Diff => CodecSpec::QuantI8,
        }
    }
}

/// A checkpoint expressed as a delta against a base checkpoint (module
/// docs §Delta checkpoints).
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaCheckpoint {
    /// Metadata of the *result* state (matches the base by
    /// construction).
    pub meta: CheckpointMeta,
    /// [`Checkpoint::state_checksum`] of the state this applies onto.
    pub base_checksum: u64,
    codec: DeltaCodec,
    /// One [`crate::federated::wire`] delta payload per sub-model.
    deltas: Vec<EncodedUpdate>,
}

impl DeltaCheckpoint {
    pub fn codec(&self) -> DeltaCodec {
        self.codec
    }

    /// Apply onto `base`, reconstructing the (possibly lossy) result
    /// checkpoint. Refuses a base whose state checksum does not match
    /// the one recorded at encode time.
    pub fn apply(&self, base: &Checkpoint) -> Result<Checkpoint> {
        let m = &self.meta;
        if (m.d, m.hidden, m.out_dim) != (base.meta.d, base.meta.hidden, base.meta.out_dim)
            || self.deltas.len() != base.models.len()
        {
            bail!(
                "delta checkpoint shape ({},{},{}) × {} does not match base ({},{},{}) × {}",
                m.d,
                m.hidden,
                m.out_dim,
                self.deltas.len(),
                base.meta.d,
                base.meta.hidden,
                base.meta.out_dim,
                base.models.len()
            );
        }
        let got = base.state_checksum()?;
        if got != self.base_checksum {
            bail!(
                "delta checkpoint does not chain onto this base \
                 (base state checksum {got:#018x}, delta expects {:#018x})",
                self.base_checksum
            );
        }
        let models = base
            .models
            .iter()
            .zip(self.deltas.iter())
            .map(|(base_model, enc)| apply_delta(base_model, enc))
            .collect::<Result<Vec<_>>>()?;
        Checkpoint::new(self.meta.clone(), models)
    }

    /// Serialize to the delta wire layout (module docs).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let m = &self.meta;
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(&DELTA_MAGIC);
        out.extend_from_slice(&DELTA_VERSION.to_le_bytes());
        out.push(self.codec.tag());
        out.push(algo_tag(m.algo));
        for dim in [m.d, m.hidden, m.out_dim, m.p, self.deltas.len()] {
            let v = u32::try_from(dim).context("checkpoint dimension exceeds u32")?;
            out.extend_from_slice(&v.to_le_bytes());
        }
        for seed in [m.hash_seed, m.feat_seed, m.root_seed] {
            out.extend_from_slice(&seed.to_le_bytes());
        }
        let preset = m.preset.as_bytes();
        let preset_len = u16::try_from(preset.len()).context("preset name too long")?;
        out.extend_from_slice(&preset_len.to_le_bytes());
        out.extend_from_slice(preset);
        out.extend_from_slice(&self.base_checksum.to_le_bytes());
        for enc in &self.deltas {
            let payload = enc.to_bytes();
            let len = u32::try_from(payload.len()).context("delta payload exceeds u32")?;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&payload);
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        Ok(out)
    }

    /// Parse and validate a serialized delta checkpoint.
    pub fn from_bytes(bytes: &[u8]) -> Result<DeltaCheckpoint> {
        if bytes.len() < DELTA_MAGIC.len() + 2 {
            bail!("delta checkpoint truncated: {} bytes", bytes.len());
        }
        if bytes[..4] == MAGIC {
            bail!("this is a full checkpoint, not a delta (pass it as --checkpoint)");
        }
        if bytes[..4] != DELTA_MAGIC {
            bail!("not a FedMLH delta checkpoint (bad magic)");
        }
        let version = u16::from_le_bytes([bytes[4], bytes[5]]);
        if version != DELTA_VERSION {
            bail!(
                "unsupported delta checkpoint version {version} (this build reads {DELTA_VERSION})"
            );
        }
        if bytes.len() < DELTA_MAGIC.len() + 2 + 8 {
            bail!("delta checkpoint truncated: {} bytes", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        if fnv1a64(body) != want {
            bail!("delta checkpoint checksum mismatch (corrupt or truncated file)");
        }

        let mut r = Reader {
            bytes: body,
            pos: 6, // past magic + version
        };
        let codec = DeltaCodec::from_tag(r.u8()?)?;
        let algo = algo_from_tag(r.u8()?)?;
        let d = r.u32_as_usize()?;
        let hidden = r.u32_as_usize()?;
        let out_dim = r.u32_as_usize()?;
        let p = r.u32_as_usize()?;
        let n_models = r.u32_as_usize()?;
        for (name, v) in [("d", d), ("hidden", hidden), ("out", out_dim), ("p", p)] {
            if v == 0 || v > MAX_DIM {
                bail!("delta checkpoint dimension {name} = {v} out of range (1..={MAX_DIM})");
            }
        }
        if n_models == 0 || n_models > MAX_MODELS {
            bail!("delta checkpoint has {n_models} models (cap {MAX_MODELS})");
        }
        let hash_seed = r.u64()?;
        let feat_seed = r.u64()?;
        let root_seed = r.u64()?;
        let preset_len = r.u16()? as usize;
        let preset = String::from_utf8(r.take(preset_len)?.to_vec())
            .context("delta checkpoint preset name is not utf-8")?;
        let base_checksum = r.u64()?;

        // Unlike the full loader, no template model is materialized here
        // (a sparse delta can be tiny); payloads only parse against the
        // declared shape, and `apply` validates against the real base.
        let n_values: usize = ModelParams::shapes(d, hidden, out_dim)
            .iter()
            .map(|shape| shape.iter().product::<usize>())
            .sum();
        let mut deltas = Vec::with_capacity(n_models);
        for j in 0..n_models {
            let payload_len = r.u32_as_usize()?;
            let payload = r.take(payload_len)?;
            let enc = EncodedUpdate::from_bytes(
                codec.wire_spec(),
                crate::model::params::N_PARAMS,
                n_values,
                payload,
            )
            .with_context(|| format!("decoding delta checkpoint model {j}"))?;
            deltas.push(enc);
        }
        if r.pos != body.len() {
            bail!(
                "delta checkpoint has {} trailing bytes after the last model",
                body.len() - r.pos
            );
        }
        Ok(DeltaCheckpoint {
            meta: CheckpointMeta {
                algo,
                preset,
                d,
                hidden,
                out_dim,
                p,
                hash_seed,
                feat_seed,
                root_seed,
            },
            base_checksum,
            codec,
            deltas,
        })
    }

    /// Write to `path` (parent directories created on demand).
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes()?;
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(path, &bytes)
            .with_context(|| format!("writing delta checkpoint {}", path.display()))
    }

    /// Read and validate a delta checkpoint file.
    pub fn load(path: &Path) -> Result<DeltaCheckpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading delta checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("parsing delta checkpoint {}", path.display()))
    }
}

/// FNV-1a 64-bit — a fast corruption check (not cryptographic).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Bounds-checked little-endian reader over a byte slice.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("checkpoint length overflow"))?;
        if end > self.bytes.len() {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32_as_usize(&mut self) -> Result<usize> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]) as usize)
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fedmlh_checkpoint(seed: u64) -> Checkpoint {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        let models: Vec<ModelParams> = (0..cfg.r())
            .map(|j| {
                let mut m = ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), seed + j as u64);
                for t in m.tensors.iter_mut() {
                    for v in t.data_mut() {
                        *v += (rng.next_f32() - 0.5) * 0.1;
                    }
                }
                m
            })
            .collect();
        Checkpoint::from_run(&cfg, Algo::FedMlh, cfg.preset.d, cfg.preset.p, models).unwrap()
    }

    #[test]
    fn dense_roundtrip_is_bitwise() {
        let ckpt = fedmlh_checkpoint(1);
        let bytes = ckpt.to_bytes(CheckpointCodec::Dense).unwrap();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, ckpt);
    }

    #[test]
    fn q8_roundtrip_is_stable_and_smaller() {
        let ckpt = fedmlh_checkpoint(2);
        let dense = ckpt.to_bytes(CheckpointCodec::Dense).unwrap();
        let q8 = ckpt.to_bytes(CheckpointCodec::QuantI8).unwrap();
        assert!(
            (dense.len() as f64) / (q8.len() as f64) >= 3.5,
            "q8 {} vs dense {}",
            q8.len(),
            dense.len()
        );
        let back = Checkpoint::from_bytes(&q8).unwrap();
        assert_eq!(back.meta, ckpt.meta);
        // Lossy, but within the per-tensor quantization scale bound.
        for (orig, got) in ckpt.models.iter().zip(back.models.iter()) {
            for (t_orig, t_got) in orig.tensors.iter().zip(got.tensors.iter()) {
                let max_abs = t_orig.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = max_abs / 127.0;
                let err = t_orig.max_abs_diff(t_got).unwrap();
                assert!(err <= 0.5 * scale + 1e-7, "err {err} vs scale {scale}");
            }
        }
    }

    #[test]
    fn q4g_roundtrip_is_block_bounded_and_sub_byte() {
        assert_eq!(
            CheckpointCodec::parse("q4g").unwrap(),
            CheckpointCodec::QuantI4Group
        );
        let ckpt = fedmlh_checkpoint(7);
        let dense = ckpt.to_bytes(CheckpointCodec::Dense).unwrap();
        let q4g = ckpt.to_bytes(CheckpointCodec::QuantI4Group).unwrap();
        let q8 = ckpt.to_bytes(CheckpointCodec::QuantI8).unwrap();
        assert!(
            (dense.len() as f64) / (q4g.len() as f64) >= 6.0,
            "q4g {} vs dense {}",
            q4g.len(),
            dense.len()
        );
        assert!(q4g.len() < q8.len(), "q4g {} vs q8 {}", q4g.len(), q8.len());
        let back = Checkpoint::from_bytes(&q4g).unwrap();
        assert_eq!(back.meta, ckpt.meta);
        // Lossy, but each value stays within half its block's int4 step.
        // The per-tensor max is an upper bound on every block max, so
        // 0.5 · (tensor_max / 7) bounds the per-tensor error too.
        for (orig, got) in ckpt.models.iter().zip(back.models.iter()) {
            for (t_orig, t_got) in orig.tensors.iter().zip(got.tensors.iter()) {
                let max_abs = t_orig.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = max_abs / 7.0;
                let err = t_orig.max_abs_diff(t_got).unwrap();
                assert!(err <= 0.5 * scale + 1e-7, "err {err} vs scale {scale}");
            }
        }
    }

    #[test]
    fn corruption_and_truncation_are_rejected() {
        let ckpt = fedmlh_checkpoint(3);
        let bytes = ckpt.to_bytes(CheckpointCodec::QuantI8).unwrap();
        // flip one payload byte → checksum mismatch
        let mut corrupt = bytes.clone();
        let mid = corrupt.len() / 2;
        corrupt[mid] ^= 0x40;
        let err = Checkpoint::from_bytes(&corrupt).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // truncate → checksum (or length) failure
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..3]).is_err());
        // trailing garbage → checksum failure
        let mut padded = bytes.clone();
        padded.extend_from_slice(&[0u8; 7]);
        assert!(Checkpoint::from_bytes(&padded).is_err());
    }

    #[test]
    fn wrong_magic_and_version_are_rejected() {
        let ckpt = fedmlh_checkpoint(4);
        let bytes = ckpt.to_bytes(CheckpointCodec::Dense).unwrap();
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        let err = Checkpoint::from_bytes(&wrong_magic).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        let mut wrong_version = bytes.clone();
        wrong_version[4] = 99;
        let err = Checkpoint::from_bytes(&wrong_version).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn save_load_through_the_filesystem() {
        let ckpt = fedmlh_checkpoint(5);
        let dir = std::env::temp_dir().join(format!("fedmlh_ckpt_{}", std::process::id()));
        let path = dir.join("tiny.fmlh");
        ckpt.save(&path, CheckpointCodec::Dense).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ckpt);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fedavg_checkpoint_shape_rules() {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let model = ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.preset.p, 1);
        let ckpt = Checkpoint::from_run(
            &cfg,
            Algo::FedAvg,
            cfg.preset.d,
            cfg.preset.p,
            vec![model.clone()],
        )
        .unwrap();
        assert_eq!(ckpt.r(), 1);
        let back = Checkpoint::from_bytes(&ckpt.to_bytes(CheckpointCodec::Dense).unwrap()).unwrap();
        assert_eq!(back, ckpt);
        // two models under fedavg is invalid
        assert!(Checkpoint::from_run(
            &cfg,
            Algo::FedAvg,
            cfg.preset.d,
            cfg.preset.p,
            vec![model.clone(), model],
        )
        .is_err());
    }

    /// A drifted copy standing in for "the same run, fine-tuned".
    fn drifted(ckpt: &Checkpoint, seed: u64, frac_changed: f64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let mut out = ckpt.clone();
        for m in out.models.iter_mut() {
            for t in m.tensors.iter_mut() {
                for v in t.data_mut() {
                    if (rng.next_f32() as f64) < frac_changed {
                        *v += (rng.next_f32() - 0.5) * 0.1;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn delta_codec_names_parse() {
        for codec in [DeltaCodec::Sparse, DeltaCodec::QuantI8Diff] {
            assert_eq!(DeltaCodec::parse(codec.name()).unwrap(), codec);
        }
        assert_eq!(DeltaCodec::parse("q8").unwrap(), DeltaCodec::QuantI8Diff);
        assert!(DeltaCodec::parse("dense").is_err());
    }

    #[test]
    fn sparse_delta_roundtrips_bitwise() {
        let base = fedmlh_checkpoint(10);
        let tuned = drifted(&base, 11, 1.0);
        let delta = tuned.delta_against(&base, DeltaCodec::Sparse).unwrap();
        let bytes = delta.to_bytes().unwrap();
        let back = DeltaCheckpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, delta);
        // Applying reproduces the tuned checkpoint bit for bit.
        assert_eq!(back.apply(&base).unwrap(), tuned);
    }

    #[test]
    fn sparse_delta_is_small_when_little_changed() {
        let base = fedmlh_checkpoint(12);
        let tuned = drifted(&base, 13, 0.05);
        let delta = tuned.delta_against(&base, DeltaCodec::Sparse).unwrap();
        let bytes = delta.to_bytes().unwrap();
        assert!(
            bytes.len() < tuned.dense_byte_size() / 2,
            "sparse delta {} bytes vs dense {}",
            bytes.len(),
            tuned.dense_byte_size()
        );
        assert_eq!(delta.apply(&base).unwrap(), tuned);
    }

    #[test]
    fn q8diff_delta_is_scale_bounded() {
        let base = fedmlh_checkpoint(14);
        let tuned = drifted(&base, 15, 1.0);
        let delta = tuned.delta_against(&base, DeltaCodec::QuantI8Diff).unwrap();
        let back = delta.apply(&base).unwrap();
        for ((m_t, m_b), m_base) in
            tuned.models.iter().zip(back.models.iter()).zip(base.models.iter())
        {
            for ((t_t, t_b), t_base) in
                m_t.tensors.iter().zip(m_b.tensors.iter()).zip(m_base.tensors.iter())
            {
                // Error bound follows the *diff* magnitude, not the
                // absolute parameter magnitude.
                let max_diff = t_t
                    .data()
                    .iter()
                    .zip(t_base.data().iter())
                    .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
                let bound = max_diff / 127.0 * 0.5 + 1e-6;
                let err = t_t.max_abs_diff(t_b).unwrap();
                assert!(err <= bound, "err {err} vs diff bound {bound}");
            }
        }
    }

    #[test]
    fn delta_refuses_the_wrong_base() {
        let base = fedmlh_checkpoint(16);
        let other = drifted(&base, 17, 1.0);
        let tuned = drifted(&base, 18, 1.0);
        let delta = tuned.delta_against(&base, DeltaCodec::Sparse).unwrap();
        let err = delta.apply(&other).unwrap_err();
        assert!(err.to_string().contains("does not chain"), "{err}");
    }

    #[test]
    fn delta_chain_applies_in_order_through_the_filesystem() {
        let a = fedmlh_checkpoint(19);
        let b = drifted(&a, 20, 0.3);
        let c = drifted(&b, 21, 0.3);
        let d_ab = b.delta_against(&a, DeltaCodec::Sparse).unwrap();
        let d_bc = c.delta_against(&b, DeltaCodec::Sparse).unwrap();
        let dir = std::env::temp_dir().join(format!("fedmlh_delta_{}", std::process::id()));
        let base_path = dir.join("base.fmlh");
        let p_ab = dir.join("d_ab.fmlh");
        let p_bc = dir.join("d_bc.fmlh");
        a.save(&base_path, CheckpointCodec::Dense).unwrap();
        d_ab.save(&p_ab).unwrap();
        d_bc.save(&p_bc).unwrap();
        let chained =
            Checkpoint::load_chain(&base_path, &[p_ab.clone(), p_bc.clone()]).unwrap();
        assert_eq!(chained, c, "base + d(a→b) + d(b→c) must equal c bitwise");
        // Out of order fails loudly on the checksum.
        assert!(Checkpoint::load_chain(&base_path, &[p_bc, p_ab]).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn full_and_delta_magics_cross_reject_with_hints() {
        let base = fedmlh_checkpoint(22);
        let tuned = drifted(&base, 23, 1.0);
        let delta = tuned.delta_against(&base, DeltaCodec::Sparse).unwrap();
        let delta_bytes = delta.to_bytes().unwrap();
        let full_bytes = base.to_bytes(CheckpointCodec::Dense).unwrap();
        let err = Checkpoint::from_bytes(&delta_bytes).unwrap_err();
        assert!(err.to_string().contains("delta checkpoint"), "{err}");
        let err = DeltaCheckpoint::from_bytes(&full_bytes).unwrap_err();
        assert!(err.to_string().contains("full checkpoint"), "{err}");
        // corruption flips the checksum
        let mut corrupt = delta_bytes.clone();
        let mid = corrupt.len() / 2;
        corrupt[mid] ^= 0x10;
        assert!(DeltaCheckpoint::from_bytes(&corrupt).is_err());
    }

    #[test]
    fn seeds_match_the_training_streams() {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let ckpt = fedmlh_checkpoint(6);
        assert_eq!(ckpt.meta.hash_seed, crate::algo::fedmlh::label_hash_seed(cfg.seed));
        assert_eq!(ckpt.meta.feat_seed, crate::data::synth::feature_hash_seed(cfg.seed));
        assert_eq!(ckpt.meta.root_seed, cfg.seed);
    }
}
