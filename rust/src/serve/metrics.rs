//! Serving telemetry behind `GET /metrics`: request count, p50/p99
//! prediction latency, and the micro-batcher's batch-size histogram
//! (the direct evidence that request coalescing is happening).
//!
//! Latencies are kept in a fixed-size ring (the most recent
//! [`LATENCY_WINDOW`] predictions) so the percentiles track current
//! behavior and memory stays bounded under sustained traffic. The
//! batch histogram uses power-of-two buckets: bucket 0 counts
//! single-row forwards, bucket i counts batch sizes in (2^(i−1), 2^i].

use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;

/// Ring size for latency percentiles.
pub const LATENCY_WINDOW: usize = 4096;

/// Shared, thread-safe serving counters.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    errors: u64,
    /// Most recent prediction latencies (µs), ring-written.
    latencies_us: Vec<u64>,
    next_slot: usize,
    /// Power-of-two batch-size buckets (index = ceil(log2(size))).
    batch_buckets: Vec<u64>,
    batches: u64,
    batched_rows: u64,
}

/// A point-in-time copy of the counters, ready to serialize.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub errors: u64,
    /// Median prediction latency over the ring window (µs).
    pub p50_us: u64,
    /// 99th-percentile prediction latency over the ring window (µs).
    pub p99_us: u64,
    /// Forward passes executed by the micro-batcher.
    pub batches: u64,
    /// Total rows across those forward passes.
    pub batched_rows: u64,
    /// `(bucket upper bound, count)` pairs, smallest bucket first.
    pub batch_hist: Vec<(usize, u64)>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one `/predict` request. Latency enters the percentile
    /// ring only for successful predictions — rejected requests fail in
    /// microseconds and would drag p50/p99 far below what real
    /// inference costs, misleading anything alerting on them.
    pub fn record_request(&self, latency: Duration, ok: bool) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        let mut inner = self.inner.lock().unwrap();
        inner.requests += 1;
        if !ok {
            inner.errors += 1;
            return;
        }
        if inner.latencies_us.len() < LATENCY_WINDOW {
            inner.latencies_us.push(us);
        } else {
            let slot = inner.next_slot;
            inner.latencies_us[slot] = us;
            inner.next_slot = (slot + 1) % LATENCY_WINDOW;
        }
    }

    /// Record one coalesced forward pass of `rows` rows.
    pub fn record_batch(&self, rows: usize) {
        if rows == 0 {
            return;
        }
        let bucket = (usize::BITS - (rows - 1).leading_zeros()) as usize;
        let mut inner = self.inner.lock().unwrap();
        if inner.batch_buckets.len() <= bucket {
            inner.batch_buckets.resize(bucket + 1, 0);
        }
        inner.batch_buckets[bucket] += 1;
        inner.batches += 1;
        inner.batched_rows += rows as u64;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        // Copy everything out under the lock, then do the O(n log n)
        // percentile sort outside it — a scrape must not stall the
        // predict path for the duration of sorting a 4096-entry ring.
        let (requests, errors, mut sorted, batches, batched_rows, batch_buckets) = {
            let inner = self.inner.lock().unwrap();
            (
                inner.requests,
                inner.errors,
                inner.latencies_us.clone(),
                inner.batches,
                inner.batched_rows,
                inner.batch_buckets.clone(),
            )
        };
        sorted.sort_unstable();
        let pick = |q: f64| -> u64 {
            if sorted.is_empty() {
                0
            } else {
                sorted[((sorted.len() as f64 - 1.0) * q).round() as usize]
            }
        };
        MetricsSnapshot {
            requests,
            errors,
            p50_us: pick(0.50),
            p99_us: pick(0.99),
            batches,
            batched_rows,
            batch_hist: batch_buckets
                .iter()
                .enumerate()
                .map(|(i, &count)| (1usize << i, count))
                .collect(),
        }
    }
}

impl MetricsSnapshot {
    /// The `GET /metrics` response body.
    pub fn to_json(&self) -> Json {
        let hist = Json::Arr(
            self.batch_hist
                .iter()
                .map(|&(le, count)| {
                    Json::obj(vec![
                        ("batch_le", Json::num(le as f64)),
                        ("count", Json::num(count as f64)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("latency_p50_us", Json::num(self.p50_us as f64)),
            ("latency_p99_us", Json::num(self.p99_us as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("batched_rows", Json::num(self.batched_rows as f64)),
            ("batch_size_hist", hist),
        ])
    }

    /// Compact JSON row used by the control plane's per-version
    /// `versions` array on `GET /metrics`: request/error counts plus
    /// latency percentiles, without the batch histogram (batching is a
    /// per-process property, not a per-version one).
    pub fn to_json_brief(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("latency_p50_us", Json::num(self.p50_us as f64)),
            ("latency_p99_us", Json::num(self.p99_us as f64)),
        ])
    }

    /// Prometheus text exposition of the same snapshot
    /// (`GET /metrics?format=prometheus`). Serve-local metrics use the
    /// `fedmlh_serve_*` prefix, disjoint from the training registry's
    /// `fedmlh_*` names, so both renders concatenate cleanly.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut scalar = |name: &str, kind: &str, help: &str, value: u64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        scalar(
            "fedmlh_serve_requests_total",
            "counter",
            "Predict requests received.",
            self.requests,
        );
        scalar(
            "fedmlh_serve_errors_total",
            "counter",
            "Predict requests that failed.",
            self.errors,
        );
        scalar(
            "fedmlh_serve_latency_p50_us",
            "gauge",
            "Median prediction latency over the ring window (microseconds).",
            self.p50_us,
        );
        scalar(
            "fedmlh_serve_latency_p99_us",
            "gauge",
            "99th-percentile prediction latency over the ring window (microseconds).",
            self.p99_us,
        );
        // Batch-size histogram: per-bucket counts become the cumulative
        // `le` buckets Prometheus expects; rows/batches double as _sum
        // and _count.
        let name = "fedmlh_serve_batch_size";
        out.push_str(&format!(
            "# HELP {name} Rows per coalesced forward pass.\n# TYPE {name} histogram\n"
        ));
        let mut running = 0u64;
        for &(le, count) in &self.batch_hist {
            running += count;
            out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {running}\n"));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {running}\n"));
        out.push_str(&format!("{name}_sum {}\n", self.batched_rows));
        out.push_str(&format!("{name}_count {}\n", self.batches));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_percentiles() {
        let m = ServeMetrics::new();
        for us in 1..=100u64 {
            m.record_request(Duration::from_micros(us), us != 7);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.errors, 1);
        // 99 successful samples (the failed us=7 request is excluded
        // from the ring): pick(0.5) → sorted[49] = 51, pick(0.99) →
        // sorted[97] = 99.
        assert_eq!(s.p50_us, 51);
        assert_eq!(s.p99_us, 99);
    }

    #[test]
    fn ring_keeps_only_the_window() {
        let m = ServeMetrics::new();
        for _ in 0..LATENCY_WINDOW {
            m.record_request(Duration::from_micros(1_000_000), true);
        }
        // overwrite the whole window with fast requests
        for _ in 0..LATENCY_WINDOW {
            m.record_request(Duration::from_micros(10), true);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 2 * LATENCY_WINDOW as u64);
        assert_eq!(s.p99_us, 10, "old slow samples must have been evicted");
    }

    #[test]
    fn batch_buckets_are_powers_of_two() {
        let m = ServeMetrics::new();
        for rows in [1usize, 1, 2, 3, 4, 5, 8, 9, 16] {
            m.record_batch(rows);
        }
        m.record_batch(0); // ignored
        let s = m.snapshot();
        assert_eq!(s.batches, 9);
        assert_eq!(s.batched_rows, 1 + 1 + 2 + 3 + 4 + 5 + 8 + 9 + 16);
        let hist: std::collections::BTreeMap<usize, u64> =
            s.batch_hist.into_iter().collect();
        assert_eq!(hist[&1], 2); // two single-row batches
        assert_eq!(hist[&2], 1); // size 2
        assert_eq!(hist[&4], 2); // sizes 3, 4
        assert_eq!(hist[&8], 2); // sizes 5, 8
        assert_eq!(hist[&16], 2); // sizes 9, 16
    }

    #[test]
    fn metrics_json_shape() {
        let m = ServeMetrics::new();
        m.record_request(Duration::from_micros(42), true);
        m.record_batch(3);
        let j = m.snapshot().to_json();
        assert_eq!(j.expect("requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.expect("latency_p50_us").unwrap().as_f64().unwrap(), 42.0);
        let hist = j.expect("batch_size_hist").unwrap().as_arr().unwrap();
        assert!(!hist.is_empty());
    }

    #[test]
    fn metrics_prometheus_shape() {
        let m = ServeMetrics::new();
        m.record_request(Duration::from_micros(42), true);
        m.record_request(Duration::from_micros(10), false);
        m.record_batch(1);
        m.record_batch(3);
        let text = m.snapshot().to_prometheus();
        assert!(text.contains("# TYPE fedmlh_serve_requests_total counter\n"));
        assert!(text.contains("fedmlh_serve_requests_total 2\n"));
        assert!(text.contains("fedmlh_serve_errors_total 1\n"));
        assert!(text.contains("fedmlh_serve_latency_p50_us 42\n"));
        // Cumulative buckets: le=1 holds the single-row batch, le=2
        // stays at 1, le=4 adds the size-3 batch, +Inf matches _count.
        assert!(text.contains("fedmlh_serve_batch_size_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("fedmlh_serve_batch_size_bucket{le=\"2\"} 1\n"));
        assert!(text.contains("fedmlh_serve_batch_size_bucket{le=\"4\"} 2\n"));
        assert!(text.contains("fedmlh_serve_batch_size_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("fedmlh_serve_batch_size_sum 4\n"));
        assert!(text.contains("fedmlh_serve_batch_size_count 2\n"));
    }
}
