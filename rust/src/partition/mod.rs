//! Client data partitioning.
//!
//! - [`noniid`] — the paper's frequent-class partition (Section 6,
//!   Fig. 2c): each frequent class's positive samples go to one random
//!   client, so clients see disjoint frequent classes.
//! - [`iid`] — uniform random control partition.
//! - [`divergence`] — pairwise KL divergence of client label
//!   distributions, the quantity Theorem 2 proves label hashing shrinks.

pub mod divergence;
pub mod iid;
pub mod noniid;

/// A partition of train-sample indices across clients. A sample may
/// appear on several clients (the paper: "samples with more than one
/// positive instance among frequent classes are assigned to multiple
/// clients").
#[derive(Clone, Debug)]
pub struct Partition {
    /// Sample indices per client.
    pub clients: Vec<Vec<usize>>,
    /// frequent class id → owning client (empty for iid partitions).
    pub class_owner: Vec<(u32, usize)>,
}

impl Partition {
    /// Total assignments (≥ dataset size when samples are replicated).
    pub fn total_assignments(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }

    /// Every sample index in [0, n) appears on at least one client.
    pub fn covers(&self, n: usize) -> bool {
        let mut seen = vec![false; n];
        for c in &self.clients {
            for &i in c {
                if i >= n {
                    return false;
                }
                seen[i] = true;
            }
        }
        seen.into_iter().all(|s| s)
    }

    /// The data shard behind a (possibly virtual) client id. Registry
    /// clients beyond the partition width wrap onto the underlying
    /// shards (`client % clients.len()`), so the async simulator can
    /// address a million-client registry over a K-shard partition
    /// without materializing per-client data. For `client <
    /// clients.len()` this is exactly `&self.clients[client]`.
    pub fn shard(&self, client: usize) -> &[usize] {
        &self.clients[client % self.clients.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_wraps_virtual_clients_onto_real_shards() {
        let p = Partition {
            clients: vec![vec![0, 1], vec![2], vec![3, 4, 5]],
            class_owner: vec![],
        };
        assert_eq!(p.shard(1), &[2][..]);
        assert_eq!(p.shard(4), &[2][..], "client 4 wraps onto shard 1");
        assert_eq!(p.shard(999_999), p.shard(999_999 % 3));
    }
}
