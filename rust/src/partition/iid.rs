//! IID control partition: uniform random shuffle split across clients.
//! Used by ablation benches to isolate how much of FedMLH's gain comes
//! from the non-iid adjustment (Theorem 2) vs the class re-balancing
//! (Lemma 1).

use crate::util::rng::{derive_seed, Rng};

use super::Partition;

/// Split `n` samples uniformly across `clients` (near-equal sizes,
/// no replication).
pub fn partition(n: usize, clients: usize, seed: u64) -> Partition {
    assert!(clients > 0);
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(derive_seed(seed, 0x11d));
    rng.shuffle(&mut idx);
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); clients];
    for (pos, i) in idx.into_iter().enumerate() {
        out[pos % clients].push(i);
    }
    Partition {
        clients: out,
        class_owner: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_without_replication() {
        let part = partition(103, 10, 5);
        assert!(part.covers(103));
        assert_eq!(part.total_assignments(), 103);
    }

    #[test]
    fn balanced_sizes() {
        let part = partition(100, 8, 1);
        for c in &part.clients {
            assert!(c.len() == 12 || c.len() == 13, "{}", c.len());
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(partition(50, 4, 7).clients, partition(50, 4, 7).clients);
        assert_ne!(partition(50, 4, 7).clients, partition(50, 4, 8).clients);
    }
}
