//! Inter-client class-distribution divergence (Theorem 2 empirics).
//!
//! π^(k) is client k's positive-instance proportion over classes;
//! ω^(k) is the same over FedMLH's B buckets. Theorem 2:
//! `KL(ω^(a), ω^(b)) < KL(π^(a), π^(b))` — hashing into fewer buckets
//! strictly shrinks the divergence. The harness computes both on real
//! partitions (and the theory tests on random simplexes).

use crate::data::dataset::Dataset;
use crate::hashing::label_hash::LabelHasher;

use super::Partition;

/// Smoothed positive-instance proportions per class for one client.
/// Theorem 2 assumes strictly positive proportions; empirical
/// distributions have zeros, so we add-ε smooth before normalizing
/// (standard for empirical KL).
pub fn class_distribution(ds: &Dataset, samples: &[usize], eps: f64) -> Vec<f64> {
    let mut counts = vec![0.0f64; ds.p()];
    for &i in samples {
        for &l in ds.labels_of(i) {
            counts[l as usize] += 1.0;
        }
    }
    normalize_smoothed(&mut counts, eps);
    counts
}

/// Same but over buckets of one hash table.
pub fn bucket_distribution(
    ds: &Dataset,
    samples: &[usize],
    hasher: &LabelHasher,
    table: usize,
    eps: f64,
) -> Vec<f64> {
    let mut counts = vec![0.0f64; hasher.b()];
    for &i in samples {
        for &l in ds.labels_of(i) {
            counts[hasher.bucket(table, l as usize)] += 1.0;
        }
    }
    normalize_smoothed(&mut counts, eps);
    counts
}

fn normalize_smoothed(counts: &mut [f64], eps: f64) {
    for c in counts.iter_mut() {
        *c += eps;
    }
    let total: f64 = counts.iter().sum();
    for c in counts.iter_mut() {
        *c /= total;
    }
}

/// KL(a ‖ b) in nats; inputs must be strictly positive distributions.
pub fn kl(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&pa, &pb)| {
            debug_assert!(pa > 0.0 && pb > 0.0);
            pa * (pa / pb).ln()
        })
        .sum()
}

/// KL over bucket aggregates of two distributions that share a
/// class→bucket map: a bucket is empty in `a` iff it is empty in `b`
/// (it received no classes), and such paired zeros contribute 0
/// (lim x→0 of x·ln(x/x)). Any `a_i > 0, b_i = 0` would be an infinite
/// divergence and is rejected.
pub fn kl_shared_support(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&pa, &pb)| {
            if pa == 0.0 && pb == 0.0 {
                0.0
            } else {
                assert!(
                    pa > 0.0 && pb > 0.0,
                    "supports differ: {pa} vs {pb} — not a shared-map aggregate"
                );
                pa * (pa / pb).ln()
            }
        })
        .sum()
}

/// Map a class distribution to the induced bucket distribution under a
/// class→bucket map (pure aggregation; used by Theorem 2 MC checks).
pub fn aggregate_to_buckets(pi: &[f64], bucket_of: &[usize], b: usize) -> Vec<f64> {
    assert_eq!(pi.len(), bucket_of.len());
    let mut omega = vec![0.0f64; b];
    for (j, &p) in pi.iter().enumerate() {
        omega[bucket_of[j]] += p;
    }
    omega
}

/// Mean pairwise KL across clients for class distributions (π) and, per
/// hash table, bucket distributions (ω). Returns (kl_pi, mean kl_omega).
pub fn mean_pairwise_divergence(
    ds: &Dataset,
    part: &Partition,
    hasher: &LabelHasher,
    eps: f64,
) -> (f64, f64) {
    let k = part.clients.len();
    let pis: Vec<Vec<f64>> = part
        .clients
        .iter()
        .map(|s| class_distribution(ds, s, eps))
        .collect();
    let mut kl_pi = 0.0;
    let mut pairs = 0usize;
    for a in 0..k {
        for b in 0..k {
            if a != b {
                kl_pi += kl(&pis[a], &pis[b]);
                pairs += 1;
            }
        }
    }
    kl_pi /= pairs.max(1) as f64;

    let mut kl_omega = 0.0;
    for t in 0..hasher.r() {
        let oms: Vec<Vec<f64>> = part
            .clients
            .iter()
            .map(|s| bucket_distribution(ds, s, hasher, t, eps))
            .collect();
        let mut acc = 0.0;
        for a in 0..k {
            for b in 0..k {
                if a != b {
                    acc += kl(&oms[a], &oms[b]);
                }
            }
        }
        kl_omega += acc / pairs.max(1) as f64;
    }
    kl_omega /= hasher.r() as f64;
    (kl_pi, kl_omega)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn kl_basics() {
        let u = vec![0.5, 0.5];
        assert!(kl(&u, &u).abs() < 1e-12);
        let v = vec![0.9, 0.1];
        assert!(kl(&v, &u) > 0.0);
        // KL is asymmetric
        assert!((kl(&v, &u) - kl(&u, &v)).abs() > 1e-6);
    }

    #[test]
    fn aggregation_preserves_mass() {
        check("bucket mass", 30, |g| {
            let p = g.usize_in(4, 200);
            let b = g.usize_in(1, p);
            let pi = g.simplex(p);
            let bucket_of: Vec<usize> = (0..p).map(|_| g.usize_in(0, b)).collect();
            let om = aggregate_to_buckets(&pi, &bucket_of, b);
            let total: f64 = om.iter().sum();
            assert!((total - 1.0).abs() < 1e-9);
        });
    }

    #[test]
    fn theorem2_holds_on_random_simplexes() {
        // KL over buckets <= KL over classes, for any shared class→bucket
        // map and any two strictly-positive class distributions.
        check("theorem 2", 50, |g| {
            let p = g.usize_in(4, 300);
            let b = g.usize_in(1, p);
            let pi_a = g.simplex(p);
            let pi_b = g.simplex(p);
            let bucket_of: Vec<usize> = (0..p).map(|_| g.usize_in(0, b)).collect();
            let om_a = aggregate_to_buckets(&pi_a, &bucket_of, b);
            let om_b = aggregate_to_buckets(&pi_b, &bucket_of, b);
            // remove empty buckets (KL needs positive support)
            let (oa, ob): (Vec<f64>, Vec<f64>) = om_a
                .iter()
                .zip(om_b.iter())
                .filter(|(&a, &b)| a > 0.0 && b > 0.0)
                .unzip();
            let lhs = kl(&oa, &ob);
            let rhs = kl(&pi_a, &pi_b);
            assert!(
                lhs <= rhs + 1e-9,
                "KL(omega)={lhs} > KL(pi)={rhs} (p={p}, b={b})"
            );
        });
    }

    #[test]
    fn noniid_partition_diverges_more_than_iid() {
        use crate::config::presets::by_name;
        use crate::data::synth::{generate, SynthSpec};
        use crate::partition::{iid, noniid};

        // Enough samples that sampling noise (which inflates the iid KL)
        // is small next to the structural divergence of the partition.
        let mut spec = SynthSpec::from_preset(&by_name("tiny").unwrap());
        spec.n_train = 4000;
        let ds = generate(&spec, 4).train;
        let hasher = LabelHasher::new(4, 2, ds.p(), 16);
        let non = noniid::partition(&ds, &noniid::NonIidOptions::new(6), 1);
        let iid_part = iid::partition(ds.len(), 6, 1);
        let (kl_non, _) = mean_pairwise_divergence(&ds, &non, &hasher, 1e-3);
        let (kl_iid, _) = mean_pairwise_divergence(&ds, &iid_part, &hasher, 1e-3);
        assert!(
            kl_non > 1.5 * kl_iid,
            "non-iid KL {kl_non} not >> iid KL {kl_iid}"
        );
    }

    #[test]
    fn hashing_shrinks_divergence_on_real_partition() {
        use crate::config::presets::by_name;
        use crate::data::synth::{generate, SynthSpec};
        use crate::partition::noniid;

        let mut spec = SynthSpec::from_preset(&by_name("tiny").unwrap());
        spec.n_train = 800;
        let ds = generate(&spec, 4).train;
        let hasher = LabelHasher::new(4, 2, ds.p(), 8);
        let part = noniid::partition(&ds, &noniid::NonIidOptions::new(6), 1);
        let (kl_pi, kl_omega) = mean_pairwise_divergence(&ds, &part, &hasher, 1e-3);
        assert!(
            kl_omega < kl_pi,
            "bucket KL {kl_omega} not below class KL {kl_pi}"
        );
    }

    #[test]
    fn class_distribution_counts() {
        let mut ds = Dataset::new(1, 3);
        ds.push(&[0.0], &[0, 1]).unwrap();
        ds.push(&[0.0], &[0]).unwrap();
        let d = class_distribution(&ds, &[0, 1], 0.0);
        assert!((d[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!((d[1] - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(d[2], 0.0);
        let _ = Rng::new(0);
    }
}
