//! The paper's non-iid partition (Section 6 "Non-iid data partition").
//!
//! For each *frequent* class j: collect D(j) = {samples with y_j = 1}
//! and assign all of D(j) to one uniformly-drawn client. Clients thus
//! hold disjoint sets of frequent classes (Fig. 2c); samples positive in
//! several frequent classes are replicated onto each owner. Samples with
//! no frequent positive are assigned to one random client so the
//! partition covers the dataset.

use crate::data::dataset::Dataset;
use crate::data::stats::LabelStats;
use crate::util::rng::{derive_seed, Rng};

use super::Partition;

/// Options for the frequent-class partitioner.
#[derive(Clone, Debug)]
pub struct NonIidOptions {
    /// Number of clients K.
    pub clients: usize,
    /// How many top classes count as "frequent". The paper partitions on
    /// the classes that dominate Fig. 2a's head; we default to 4 per
    /// client so every client owns a few frequent classes.
    pub frequent_classes: usize,
}

impl NonIidOptions {
    pub fn new(clients: usize) -> Self {
        NonIidOptions {
            clients,
            frequent_classes: 4 * clients,
        }
    }
}

/// Build the paper's non-iid partition.
pub fn partition(ds: &Dataset, opts: &NonIidOptions, seed: u64) -> Partition {
    assert!(opts.clients > 0);
    let stats = LabelStats::from_dataset(ds);
    let frequent = stats.top_k_classes(opts.frequent_classes);
    let mut rng = Rng::new(derive_seed(seed, 0x9a47));

    // class → owning client
    let mut owner_of_class = vec![usize::MAX; ds.p()];
    let mut class_owner: Vec<(u32, usize)> = Vec::with_capacity(frequent.len());
    for (rank, &c) in frequent.iter().enumerate() {
        // Round-robin over a shuffled client order keeps client loads
        // balanced while the *choice* of classes per client stays random
        // (pure uniform draws can starve a client of frequent classes).
        let k = if rank % opts.clients == 0 {
            rng.below(opts.clients)
        } else {
            (class_owner[rank - 1].1 + 1) % opts.clients
        };
        owner_of_class[c as usize] = k;
        class_owner.push((c, k));
    }

    let mut clients: Vec<Vec<usize>> = vec![Vec::new(); opts.clients];
    for i in 0..ds.len() {
        let mut assigned = [false; 64];
        debug_assert!(opts.clients <= 64);
        let mut any = false;
        for &l in ds.labels_of(i) {
            let owner = owner_of_class[l as usize];
            if owner != usize::MAX && !assigned[owner] {
                clients[owner].push(i);
                assigned[owner] = true;
                any = true;
            }
        }
        if !any {
            clients[rng.below(opts.clients)].push(i);
        }
    }

    Partition {
        clients,
        class_owner,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::data::synth::{generate, SynthSpec};
    use crate::util::prop::check;

    fn tiny_data() -> Dataset {
        let mut spec = SynthSpec::from_preset(&by_name("tiny").unwrap());
        spec.n_train = 600;
        generate(&spec, 3).train
    }

    #[test]
    fn covers_all_samples() {
        let ds = tiny_data();
        let part = partition(&ds, &NonIidOptions::new(10), 1);
        assert!(part.covers(ds.len()));
        assert_eq!(part.clients.len(), 10);
    }

    #[test]
    fn frequent_classes_have_single_owner() {
        let ds = tiny_data();
        let part = partition(&ds, &NonIidOptions::new(10), 1);
        // ownership map is a function: each class appears once
        let mut seen = std::collections::HashSet::new();
        for (c, k) in &part.class_owner {
            assert!(seen.insert(*c), "class {c} owned twice");
            assert!(*k < 10);
        }
        // every positive sample of an owned class is on the owner
        for (c, k) in &part.class_owner {
            for i in 0..ds.len() {
                if ds.labels_of(i).contains(c) {
                    assert!(
                        part.clients[*k].contains(&i),
                        "sample {i} of class {c} missing from client {k}"
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = tiny_data();
        let a = partition(&ds, &NonIidOptions::new(5), 9);
        let b = partition(&ds, &NonIidOptions::new(5), 9);
        assert_eq!(a.clients, b.clients);
        let c = partition(&ds, &NonIidOptions::new(5), 10);
        assert_ne!(a.clients, c.clients);
    }

    #[test]
    fn clients_have_distinct_frequent_profiles() {
        // The point of the partition: client class distributions diverge.
        let ds = tiny_data();
        let part = partition(&ds, &NonIidOptions::new(4), 2);
        // each client's dominant frequent class should be owned by it
        for (c, k) in part.class_owner.iter().take(4) {
            let count_owner = part.clients[*k]
                .iter()
                .filter(|&&i| ds.labels_of(i).contains(c))
                .count();
            for other in 0..4 {
                if other == *k {
                    continue;
                }
                let count_other = part.clients[other]
                    .iter()
                    .filter(|&&i| ds.labels_of(i).contains(c))
                    .count();
                assert!(
                    count_owner >= count_other,
                    "class {c}: owner {k} has {count_owner} < client {other}'s {count_other}"
                );
            }
        }
    }

    #[test]
    fn every_client_nonempty_on_reasonable_data() {
        check("nonempty clients", 5, |g| {
            let ds = tiny_data();
            let k = g.usize_in(2, 11);
            let part = partition(&ds, &NonIidOptions::new(k), g.rng().next_u64());
            for (i, c) in part.clients.iter().enumerate() {
                assert!(!c.is_empty(), "client {i}/{k} empty");
            }
        });
    }
}
