//! Lemma 1 — FedMLH re-balances the class distribution.
//!
//! If class `j` (with `n_j` positives) hashes into bucket `i`, the other
//! `p − 1` classes land in the same bucket independently with
//! probability `1/B` each, so the bucket's expected positive count is
//! bounded below by
//!
//! ```text
//! E(B_i | h(j) = i) ≥ n_j + (N_lab − n_j)/B − N_lab/B²
//! ```
//!
//! (the `N_lab/B²` term absorbs double-counting of samples positive in
//! more than one co-hashed class). For an infrequent class, the bucket
//! sees ~`N_lab/B` positives instead of `n_j` — the mechanism behind the
//! paper's infrequent-class accuracy gains (Fig. 3).

use crate::util::rng::{derive_seed, Rng};

/// The closed-form lower bound on a bucket's expected positive count.
pub fn lemma1_lower_bound(n_j: usize, n_lab: usize, b: usize) -> f64 {
    assert!(b >= 1, "need at least one bucket");
    assert!(n_j <= n_lab, "class count cannot exceed total positives");
    let (n_j, n_lab, b) = (n_j as f64, n_lab as f64, b as f64);
    n_j + (n_lab - n_j) / b - n_lab / (b * b)
}

/// Monte-Carlo estimate of `E(B_i | h(j) = i)`: draw `trials` random
/// class→bucket assignments, always conditioning class `j` into a fixed
/// bucket, and average the positives that land with it. `class_counts`
/// are the per-class positive-instance counts `n_1..n_p` (labels assumed
/// independent across classes, as in the lemma).
pub fn expected_bucket_positives_mc(
    class_counts: &[usize],
    j: usize,
    b: usize,
    trials: usize,
    seed: u64,
) -> f64 {
    expected_bucket_positives_mc_stats(class_counts, j, b, trials, seed).0
}

/// As [`expected_bucket_positives_mc`] but also returns the standard
/// error of the mean, so callers can judge `MC ≥ bound` up to noise
/// (a handful of heavy classes dominate the per-trial variance, so a
/// few hundred trials can sit 1–2 SE below the exact expectation).
pub fn expected_bucket_positives_mc_stats(
    class_counts: &[usize],
    j: usize,
    b: usize,
    trials: usize,
    seed: u64,
) -> (f64, f64) {
    assert!(j < class_counts.len());
    assert!(b >= 1 && trials >= 1);
    let mut sum = 0.0f64;
    let mut sum_sq = 0.0f64;
    for t in 0..trials {
        let mut rng = Rng::new(derive_seed(seed, 0x1e_a001 + t as u64));
        // class j is conditioned into bucket 0; every other class joins
        // independently with probability 1/B.
        let mut in_bucket = class_counts[j] as f64;
        for (c, &n_c) in class_counts.iter().enumerate() {
            if c != j && rng.below(b) == 0 {
                in_bucket += n_c as f64;
            }
        }
        sum += in_bucket;
        sum_sq += in_bucket * in_bucket;
    }
    let n = trials as f64;
    let mean = sum / n;
    let var = (sum_sq / n - mean * mean).max(0.0);
    (mean, (var / n).sqrt())
}

/// Exact expectation under independent uniform hashing:
/// `n_j + (N_lab − n_j)/B` (the quantity the lemma lower-bounds).
pub fn expected_bucket_positives_exact(n_j: usize, n_lab: usize, b: usize) -> f64 {
    n_j as f64 + (n_lab - n_j) as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn bound_reduces_to_nj_at_b_one_ish() {
        // B = 1: all positives share the bucket; bound = n_lab − n_lab = 0·? —
        // exact: n_j + (N − n_j) − N = 0... the bound is loose at B=1 but
        // must not exceed the truth (N_lab).
        assert!(lemma1_lower_bound(10, 100, 1) <= 100.0);
        // Large B: bound → n_j.
        let v = lemma1_lower_bound(10, 100, 1_000_000);
        assert!((v - 10.0).abs() < 0.01, "{v}");
    }

    #[test]
    fn paper_magnitude_example() {
        // Paper §5.1: a class with N_lab/p positives gets ~32× more
        // positives in its bucket under the AMZtitle setup (p/B ≈ 16384/1024
        // scaled here; the paper's real ratio ≈ p/B).
        let p = 16384usize;
        let b = 1024usize;
        let n_lab = 1_000_000usize;
        let n_j = n_lab / p; // 61
        let bound = lemma1_lower_bound(n_j, n_lab, b);
        let gain = bound / n_j as f64;
        assert!(gain > 10.0, "expected order-of-magnitude gain, got {gain}");
    }

    #[test]
    fn mc_respects_bound() {
        // Zipf-ish class counts; MC mean must sit at or above the bound.
        let counts: Vec<usize> = (1..=200).map(|r| 2000 / r).collect();
        let n_lab: usize = counts.iter().sum();
        for &j in &[0usize, 50, 199] {
            for &b in &[4usize, 16, 64] {
                let mc = expected_bucket_positives_mc(&counts, j, b, 400, 7);
                let bound = lemma1_lower_bound(counts[j], n_lab, b);
                assert!(
                    mc >= bound - 1e-9,
                    "MC {mc} below bound {bound} (j={j}, B={b})"
                );
            }
        }
    }

    #[test]
    fn mc_matches_exact_expectation() {
        // Exact: n_j + (N_lab − n_j)/B under independent hashing (the MC
        // samples exactly this process, without the multi-label overlap
        // the −N/B² term guards against).
        check("lemma1 exact expectation", 10, |g| {
            let p = g.usize_in(5, 40);
            let counts: Vec<usize> = (0..p).map(|_| g.usize_in(0, 50)).collect();
            let n_lab: usize = counts.iter().sum();
            let j = g.usize_in(0, p - 1);
            let b = g.usize_in(2, 16);
            let mc = expected_bucket_positives_mc(&counts, j, b, 3000, 11);
            let exact =
                counts[j] as f64 + (n_lab - counts[j]) as f64 / b as f64;
            let tol = 4.0 * (n_lab as f64).sqrt() / (3000f64).sqrt() + 1.0;
            assert!(
                (mc - exact).abs() < tol,
                "MC {mc} vs exact {exact} (tol {tol})"
            );
        });
    }

    #[test]
    #[should_panic]
    fn rejects_inconsistent_counts() {
        lemma1_lower_bound(101, 100, 4);
    }
}
