//! Theorem 2 — FedMLH shrinks the inter-client class-distribution
//! divergence.
//!
//! For clients a and b with class-proportion vectors π⁽ᵃ⁾, π⁽ᵇ⁾ and the
//! bucket proportions ω⁽ᵃ⁾, ω⁽ᵇ⁾ induced by any class→bucket map, the
//! log-sum inequality gives
//!
//! ```text
//! KL(ω⁽ᵃ⁾ ‖ ω⁽ᵇ⁾) ≤ KL(π⁽ᵃ⁾ ‖ π⁽ᵇ⁾)
//! ```
//!
//! with equality only when the map never merges classes with different
//! likelihood ratios — i.e. hashing into B < p buckets *strictly*
//! contracts the non-iid divergence the paper blames for FedAvg's
//! degradation.

use crate::data::dataset::Dataset;
use crate::hashing::label_hash::LabelHasher;
use crate::partition::divergence::{aggregate_to_buckets, class_distribution, kl, kl_shared_support};
use crate::partition::Partition;
use crate::util::prop::Gen;
use crate::util::rng::derive_seed;

/// One KL-contraction measurement.
#[derive(Clone, Copy, Debug)]
pub struct KlContraction {
    /// Mean pairwise KL over class distributions (π).
    pub kl_classes: f64,
    /// Mean pairwise KL over bucket distributions (ω), averaged over the
    /// R hash tables.
    pub kl_buckets: f64,
}

impl KlContraction {
    /// Contraction factor `KL(π) / KL(ω)` (≥ 1 when the theorem holds).
    pub fn factor(&self) -> f64 {
        if self.kl_buckets <= 0.0 {
            f64::INFINITY
        } else {
            self.kl_classes / self.kl_buckets
        }
    }

    pub fn holds(&self) -> bool {
        self.kl_buckets <= self.kl_classes + 1e-12
    }
}

/// Measure the contraction on a real partition: mean pairwise KL across
/// clients, over classes vs over each hash table's buckets.
pub fn kl_contraction_on_partition(
    ds: &Dataset,
    part: &Partition,
    hasher: &LabelHasher,
    eps: f64,
) -> KlContraction {
    let k = part.clients.len();
    let pis: Vec<Vec<f64>> = part
        .clients
        .iter()
        .map(|s| class_distribution(ds, s, eps))
        .collect();

    let mut kl_pi = 0.0f64;
    let mut kl_omega = 0.0f64;
    let mut pairs = 0usize;
    // Precompute class→bucket maps per table.
    let maps: Vec<Vec<usize>> = (0..hasher.r())
        .map(|t| (0..ds.p()).map(|c| hasher.bucket(t, c)).collect())
        .collect();
    for a in 0..k {
        for b in 0..k {
            if a == b {
                continue;
            }
            kl_pi += kl(&pis[a], &pis[b]);
            for map in &maps {
                let oa = aggregate_to_buckets(&pis[a], map, hasher.b());
                let ob = aggregate_to_buckets(&pis[b], map, hasher.b());
                kl_omega += kl_shared_support(&oa, &ob) / hasher.r() as f64;
            }
            pairs += 1;
        }
    }
    let pairs = pairs.max(1) as f64;
    KlContraction {
        kl_classes: kl_pi / pairs,
        kl_buckets: kl_omega / pairs,
    }
}

/// Monte-Carlo check on random strictly-positive distributions: draws
/// `trials` (π⁽ᵃ⁾, π⁽ᵇ⁾, random class→bucket map) triples and returns
/// the worst observed `KL(ω) − KL(π)` (≤ 0 iff the theorem held in every
/// trial) together with the mean contraction factor.
pub fn kl_contraction_mc(p: usize, b: usize, trials: usize, seed: u64) -> (f64, f64) {
    assert!(p >= 2 && b >= 1 && b <= p && trials >= 1);
    let mut worst_violation = f64::NEG_INFINITY;
    let mut factor_sum = 0.0f64;
    for t in 0..trials {
        let mut g = Gen::new(derive_seed(seed, 0x7e0_2 + t as u64));
        let pi_a = g.simplex(p);
        let pi_b = g.simplex(p);
        let map: Vec<usize> = (0..p).map(|_| g.rng().below(b)).collect();
        let kl_pi = kl(&pi_a, &pi_b);
        let oa = aggregate_to_buckets(&pi_a, &map, b);
        let ob = aggregate_to_buckets(&pi_b, &map, b);
        let kl_o = kl_shared_support(&oa, &ob);
        worst_violation = worst_violation.max(kl_o - kl_pi);
        factor_sum += if kl_o > 0.0 { kl_pi / kl_o } else { 1.0 };
    }
    (worst_violation, factor_sum / trials as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate_preset;
    use crate::partition::noniid::{partition as noniid, NonIidOptions};

    #[test]
    fn mc_never_violates() {
        for &(p, b) in &[(10usize, 3usize), (50, 10), (100, 100)] {
            let (worst, factor) = kl_contraction_mc(p, b, 200, 5);
            assert!(worst <= 1e-10, "violation {worst} at p={p} B={b}");
            assert!(factor >= 1.0 - 1e-9, "mean factor {factor}");
        }
    }

    #[test]
    fn identity_map_preserves_kl() {
        // B = p with the identity map: ω is a permutation of π → KL equal.
        let pi_a = vec![0.5, 0.3, 0.2];
        let pi_b = vec![0.2, 0.3, 0.5];
        let map = vec![0usize, 1, 2];
        let oa = aggregate_to_buckets(&pi_a, &map, 3);
        let kl_pi = kl(&pi_a, &pi_b);
        let kl_o = kl(&oa, &aggregate_to_buckets(&pi_b, &map, 3));
        assert!((kl_pi - kl_o).abs() < 1e-12);
    }

    #[test]
    fn single_bucket_collapses_divergence() {
        let pi_a = vec![0.9, 0.05, 0.05];
        let pi_b = vec![0.05, 0.05, 0.9];
        let map = vec![0usize, 0, 0];
        let kl_o = kl(
            &aggregate_to_buckets(&pi_a, &map, 1),
            &aggregate_to_buckets(&pi_b, &map, 1),
        );
        assert!(kl_o.abs() < 1e-12, "B=1 must zero the divergence");
    }

    #[test]
    fn holds_on_real_noniid_partition() {
        let cfg = crate::config::ExperimentConfig::preset("tiny").unwrap();
        let data = generate_preset(&cfg.preset, 3);
        let part = noniid(&data.train, &NonIidOptions::new(6), 3);
        let hasher = LabelHasher::new(3, cfg.r(), data.train.p(), cfg.b());
        let c = kl_contraction_on_partition(&data.train, &part, &hasher, 1e-3);
        assert!(c.holds(), "theorem 2 violated: {c:?}");
        assert!(
            c.factor() > 1.0,
            "expected strict contraction on non-iid data: {c:?}"
        );
    }
}
