//! The paper's Section-5 analysis, made executable.
//!
//! Each result ships as (a) the closed-form bound from the paper and
//! (b) a Monte-Carlo estimator over the same random object, so the
//! `fedmlh theory` subcommand (and the `theory_validation` integration
//! tests) can verify the bound *holds* and report how tight it is on
//! real partitions:
//!
//! - [`lemma1`] — bucket positive-instance count lower bound:
//!   `E(B_i | h(j)=i) ≥ n_j + (N_lab − n_j)/B − N_lab/B²`.
//! - [`lemma2`] — class distinguishability: with
//!   `B ≥ (p(p−1)/2δ)^{1/R}` no two classes collide in *all* R tables
//!   with probability ≥ 1 − δ.
//! - [`theorem2`] — KL contraction: hashing classes into buckets
//!   strictly shrinks the inter-client distribution divergence,
//!   `KL(ω⁽ᵃ⁾‖ω⁽ᵇ⁾) ≤ KL(π⁽ᵃ⁾‖π⁽ᵇ⁾)` (log-sum inequality).

pub mod lemma1;
pub mod lemma2;
pub mod theorem2;

pub use lemma1::{
    expected_bucket_positives_exact, expected_bucket_positives_mc,
    expected_bucket_positives_mc_stats, lemma1_lower_bound,
};
pub use lemma2::{all_table_collision_probability_mc, collision_union_bound, lemma2_min_buckets};
pub use theorem2::{kl_contraction_mc, kl_contraction_on_partition, KlContraction};
