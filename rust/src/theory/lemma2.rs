//! Lemma 2 — class distinguishability constrains how small B can get.
//!
//! Two classes that collide in *every* one of the R tables are
//! indistinguishable to the decoder. Under independent uniform hashing a
//! fixed pair fully collides with probability `B^{−R}`; a union bound
//! over all `p(p−1)/2` pairs gives
//!
//! ```text
//! P(∃ fully-colliding pair) ≤ p(p−1)/2 · B^{−R} ≤ δ
//!   ⟺  B ≥ (p(p−1)/2δ)^{1/R}
//! ```

use crate::hashing::label_hash::LabelHasher;
use crate::util::rng::derive_seed;

/// Union bound on the probability that some pair of classes collides in
/// all R tables.
pub fn collision_union_bound(p: usize, b: usize, r: usize) -> f64 {
    assert!(p >= 2 && b >= 1 && r >= 1);
    let pairs = 0.5 * p as f64 * (p as f64 - 1.0);
    (pairs * (b as f64).powi(-(r as i32))).min(1.0)
}

/// The paper's minimum hash-table size: smallest `B` with
/// `P(full collision) ≤ δ` by the union bound.
pub fn lemma2_min_buckets(p: usize, r: usize, delta: f64) -> f64 {
    assert!(p >= 2 && r >= 1);
    assert!(delta > 0.0 && delta < 1.0);
    let pairs = 0.5 * p as f64 * (p as f64 - 1.0);
    (pairs / delta).powf(1.0 / r as f64)
}

/// Monte-Carlo estimate of the full-collision probability: draw `trials`
/// independent R-table hasher families over (p, b) and count the
/// fraction that contain at least one fully-colliding class pair.
pub fn all_table_collision_probability_mc(
    p: usize,
    b: usize,
    r: usize,
    trials: usize,
    seed: u64,
) -> f64 {
    assert!(trials >= 1);
    let mut hits = 0usize;
    for t in 0..trials {
        let hasher = LabelHasher::new(derive_seed(seed, 0x1e_a002 + t as u64), r, p, b);
        if hasher.has_fully_colliding_pair() {
            hits += 1;
        }
    }
    hits as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_buckets_inverts_union_bound() {
        for &(p, r, delta) in &[(100usize, 2usize, 0.05f64), (4000, 4, 0.01), (64, 3, 0.1)] {
            let b_min = lemma2_min_buckets(p, r, delta);
            // At B = ⌈b_min⌉ the union bound is ≤ δ; just below it is > δ.
            assert!(collision_union_bound(p, b_min.ceil() as usize, r) <= delta + 1e-12);
            let below = (b_min * 0.9).floor().max(1.0) as usize;
            if (below as f64) < b_min {
                assert!(collision_union_bound(p, below, r) > delta);
            }
        }
    }

    #[test]
    fn paper_configs_are_collision_safe() {
        // Every Table-2 configuration satisfies the lemma comfortably at
        // δ = 0.05 (scaled presets; the check is structural).
        for &(p, b, r) in &[(4000usize, 250usize, 4usize), (8000, 500, 4), (32768, 2048, 8)] {
            let bound = collision_union_bound(p, b, r);
            assert!(bound < 0.05, "p={p} B={b} R={r}: bound {bound}");
        }
    }

    #[test]
    fn mc_within_union_bound() {
        // MC collision frequency never exceeds the union bound (it is an
        // upper bound) but should be of comparable order when small.
        let (p, b, r) = (60usize, 40usize, 2usize);
        let bound = collision_union_bound(p, b, r);
        let mc = all_table_collision_probability_mc(p, b, r, 300, 3);
        assert!(
            mc <= bound + 3.0 * (bound / 300.0).sqrt() + 0.02,
            "MC {mc} far above union bound {bound}"
        );
    }

    #[test]
    fn tiny_tables_do_collide() {
        // Degenerate: B=1 → every pair collides in every table.
        let mc = all_table_collision_probability_mc(10, 1, 3, 20, 1);
        assert_eq!(mc, 1.0);
        assert_eq!(collision_union_bound(10, 1, 3), 1.0);
    }

    #[test]
    fn more_tables_reduce_collisions() {
        let (p, b) = (80usize, 16usize);
        let one = all_table_collision_probability_mc(p, b, 1, 400, 9);
        let three = all_table_collision_probability_mc(p, b, 3, 400, 9);
        assert!(three < one, "R=3 {three} !< R=1 {one}");
    }
}
