//! Minimal benchmarking harness (criterion is unavailable offline; all
//! `[[bench]]` targets set `harness = false` and drive this instead).
//!
//! Usage from a bench binary:
//!
//! ```no_run
//! use fedmlh::bench::Bencher;
//! let mut b = Bencher::from_env("bench_example");
//! b.bench("aggregate/tiny", || { /* measured body */ });
//! b.finish();
//! ```
//!
//! Protocol: warm up, then run timed iterations until both a minimum
//! iteration count and a minimum measurement window are reached; report
//! mean / median / p95 per iteration plus throughput hooks. Output is
//! one aligned text row per benchmark (and optionally a CSV under
//! `results/` for EXPERIMENTS.md).

use std::time::Instant;

/// Per-benchmark summary statistics (seconds per iteration).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    fn from_samples(name: &str, mut samples: Vec<f64>) -> Stats {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let pick = |q: f64| samples[((n as f64 - 1.0) * q).round() as usize];
        Stats {
            name: name.to_string(),
            iters: n,
            mean,
            median: pick(0.5),
            p95: pick(0.95),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    /// Render seconds in the most readable unit.
    pub fn fmt_time(seconds: f64) -> String {
        if seconds >= 1.0 {
            format!("{seconds:.3} s")
        } else if seconds >= 1e-3 {
            format!("{:.3} ms", seconds * 1e3)
        } else if seconds >= 1e-6 {
            format!("{:.3} us", seconds * 1e6)
        } else {
            format!("{:.1} ns", seconds * 1e9)
        }
    }
}

/// The bench driver: collects [`Stats`] rows and prints a table.
pub struct Bencher {
    suite: String,
    /// Minimum timed iterations per benchmark.
    pub min_iters: usize,
    /// Minimum total measurement window per benchmark (seconds).
    pub min_seconds: f64,
    /// Warmup iterations (untimed).
    pub warmup: usize,
    results: Vec<Stats>,
    quiet: bool,
}

impl Bencher {
    pub fn new(suite: &str) -> Self {
        Bencher {
            suite: suite.to_string(),
            min_iters: 10,
            min_seconds: 0.25,
            warmup: 2,
            results: Vec::new(),
            quiet: false,
        }
    }

    /// Construct honoring `FEDMLH_BENCH_FAST=1` (CI smoke: 3 iters, no
    /// window) and `--quiet`.
    pub fn from_env(suite: &str) -> Self {
        let mut b = Self::new(suite);
        if std::env::var("FEDMLH_BENCH_FAST").ok().as_deref() == Some("1") {
            b.min_iters = 3;
            b.min_seconds = 0.0;
            b.warmup = 1;
        }
        if std::env::args().any(|a| a == "--quiet") {
            b.quiet = true;
        }
        eprintln!("# suite {suite}");
        b
    }

    /// Measure `f` (called once per iteration) and record a row.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.min_iters * 2);
        let window = Instant::now();
        loop {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            if samples.len() >= self.min_iters
                && window.elapsed().as_secs_f64() >= self.min_seconds
            {
                break;
            }
            // Hard cap so a slow benchmark cannot hang the suite.
            if samples.len() >= 10_000 {
                break;
            }
        }
        let stats = Stats::from_samples(name, samples);
        if !self.quiet {
            println!(
                "{:<44} {:>12} {:>12} {:>12}  x{}",
                format!("{}/{}", self.suite, stats.name),
                Stats::fmt_time(stats.median),
                Stats::fmt_time(stats.mean),
                Stats::fmt_time(stats.p95),
                stats.iters
            );
        }
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Measure `f` which returns a value (prevents dead-code elimination
    /// via `std::hint::black_box`).
    pub fn bench_val<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        self.bench(name, || {
            std::hint::black_box(f());
        })
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Render all rows as CSV (EXPERIMENTS.md appendix material).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("suite,name,iters,median_s,mean_s,p95_s,min_s,max_s\n");
        for s in &self.results {
            out.push_str(&format!(
                "{},{},{},{:.9},{:.9},{:.9},{:.9},{:.9}\n",
                self.suite, s.name, s.iters, s.median, s.mean, s.p95, s.min, s.max
            ));
        }
        out
    }

    /// Print the header + flush the CSV if `FEDMLH_BENCH_CSV` names a
    /// directory. Call once at the end of the bench binary.
    pub fn finish(&self) {
        if let Ok(dir) = std::env::var("FEDMLH_BENCH_CSV") {
            let path = std::path::Path::new(&dir).join(format!("{}.csv", self.suite));
            if std::fs::create_dir_all(&dir).is_ok() {
                let _ = std::fs::write(&path, self.to_csv());
                eprintln!("# wrote {}", path.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples("x", vec![3.0, 1.0, 2.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn bencher_runs_minimum_iterations() {
        let mut b = Bencher::new("test");
        b.quiet = true;
        b.min_iters = 5;
        b.min_seconds = 0.0;
        b.warmup = 0;
        let mut count = 0u32;
        b.bench("count", || {
            count += 1;
        });
        assert!(count >= 5);
        assert_eq!(b.results().len(), 1);
        let csv = b.to_csv();
        assert!(csv.contains("test,count,"), "{csv}");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(Stats::fmt_time(2.5), "2.500 s");
        assert_eq!(Stats::fmt_time(0.002), "2.000 ms");
        assert_eq!(Stats::fmt_time(3.5e-6), "3.500 us");
        assert_eq!(Stats::fmt_time(5e-9), "5.0 ns");
    }
}
