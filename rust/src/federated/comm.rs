//! Communication-volume accounting (paper Table 4, Figure 4).
//!
//! The paper: "The communication volume is defined as the size of the
//! model parameters (in bytes) communicated between local clients and
//! central server during the training", measured "until the model
//! achieves the best accuracy". Per synchronization round each selected
//! client downloads the global model and uploads its update — for FedMLH
//! that is R sub-models each way (they are communicated independently;
//! no parameters flow between sub-models).

/// Byte meter for one training run.
#[derive(Clone, Debug, Default)]
pub struct CommMeter {
    download_bytes: u64,
    upload_bytes: u64,
    /// Cumulative total at the end of each completed round (Fig 4 x-axis).
    per_round_totals: Vec<u64>,
}

impl CommMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one client downloading `bytes` of global parameters.
    pub fn download(&mut self, bytes: usize) {
        self.download_bytes += bytes as u64;
    }

    /// Record one client uploading `bytes` of updated parameters.
    pub fn upload(&mut self, bytes: usize) {
        self.upload_bytes += bytes as u64;
    }

    /// Close out a synchronization round (snapshots the running total).
    pub fn end_round(&mut self) {
        self.per_round_totals.push(self.total());
    }

    pub fn total(&self) -> u64 {
        self.download_bytes + self.upload_bytes
    }

    pub fn downloaded(&self) -> u64 {
        self.download_bytes
    }

    pub fn uploaded(&self) -> u64 {
        self.upload_bytes
    }

    /// Cumulative bytes at the end of round `r` (0-based).
    pub fn total_at_round(&self, r: usize) -> u64 {
        self.per_round_totals.get(r).copied().unwrap_or(0)
    }

    pub fn rounds(&self) -> usize {
        self.per_round_totals.len()
    }

    pub fn per_round_totals(&self) -> &[u64] {
        &self.per_round_totals
    }
}

/// Closed-form per-round volume: `clients × (down + up) × model_bytes ×
/// n_models` — used by tests and the Table 4 analytic cross-check.
pub fn expected_round_bytes(clients: usize, model_bytes: usize, n_models: usize) -> u64 {
    (clients * 2 * model_bytes * n_models) as u64
}

/// Pretty-print bytes the way the paper's Table 4 does (Mb/Gb).
pub fn format_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.1}Gb", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1}Mb", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}Kb", b / 1e3)
    } else {
        format!("{bytes}b")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_snapshots() {
        let mut m = CommMeter::new();
        m.download(100);
        m.upload(50);
        m.end_round();
        m.download(100);
        m.upload(50);
        m.end_round();
        assert_eq!(m.total(), 300);
        assert_eq!(m.downloaded(), 200);
        assert_eq!(m.uploaded(), 100);
        assert_eq!(m.total_at_round(0), 150);
        assert_eq!(m.total_at_round(1), 300);
        assert_eq!(m.rounds(), 2);
    }

    #[test]
    fn expected_formula() {
        // 4 clients, 1MB model, 3 sub-models: 4 × 2 × 1e6 × 3
        assert_eq!(expected_round_bytes(4, 1_000_000, 3), 24_000_000);
    }

    #[test]
    fn formatting() {
        assert_eq!(format_bytes(500), "500b");
        assert_eq!(format_bytes(2_500), "2.5Kb");
        assert_eq!(format_bytes(199_700_000), "199.7Mb");
        assert_eq!(format_bytes(7_200_000_000), "7.2Gb");
    }
}
