//! Communication-volume accounting (paper Table 4, Figure 4).
//!
//! The paper: "The communication volume is defined as the size of the
//! model parameters (in bytes) communicated between local clients and
//! central server during the training", measured "until the model
//! achieves the best accuracy". Per synchronization round each selected
//! client downloads the global model and uploads its update — for FedMLH
//! that is R sub-models each way (they are communicated independently;
//! no parameters flow between sub-models).

/// Byte meter for one training run.
///
/// Both links are charged the *encoded* payload size — uploads since
/// the wire-format layer ([`super::wire`]) landed, downloads since the
/// transport pipeline ([`super::transport`]) made the broadcast
/// compressible too. The dense `f32` equivalent is tracked per link so
/// compression wins are reportable ([`Self::upload_compression`],
/// [`Self::download_compression`]) without guessing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommMeter {
    download_bytes: u64,
    upload_bytes: u64,
    /// What the uploads would have cost as dense `f32` (the seed's
    /// `model_bytes_each` flat accounting).
    dense_upload_bytes: u64,
    /// What the downloads would have cost as dense `f32`.
    dense_download_bytes: u64,
    /// Cumulative total at the end of each completed round (Fig 4 x-axis).
    per_round_totals: Vec<u64>,
}

impl CommMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one client downloading `bytes` of global parameters
    /// (uncompressed — dense equivalent equals the actual bytes).
    pub fn download(&mut self, bytes: usize) {
        self.download_encoded(bytes, bytes);
    }

    /// Record one client downloading an encoded broadcast: `actual`
    /// bytes on the wire, `dense_equiv` bytes had it shipped raw `f32`.
    pub fn download_encoded(&mut self, actual: usize, dense_equiv: usize) {
        self.download_bytes += actual as u64;
        self.dense_download_bytes += dense_equiv as u64;
    }

    /// Record one client uploading `bytes` of updated parameters
    /// (uncompressed — dense equivalent equals the actual bytes).
    pub fn upload(&mut self, bytes: usize) {
        self.upload_encoded(bytes, bytes);
    }

    /// Record one client uploading an encoded update: `actual` bytes on
    /// the wire, `dense_equiv` bytes had it shipped raw `f32`.
    pub fn upload_encoded(&mut self, actual: usize, dense_equiv: usize) {
        self.upload_bytes += actual as u64;
        self.dense_upload_bytes += dense_equiv as u64;
    }

    /// Close out a synchronization round (snapshots the running total).
    pub fn end_round(&mut self) {
        self.per_round_totals.push(self.total());
    }

    pub fn total(&self) -> u64 {
        self.download_bytes + self.upload_bytes
    }

    pub fn downloaded(&self) -> u64 {
        self.download_bytes
    }

    pub fn uploaded(&self) -> u64 {
        self.upload_bytes
    }

    /// Dense-`f32` equivalent of everything uploaded.
    pub fn uploaded_dense_equiv(&self) -> u64 {
        self.dense_upload_bytes
    }

    /// Uplink compression ratio (dense / actual; 1.0 when uncompressed
    /// or nothing was uploaded yet).
    pub fn upload_compression(&self) -> f64 {
        if self.upload_bytes == 0 {
            1.0
        } else {
            self.dense_upload_bytes as f64 / self.upload_bytes as f64
        }
    }

    /// Dense-`f32` equivalent of everything downloaded.
    pub fn downloaded_dense_equiv(&self) -> u64 {
        self.dense_download_bytes
    }

    /// Downlink compression ratio (dense / actual; 1.0 when
    /// uncompressed or nothing was downloaded yet).
    pub fn download_compression(&self) -> f64 {
        if self.download_bytes == 0 {
            1.0
        } else {
            self.dense_download_bytes as f64 / self.download_bytes as f64
        }
    }

    /// Cumulative bytes at the end of round `r` (0-based).
    pub fn total_at_round(&self, r: usize) -> u64 {
        self.per_round_totals.get(r).copied().unwrap_or(0)
    }

    pub fn rounds(&self) -> usize {
        self.per_round_totals.len()
    }

    pub fn per_round_totals(&self) -> &[u64] {
        &self.per_round_totals
    }

    /// All counters, for crash-resume snapshots
    /// ([`super::snapshot`]): `(download, upload, dense_upload,
    /// dense_download, per_round_totals)`.
    pub fn snapshot_parts(&self) -> (u64, u64, u64, u64, &[u64]) {
        (
            self.download_bytes,
            self.upload_bytes,
            self.dense_upload_bytes,
            self.dense_download_bytes,
            &self.per_round_totals,
        )
    }

    /// Rebuild a meter from snapshot counters (inverse of
    /// [`Self::snapshot_parts`]).
    pub fn from_parts(
        download_bytes: u64,
        upload_bytes: u64,
        dense_upload_bytes: u64,
        dense_download_bytes: u64,
        per_round_totals: Vec<u64>,
    ) -> CommMeter {
        CommMeter {
            download_bytes,
            upload_bytes,
            dense_upload_bytes,
            dense_download_bytes,
            per_round_totals,
        }
    }
}

/// Closed-form per-round volume: `clients × (down + up) × model_bytes ×
/// n_models` — used by tests and the Table 4 analytic cross-check.
/// Widened to `u64` *before* multiplying: the old `usize` product
/// overflowed 32-bit targets at million-client × MB-model scale.
pub fn expected_round_bytes(clients: usize, model_bytes: usize, n_models: usize) -> u64 {
    clients as u64 * 2 * model_bytes as u64 * n_models as u64
}

/// Pretty-print bytes the way the paper's Table 4 does (Mb/Gb).
pub fn format_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.1}Gb", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1}Mb", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}Kb", b / 1e3)
    } else {
        format!("{bytes}b")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_round_bytes_is_u64_wide() {
        // 1M clients × 250MB sub-model × 4 sub-models = 2×10^15 bytes —
        // far past u32::MAX, where 32-bit usize arithmetic wrapped.
        assert_eq!(
            expected_round_bytes(1_000_000, 250_000_000, 4),
            2_000_000_000_000_000u64
        );
        // a single factor at 2^31 already exceeds 32-bit usize
        assert_eq!(expected_round_bytes(3, 1 << 31, 1), 3 * 2 * (1u64 << 31));
        assert_eq!(expected_round_bytes(0, 1 << 31, 7), 0);
    }

    #[test]
    fn accumulates_and_snapshots() {
        let mut m = CommMeter::new();
        m.download(100);
        m.upload(50);
        m.end_round();
        m.download(100);
        m.upload(50);
        m.end_round();
        assert_eq!(m.total(), 300);
        assert_eq!(m.downloaded(), 200);
        assert_eq!(m.uploaded(), 100);
        assert_eq!(m.total_at_round(0), 150);
        assert_eq!(m.total_at_round(1), 300);
        assert_eq!(m.rounds(), 2);
    }

    #[test]
    fn encoded_downloads_track_dense_equivalent() {
        // Two-sided accounting: each link carries its own actual vs
        // dense-equivalent pair and reports its own ratio.
        let mut m = CommMeter::new();
        m.download_encoded(30, 120); // 4x compressed broadcast
        m.download_encoded(30, 120);
        m.upload_encoded(10, 120); // 12x compressed upload
        assert_eq!(m.downloaded(), 60);
        assert_eq!(m.downloaded_dense_equiv(), 240);
        assert!((m.download_compression() - 4.0).abs() < 1e-12);
        assert!((m.upload_compression() - 12.0).abs() < 1e-12);
        assert_eq!(m.total(), 70);
        // plain downloads stay 1:1 (the seed accounting)
        let mut plain = CommMeter::new();
        plain.download(80);
        assert_eq!(plain.downloaded_dense_equiv(), 80);
        assert_eq!(plain.download_compression(), 1.0);
        assert_eq!(CommMeter::new().download_compression(), 1.0);
    }

    #[test]
    fn encoded_uploads_track_dense_equivalent() {
        let mut m = CommMeter::new();
        m.upload_encoded(25, 100);
        m.upload_encoded(25, 100);
        assert_eq!(m.uploaded(), 50);
        assert_eq!(m.uploaded_dense_equiv(), 200);
        assert!((m.upload_compression() - 4.0).abs() < 1e-12);
        // plain uploads stay 1:1
        let mut plain = CommMeter::new();
        plain.upload(80);
        assert_eq!(plain.uploaded_dense_equiv(), 80);
        assert_eq!(plain.upload_compression(), 1.0);
        assert_eq!(CommMeter::new().upload_compression(), 1.0);
    }

    #[test]
    fn expected_formula() {
        // 4 clients, 1MB model, 3 sub-models: 4 × 2 × 1e6 × 3
        assert_eq!(expected_round_bytes(4, 1_000_000, 3), 24_000_000);
    }

    #[test]
    fn formatting() {
        assert_eq!(format_bytes(500), "500b");
        assert_eq!(format_bytes(2_500), "2.5Kb");
        assert_eq!(format_bytes(199_700_000), "199.7Mb");
        assert_eq!(format_bytes(7_200_000_000), "7.2Gb");
    }
}
