//! The training backend abstraction.
//!
//! [`TrainBackend`] is the seam between the federated coordinator and
//! the compute substrate. Two implementations:
//!
//! - [`RustBackend`] — the pure-rust reference MLP
//!   ([`crate::model::mlp`]). Exact same math as the AOT graph; used by
//!   fast tests and as the numeric cross-check.
//! - [`crate::runtime::XlaBackend`] — executes the AOT HLO artifacts on
//!   the PJRT CPU client (the production path; python is never loaded).

use anyhow::Result;

use crate::model::mlp;
use crate::model::params::ModelParams;

use super::batcher::ClientBatcher;

/// Statistics from one client's local training round (E epochs).
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainStats {
    /// SGD steps executed.
    pub steps: usize,
    /// Mean per-step (pre-update) loss.
    pub mean_loss: f64,
    /// Wall-clock seconds spent in the backend.
    pub seconds: f64,
}

/// A compute backend able to run the paper's three operations.
pub trait TrainBackend {
    /// Run `epochs` local epochs of SGD on `params` over the client's
    /// shard (paper Algorithm 2 `DeviceTrain`). `params` is updated in
    /// place.
    fn local_train(
        &self,
        params: &mut ModelParams,
        batcher: &mut ClientBatcher<'_>,
        epochs: usize,
        lr: f32,
    ) -> Result<TrainStats>;

    /// Inference logits for a padded `[batch, d]` input; returns flat
    /// `[batch, out]`. `batch` must equal the backend's fixed batch size.
    fn predict(&self, params: &ModelParams, x: &[f32]) -> Result<Vec<f32>>;

    /// Inference logits written into `out` (flat `[rows, out]`), using
    /// the caller's persistent [`mlp::InferScratch`] so repeated
    /// evaluation batches allocate nothing. The default delegates to
    /// [`Self::predict`] (one allocation per call) for backends whose
    /// compute lives off-host; the pure-rust backend overrides it with
    /// the zero-allocation kernel path.
    fn predict_into(
        &self,
        params: &ModelParams,
        x: &[f32],
        rows: usize,
        scratch: &mut mlp::InferScratch,
        out: &mut [f32],
    ) -> Result<()> {
        let _ = (rows, scratch);
        let z = self.predict(params, x)?;
        anyhow::ensure!(
            z.len() == out.len(),
            "predict returned {} logits, caller expected {}",
            z.len(),
            out.len()
        );
        out.copy_from_slice(&z);
        Ok(())
    }

    /// Forward one padded batch through every sub-model (the evaluation
    /// sweep's shape): fills `outs[j]` with model `j`'s flat
    /// `[rows, out]` logits. The default loops [`Self::predict_into`];
    /// the pure-rust backend overrides it to convert the input batch
    /// once instead of once per sub-model.
    fn predict_models_into(
        &self,
        models: &[ModelParams],
        x: &[f32],
        rows: usize,
        scratch: &mut mlp::InferScratch,
        outs: &mut [Vec<f32>],
    ) -> Result<()> {
        debug_assert_eq!(models.len(), outs.len());
        for (m, buf) in models.iter().zip(outs.iter_mut()) {
            self.predict_into(m, x, rows, scratch, buf)?;
        }
        Ok(())
    }

    /// Count-sketch mean decode: `logits` flat `[r, batch, b]`, `idx`
    /// flat `[r, p]` → scores flat `[batch, p]`.
    fn decode(
        &self,
        logits: &[f32],
        idx: &[i32],
        r: usize,
        rows: usize,
        b: usize,
        p: usize,
    ) -> Result<Vec<f32>>;

    /// Fixed batch size (baked into AOT artifacts; the rust backend
    /// adopts whatever the batcher uses, but reports the config batch).
    fn batch_size(&self) -> usize;

    /// Human-readable name for logs/EXPERIMENTS.md.
    fn name(&self) -> &str;

    /// Thread-safe view of this backend for the parallel round engine
    /// ([`crate::federated::engine::RoundEngine`]). `None` (the
    /// default) keeps the engine on its sequential path — correct for
    /// the PJRT backend, whose `Rc`/`RefCell` compile cache is
    /// single-threaded by construction. Backends that are freely
    /// shareable override this to `Some(self)`.
    fn as_parallel(&self) -> Option<&(dyn TrainBackend + Sync)> {
        None
    }
}

/// Pure-rust backend over [`crate::model::mlp`].
#[derive(Debug, Default)]
pub struct RustBackend {
    batch: usize,
}

impl RustBackend {
    pub fn new() -> Self {
        RustBackend { batch: 0 }
    }

    /// With an explicit nominal batch size (only used by `batch_size()`).
    pub fn with_batch(batch: usize) -> Self {
        RustBackend { batch }
    }
}

impl TrainBackend for RustBackend {
    fn local_train(
        &self,
        params: &mut ModelParams,
        batcher: &mut ClientBatcher<'_>,
        epochs: usize,
        lr: f32,
    ) -> Result<TrainStats> {
        let t0 = std::time::Instant::now();
        let mut ws = mlp::Workspace::new(params, batcher.batch_size());
        let mut steps = 0usize;
        let mut loss_sum = 0.0f64;
        for epoch in 0..epochs {
            batcher.reset(epoch);
            while let Some(batch) = batcher.next_batch() {
                loss_sum += mlp::train_step(params, &mut ws, batch.x, batch.y, lr) as f64;
                steps += 1;
            }
        }
        Ok(TrainStats {
            steps,
            mean_loss: if steps > 0 { loss_sum / steps as f64 } else { 0.0 },
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn predict(&self, params: &ModelParams, x: &[f32]) -> Result<Vec<f32>> {
        let rows = x.len() / params.d;
        Ok(mlp::forward(params, x, rows))
    }

    fn predict_into(
        &self,
        params: &ModelParams,
        x: &[f32],
        rows: usize,
        scratch: &mut mlp::InferScratch,
        out: &mut [f32],
    ) -> Result<()> {
        mlp::forward_into(params, x, rows, scratch, out);
        Ok(())
    }

    fn predict_models_into(
        &self,
        models: &[ModelParams],
        x: &[f32],
        rows: usize,
        scratch: &mut mlp::InferScratch,
        outs: &mut [Vec<f32>],
    ) -> Result<()> {
        debug_assert_eq!(models.len(), outs.len());
        // One dense→CSR conversion shared by all R sub-model forwards.
        mlp::forward_models_into(
            models,
            x,
            rows,
            scratch,
            outs.iter_mut().map(|v| v.as_mut_slice()),
        );
        Ok(())
    }

    fn decode(
        &self,
        logits: &[f32],
        idx: &[i32],
        r: usize,
        rows: usize,
        b: usize,
        p: usize,
    ) -> Result<Vec<f32>> {
        Ok(crate::eval::decode::sketch_decode(logits, idx, r, rows, b, p))
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn name(&self) -> &str {
        "rust-reference"
    }

    fn as_parallel(&self) -> Option<&(dyn TrainBackend + Sync)> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::data::synth::{generate, SynthSpec};
    use crate::federated::batcher::Target;

    #[test]
    fn local_train_reduces_loss_on_tiny() {
        let mut spec = SynthSpec::from_preset(&by_name("tiny").unwrap());
        spec.n_train = 256;
        let data = generate(&spec, 2);
        let ds = &data.train;
        let samples: Vec<usize> = (0..ds.len()).collect();
        let mut params = ModelParams::init(ds.d(), 16, ds.p(), 1);
        let backend = RustBackend::new();

        let mut batcher = ClientBatcher::new(ds, &samples, Target::Classes, 16, 4);
        let first = backend
            .local_train(&mut params, &mut batcher, 1, 0.5)
            .unwrap();
        let mut batcher = ClientBatcher::new(ds, &samples, Target::Classes, 16, 4);
        let later = backend
            .local_train(&mut params, &mut batcher, 3, 0.5)
            .unwrap();
        assert!(later.mean_loss < first.mean_loss, "{later:?} vs {first:?}");
        assert_eq!(first.steps, 16); // 256/16 batches × 1 epoch
        assert_eq!(later.steps, 48);
    }

    #[test]
    fn predict_shape() {
        let params = ModelParams::init(8, 4, 10, 0);
        let backend = RustBackend::new();
        let x = vec![0.1f32; 3 * 8];
        let z = backend.predict(&params, &x).unwrap();
        assert_eq!(z.len(), 3 * 10);
    }

    #[test]
    fn predict_into_matches_predict() {
        let params = ModelParams::init(8, 4, 10, 0);
        let backend = RustBackend::new();
        let x: Vec<f32> = (0..3 * 8).map(|i| ((i * 7 % 5) as f32 - 2.0) * 0.3).collect();
        let want = backend.predict(&params, &x).unwrap();
        let mut scratch = crate::model::mlp::InferScratch::new();
        let mut out = vec![f32::NAN; 3 * 10];
        backend
            .predict_into(&params, &x, 3, &mut scratch, &mut out)
            .unwrap();
        assert_eq!(out, want);
    }

    #[test]
    fn predict_models_into_matches_per_model_predict() {
        // The hoisted one-conversion-per-batch path must be bitwise
        // identical to forwarding each sub-model independently.
        let backend = RustBackend::new();
        let models: Vec<ModelParams> =
            (0..3).map(|j| ModelParams::init(6, 4, 5, j as u64)).collect();
        let x: Vec<f32> = (0..2 * 6).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut scratch = crate::model::mlp::InferScratch::new();
        let mut outs: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0f32; 2 * 5]).collect();
        backend
            .predict_models_into(&models, &x, 2, &mut scratch, &mut outs)
            .unwrap();
        for (m, out) in models.iter().zip(&outs) {
            assert_eq!(out, &backend.predict(m, &x).unwrap());
        }
    }

    #[test]
    fn decode_delegates_to_eval() {
        let backend = RustBackend::new();
        let logits = vec![1.0f32, 2.0, 3.0, 4.0];
        let idx = vec![0i32, 1];
        let scores = backend.decode(&logits, &idx, 1, 2, 2, 2).unwrap();
        assert_eq!(scores, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
