//! Per-round client sampling (Algorithm 2 line 10: "Randomly select a
//! set K_t that includes S out of K clients").

use crate::util::rng::{derive_seed, Rng};

/// Seeded sampler: round `t` always draws the same subset for the same
/// run seed, so paired FedMLH/FedAvg comparisons see identical client
/// schedules (removes one source of comparison noise).
///
/// ## Interaction with the delta downlink
///
/// Partial participation is what makes per-client downlink bases
/// diverge: a client sampled out for `k` rounds still holds the base it
/// decoded `k` rounds ago, so on its next draw the
/// [`DeltaDownlink`](super::transport::DeltaDownlink) either ships a
/// delta against that stale base (`k ≤ --resync-every`) or falls back
/// to a full dense resync (`k` past the cap). Uniform sampling without
/// replacement bounds the *expected* staleness at `K / S` rounds, but
/// an unlucky client's gap is unbounded — which is why the resync cap
/// exists at all.
#[derive(Clone, Debug)]
pub struct ClientSampler {
    clients: usize,
    per_round: usize,
    seed: u64,
}

impl ClientSampler {
    pub fn new(clients: usize, per_round: usize, seed: u64) -> Self {
        assert!(per_round <= clients && per_round > 0);
        ClientSampler {
            clients,
            per_round,
            seed,
        }
    }

    /// The S client ids participating in round `t`.
    pub fn sample(&self, round: usize) -> Vec<usize> {
        let mut rng = Rng::new(derive_seed(self.seed, 0x5a3e_0000 + round as u64));
        rng.sample_without_replacement(self.clients, self.per_round)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_round() {
        let s = ClientSampler::new(10, 4, 7);
        assert_eq!(s.sample(3), s.sample(3));
        assert_ne!(s.sample(3), s.sample(4));
    }

    #[test]
    fn correct_size_and_distinct() {
        let s = ClientSampler::new(10, 4, 1);
        for t in 0..50 {
            let picked = s.sample(t);
            assert_eq!(picked.len(), 4);
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4);
            assert!(sorted.iter().all(|&c| c < 10));
        }
    }

    #[test]
    fn covers_all_clients_eventually() {
        let s = ClientSampler::new(10, 4, 2);
        let mut seen = vec![false; 10];
        for t in 0..30 {
            for c in s.sample(t) {
                seen[c] = true;
            }
        }
        assert!(seen.into_iter().all(|s| s));
    }
}
