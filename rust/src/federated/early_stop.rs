//! Early stopping on the paper's criterion: "the best accuracy (the
//! average of top 1, 3 and 5 accuracy)" with a patience window.
//! Table 4/6 report communication volume / rounds *to reach the best
//! accuracy*, so the tracker also remembers when the best was seen.

/// Best-metric tracker with patience.
#[derive(Clone, Debug)]
pub struct EarlyStopper {
    patience: usize,
    best: f64,
    best_round: usize,
    since_best: usize,
    observed: usize,
}

impl EarlyStopper {
    /// `patience` rounds without improvement stop training; 0 disables
    /// stopping (but the best round is still tracked).
    pub fn new(patience: usize) -> Self {
        EarlyStopper {
            patience,
            best: f64::NEG_INFINITY,
            best_round: 0,
            since_best: 0,
            observed: 0,
        }
    }

    /// Record the metric for `round`; returns `true` if training should
    /// stop *after* this round.
    pub fn observe(&mut self, round: usize, metric: f64) -> bool {
        self.observed += 1;
        if metric > self.best {
            self.best = metric;
            self.best_round = round;
            self.since_best = 0;
            false
        } else {
            self.since_best += 1;
            self.patience > 0 && self.since_best >= self.patience
        }
    }

    pub fn best(&self) -> f64 {
        self.best
    }

    /// Round index (0-based) at which the best metric occurred.
    pub fn best_round(&self) -> usize {
        self.best_round
    }

    /// Tracker state for crash-resume snapshots
    /// ([`super::snapshot`]): `(best, best_round, since_best,
    /// observed)`. Patience is configuration, not state.
    pub fn snapshot_parts(&self) -> (f64, usize, usize, usize) {
        (self.best, self.best_round, self.since_best, self.observed)
    }

    /// Restore tracker state captured by [`Self::snapshot_parts`].
    pub fn restore_parts(
        &mut self,
        best: f64,
        best_round: usize,
        since_best: usize,
        observed: usize,
    ) {
        self.best = best;
        self.best_round = best_round;
        self.since_best = since_best;
        self.observed = observed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_best_and_stops() {
        let mut es = EarlyStopper::new(2);
        assert!(!es.observe(0, 0.1));
        assert!(!es.observe(1, 0.3));
        assert!(!es.observe(2, 0.2)); // 1 since best
        assert!(es.observe(3, 0.25)); // 2 since best → stop
        assert_eq!(es.best_round(), 1);
        assert!((es.best() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn patience_zero_never_stops() {
        let mut es = EarlyStopper::new(0);
        for r in 0..100 {
            assert!(!es.observe(r, -1.0 * r as f64));
        }
        assert_eq!(es.best_round(), 0);
    }

    #[test]
    fn improvement_resets_patience() {
        let mut es = EarlyStopper::new(2);
        assert!(!es.observe(0, 0.1));
        assert!(!es.observe(1, 0.05));
        assert!(!es.observe(2, 0.2)); // new best resets
        assert!(!es.observe(3, 0.1));
        assert!(es.observe(4, 0.1));
        assert_eq!(es.best_round(), 2);
    }
}
