//! The parallel round engine — fans one synchronization round's
//! `(selected client, sub-model)` work items across a worker pool.
//!
//! Each work item is keyed by `(round, client, sub-model)`: clone the
//! client's decoded broadcast base from the round's
//! [`RoundBroadcast`](super::transport::RoundBroadcast) (per-client
//! under the delta downlink, shared otherwise), run E local epochs with
//! the item's [`derive_seed`]-derived batch stream, and encode the
//! update through the run's shared
//! [`super::transport::UplinkCompressor`] against that same base.
//! Items never share mutable state — a stateful (error-feedback)
//! compressor keeps one slot per `(client, sub-model)`, a round touches
//! each slot from exactly one item, and the broadcast (including all
//! per-client downlink state) is produced on the coordinator thread
//! before the fan-out — so executing them on N threads instead of one
//! changes *nothing* about the numbers:
//!
//! - the per-item RNG seed depends only on `(round, client, sub-model)`
//!   — the seed scheme the sequential loop always used;
//! - results are written into their item-index slot and consumed in
//!   deterministic `(selected order, sub-model)` order, so aggregation
//!   and loss averaging see the identical operand order;
//! - communication metering happens after the fan-in, in item order.
//! - the compute itself is deterministic: the tiled kernels under
//!   [`crate::kernels`] keep a fixed, tiling-independent summation
//!   order, so an item's numbers do not depend on which worker ran it
//!   or on what ran before it on that worker — and the same property
//!   makes the *intra-step* budget safe: workers beyond the item count
//!   are handed down as `kernels::parallel::set_kernel_threads`
//!   row-slicing budget (large GEMMs split output rows across scoped
//!   threads, every element still written once in the same order), so
//!   `--workers N` fills N cores whether a round has many small
//!   clients or one huge one.
//!
//! `tests/parallel_determinism.rs` pins `workers = 4` to be
//! bit-identical to `workers = 1`.
//!
//! Backends opt into the pool via
//! [`TrainBackend::as_parallel`](super::backend::TrainBackend::as_parallel):
//! the pure-rust backend is freely shareable, while the PJRT/`Rc`-based
//! xla backend stays on the sequential path by construction.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::algo::LabelScheme;
use crate::config::ExperimentConfig;
use crate::data::dataset::Dataset;
use crate::partition::Partition;
use crate::util::rng::derive_seed;

use super::backend::{TrainBackend, TrainStats};
use super::batcher::ClientBatcher;
use super::transport::{RoundBroadcast, UplinkCompressor};
use super::wire::EncodedUpdate;

/// What one `(client, sub-model)` work item produces.
#[derive(Clone, Debug)]
pub struct ClientUpdate {
    /// Local-training statistics (steps, mean loss, wall-clock).
    pub stats: TrainStats,
    /// Wall-clock seconds spent wire-encoding the update (the
    /// client-side cost of the codec; telemetry for `RoundTiming`).
    pub encode_seconds: f64,
    /// The wire-encoded update the client ships back.
    pub encoded: EncodedUpdate,
}

/// Worker-pool executor for one round's local-training fan-out.
#[derive(Clone, Copy, Debug)]
pub struct RoundEngine {
    workers: usize,
}

impl RoundEngine {
    /// `workers = 1` is the sequential path; `N > 1` uses N OS threads
    /// with an atomic work queue (items vary in cost with shard size,
    /// so static chunking would straggle).
    pub fn new(workers: usize) -> Self {
        RoundEngine {
            workers: workers.max(1),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Train every `(selected client, sub-model)` pair of one round.
    ///
    /// `bcast` is the round's *decoded broadcast* — each client trains
    /// from (and encodes its update against) its own base,
    /// `bcast.global(slot, j)`, which is client-specific under the
    /// delta downlink and shared otherwise. `uplink` is the run's
    /// shared (possibly stateful) update compressor.
    ///
    /// Returns updates indexed `[slot][sub-model]` where `slot` follows
    /// `selected`'s order — independent of worker count or scheduling.
    #[allow(clippy::too_many_arguments)]
    pub fn run_round(
        &self,
        cfg: &ExperimentConfig,
        scheme: &dyn LabelScheme,
        backend: &dyn TrainBackend,
        uplink: &dyn UplinkCompressor,
        train: &Dataset,
        partition: &Partition,
        bcast: &RoundBroadcast,
        round: usize,
        selected: &[usize],
    ) -> Result<Vec<Vec<ClientUpdate>>> {
        let n_models = bcast.n_models();
        let n_items = selected.len() * n_models;

        // One work item; `be` is threaded through explicitly so the
        // closure itself only captures Sync data. `lane` is the trace
        // lane (worker index + 1; lane 0 is the coordinator thread) —
        // purely observational, it never touches seeds or numbers.
        let run_item = |be: &dyn TrainBackend,
                        lane: u64,
                        slot: usize,
                        j: usize|
         -> Result<ClientUpdate> {
            let client = selected[slot];
            // `shard` maps virtual registry ids onto real partition
            // shards; for the synchronous loop (client < shard count)
            // it is the historical `&partition.clients[client]`.
            let shard = partition.shard(client);
            let global = bcast.global(slot, j);
            let mut local = global.clone();
            // Seed stride = the full client population (registry under
            // --async), computed in u64 so million-client ids don't
            // overflow; identical to the old usize arithmetic for every
            // synchronous configuration.
            let stream = (round as u64)
                .wrapping_mul(cfg.client_population() as u64)
                .wrapping_add(client as u64)
                .wrapping_mul(n_models as u64)
                .wrapping_add(j as u64);
            let mut batcher = ClientBatcher::new(
                train,
                shard,
                scheme.target(j),
                cfg.preset.batch,
                derive_seed(cfg.seed, stream),
            );
            let stats = {
                let _span = crate::obs::trace::wall_span("train", lane).map(|g| {
                    g.arg("client", crate::util::json::Json::num(client as f64))
                        .arg("model", crate::util::json::Json::num(j as f64))
                });
                be.local_train(&mut local, &mut batcher, cfg.local_epochs, cfg.lr)?
            };
            let t_enc = std::time::Instant::now();
            let encoded = {
                let _span = crate::obs::trace::wall_span("encode", lane);
                uplink.compress(client, j, global, &local)?
            };
            Ok(ClientUpdate {
                stats,
                encode_seconds: t_enc.elapsed().as_secs_f64(),
                encoded,
            })
        };

        let pool = self.workers.min(n_items.max(1));
        let parallel_backend = if pool > 1 { backend.as_parallel() } else { None };
        // Workers beyond the item fan-out flow *down* into the kernels:
        // each pool thread gets `workers / pool` intra-kernel threads
        // (kernels::parallel row-slices large GEMMs, bitwise-identical
        // at any count), so `--workers 8` saturates eight cores whether
        // the round has eight clients or one.
        let intra = (self.workers / pool.max(1)).max(1);

        let collected: Vec<Result<ClientUpdate>> = match parallel_backend {
            Some(sync_be) => {
                let next = AtomicUsize::new(0);
                let slots: Vec<Mutex<Option<Result<ClientUpdate>>>> =
                    (0..n_items).map(|_| Mutex::new(None)).collect();
                std::thread::scope(|scope| {
                    let next = &next;
                    let slots = &slots;
                    let run_item = &run_item;
                    for w in 0..pool {
                        scope.spawn(move || {
                            let _budget = crate::kernels::parallel::set_kernel_threads(intra);
                            let be: &dyn TrainBackend = sync_be;
                            loop {
                                let i = next.fetch_add(1, Ordering::Relaxed);
                                if i >= n_items {
                                    break;
                                }
                                let out = run_item(be, w as u64 + 1, i / n_models, i % n_models);
                                *slots[i].lock().unwrap() = Some(out);
                            }
                        });
                    }
                });
                slots
                    .into_iter()
                    .map(|m| {
                        m.into_inner()
                            .expect("worker panicked mid-item")
                            .expect("every item slot is filled before join")
                    })
                    .collect()
            }
            None => {
                // Sequential fan-out (one item, one worker, or a
                // non-shareable backend): the whole `--workers` budget
                // goes to intra-kernel parallelism instead.
                let _budget = crate::kernels::parallel::set_kernel_threads(self.workers);
                (0..n_items)
                    .map(|i| run_item(backend, 0, i / n_models, i % n_models))
                    .collect()
            }
        };

        // Fan-in: fail on the first bad item in deterministic order,
        // then group [slot][sub-model].
        let mut flat = Vec::with_capacity(n_items);
        for item in collected {
            flat.push(item?);
        }
        let mut grouped = Vec::with_capacity(selected.len());
        let mut items = flat.into_iter();
        for _ in 0..selected.len() {
            grouped.push((0..n_models).map(|_| items.next().expect("item count")).collect());
        }
        Ok(grouped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::scheme_for;
    use crate::config::Algo;
    use crate::data::synth::generate_preset;
    use crate::federated::backend::RustBackend;
    use crate::federated::transport::{
        DownCodec, DownlinkCompressor, FeedbackUplink, StatelessDownlink, StatelessUplink,
    };
    use crate::federated::wire::CodecSpec;
    use crate::model::params::ModelParams;
    use crate::partition::noniid::{partition as noniid, NonIidOptions};

    fn setup() -> (ExperimentConfig, crate::data::synth::SynthData, Partition) {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.clients = 4;
        cfg.clients_per_round = 3;
        cfg.local_epochs = 1;
        let data = generate_preset(&cfg.preset, cfg.seed);
        let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
        (cfg, data, part)
    }

    fn run_with(workers: usize, uplink: &dyn UplinkCompressor) -> Vec<Vec<ClientUpdate>> {
        let (cfg, data, part) = setup();
        let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
        let backend = RustBackend::new();
        let globals: Vec<ModelParams> = (0..scheme.n_models())
            .map(|j| {
                ModelParams::init(
                    data.train.d(),
                    cfg.preset.hidden,
                    scheme.out_dim(),
                    derive_seed(cfg.seed, 0x1417_0000 + j as u64),
                )
            })
            .collect();
        let selected = vec![0usize, 2, 3];
        // A dense shared broadcast reproduces the historical "clients
        // clone the global" behavior the engine contract is pinned on.
        let bcast = StatelessDownlink::new(DownCodec::Dense)
            .broadcast(0, &selected, &globals)
            .unwrap();
        RoundEngine::new(workers)
            .run_round(
                &cfg,
                scheme.as_ref(),
                &backend,
                uplink,
                &data.train,
                &part,
                &bcast,
                0,
                &selected,
            )
            .unwrap()
    }

    #[test]
    fn groups_by_client_then_model() {
        let uplink = StatelessUplink::new(CodecSpec::Dense);
        let out = run_with(1, &uplink);
        assert_eq!(out.len(), 3);
        for per_model in &out {
            assert_eq!(per_model.len(), 2); // tiny preset R=2
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let uplink = StatelessUplink::new(CodecSpec::Dense);
        let seq = run_with(1, &uplink);
        for workers in [2usize, 4, 7] {
            let par = run_with(workers, &uplink);
            assert_eq!(seq.len(), par.len());
            for (a, b) in seq.iter().zip(par.iter()) {
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.encoded, y.encoded, "workers={workers}");
                    assert_eq!(x.stats.steps, y.stats.steps);
                    assert_eq!(x.stats.mean_loss, y.stats.mean_loss);
                }
            }
        }
    }

    #[test]
    fn stateful_uplink_is_worker_count_invariant_too() {
        // Fresh compressor state per engine run: the residual written by
        // round 1 must come out identical no matter how many workers
        // raced through the items.
        let (cfg, ..) = setup();
        let spec = CodecSpec::TopK { frac: 0.1 };
        let seq_up = FeedbackUplink::new(spec, cfg.clients, 2);
        let seq = run_with(1, &seq_up);
        for workers in [2usize, 4] {
            let par_up = FeedbackUplink::new(spec, cfg.clients, 2);
            let par = run_with(workers, &par_up);
            for (a, b) in seq.iter().zip(par.iter()) {
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.encoded, y.encoded, "workers={workers}");
                }
            }
            for &client in &[0usize, 2, 3] {
                for j in 0..2 {
                    assert_eq!(
                        seq_up.residual(client, j),
                        par_up.residual(client, j),
                        "residual slot ({client},{j}) with workers={workers}"
                    );
                }
            }
        }
    }
}
