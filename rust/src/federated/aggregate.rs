//! Parameter aggregation (paper Algorithm 2 line 17, FedAvg §3.1).
//!
//! FedMLH aggregates uniformly over the selected clients
//! (`w ← Σ_k w_k / S`); classic FedAvg weights by client sample count
//! (`w ← Σ_k n_k/N · w_k`). Both are supported; the harness uses uniform
//! weights for both algorithms, matching the paper's Algorithm 2.

use anyhow::{bail, Result};

use crate::config::RobustAgg;
use crate::model::params::ModelParams;

/// Aggregation weighting scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weighting {
    /// `1/S` each (Algorithm 2 line 17).
    Uniform,
    /// `n_k / Σ n_k` (McMahan et al. FedAvg).
    BySamples,
}

/// Aggregate `locals` (paired with their shard sizes) into a fresh
/// global model.
pub fn aggregate(
    locals: &[(&ModelParams, usize)],
    weighting: Weighting,
) -> Result<ModelParams> {
    if locals.is_empty() {
        bail!("aggregate() needs at least one local model");
    }
    let (d, h, out) = {
        let p = locals[0].0;
        (p.d, p.hidden, p.out)
    };
    let mut global = ModelParams::zeros(d, h, out);
    let weights = weights_for(locals, weighting);
    for ((local, _), w) in locals.iter().zip(weights.iter()) {
        global.accumulate(local, *w as f32)?;
    }
    Ok(global)
}

/// Defensive aggregation (`--robust-agg`): screen non-finite updates,
/// then clip or trim outliers before averaging, so a single divergent
/// or adversarial client cannot poison the global model.
///
/// `global` is the model the round started from — it anchors the
/// per-update deltas under norm-clipping and is what survives unchanged
/// when *every* update is screened out. [`RobustAgg::None`] delegates
/// straight to [`aggregate`], bit-identical to the historical pipeline.
///
/// - `norm-clip:<c>`: each surviving update's delta from `global` is
///   rescaled to L2 norm ≤ `c`, then the weighted mean of clipped
///   deltas is applied to `global`.
/// - `trimmed:<frac>`: coordinate-wise trimmed mean — the `⌊frac·m⌋`
///   smallest and largest values per coordinate are dropped and the
///   rest averaged (unweighted; trimming has no natural per-client
///   weight).
pub fn aggregate_robust(
    global: &ModelParams,
    locals: &[(&ModelParams, usize)],
    weighting: Weighting,
    robust: RobustAgg,
) -> Result<ModelParams> {
    if robust == RobustAgg::None {
        return aggregate(locals, weighting);
    }
    let survivors: Vec<(&ModelParams, usize)> = locals
        .iter()
        .filter(|(p, _)| is_finite_params(p))
        .copied()
        .collect();
    let screened = locals.len() - survivors.len();
    if screened > 0 {
        crate::obs::metrics::global()
            .counter(
                "fedmlh_robust_screened_total",
                "Non-finite client updates screened out by --robust-agg.",
            )
            .add(screened as u64);
    }
    if survivors.is_empty() {
        // Every update was poisoned; keep the round's starting model.
        return Ok(global.clone());
    }
    match robust {
        RobustAgg::None => unreachable!("handled above"),
        RobustAgg::NormClip { c } => {
            let base = global.flat_values();
            let weights = weights_for(&survivors, weighting);
            let mut mean = vec![0.0f64; base.len()];
            for ((local, _), w) in survivors.iter().zip(weights.iter()) {
                let flat = local.flat_values();
                if flat.len() != base.len() {
                    bail!(
                        "norm-clip: update has {} values, global has {}",
                        flat.len(),
                        base.len()
                    );
                }
                let mut norm_sq = 0.0f64;
                for (v, b) in flat.iter().zip(base.iter()) {
                    let d = f64::from(v - b);
                    norm_sq += d * d;
                }
                let norm = norm_sq.sqrt();
                let scale = if norm > c { c / norm } else { 1.0 };
                for ((m, v), b) in mean.iter_mut().zip(flat.iter()).zip(base.iter()) {
                    *m += w * scale * f64::from(v - b);
                }
            }
            let clipped: Vec<f32> = base
                .iter()
                .zip(mean.iter())
                .map(|(b, m)| (f64::from(*b) + m) as f32)
                .collect();
            let mut out = ModelParams::zeros(global.d, global.hidden, global.out);
            out.set_from_flat(&clipped)?;
            Ok(out)
        }
        RobustAgg::Trimmed { frac } => {
            let flats: Vec<Vec<f32>> = survivors.iter().map(|(p, _)| p.flat_values()).collect();
            let n = flats[0].len();
            if flats.iter().any(|f| f.len() != n) {
                bail!("trimmed: update length mismatch");
            }
            let m = flats.len();
            let k = (frac * m as f64).floor() as usize;
            let kept = m - 2 * k;
            if kept == 0 {
                bail!("trimmed:{frac} leaves no survivors out of {m} updates");
            }
            let mut values = vec![0.0f32; m];
            let mut out_flat = vec![0.0f32; n];
            for (i, slot) in out_flat.iter_mut().enumerate() {
                for (v, f) in values.iter_mut().zip(flats.iter()) {
                    *v = f[i];
                }
                values.sort_by(f32::total_cmp);
                let sum: f64 = values[k..m - k].iter().map(|&v| f64::from(v)).sum();
                *slot = (sum / kept as f64) as f32;
            }
            let mut out = ModelParams::zeros(global.d, global.hidden, global.out);
            out.set_from_flat(&out_flat)?;
            Ok(out)
        }
    }
}

fn is_finite_params(p: &ModelParams) -> bool {
    p.tensors
        .iter()
        .all(|t| t.data().iter().all(|v| v.is_finite()))
}

fn weights_for(locals: &[(&ModelParams, usize)], weighting: Weighting) -> Vec<f64> {
    match weighting {
        Weighting::Uniform => vec![1.0 / locals.len() as f64; locals.len()],
        Weighting::BySamples => {
            let total: usize = locals.iter().map(|(_, n)| n).sum();
            if total == 0 {
                // Every shard is empty (e.g. all selected clients hold
                // fewer samples than one batch). The old `total.max(1)`
                // guard produced weights summing to 0 — a silent zero
                // model out of `aggregate()`. Convex weights must sum
                // to 1, so fall back to the uniform rule instead.
                return weights_for(locals, Weighting::Uniform);
            }
            locals
                .iter()
                .map(|(_, n)| *n as f64 / total as f64)
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn constant_params(v: f32) -> ModelParams {
        let mut p = ModelParams::zeros(2, 3, 4);
        for t in p.tensors.iter_mut() {
            t.fill(v);
        }
        p
    }

    #[test]
    fn uniform_mean() {
        let a = constant_params(1.0);
        let b = constant_params(3.0);
        let g = aggregate(&[(&a, 10), (&b, 90)], Weighting::Uniform).unwrap();
        for t in &g.tensors {
            assert!(t.data().iter().all(|&v| (v - 2.0).abs() < 1e-6));
        }
    }

    #[test]
    fn sample_weighted_mean() {
        let a = constant_params(1.0);
        let b = constant_params(3.0);
        let g = aggregate(&[(&a, 25), (&b, 75)], Weighting::BySamples).unwrap();
        for t in &g.tensors {
            assert!(t.data().iter().all(|&v| (v - 2.5).abs() < 1e-6));
        }
    }

    #[test]
    fn by_samples_with_all_empty_shards_falls_back_to_uniform() {
        // Regression: sizes (0, 0) used to yield weights (0, 0) via the
        // `total.max(1)` guard, silently aggregating to the zero model.
        let a = constant_params(1.0);
        let b = constant_params(3.0);
        let g = aggregate(&[(&a, 0), (&b, 0)], Weighting::BySamples).unwrap();
        for t in &g.tensors {
            assert!(
                t.data().iter().all(|&v| (v - 2.0).abs() < 1e-6),
                "expected the uniform mean, got {:?}",
                &t.data()[..t.len().min(4)]
            );
        }
        // One non-empty shard still dominates normally.
        let g = aggregate(&[(&a, 0), (&b, 10)], Weighting::BySamples).unwrap();
        for t in &g.tensors {
            assert!(t.data().iter().all(|&v| (v - 3.0).abs() < 1e-6));
        }
    }

    #[test]
    fn rejects_empty_and_mismatched() {
        assert!(aggregate(&[], Weighting::Uniform).is_err());
        let a = constant_params(1.0);
        let b = ModelParams::zeros(9, 3, 4);
        assert!(aggregate(&[(&a, 1), (&b, 1)], Weighting::Uniform).is_err());
    }

    #[test]
    fn robust_none_matches_plain_aggregate() {
        let a = constant_params(1.0);
        let b = constant_params(3.0);
        let global = constant_params(0.0);
        let refs = [(&a, 10), (&b, 90)];
        let plain = aggregate(&refs, Weighting::Uniform).unwrap();
        let robust =
            aggregate_robust(&global, &refs, Weighting::Uniform, RobustAgg::None).unwrap();
        assert_eq!(plain, robust);
    }

    #[test]
    fn robust_screens_nan_updates() {
        let global = constant_params(2.0);
        let good = constant_params(4.0);
        let mut bad = constant_params(4.0);
        bad.tensors[0].data_mut()[0] = f32::NAN;
        for robust in [
            RobustAgg::NormClip { c: 1e9 },
            RobustAgg::Trimmed { frac: 0.0 },
        ] {
            let g = aggregate_robust(
                &global,
                &[(&good, 1), (&bad, 1)],
                Weighting::Uniform,
                robust,
            )
            .unwrap();
            for t in &g.tensors {
                assert!(
                    t.data().iter().all(|&v| (v - 4.0).abs() < 1e-5),
                    "{robust:?}"
                );
            }
        }
        // Every update poisoned → the starting global survives untouched.
        let g = aggregate_robust(
            &global,
            &[(&bad, 1)],
            Weighting::Uniform,
            RobustAgg::NormClip { c: 10.0 },
        )
        .unwrap();
        assert_eq!(g, global);
    }

    #[test]
    fn norm_clip_bounds_the_step() {
        let global = constant_params(0.0);
        let huge = constant_params(1000.0);
        let c = 1.0;
        let g = aggregate_robust(
            &global,
            &[(&huge, 1)],
            Weighting::Uniform,
            RobustAgg::NormClip { c },
        )
        .unwrap();
        let mut norm_sq = 0.0f64;
        for t in &g.tensors {
            for &v in t.data() {
                norm_sq += f64::from(v) * f64::from(v);
            }
        }
        let norm = norm_sq.sqrt();
        assert!(
            (norm - c).abs() < 1e-4,
            "clipped step norm {norm} should sit at the clip bound {c}"
        );
        // A small update inside the bound passes through unclipped.
        let mut small = constant_params(0.0);
        small.tensors[1].data_mut()[0] = 0.5;
        let g = aggregate_robust(
            &global,
            &[(&small, 1)],
            Weighting::Uniform,
            RobustAgg::NormClip { c },
        )
        .unwrap();
        assert!((g.tensors[1].data()[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let global = constant_params(0.0);
        let locals: Vec<ModelParams> = [1.0, 2.0, 3.0, 4.0, 100.0]
            .iter()
            .map(|&v| constant_params(v))
            .collect();
        let refs: Vec<(&ModelParams, usize)> = locals.iter().map(|p| (p, 1)).collect();
        let g = aggregate_robust(
            &global,
            &refs,
            Weighting::Uniform,
            RobustAgg::Trimmed { frac: 0.2 },
        )
        .unwrap();
        // frac 0.2 of 5 drops one from each side: mean(2, 3, 4) = 3.
        for t in &g.tensors {
            assert!(t.data().iter().all(|&v| (v - 3.0).abs() < 1e-5));
        }
        // Trimming everything is an error, not a zero model.
        assert!(aggregate_robust(
            &global,
            &refs[..2],
            Weighting::Uniform,
            RobustAgg::Trimmed { frac: 0.5 }
        )
        .is_err());
    }

    #[test]
    fn aggregate_stays_in_convex_hull() {
        // Property: every aggregated coordinate lies within the min/max of
        // the locals' coordinates (convex combination invariant).
        check("convex hull", 20, |g| {
            let k = g.usize_in(1, 6);
            let locals: Vec<ModelParams> = (0..k)
                .map(|i| {
                    let mut p = ModelParams::zeros(2, 2, 2);
                    for t in p.tensors.iter_mut() {
                        for v in t.data_mut() {
                            *v = g.f32_in(-5.0, 5.0) + i as f32;
                        }
                    }
                    p
                })
                .collect();
            let sizes: Vec<usize> = (0..k).map(|_| g.usize_in(1, 100)).collect();
            let refs: Vec<(&ModelParams, usize)> =
                locals.iter().zip(sizes.iter().copied()).collect();
            for weighting in [Weighting::Uniform, Weighting::BySamples] {
                let agg = aggregate(&refs, weighting).unwrap();
                for ti in 0..agg.tensors.len() {
                    for (ei, &v) in agg.tensors[ti].data().iter().enumerate() {
                        let lo = locals
                            .iter()
                            .map(|l| l.tensors[ti].data()[ei])
                            .fold(f32::INFINITY, f32::min);
                        let hi = locals
                            .iter()
                            .map(|l| l.tensors[ti].data()[ei])
                            .fold(f32::NEG_INFINITY, f32::max);
                        assert!(
                            v >= lo - 1e-5 && v <= hi + 1e-5,
                            "coordinate escaped hull: {v} not in [{lo}, {hi}]"
                        );
                    }
                }
            }
        });
    }
}
