//! Parameter aggregation (paper Algorithm 2 line 17, FedAvg §3.1).
//!
//! FedMLH aggregates uniformly over the selected clients
//! (`w ← Σ_k w_k / S`); classic FedAvg weights by client sample count
//! (`w ← Σ_k n_k/N · w_k`). Both are supported; the harness uses uniform
//! weights for both algorithms, matching the paper's Algorithm 2.

use anyhow::{bail, Result};

use crate::model::params::ModelParams;

/// Aggregation weighting scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weighting {
    /// `1/S` each (Algorithm 2 line 17).
    Uniform,
    /// `n_k / Σ n_k` (McMahan et al. FedAvg).
    BySamples,
}

/// Aggregate `locals` (paired with their shard sizes) into a fresh
/// global model.
pub fn aggregate(
    locals: &[(&ModelParams, usize)],
    weighting: Weighting,
) -> Result<ModelParams> {
    if locals.is_empty() {
        bail!("aggregate() needs at least one local model");
    }
    let (d, h, out) = {
        let p = locals[0].0;
        (p.d, p.hidden, p.out)
    };
    let mut global = ModelParams::zeros(d, h, out);
    let weights = weights_for(locals, weighting);
    for ((local, _), w) in locals.iter().zip(weights.iter()) {
        global.accumulate(local, *w as f32)?;
    }
    Ok(global)
}

fn weights_for(locals: &[(&ModelParams, usize)], weighting: Weighting) -> Vec<f64> {
    match weighting {
        Weighting::Uniform => vec![1.0 / locals.len() as f64; locals.len()],
        Weighting::BySamples => {
            let total: usize = locals.iter().map(|(_, n)| n).sum();
            if total == 0 {
                // Every shard is empty (e.g. all selected clients hold
                // fewer samples than one batch). The old `total.max(1)`
                // guard produced weights summing to 0 — a silent zero
                // model out of `aggregate()`. Convex weights must sum
                // to 1, so fall back to the uniform rule instead.
                return weights_for(locals, Weighting::Uniform);
            }
            locals
                .iter()
                .map(|(_, n)| *n as f64 / total as f64)
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn constant_params(v: f32) -> ModelParams {
        let mut p = ModelParams::zeros(2, 3, 4);
        for t in p.tensors.iter_mut() {
            t.fill(v);
        }
        p
    }

    #[test]
    fn uniform_mean() {
        let a = constant_params(1.0);
        let b = constant_params(3.0);
        let g = aggregate(&[(&a, 10), (&b, 90)], Weighting::Uniform).unwrap();
        for t in &g.tensors {
            assert!(t.data().iter().all(|&v| (v - 2.0).abs() < 1e-6));
        }
    }

    #[test]
    fn sample_weighted_mean() {
        let a = constant_params(1.0);
        let b = constant_params(3.0);
        let g = aggregate(&[(&a, 25), (&b, 75)], Weighting::BySamples).unwrap();
        for t in &g.tensors {
            assert!(t.data().iter().all(|&v| (v - 2.5).abs() < 1e-6));
        }
    }

    #[test]
    fn by_samples_with_all_empty_shards_falls_back_to_uniform() {
        // Regression: sizes (0, 0) used to yield weights (0, 0) via the
        // `total.max(1)` guard, silently aggregating to the zero model.
        let a = constant_params(1.0);
        let b = constant_params(3.0);
        let g = aggregate(&[(&a, 0), (&b, 0)], Weighting::BySamples).unwrap();
        for t in &g.tensors {
            assert!(
                t.data().iter().all(|&v| (v - 2.0).abs() < 1e-6),
                "expected the uniform mean, got {:?}",
                &t.data()[..t.len().min(4)]
            );
        }
        // One non-empty shard still dominates normally.
        let g = aggregate(&[(&a, 0), (&b, 10)], Weighting::BySamples).unwrap();
        for t in &g.tensors {
            assert!(t.data().iter().all(|&v| (v - 3.0).abs() < 1e-6));
        }
    }

    #[test]
    fn rejects_empty_and_mismatched() {
        assert!(aggregate(&[], Weighting::Uniform).is_err());
        let a = constant_params(1.0);
        let b = ModelParams::zeros(9, 3, 4);
        assert!(aggregate(&[(&a, 1), (&b, 1)], Weighting::Uniform).is_err());
    }

    #[test]
    fn aggregate_stays_in_convex_hull() {
        // Property: every aggregated coordinate lies within the min/max of
        // the locals' coordinates (convex combination invariant).
        check("convex hull", 20, |g| {
            let k = g.usize_in(1, 6);
            let locals: Vec<ModelParams> = (0..k)
                .map(|i| {
                    let mut p = ModelParams::zeros(2, 2, 2);
                    for t in p.tensors.iter_mut() {
                        for v in t.data_mut() {
                            *v = g.f32_in(-5.0, 5.0) + i as f32;
                        }
                    }
                    p
                })
                .collect();
            let sizes: Vec<usize> = (0..k).map(|_| g.usize_in(1, 100)).collect();
            let refs: Vec<(&ModelParams, usize)> =
                locals.iter().zip(sizes.iter().copied()).collect();
            for weighting in [Weighting::Uniform, Weighting::BySamples] {
                let agg = aggregate(&refs, weighting).unwrap();
                for ti in 0..agg.tensors.len() {
                    for (ei, &v) in agg.tensors[ti].data().iter().enumerate() {
                        let lo = locals
                            .iter()
                            .map(|l| l.tensors[ti].data()[ei])
                            .fold(f32::INFINITY, f32::min);
                        let hi = locals
                            .iter()
                            .map(|l| l.tensors[ti].data()[ei])
                            .fold(f32::NEG_INFINITY, f32::max);
                        assert!(
                            v >= lo - 1e-5 && v <= hi + 1e-5,
                            "coordinate escaped hull: {v} not in [{lo}, {hi}]"
                        );
                    }
                }
            }
        });
    }
}
