//! The stateful transport pipeline — compression state that lives
//! *across* synchronization rounds, on both ends of the wire.
//!
//! PR 1's wire layer ([`super::wire`]) is deliberately stateless: a
//! codec sees one `(global, local)` pair, encodes, and forgets. That is
//! exactly what the communication-efficiency literature says you must
//! not do under aggressive compression — the un-shipped part of every
//! update (the top-k residual, the quantization error) is discarded
//! each round and the error compounds (arXiv 2107.10996 §IV; CatFedAvg,
//! arXiv 2011.07229). This module inverts the ownership: compressors
//! are *objects* that carry state round to round, and the round loop
//! drives them through a [`Transport`] facade.
//!
//! ## The three pieces
//!
//! - [`UplinkCompressor`] — client→server. The error-feedback
//!   implementation ([`FeedbackUplink`]) keeps one residual accumulator
//!   per `(client, sub-model)` slot: before encoding, the previous
//!   rounds' un-shipped delta is added back into the local model
//!   (`virtual = local + residual`), and after encoding the new
//!   residual is `virtual − decode(encoded)`. Top-k then re-surfaces
//!   coordinates it dropped (their accumulated delta doubles until
//!   selected), and q8 cancels its quantization bias over time.
//!   [`StatelessUplink`] reproduces the PR 1 behavior bit-for-bit.
//! - [`DownlinkCompressor`] — server→client. Produces a codec-tagged
//!   [`BroadcastPayload`] (dense or q8, reusing the [`super::wire`]
//!   codecs as backends) and reports the *decoded* model — the state
//!   every client actually trains from, so a lossy broadcast affects
//!   training exactly as it would in deployment. [`FoldingDownlink`]
//!   folds the broadcast's own quantization error into the next
//!   round's broadcast (server-side residual feedback), so the mean of
//!   the broadcasts converges to the true aggregate.
//! - [`Transport`] — the facade the round loop owns: `broadcast()`
//!   compresses every sub-model's global down, `uplink()` hands the
//!   engine the shared (Sync) uplink compressor, `decode()` brings an
//!   encoded update back for aggregation.
//!
//! ## Invariants
//!
//! - `dense` on both links with feedback off is **bitwise identical**
//!   to the stateless PR 1 pipeline (`tests/parallel_determinism.rs`);
//!   dense is lossless, so even feedback *on* cannot change it — both
//!   stateful impls short-circuit to the stateless path for `dense`.
//! - Per-slot state makes the parallel engine safe: one round touches
//!   each `(client, sub-model)` slot from exactly one work item, so
//!   worker count and scheduling cannot reorder state updates.
//! - Every pre-existing wire tag (`dense`/`q8`/`topk`/`topkv`) still
//!   decodes unchanged — the codecs are backends, not replaced.

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::model::params::ModelParams;

use super::wire::{decode_update, encode_update, CodecSpec, EncodedUpdate};

/// Which codec compresses the server→client broadcast (CLI:
/// `--down-codec`). Top-k makes no sense here — the broadcast is a
/// full model state, not a sparse delta against something the client
/// already holds — so the downlink menu is dense / q8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DownCodec {
    /// Raw `f32` broadcast — the seed behavior, lossless.
    Dense,
    /// Per-tensor symmetric int8 (~4× smaller), decoded client-side.
    QuantI8,
}

impl DownCodec {
    /// Parse a CLI name (`name()` output always re-parses).
    pub fn parse(name: &str) -> Result<DownCodec> {
        match name {
            "dense" => Ok(DownCodec::Dense),
            "q8" | "quant" => Ok(DownCodec::QuantI8),
            other => bail!("unknown downlink codec '{other}' (expected dense|q8)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DownCodec::Dense => "dense",
            DownCodec::QuantI8 => "q8",
        }
    }

    /// The wire codec that serializes this broadcast.
    fn wire_spec(&self) -> CodecSpec {
        match self {
            DownCodec::Dense => CodecSpec::Dense,
            DownCodec::QuantI8 => CodecSpec::QuantI8,
        }
    }
}

/// One sub-model's compressed broadcast: the codec tag plus the
/// [`super::wire`]-encoded payload. The tag is shared setup state (like
/// the model shape), so old dense receivers and new q8 receivers can
/// coexist as long as both ends agree on it.
#[derive(Clone, Debug, PartialEq)]
pub struct BroadcastPayload {
    codec: DownCodec,
    enc: EncodedUpdate,
}

impl BroadcastPayload {
    pub fn codec(&self) -> DownCodec {
        self.codec
    }

    /// Exact wire size in bytes — what [`super::comm::CommMeter`] is
    /// charged per client download.
    pub fn byte_len(&self) -> usize {
        self.enc.byte_len()
    }

    /// Serialize to the little-endian wire layout (see [`super::wire`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.enc.to_bytes()
    }

    /// Parse a broadcast back; shape metadata comes from the shared
    /// model setup, exactly like update payloads.
    pub fn from_bytes(
        codec: DownCodec,
        n_tensors: usize,
        n_values: usize,
        bytes: &[u8],
    ) -> Result<BroadcastPayload> {
        let enc = EncodedUpdate::from_bytes(codec.wire_spec(), n_tensors, n_values, bytes)?;
        Ok(BroadcastPayload { codec, enc })
    }

    /// Reconstruct the model a client sees. `shape` only supplies the
    /// tensor layout (dense and q8 decoding never read its values).
    pub fn decode(&self, shape: &ModelParams) -> Result<ModelParams> {
        decode_update(shape, &self.enc)
    }
}

/// The shared error-feedback fold both stateful compressors are built
/// on: add the carried `residual` into `vals` (the model the sender
/// *wishes* it could ship), encode that against `reference` with
/// `spec`, then store the new residual — everything the receiver will
/// NOT see after decoding (`vals − decoded`) — back into `residual`.
/// Returns the encoded payload and its decoded form.
fn fold_encode(
    spec: CodecSpec,
    reference: &ModelParams,
    mut vals: Vec<f32>,
    residual: &mut Vec<f32>,
) -> Result<(EncodedUpdate, ModelParams)> {
    if !residual.is_empty() {
        if residual.len() != vals.len() {
            bail!(
                "transport residual has {} values, model has {} — \
                 model shape changed mid-run?",
                residual.len(),
                vals.len()
            );
        }
        for (v, r) in vals.iter_mut().zip(residual.iter()) {
            *v += *r;
        }
    }
    let mut virt = ModelParams::zeros(reference.d, reference.hidden, reference.out);
    virt.set_from_flat(&vals)?;
    let enc = encode_update(spec, reference, &virt)?;
    let decoded = decode_update(reference, &enc)?;
    let decoded_vals = decoded.flat_values();
    residual.clear();
    residual.extend(vals.iter().zip(decoded_vals.iter()).map(|(v, d)| *v - *d));
    Ok((enc, decoded))
}

// ------------------------------------------------------------- uplink

/// Client→server compressor. Implementations may carry per-
/// `(client, sub-model)` state across rounds; the engine calls
/// [`UplinkCompressor::compress`] from its worker threads, so the
/// trait requires `Send + Sync` and state must be interior-mutable.
/// Within one round each `(client, sub-model)` slot is touched by
/// exactly one work item, which is what keeps the parallel engine's
/// bitwise-determinism guarantee intact.
pub trait UplinkCompressor: Send + Sync {
    /// The wire codec this compressor encodes with.
    fn spec(&self) -> CodecSpec;

    /// Whether state is carried across rounds (reporting only).
    fn stateful(&self) -> bool;

    /// Encode `client`'s locally trained sub-model `j` against the
    /// broadcast `global` it started from.
    fn compress(
        &self,
        client: usize,
        j: usize,
        global: &ModelParams,
        local: &ModelParams,
    ) -> Result<EncodedUpdate>;
}

/// The PR 1 behavior: encode each round independently, remember
/// nothing. `dense` through this path is the seed pipeline bit-for-bit.
#[derive(Clone, Copy, Debug)]
pub struct StatelessUplink {
    spec: CodecSpec,
}

impl StatelessUplink {
    pub fn new(spec: CodecSpec) -> Self {
        StatelessUplink { spec }
    }
}

impl UplinkCompressor for StatelessUplink {
    fn spec(&self) -> CodecSpec {
        self.spec
    }

    fn stateful(&self) -> bool {
        false
    }

    fn compress(
        &self,
        _client: usize,
        _j: usize,
        global: &ModelParams,
        local: &ModelParams,
    ) -> Result<EncodedUpdate> {
        encode_update(self.spec, global, local)
    }
}

/// Error-feedback uplink (EF-SGD style): each `(client, sub-model)`
/// slot accumulates the part of the update the codec did not ship, and
/// adds it back into the next round's encode. An empty slot means "no
/// residual yet" — the first compress of a slot starts from the plain
/// local model.
pub struct FeedbackUplink {
    spec: CodecSpec,
    n_models: usize,
    /// `clients × n_models` residual slots, flat-indexed
    /// `client * n_models + j`. Mutex per slot: items never contend
    /// within a round (one item per slot), the lock is for `Sync`.
    slots: Vec<Mutex<Vec<f32>>>,
}

impl FeedbackUplink {
    pub fn new(spec: CodecSpec, clients: usize, n_models: usize) -> Self {
        FeedbackUplink {
            spec,
            n_models,
            slots: (0..clients * n_models).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// A slot's current residual (empty until its first lossy encode) —
    /// test/diagnostic hook.
    pub fn residual(&self, client: usize, j: usize) -> Vec<f32> {
        self.slots[client * self.n_models + j]
            .lock()
            .expect("uplink residual lock poisoned")
            .clone()
    }
}

impl UplinkCompressor for FeedbackUplink {
    fn spec(&self) -> CodecSpec {
        self.spec
    }

    fn stateful(&self) -> bool {
        true
    }

    fn compress(
        &self,
        client: usize,
        j: usize,
        global: &ModelParams,
        local: &ModelParams,
    ) -> Result<EncodedUpdate> {
        // Dense is lossless: the residual is identically zero, so skip
        // the bookkeeping entirely. This is what makes `dense` +
        // feedback *on* still bitwise-identical to the seed pipeline.
        if self.spec == CodecSpec::Dense {
            return encode_update(self.spec, global, local);
        }
        let Some(slot) = self.slots.get(client * self.n_models + j) else {
            bail!(
                "uplink state has no slot for client {client}, sub-model {j} \
                 ({} slots, {} sub-models)",
                self.slots.len(),
                self.n_models
            );
        };
        let mut residual = slot.lock().expect("uplink residual lock poisoned");
        let (enc, _) = fold_encode(self.spec, global, local.flat_values(), &mut residual)?;
        Ok(enc)
    }
}

// ----------------------------------------------------------- downlink

/// Server→client compressor for the per-round global broadcast.
/// `compress` returns both the tagged payload (what crosses the wire,
/// what the meter charges) and its decoded form (what every client
/// trains from this round).
pub trait DownlinkCompressor: Send {
    fn codec(&self) -> DownCodec;

    /// Whether broadcast residual is folded across rounds (reporting).
    fn stateful(&self) -> bool;

    /// Compress sub-model `j`'s current aggregate for broadcast.
    fn compress(&mut self, j: usize, global: &ModelParams)
        -> Result<(BroadcastPayload, ModelParams)>;
}

/// Broadcast each round independently (no residual folding).
#[derive(Clone, Copy, Debug)]
pub struct StatelessDownlink {
    codec: DownCodec,
}

impl StatelessDownlink {
    pub fn new(codec: DownCodec) -> Self {
        StatelessDownlink { codec }
    }
}

fn broadcast_model(
    codec: DownCodec,
    model: &ModelParams,
) -> Result<(BroadcastPayload, ModelParams)> {
    // Dense and q8 both encode the model's own values (the `global`
    // argument of `encode_update` is only a shape witness for them).
    let enc = encode_update(codec.wire_spec(), model, model)?;
    let payload = BroadcastPayload { codec, enc };
    // A dense decode is a bitwise copy — skip the second full pass on
    // the default path.
    let decoded = match codec {
        DownCodec::Dense => model.clone(),
        DownCodec::QuantI8 => payload.decode(model)?,
    };
    Ok((payload, decoded))
}

impl DownlinkCompressor for StatelessDownlink {
    fn codec(&self) -> DownCodec {
        self.codec
    }

    fn stateful(&self) -> bool {
        false
    }

    fn compress(
        &mut self,
        _j: usize,
        global: &ModelParams,
    ) -> Result<(BroadcastPayload, ModelParams)> {
        broadcast_model(self.codec, global)
    }
}

/// Server-side residual folding: the quantization error of round `t`'s
/// decoded broadcast is added into round `t+1`'s pre-quantization
/// state, so the running mean of what clients receive converges to the
/// true aggregate instead of carrying a persistent rounding bias.
pub struct FoldingDownlink {
    codec: DownCodec,
    /// One residual per sub-model (empty = none yet).
    residuals: Vec<Vec<f32>>,
}

impl FoldingDownlink {
    pub fn new(codec: DownCodec, n_models: usize) -> Self {
        FoldingDownlink {
            codec,
            residuals: vec![Vec::new(); n_models],
        }
    }
}

impl DownlinkCompressor for FoldingDownlink {
    fn codec(&self) -> DownCodec {
        self.codec
    }

    fn stateful(&self) -> bool {
        true
    }

    fn compress(
        &mut self,
        j: usize,
        global: &ModelParams,
    ) -> Result<(BroadcastPayload, ModelParams)> {
        // Dense broadcasts are lossless → residual identically zero.
        if self.codec == DownCodec::Dense {
            return broadcast_model(self.codec, global);
        }
        let Some(slot) = self.residuals.get_mut(j) else {
            bail!(
                "downlink state has no slot for sub-model {j} ({} slots)",
                self.residuals.len()
            );
        };
        let (enc, decoded) =
            fold_encode(self.codec.wire_spec(), global, global.flat_values(), slot)?;
        let payload = BroadcastPayload {
            codec: self.codec,
            enc,
        };
        Ok((payload, decoded))
    }
}

// ------------------------------------------------------------- facade

/// What one round's downlink produced: the payloads that crossed the
/// wire (for metering) and the decoded sub-models every selected
/// client trains from.
#[derive(Debug)]
pub struct RoundBroadcast {
    pub payloads: Vec<BroadcastPayload>,
    pub client_globals: Vec<ModelParams>,
}

/// The transport facade the round loop drives: owns both compressors
/// and their cross-round state for the lifetime of one training run.
pub struct Transport {
    uplink: Box<dyn UplinkCompressor>,
    downlink: Box<dyn DownlinkCompressor>,
}

impl Transport {
    /// Wire the pipeline for a run: `cfg.codec`/`cfg.down_codec` select
    /// the codecs, `cfg.error_feedback` selects the stateful (error-
    /// feedback + residual-folding) implementations on both links.
    pub fn new(cfg: &ExperimentConfig, n_models: usize) -> Transport {
        let uplink: Box<dyn UplinkCompressor> = if cfg.error_feedback {
            Box::new(FeedbackUplink::new(cfg.codec, cfg.clients, n_models))
        } else {
            Box::new(StatelessUplink::new(cfg.codec))
        };
        let downlink: Box<dyn DownlinkCompressor> = if cfg.error_feedback {
            Box::new(FoldingDownlink::new(cfg.down_codec, n_models))
        } else {
            Box::new(StatelessDownlink::new(cfg.down_codec))
        };
        Transport { uplink, downlink }
    }

    /// Assemble from explicit parts (tests, custom pipelines).
    pub fn from_parts(
        uplink: Box<dyn UplinkCompressor>,
        downlink: Box<dyn DownlinkCompressor>,
    ) -> Transport {
        Transport { uplink, downlink }
    }

    /// The shared uplink compressor the engine's workers encode through.
    pub fn uplink(&self) -> &dyn UplinkCompressor {
        self.uplink.as_ref()
    }

    /// Compress every sub-model's current global for this round's
    /// broadcast (downlink residual folding happens here).
    pub fn broadcast(&mut self, globals: &[ModelParams]) -> Result<RoundBroadcast> {
        let mut payloads = Vec::with_capacity(globals.len());
        let mut client_globals = Vec::with_capacity(globals.len());
        for (j, g) in globals.iter().enumerate() {
            let (payload, decoded) = self.downlink.compress(j, g)?;
            payloads.push(payload);
            client_globals.push(decoded);
        }
        Ok(RoundBroadcast {
            payloads,
            client_globals,
        })
    }

    /// Decode one client update for aggregation. `reference` must be
    /// the broadcast model the client encoded against
    /// ([`RoundBroadcast::client_globals`]`[j]`).
    pub fn decode(&self, reference: &ModelParams, enc: &EncodedUpdate) -> Result<ModelParams> {
        decode_update(reference, enc)
    }

    /// `true` when either link carries state across rounds.
    pub fn stateful(&self) -> bool {
        self.uplink.stateful() || self.downlink.stateful()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_pair(seed: u64) -> (ModelParams, ModelParams) {
        let global = ModelParams::init(6, 4, 9, seed);
        let mut local = global.clone();
        let mut rng = Rng::new(seed ^ 0x5a5a);
        for t in local.tensors.iter_mut() {
            for v in t.data_mut() {
                *v += (rng.next_f32() - 0.5) * 0.2;
            }
        }
        (global, local)
    }

    fn entry_indices(enc: &EncodedUpdate) -> Vec<u32> {
        match enc {
            EncodedUpdate::TopKDelta { entries } | EncodedUpdate::TopKPacked { entries } => {
                entries.iter().map(|&(i, _)| i).collect()
            }
            other => panic!("expected a sparse update, got {other:?}"),
        }
    }

    #[test]
    fn down_codec_names_roundtrip() {
        for codec in [DownCodec::Dense, DownCodec::QuantI8] {
            assert_eq!(DownCodec::parse(codec.name()).unwrap(), codec);
        }
        assert_eq!(DownCodec::parse("quant").unwrap(), DownCodec::QuantI8);
        assert!(DownCodec::parse("topk").is_err());
    }

    #[test]
    fn stateless_uplink_matches_free_function() {
        let (global, local) = random_pair(1);
        for spec in [
            CodecSpec::Dense,
            CodecSpec::QuantI8,
            CodecSpec::TopK { frac: 0.2 },
            CodecSpec::TopKPacked { frac: 0.2 },
        ] {
            let up = StatelessUplink::new(spec);
            assert!(!up.stateful());
            let a = up.compress(0, 0, &global, &local).unwrap();
            let b = up.compress(3, 1, &global, &local).unwrap();
            let free = encode_update(spec, &global, &local).unwrap();
            assert_eq!(a, free, "stateless must equal the free function");
            assert_eq!(b, free, "…for every (client, sub-model) key");
        }
    }

    #[test]
    fn feedback_dense_is_a_no_op() {
        let (global, local) = random_pair(2);
        let up = FeedbackUplink::new(CodecSpec::Dense, 2, 1);
        let enc = up.compress(1, 0, &global, &local).unwrap();
        assert_eq!(enc, encode_update(CodecSpec::Dense, &global, &local).unwrap());
        assert!(up.residual(1, 0).is_empty(), "dense must never store residual");
    }

    #[test]
    fn feedback_topk_resurfaces_dropped_coordinates() {
        let (global, local) = random_pair(3);
        let spec = CodecSpec::TopK { frac: 0.1 };
        let up = FeedbackUplink::new(spec, 1, 1);

        // Round 1: no residual yet — identical to the stateless encode.
        let r1 = up.compress(0, 0, &global, &local).unwrap();
        assert_eq!(r1, encode_update(spec, &global, &local).unwrap());
        let kept1 = entry_indices(&r1);
        // Residual is exactly the un-shipped delta.
        let res = up.residual(0, 0);
        assert_eq!(res.len(), global.num_params());
        let (gf, lf) = (global.flat_values(), local.flat_values());
        for (i, r) in res.iter().enumerate() {
            if kept1.contains(&(i as u32)) {
                assert_eq!(*r, 0.0, "shipped coordinate {i} keeps no residual");
            } else {
                assert_eq!(*r, lf[i] - gf[i], "dropped coordinate {i}");
            }
        }

        // Round 2 with the *same* local: dropped coordinates now carry a
        // doubled accumulated delta, so the selection must move off the
        // round-1 set — feedback re-surfaces what was dropped.
        let r2 = up.compress(0, 0, &global, &local).unwrap();
        let kept2 = entry_indices(&r2);
        assert_ne!(kept1, kept2, "feedback must change the top-k selection");
        let fresh: usize = kept2.iter().filter(|&i| !kept1.contains(i)).count();
        assert!(fresh > 0, "round 2 must ship previously dropped coordinates");

        // A stateless uplink keeps shipping the identical set forever.
        let stateless = StatelessUplink::new(spec);
        assert_eq!(
            stateless.compress(0, 0, &global, &local).unwrap(),
            stateless.compress(0, 0, &global, &local).unwrap()
        );
    }

    #[test]
    fn feedback_q8_residual_is_quantization_bounded() {
        let (global, local) = random_pair(4);
        let up = FeedbackUplink::new(CodecSpec::QuantI8, 1, 1);
        up.compress(0, 0, &global, &local).unwrap();
        let res = up.residual(0, 0);
        assert_eq!(res.len(), local.num_params());
        // Per-tensor bound: |residual| ≤ scale/2 (+ float slack).
        let mut off = 0usize;
        for t in local.tensors.iter() {
            let max_abs = t.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = max_abs / 127.0;
            for &r in &res[off..off + t.len()] {
                assert!(r.abs() <= scale * 0.5 + 1e-6, "residual {r} vs scale {scale}");
            }
            off += t.len();
        }
    }

    #[test]
    fn feedback_slots_are_independent() {
        let (global, la) = random_pair(5);
        let (_, lb) = random_pair(6);
        let spec = CodecSpec::TopK { frac: 0.1 };
        let up = FeedbackUplink::new(spec, 2, 2);
        up.compress(0, 0, &global, &la).unwrap();
        // A different slot has no residual yet: its first compress is
        // exactly the stateless encode, regardless of slot (0,0) state.
        let other = up.compress(1, 1, &global, &lb).unwrap();
        assert_eq!(other, encode_update(spec, &global, &lb).unwrap());
        assert!(up.residual(0, 1).is_empty());
    }

    #[test]
    fn feedback_rejects_out_of_range_slot() {
        let (global, local) = random_pair(7);
        let up = FeedbackUplink::new(CodecSpec::QuantI8, 2, 2);
        assert!(up.compress(2, 0, &global, &local).is_err());
    }

    #[test]
    fn dense_downlink_is_bitwise_lossless() {
        let (global, _) = random_pair(8);
        for stateful in [false, true] {
            let (payload, decoded) = if stateful {
                FoldingDownlink::new(DownCodec::Dense, 1).compress(0, &global).unwrap()
            } else {
                StatelessDownlink::new(DownCodec::Dense).compress(0, &global).unwrap()
            };
            assert_eq!(decoded, global, "dense broadcast must be exact");
            assert_eq!(payload.byte_len(), global.byte_size());
            assert_eq!(payload.codec(), DownCodec::Dense);
        }
    }

    #[test]
    fn q8_downlink_folding_cancels_quantization_bias() {
        let (global, _) = random_pair(9);
        let gf = global.flat_values();
        let mut folding = FoldingDownlink::new(DownCodec::QuantI8, 1);

        let (_, first) = folding.compress(0, &global).unwrap();
        let first_err: f64 = first
            .flat_values()
            .iter()
            .zip(gf.iter())
            .map(|(a, b)| (*a as f64 - *b as f64).abs())
            .sum();
        assert!(first_err > 0.0, "q8 of a random model must be lossy");

        // Re-broadcasting the same global T times: the mean of the
        // decoded broadcasts converges to the true global (the folded
        // residual is bounded, so bias ~ residual/T), while the
        // stateless downlink repeats the same biased decode forever.
        let t = 8usize;
        let mut mean = vec![0.0f64; gf.len()];
        let mut folding = FoldingDownlink::new(DownCodec::QuantI8, 1);
        for _ in 0..t {
            let (_, decoded) = folding.compress(0, &global).unwrap();
            for (m, v) in mean.iter_mut().zip(decoded.flat_values()) {
                *m += v as f64 / t as f64;
            }
        }
        let mean_err: f64 = mean
            .iter()
            .zip(gf.iter())
            .map(|(a, b)| (a - *b as f64).abs())
            .sum();
        assert!(
            mean_err < first_err * 0.5,
            "folding must shrink the broadcast bias: mean {mean_err} vs single {first_err}"
        );
    }

    #[test]
    fn broadcast_payload_bytes_roundtrip() {
        let (global, _) = random_pair(10);
        for codec in [DownCodec::Dense, DownCodec::QuantI8] {
            let (payload, _) = StatelessDownlink::new(codec).compress(0, &global).unwrap();
            let bytes = payload.to_bytes();
            assert_eq!(bytes.len(), payload.byte_len(), "{}", codec.name());
            let back = BroadcastPayload::from_bytes(
                codec,
                global.tensors.len(),
                global.num_params(),
                &bytes,
            )
            .unwrap();
            assert_eq!(back, payload);
            assert_eq!(back.decode(&global).unwrap(), payload.decode(&global).unwrap());
        }
    }

    #[test]
    fn facade_selects_impls_from_config() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.codec = CodecSpec::TopK { frac: 0.1 };
        let t = Transport::new(&cfg, 2);
        assert!(!t.stateful(), "feedback off → stateless pipeline");
        cfg.error_feedback = true;
        let t = Transport::new(&cfg, 2);
        assert!(t.stateful());
        assert_eq!(t.uplink().spec(), CodecSpec::TopK { frac: 0.1 });
    }

    #[test]
    fn facade_broadcast_and_decode_close_the_loop() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.codec = CodecSpec::QuantI8;
        cfg.down_codec = DownCodec::QuantI8;
        cfg.error_feedback = true;
        let (global, local) = random_pair(11);
        let globals = vec![global.clone()];
        let mut transport = Transport::new(&cfg, 1);
        let bcast = transport.broadcast(&globals).unwrap();
        assert_eq!(bcast.payloads.len(), 1);
        assert_eq!(bcast.client_globals.len(), 1);
        // q8 broadcast is smaller than dense and decodes near the global.
        assert!(bcast.payloads[0].byte_len() < global.byte_size());
        // Close the loop: client encodes against the *decoded* broadcast,
        // server decodes against the same reference.
        let enc = transport
            .uplink()
            .compress(0, 0, &bcast.client_globals[0], &local)
            .unwrap();
        let back = transport.decode(&bcast.client_globals[0], &enc).unwrap();
        assert_eq!(back.num_params(), local.num_params());
    }
}
