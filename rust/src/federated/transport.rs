//! The stateful transport pipeline — compression state that lives
//! *across* synchronization rounds, on both ends of the wire.
//!
//! PR 1's wire layer ([`super::wire`]) is deliberately stateless: a
//! codec sees one `(global, local)` pair, encodes, and forgets. That is
//! exactly what the communication-efficiency literature says you must
//! not do under aggressive compression — the un-shipped part of every
//! update (the top-k residual, the quantization error) is discarded
//! each round and the error compounds (arXiv 2107.10996 §IV; CatFedAvg,
//! arXiv 2011.07229). This module inverts the ownership: compressors
//! are *objects* that carry state round to round, and the round loop
//! drives them through a [`Transport`] facade.
//!
//! ## Ownership model: per-client, not per-broadcast
//!
//! Since the delta downlink landed, the unit of downlink state is the
//! `(client, sub-model)` pair, not the round. The server no longer
//! thinks in terms of "one payload for everyone": every broadcast is
//! addressed to a specific client, against the *base* that client is
//! known to hold, and the trait seam reflects that —
//! [`DownlinkCompressor::broadcast`] receives the round's selected
//! clients and returns a [`RoundBroadcast`] that can be shared (dense /
//! q8 / q8g: every client decodes the same bytes) or per-client
//! ([`DeltaDownlink`]: each client gets a delta against its own
//! replica).
//!
//! ## The pieces
//!
//! - [`UplinkCompressor`] — client→server. The error-feedback
//!   implementation ([`FeedbackUplink`]) keeps one residual accumulator
//!   per `(client, sub-model)` slot: before encoding, the previous
//!   rounds' un-shipped delta is added back into the local model
//!   (`virtual = local + residual`), and after encoding the new
//!   residual is `virtual − decode(encoded)`. Top-k then re-surfaces
//!   coordinates it dropped (their accumulated delta doubles until
//!   selected), and q8 cancels its quantization bias over time.
//!   [`StatelessUplink`] reproduces the PR 1 behavior bit-for-bit.
//! - [`DownlinkCompressor`] — server→client. [`StatelessDownlink`]
//!   encodes each sub-model once per round (dense/q8/q8g) and every
//!   selected client decodes the same payload; [`FoldingDownlink`] adds
//!   server-side residual feedback (the broadcast's quantization error
//!   folds into the next round); [`DeltaDownlink`] keeps one *replica*
//!   per `(client, sub-model)` — the model that client last decoded —
//!   and ships a version-tagged top-k delta against it, falling back to
//!   a full dense resync when the client's base is stale past
//!   `--resync-every` (or was never initialized). Partial participation
//!   ([`super::sampler::ClientSampler`]) is exactly what makes the
//!   bases diverge.
//! - [`Transport`] — the facade the round loop owns: `broadcast()`
//!   produces the round's per-client downlink, `uplink()` hands the
//!   engine the shared (Sync) uplink compressor, `decode()` brings an
//!   encoded update back for aggregation against the base *that client*
//!   trained from.
//!
//! ## Invariants
//!
//! - `dense` on both links with feedback off is **bitwise identical**
//!   to the stateless PR 1 pipeline (`tests/parallel_determinism.rs`);
//!   dense is lossless, so even feedback *on* cannot change it — both
//!   stateful impls short-circuit to the stateless path for `dense`.
//!   Non-delta payloads also carry no version header, so the byte
//!   accounting is unchanged too.
//! - Per-slot state makes the parallel engine safe: one round touches
//!   each `(client, sub-model)` slot from exactly one work item, and
//!   the downlink runs on the coordinator thread before the fan-out, so
//!   worker count and scheduling cannot reorder state updates.
//! - Every pre-existing wire tag (`dense`/`q8`/`topk`/`topkv`) still
//!   decodes unchanged — the codecs are backends, not replaced.
//! - A full resync is always dense: after it, the client's replica is
//!   bitwise equal to the server's broadcast base
//!   (`tests/downlink_delta.rs`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::model::params::ModelParams;

use super::snapshot::{ByteReader, ByteWriter};
use super::wire::{
    apply_delta, decode_update, encode_delta, encode_update, CodecSpec, EncodedUpdate,
};

/// Which codec compresses the server→client broadcast (CLI:
/// `--down-codec`). `dense`/`q8`/`q8g`/`q4g` encode the full model
/// state every round; `topk`/`topkv` select the **delta downlink** — a
/// per-client, versioned delta against the model that client last
/// decoded ([`DeltaDownlink`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DownCodec {
    /// Raw `f32` broadcast — the seed behavior, lossless.
    Dense,
    /// Per-tensor symmetric int8 (~4× smaller), decoded client-side.
    QuantI8,
    /// Group-wise int8: one scale per `block` values (`q8g:<block>`).
    QuantI8Group { block: usize },
    /// Group-wise int4, two values per byte (`q4g:<block>`, ~8×).
    QuantI4Group { block: usize },
    /// Per-client top-k delta vs the client's last decoded base.
    TopK { frac: f32 },
    /// Same, with the delta+varint packed index stream.
    TopKPacked { frac: f32 },
}

impl DownCodec {
    /// Parse a CLI name (`name()` output always re-parses). Shares the
    /// grammar of [`CodecSpec::parse`]: `topk`/`topkv` take their
    /// fraction embedded (`topk:0.1`) or from `topk_frac`.
    pub fn parse(name: &str, topk_frac: f32) -> Result<DownCodec> {
        Ok(match CodecSpec::parse(name, topk_frac)? {
            CodecSpec::Dense => DownCodec::Dense,
            CodecSpec::QuantI8 => DownCodec::QuantI8,
            CodecSpec::QuantI8Group { block } => DownCodec::QuantI8Group { block },
            CodecSpec::QuantI4Group { block } => DownCodec::QuantI4Group { block },
            CodecSpec::TopK { frac } => DownCodec::TopK { frac },
            CodecSpec::TopKPacked { frac } => DownCodec::TopKPacked { frac },
        })
    }

    /// Canonical spec string (re-parses to an equal codec).
    pub fn name(&self) -> String {
        match self {
            DownCodec::Dense => "dense".to_string(),
            DownCodec::QuantI8 => "q8".to_string(),
            DownCodec::QuantI8Group { block } => format!("q8g:{block}"),
            DownCodec::QuantI4Group { block } => format!("q4g:{block}"),
            DownCodec::TopK { frac } => format!("topk:{frac}"),
            DownCodec::TopKPacked { frac } => format!("topkv:{frac}"),
        }
    }

    /// `true` for the codecs that require per-client base state.
    pub fn is_delta(&self) -> bool {
        matches!(self, DownCodec::TopK { .. } | DownCodec::TopKPacked { .. })
    }

    /// The wire codec that serializes this broadcast's payloads. The
    /// sparse downlink always ships the packed (delta+varint) index
    /// stream: sorted top-k indices have small gaps, and unlike the
    /// uplink there is no legacy raw-index delta receiver to stay
    /// compatible with — `topk` and `topkv` differ only in name here.
    pub fn wire_spec(&self) -> CodecSpec {
        match self {
            DownCodec::Dense => CodecSpec::Dense,
            DownCodec::QuantI8 => CodecSpec::QuantI8,
            DownCodec::QuantI8Group { block } => CodecSpec::QuantI8Group { block: *block },
            DownCodec::QuantI4Group { block } => CodecSpec::QuantI4Group { block: *block },
            DownCodec::TopK { frac } | DownCodec::TopKPacked { frac } => {
                CodecSpec::TopKPacked { frac: *frac }
            }
        }
    }
}

/// Whether a downlink payload is self-contained or applies onto a base.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// Complete model state. For the delta downlink this is always
    /// dense-encoded, so the receiving client lands *bitwise* on the
    /// server's broadcast base (initial sync and staleness resync).
    Full,
    /// Applies onto the client's base replica at `base_version`.
    Delta { base_version: u64 },
}

/// One `(client, sub-model)` downlink payload: the codec tag (shared
/// setup state, like the model shape), a version tag, and the
/// [`super::wire`]-encoded body.
///
/// Wire layout: for the non-delta codecs this is exactly the encoded
/// body — no header, byte-identical to the PR 3 broadcast. For the
/// delta codecs a header precedes the body: `u8` kind (0 full,
/// 1 delta), `u64` version, and for deltas the `u64` base version the
/// payload applies onto.
#[derive(Clone, Debug, PartialEq)]
pub struct DownlinkPayload {
    codec: DownCodec,
    /// Server broadcast version this payload brings the client to
    /// (`round + 1` under the delta downlink; 0 = unversioned).
    version: u64,
    kind: PayloadKind,
    enc: EncodedUpdate,
}

impl DownlinkPayload {
    pub fn codec(&self) -> DownCodec {
        self.codec
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn kind(&self) -> PayloadKind {
        self.kind
    }

    pub fn is_full(&self) -> bool {
        self.kind == PayloadKind::Full
    }

    /// The version of the base this payload applies onto (`None` for
    /// self-contained full payloads).
    pub fn base_version(&self) -> Option<u64> {
        match self.kind {
            PayloadKind::Full => None,
            PayloadKind::Delta { base_version } => Some(base_version),
        }
    }

    fn header_len(&self) -> usize {
        if !self.codec.is_delta() {
            return 0;
        }
        match self.kind {
            PayloadKind::Full => 1 + 8,
            PayloadKind::Delta { .. } => 1 + 8 + 8,
        }
    }

    /// Exact wire size in bytes — what [`super::comm::CommMeter`] is
    /// charged for this client's download.
    pub fn byte_len(&self) -> usize {
        self.header_len() + self.enc.byte_len()
    }

    /// Serialize to the wire layout (struct docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.byte_len());
        if self.codec.is_delta() {
            match self.kind {
                PayloadKind::Full => out.push(0u8),
                PayloadKind::Delta { .. } => out.push(1u8),
            }
            out.extend_from_slice(&self.version.to_le_bytes());
            if let PayloadKind::Delta { base_version } = self.kind {
                out.extend_from_slice(&base_version.to_le_bytes());
            }
        }
        out.extend_from_slice(&self.enc.to_bytes());
        out
    }

    /// Parse a payload back; shape metadata comes from the shared model
    /// setup, exactly like update payloads.
    pub fn from_bytes(
        codec: DownCodec,
        n_tensors: usize,
        n_values: usize,
        bytes: &[u8],
    ) -> Result<DownlinkPayload> {
        if !codec.is_delta() {
            let enc = EncodedUpdate::from_bytes(codec.wire_spec(), n_tensors, n_values, bytes)?;
            return Ok(DownlinkPayload {
                codec,
                version: 0,
                kind: PayloadKind::Full,
                enc,
            });
        }
        if bytes.len() < 9 {
            bail!("downlink payload is {} bytes, expected at least 9", bytes.len());
        }
        let version = u64::from_le_bytes(bytes[1..9].try_into().expect("8-byte version"));
        let (kind, body) = match bytes[0] {
            0 => (PayloadKind::Full, &bytes[9..]),
            1 => {
                if bytes.len() < 17 {
                    bail!("delta payload is {} bytes, expected at least 17", bytes.len());
                }
                let base_version =
                    u64::from_le_bytes(bytes[9..17].try_into().expect("8-byte base version"));
                (PayloadKind::Delta { base_version }, &bytes[17..])
            }
            other => bail!("unknown downlink payload kind {other}"),
        };
        // Full payloads under the delta downlink are dense resyncs.
        let spec = match kind {
            PayloadKind::Full => CodecSpec::Dense,
            PayloadKind::Delta { .. } => codec.wire_spec(),
        };
        let enc = EncodedUpdate::from_bytes(spec, n_tensors, n_values, body)?;
        Ok(DownlinkPayload {
            codec,
            version,
            kind,
            enc,
        })
    }

    /// Decode a [`PayloadKind::Full`] payload into the complete model
    /// state. `shape` only supplies the tensor layout.
    pub fn decode_full(&self, shape: &ModelParams) -> Result<ModelParams> {
        if let PayloadKind::Delta { base_version } = self.kind {
            bail!("delta payload (base version {base_version}) needs a base model to apply onto");
        }
        decode_update(shape, &self.enc)
    }

    /// Reconstruct the model a client sees: full payloads decode
    /// directly, deltas apply onto the client's current `base`.
    ///
    /// This trusts the caller to supply the state tagged by
    /// [`Self::base_version`] — the in-process [`DeltaDownlink`] holds
    /// that state itself, so it is correct by construction; a real
    /// remote client must compare `base_version()` against its own
    /// version first and request a resync on mismatch (applying a delta
    /// onto the wrong base silently produces a wrong model).
    pub fn apply(&self, base: &ModelParams) -> Result<ModelParams> {
        match self.kind {
            PayloadKind::Full => self.decode_full(base),
            PayloadKind::Delta { .. } => apply_delta(base, &self.enc),
        }
    }
}

/// The shared error-feedback fold both stateful compressors are built
/// on: add the carried `residual` into `vals` (the model the sender
/// *wishes* it could ship), encode that against `reference` with
/// `spec`, then store the new residual — everything the receiver will
/// NOT see after decoding (`vals − decoded`) — back into `residual`.
/// Returns the encoded payload and its decoded form.
fn fold_encode(
    spec: CodecSpec,
    reference: &ModelParams,
    mut vals: Vec<f32>,
    residual: &mut Vec<f32>,
) -> Result<(EncodedUpdate, ModelParams)> {
    if !residual.is_empty() {
        if residual.len() != vals.len() {
            bail!(
                "transport residual has {} values, model has {} — \
                 model shape changed mid-run?",
                residual.len(),
                vals.len()
            );
        }
        for (v, r) in vals.iter_mut().zip(residual.iter()) {
            *v += *r;
        }
    }
    let mut virt = ModelParams::zeros(reference.d, reference.hidden, reference.out);
    virt.set_from_flat(&vals)?;
    let enc = encode_update(spec, reference, &virt)?;
    let decoded = decode_update(reference, &enc)?;
    let decoded_vals = decoded.flat_values();
    residual.clear();
    residual.extend(vals.iter().zip(decoded_vals.iter()).map(|(v, d)| *v - *d));
    Ok((enc, decoded))
}

// ------------------------------------------------------------- uplink

/// Client→server compressor. Implementations may carry per-
/// `(client, sub-model)` state across rounds; the engine calls
/// [`UplinkCompressor::compress`] from its worker threads, so the
/// trait requires `Send + Sync` and state must be interior-mutable.
/// Within one round each `(client, sub-model)` slot is touched by
/// exactly one work item, which is what keeps the parallel engine's
/// bitwise-determinism guarantee intact.
pub trait UplinkCompressor: Send + Sync {
    /// The wire codec this compressor encodes with.
    fn spec(&self) -> CodecSpec;

    /// Whether state is carried across rounds (reporting only).
    fn stateful(&self) -> bool;

    /// Encode `client`'s locally trained sub-model `j` against the
    /// broadcast `global` it started from (under the delta downlink
    /// that base is client-specific).
    fn compress(
        &self,
        client: usize,
        j: usize,
        global: &ModelParams,
        local: &ModelParams,
    ) -> Result<EncodedUpdate>;

    /// Serialize cross-round state for a crash-resume snapshot
    /// ([`super::snapshot`]); stateless links answer an empty blob.
    fn snapshot_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state captured by [`UplinkCompressor::snapshot_state`].
    /// The default (stateless) accepts only the empty blob.
    fn restore_state(&self, bytes: &[u8]) -> Result<()> {
        if bytes.is_empty() {
            Ok(())
        } else {
            bail!("this uplink carries no cross-round state to restore")
        }
    }
}

/// The PR 1 behavior: encode each round independently, remember
/// nothing. `dense` through this path is the seed pipeline bit-for-bit.
#[derive(Clone, Copy, Debug)]
pub struct StatelessUplink {
    spec: CodecSpec,
}

impl StatelessUplink {
    pub fn new(spec: CodecSpec) -> Self {
        StatelessUplink { spec }
    }
}

impl UplinkCompressor for StatelessUplink {
    fn spec(&self) -> CodecSpec {
        self.spec
    }

    fn stateful(&self) -> bool {
        false
    }

    fn compress(
        &self,
        _client: usize,
        _j: usize,
        global: &ModelParams,
        local: &ModelParams,
    ) -> Result<EncodedUpdate> {
        encode_update(self.spec, global, local)
    }
}

/// Error-feedback uplink (EF-SGD style): each `(client, sub-model)`
/// slot accumulates the part of the update the codec did not ship, and
/// adds it back into the next round's encode. An empty slot means "no
/// residual yet" — the first compress of a slot starts from the plain
/// local model.
pub struct FeedbackUplink {
    spec: CodecSpec,
    n_models: usize,
    /// Slot-address bound: `client < clients` and `j < n_models`.
    clients: usize,
    /// Residual slots keyed `(client, sub-model)`, materialized on a
    /// slot's first lossy encode — memory is proportional to the
    /// clients that actually *participated*, so a million-client
    /// registry costs nothing up front. The outer lock guards the map;
    /// the per-slot `Arc<Mutex<_>>` is taken out under it and held for
    /// the encode (items never contend within a round — one item per
    /// slot — the locks are for `Sync`).
    slots: Mutex<HashMap<(usize, usize), Arc<Mutex<Vec<f32>>>>>,
}

impl FeedbackUplink {
    pub fn new(spec: CodecSpec, clients: usize, n_models: usize) -> Self {
        FeedbackUplink {
            spec,
            n_models,
            clients,
            slots: Mutex::new(HashMap::new()),
        }
    }

    /// A slot's current residual (empty until its first lossy encode) —
    /// test/diagnostic hook.
    pub fn residual(&self, client: usize, j: usize) -> Vec<f32> {
        let map = self.slots.lock().expect("uplink slot map lock poisoned");
        map.get(&(client, j))
            .map(|slot| slot.lock().expect("uplink residual lock poisoned").clone())
            .unwrap_or_default()
    }
}

impl UplinkCompressor for FeedbackUplink {
    fn spec(&self) -> CodecSpec {
        self.spec
    }

    fn stateful(&self) -> bool {
        true
    }

    fn compress(
        &self,
        client: usize,
        j: usize,
        global: &ModelParams,
        local: &ModelParams,
    ) -> Result<EncodedUpdate> {
        // Dense is lossless: the residual is identically zero, so skip
        // the bookkeeping entirely. This is what makes `dense` +
        // feedback *on* still bitwise-identical to the seed pipeline.
        if self.spec == CodecSpec::Dense {
            return encode_update(self.spec, global, local);
        }
        if client >= self.clients || j >= self.n_models {
            bail!(
                "uplink state has no slot for client {client}, sub-model {j} \
                 ({} clients, {} sub-models)",
                self.clients,
                self.n_models
            );
        }
        let slot = {
            let mut map = self.slots.lock().expect("uplink slot map lock poisoned");
            map.entry((client, j)).or_default().clone()
        };
        let mut residual = slot.lock().expect("uplink residual lock poisoned");
        let (enc, _) = fold_encode(self.spec, global, local.flat_values(), &mut residual)?;
        Ok(enc)
    }

    fn snapshot_state(&self) -> Vec<u8> {
        let map = self.slots.lock().expect("uplink slot map lock poisoned");
        // Canonical key order: snapshot bytes must not depend on
        // HashMap iteration order.
        let mut keys: Vec<(usize, usize)> = map.keys().copied().collect();
        keys.sort_unstable();
        let mut w = ByteWriter::new();
        w.u64(keys.len() as u64);
        for key in keys {
            let residual = map[&key].lock().expect("uplink residual lock poisoned");
            w.u64(key.0 as u64);
            w.u64(key.1 as u64);
            w.u64(residual.len() as u64);
            w.f32s(&residual);
        }
        w.into_bytes()
    }

    fn restore_state(&self, bytes: &[u8]) -> Result<()> {
        let mut map = self.slots.lock().expect("uplink slot map lock poisoned");
        map.clear();
        if bytes.is_empty() {
            return Ok(());
        }
        let mut r = ByteReader::new(bytes);
        let n = r.counted(3 * 8)?;
        for _ in 0..n {
            let client = r.u64()? as usize;
            let j = r.u64()? as usize;
            if client >= self.clients || j >= self.n_models {
                bail!(
                    "uplink snapshot has a slot for client {client}, sub-model {j} \
                     outside this run's ({}, {}) bounds",
                    self.clients,
                    self.n_models
                );
            }
            let len = r.counted(4)?;
            map.insert((client, j), Arc::new(Mutex::new(r.f32s(len)?)));
        }
        r.finish()
    }
}

// ----------------------------------------------------------- downlink

/// Either one value per sub-model (shared by every selected client) or
/// one per `(slot, sub-model)` pair.
#[derive(Debug)]
enum PerSlot<T> {
    Shared(Vec<T>),
    PerClient(Vec<Vec<T>>),
}

impl<T> PerSlot<T> {
    fn get(&self, slot: usize, j: usize) -> &T {
        match self {
            PerSlot::Shared(v) => &v[j],
            PerSlot::PerClient(v) => &v[slot][j],
        }
    }
}

/// What one round's downlink produced: the payloads that crossed the
/// wire to each selected client (for per-client metering) and the
/// decoded sub-models each client trains from. `slot` indexes the
/// round's `selected` order.
#[derive(Debug)]
pub struct RoundBroadcast {
    n_models: usize,
    payloads: PerSlot<DownlinkPayload>,
    globals: PerSlot<ModelParams>,
}

impl RoundBroadcast {
    /// Every selected client receives (and decodes) the same broadcast.
    pub fn shared(payloads: Vec<DownlinkPayload>, globals: Vec<ModelParams>) -> RoundBroadcast {
        debug_assert_eq!(payloads.len(), globals.len());
        RoundBroadcast {
            n_models: globals.len(),
            payloads: PerSlot::Shared(payloads),
            globals: PerSlot::Shared(globals),
        }
    }

    /// Client-specific payloads and bases, indexed `[slot][sub-model]`.
    pub fn per_client(
        payloads: Vec<Vec<DownlinkPayload>>,
        globals: Vec<Vec<ModelParams>>,
    ) -> RoundBroadcast {
        debug_assert_eq!(payloads.len(), globals.len());
        let n_models = globals.first().map(|g| g.len()).unwrap_or(0);
        RoundBroadcast {
            n_models,
            payloads: PerSlot::PerClient(payloads),
            globals: PerSlot::PerClient(globals),
        }
    }

    pub fn n_models(&self) -> usize {
        self.n_models
    }

    /// `true` when clients received client-specific payloads.
    pub fn is_per_client(&self) -> bool {
        matches!(self.payloads, PerSlot::PerClient(_))
    }

    /// The payload shipped to the client at `slot` for sub-model `j`.
    pub fn payload(&self, slot: usize, j: usize) -> &DownlinkPayload {
        self.payloads.get(slot, j)
    }

    /// The decoded sub-model `j` the client at `slot` trains from (and
    /// the reference its uplink update is encoded/decoded against).
    pub fn global(&self, slot: usize, j: usize) -> &ModelParams {
        self.globals.get(slot, j)
    }
}

/// Server→client compressor for the per-round broadcast, reshaped
/// around `(client, sub-model)` ownership: one call produces the whole
/// round's downlink for the selected clients, so implementations decide
/// whether payloads are shared or client-specific.
pub trait DownlinkCompressor: Send {
    fn codec(&self) -> DownCodec;

    /// Whether broadcast state is carried across rounds (reporting).
    fn stateful(&self) -> bool;

    /// Produce round `round`'s broadcast of `globals` for the
    /// `selected` clients.
    fn broadcast(
        &mut self,
        round: usize,
        selected: &[usize],
        globals: &[ModelParams],
    ) -> Result<RoundBroadcast>;

    /// Serialize cross-round state for a crash-resume snapshot
    /// ([`super::snapshot`]); stateless links answer an empty blob.
    fn snapshot_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state captured by [`DownlinkCompressor::snapshot_state`].
    /// The default (stateless) accepts only the empty blob.
    fn restore_state(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.is_empty() {
            Ok(())
        } else {
            bail!("this downlink carries no cross-round state to restore")
        }
    }
}

fn broadcast_model(
    codec: DownCodec,
    model: &ModelParams,
) -> Result<(DownlinkPayload, ModelParams)> {
    // Dense and the quantizers encode the model's own values (the
    // `global` argument of `encode_update` is only a shape witness).
    let enc = encode_update(codec.wire_spec(), model, model)?;
    let payload = DownlinkPayload {
        codec,
        version: 0,
        kind: PayloadKind::Full,
        enc,
    };
    // A dense decode is a bitwise copy — skip the second full pass on
    // the default path.
    let decoded = match codec {
        DownCodec::Dense => model.clone(),
        _ => payload.decode_full(model)?,
    };
    Ok((payload, decoded))
}

/// Broadcast each round independently (no residual folding): encode
/// each sub-model once, every selected client decodes the same payload.
#[derive(Clone, Copy, Debug)]
pub struct StatelessDownlink {
    codec: DownCodec,
}

impl StatelessDownlink {
    pub fn new(codec: DownCodec) -> Self {
        StatelessDownlink { codec }
    }
}

impl DownlinkCompressor for StatelessDownlink {
    fn codec(&self) -> DownCodec {
        self.codec
    }

    fn stateful(&self) -> bool {
        false
    }

    fn broadcast(
        &mut self,
        _round: usize,
        _selected: &[usize],
        globals: &[ModelParams],
    ) -> Result<RoundBroadcast> {
        if self.codec.is_delta() {
            bail!(
                "downlink codec '{}' needs per-client base state — use DeltaDownlink",
                self.codec.name()
            );
        }
        let mut payloads = Vec::with_capacity(globals.len());
        let mut decoded = Vec::with_capacity(globals.len());
        for g in globals {
            let (p, d) = broadcast_model(self.codec, g)?;
            payloads.push(p);
            decoded.push(d);
        }
        Ok(RoundBroadcast::shared(payloads, decoded))
    }
}

/// Server-side residual folding: the quantization error of round `t`'s
/// decoded broadcast is added into round `t+1`'s pre-quantization
/// state, so the running mean of what clients receive converges to the
/// true aggregate instead of carrying a persistent rounding bias.
pub struct FoldingDownlink {
    codec: DownCodec,
    /// One residual per sub-model (empty = none yet).
    residuals: Vec<Vec<f32>>,
}

impl FoldingDownlink {
    pub fn new(codec: DownCodec, n_models: usize) -> Self {
        FoldingDownlink {
            codec,
            residuals: vec![Vec::new(); n_models],
        }
    }

    fn fold_one(
        &mut self,
        j: usize,
        global: &ModelParams,
    ) -> Result<(DownlinkPayload, ModelParams)> {
        // Dense broadcasts are lossless → residual identically zero.
        if self.codec == DownCodec::Dense {
            return broadcast_model(self.codec, global);
        }
        let Some(slot) = self.residuals.get_mut(j) else {
            bail!(
                "downlink state has no slot for sub-model {j} ({} slots)",
                self.residuals.len()
            );
        };
        let (enc, decoded) =
            fold_encode(self.codec.wire_spec(), global, global.flat_values(), slot)?;
        let payload = DownlinkPayload {
            codec: self.codec,
            version: 0,
            kind: PayloadKind::Full,
            enc,
        };
        Ok((payload, decoded))
    }
}

impl DownlinkCompressor for FoldingDownlink {
    fn codec(&self) -> DownCodec {
        self.codec
    }

    fn stateful(&self) -> bool {
        true
    }

    fn broadcast(
        &mut self,
        _round: usize,
        _selected: &[usize],
        globals: &[ModelParams],
    ) -> Result<RoundBroadcast> {
        if self.codec.is_delta() {
            bail!(
                "downlink codec '{}' needs per-client base state — use DeltaDownlink",
                self.codec.name()
            );
        }
        let mut payloads = Vec::with_capacity(globals.len());
        let mut decoded = Vec::with_capacity(globals.len());
        for (j, g) in globals.iter().enumerate() {
            let (p, d) = self.fold_one(j, g)?;
            payloads.push(p);
            decoded.push(d);
        }
        Ok(RoundBroadcast::shared(payloads, decoded))
    }

    fn snapshot_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u64(self.residuals.len() as u64);
        for res in &self.residuals {
            w.u64(res.len() as u64);
            w.f32s(res);
        }
        w.into_bytes()
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.is_empty() {
            for slot in self.residuals.iter_mut() {
                slot.clear();
            }
            return Ok(());
        }
        let mut r = ByteReader::new(bytes);
        let n = r.counted(8)?;
        if n != self.residuals.len() {
            bail!(
                "downlink snapshot has {n} residual slots, this run has {}",
                self.residuals.len()
            );
        }
        for slot in self.residuals.iter_mut() {
            let len = r.counted(4)?;
            *slot = r.f32s(len)?;
        }
        r.finish()
    }
}

/// One client's downlink base: the model it last decoded and the
/// broadcast version it decoded at.
#[derive(Clone, Debug)]
struct Replica {
    model: ModelParams,
    version: u64,
}

/// The per-client versioned delta downlink. The server maintains a
/// persistent replica of every `(client, sub-model)` — exactly the
/// state the client holds on-device — and each broadcast ships the
/// top-k delta between the current global and that replica, tagged with
/// the `(base_version → version)` transition. Because the base is what
/// the client *actually decoded* (not what the server wishes it had),
/// every coordinate the top-k selection drops stays pending in the next
/// round's |global − replica| delta: the scheme is error-feedback by
/// construction, per client.
///
/// Clients with no replica yet, or whose base is more than
/// `resync_every` versions stale (a run of unlucky
/// [`super::sampler::ClientSampler`] draws), get a **full dense
/// resync** instead: after it, replica == broadcast base, bitwise.
pub struct DeltaDownlink {
    codec: DownCodec,
    spec: CodecSpec,
    n_models: usize,
    /// Slot-address bound: `client < clients` and `j < n_models`.
    clients: usize,
    /// Staleness cap: deltas are allowed while
    /// `version − replica.version <= resync_every` (0 = full resync on
    /// every participation).
    resync_every: u64,
    /// Replicas keyed `(client, sub-model)`, materialized on a client's
    /// first participation (absent = never synced) — memory is
    /// proportional to clients *seen*, so a million-client registry
    /// costs nothing up front.
    replicas: HashMap<(usize, usize), Replica>,
}

impl DeltaDownlink {
    pub fn new(
        codec: DownCodec,
        clients: usize,
        n_models: usize,
        resync_every: usize,
    ) -> Result<DeltaDownlink> {
        if !codec.is_delta() {
            bail!(
                "DeltaDownlink needs a sparse down codec (topk/topkv), got '{}'",
                codec.name()
            );
        }
        Ok(DeltaDownlink {
            codec,
            spec: codec.wire_spec(),
            n_models,
            clients,
            resync_every: resync_every as u64,
            replicas: HashMap::new(),
        })
    }

    /// The version a client's sub-model base is at (0 = never synced) —
    /// test/diagnostic hook.
    pub fn base_version(&self, client: usize, j: usize) -> u64 {
        self.replicas
            .get(&(client, j))
            .map(|r| r.version)
            .unwrap_or(0)
    }

    /// The server's replica of what a client currently holds.
    pub fn replica(&self, client: usize, j: usize) -> Option<&ModelParams> {
        self.replicas.get(&(client, j)).map(|r| &r.model)
    }

    fn ship(
        &mut self,
        version: u64,
        client: usize,
        j: usize,
        global: &ModelParams,
    ) -> Result<(DownlinkPayload, ModelParams)> {
        if client >= self.clients || j >= self.n_models {
            bail!(
                "downlink state has no slot for client {client}, sub-model {j} \
                 ({} clients, {} sub-models)",
                self.clients,
                self.n_models
            );
        }
        let (kind, enc, decoded) = match self.replicas.get(&(client, j)) {
            Some(r) if version.saturating_sub(r.version) <= self.resync_every => {
                let enc = encode_delta(self.spec, &r.model, global)?;
                let decoded = apply_delta(&r.model, &enc)?;
                crate::obs::metrics::global()
                    .counter_with(
                        "fedmlh_downlink_payloads_total",
                        "Delta-downlink payloads shipped, by kind.",
                        &[("kind", "delta")],
                    )
                    .inc();
                (PayloadKind::Delta { base_version: r.version }, enc, decoded)
            }
            _ => {
                // Full dense resync: the client lands bitwise on the
                // server's current broadcast base.
                let enc = encode_update(CodecSpec::Dense, global, global)?;
                crate::obs::metrics::global()
                    .counter_with(
                        "fedmlh_downlink_payloads_total",
                        "Delta-downlink payloads shipped, by kind.",
                        &[("kind", "resync")],
                    )
                    .inc();
                (PayloadKind::Full, enc, global.clone())
            }
        };
        self.replicas.insert(
            (client, j),
            Replica {
                model: decoded.clone(),
                version,
            },
        );
        let payload = DownlinkPayload {
            codec: self.codec,
            version,
            kind,
            enc,
        };
        Ok((payload, decoded))
    }
}

impl DownlinkCompressor for DeltaDownlink {
    fn codec(&self) -> DownCodec {
        self.codec
    }

    fn stateful(&self) -> bool {
        true
    }

    fn broadcast(
        &mut self,
        round: usize,
        selected: &[usize],
        globals: &[ModelParams],
    ) -> Result<RoundBroadcast> {
        if globals.len() != self.n_models {
            bail!(
                "delta downlink was built for {} sub-models, got {}",
                self.n_models,
                globals.len()
            );
        }
        // Versions are 1-based so 0 can mean "never synced".
        let version = round as u64 + 1;
        let mut payloads = Vec::with_capacity(selected.len());
        let mut decoded = Vec::with_capacity(selected.len());
        for &client in selected {
            let mut row_p = Vec::with_capacity(globals.len());
            let mut row_g = Vec::with_capacity(globals.len());
            for (j, g) in globals.iter().enumerate() {
                let (p, d) = self.ship(version, client, j, g)?;
                row_p.push(p);
                row_g.push(d);
            }
            payloads.push(row_p);
            decoded.push(row_g);
        }
        Ok(RoundBroadcast::per_client(payloads, decoded))
    }

    fn snapshot_state(&self) -> Vec<u8> {
        // Canonical key order: snapshot bytes must not depend on
        // HashMap iteration order.
        let mut keys: Vec<(usize, usize)> = self.replicas.keys().copied().collect();
        keys.sort_unstable();
        let mut w = ByteWriter::new();
        w.u64(keys.len() as u64);
        for key in keys {
            let rep = &self.replicas[&key];
            w.u64(key.0 as u64);
            w.u64(key.1 as u64);
            w.u64(rep.version);
            w.u32(rep.model.d as u32);
            w.u32(rep.model.hidden as u32);
            w.u32(rep.model.out as u32);
            w.u64(rep.model.num_params() as u64);
            w.f32s(&rep.model.flat_values());
        }
        w.into_bytes()
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<()> {
        self.replicas.clear();
        if bytes.is_empty() {
            return Ok(());
        }
        let mut r = ByteReader::new(bytes);
        let n = r.counted(3 * 8 + 3 * 4 + 8)?;
        for _ in 0..n {
            let client = r.u64()? as usize;
            let j = r.u64()? as usize;
            if client >= self.clients || j >= self.n_models {
                bail!(
                    "downlink snapshot has a replica for client {client}, sub-model {j} \
                     outside this run's ({}, {}) bounds",
                    self.clients,
                    self.n_models
                );
            }
            let version = r.u64()?;
            let d = r.u32()? as usize;
            let hidden = r.u32()? as usize;
            let out = r.u32()? as usize;
            let len = r.counted(4)?;
            let mut model = ModelParams::zeros(d, hidden, out);
            if len != model.num_params() {
                bail!(
                    "replica ({d},{hidden},{out}) declares {len} values, shape needs {}",
                    model.num_params()
                );
            }
            model.set_from_flat(&r.f32s(len)?)?;
            self.replicas.insert((client, j), Replica { model, version });
        }
        r.finish()
    }
}

// ------------------------------------------------------------- facade

/// The transport facade the round loop drives: owns both compressors
/// and their cross-round state for the lifetime of one training run.
pub struct Transport {
    uplink: Box<dyn UplinkCompressor>,
    downlink: Box<dyn DownlinkCompressor>,
}

impl Transport {
    /// Wire the pipeline for a run: `cfg.codec`/`cfg.down_codec` select
    /// the codecs; a sparse `down_codec` selects the per-client
    /// [`DeltaDownlink`] (capped by `cfg.resync_every`), and
    /// `cfg.error_feedback` selects the stateful (error-feedback +
    /// residual-folding) implementations otherwise.
    pub fn new(cfg: &ExperimentConfig, n_models: usize) -> Result<Transport> {
        // Stateful links are addressed by the full client population —
        // the async registry when simulating, `cfg.clients` otherwise.
        // Both links materialize state lazily, so a huge population
        // only costs memory for clients that actually participate.
        let population = cfg.client_population();
        let uplink: Box<dyn UplinkCompressor> = if cfg.error_feedback {
            Box::new(FeedbackUplink::new(cfg.codec, population, n_models))
        } else {
            Box::new(StatelessUplink::new(cfg.codec))
        };
        let downlink: Box<dyn DownlinkCompressor> = if cfg.down_codec.is_delta() {
            Box::new(DeltaDownlink::new(
                cfg.down_codec,
                population,
                n_models,
                cfg.resync_every,
            )?)
        } else if cfg.error_feedback {
            Box::new(FoldingDownlink::new(cfg.down_codec, n_models))
        } else {
            Box::new(StatelessDownlink::new(cfg.down_codec))
        };
        Ok(Transport { uplink, downlink })
    }

    /// Assemble from explicit parts (tests, custom pipelines).
    pub fn from_parts(
        uplink: Box<dyn UplinkCompressor>,
        downlink: Box<dyn DownlinkCompressor>,
    ) -> Transport {
        Transport { uplink, downlink }
    }

    /// The shared uplink compressor the engine's workers encode through.
    pub fn uplink(&self) -> &dyn UplinkCompressor {
        self.uplink.as_ref()
    }

    /// Produce round `round`'s downlink for the `selected` clients
    /// (per-client delta state and residual folding happen here, on the
    /// coordinator thread, before the training fan-out).
    pub fn broadcast(
        &mut self,
        round: usize,
        selected: &[usize],
        globals: &[ModelParams],
    ) -> Result<RoundBroadcast> {
        self.downlink.broadcast(round, selected, globals)
    }

    /// Decode one client update for aggregation. `reference` must be
    /// the decoded broadcast *that client* encoded against
    /// ([`RoundBroadcast::global`]`(slot, j)`).
    pub fn decode(&self, reference: &ModelParams, enc: &EncodedUpdate) -> Result<ModelParams> {
        decode_update(reference, enc)
    }

    /// `true` when either link carries state across rounds.
    pub fn stateful(&self) -> bool {
        self.uplink.stateful() || self.downlink.stateful()
    }

    /// Both links' cross-round state for a crash-resume snapshot:
    /// `(uplink, downlink)` opaque blobs, each restorable only by the
    /// same pipeline configuration (enforced upstream by the snapshot's
    /// config fingerprint).
    pub fn snapshot_state(&self) -> (Vec<u8>, Vec<u8>) {
        (self.uplink.snapshot_state(), self.downlink.snapshot_state())
    }

    /// Restore both links from a snapshot's blobs (inverse of
    /// [`Transport::snapshot_state`]).
    pub fn restore_state(&mut self, uplink: &[u8], downlink: &[u8]) -> Result<()> {
        self.uplink.restore_state(uplink)?;
        self.downlink.restore_state(downlink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_pair(seed: u64) -> (ModelParams, ModelParams) {
        let global = ModelParams::init(6, 4, 9, seed);
        let mut local = global.clone();
        let mut rng = Rng::new(seed ^ 0x5a5a);
        for t in local.tensors.iter_mut() {
            for v in t.data_mut() {
                *v += (rng.next_f32() - 0.5) * 0.2;
            }
        }
        (global, local)
    }

    /// Step a model the way a round of training would (small drift).
    fn drift(model: &ModelParams, seed: u64) -> ModelParams {
        let mut out = model.clone();
        let mut rng = Rng::new(seed);
        for t in out.tensors.iter_mut() {
            for v in t.data_mut() {
                *v += (rng.next_f32() - 0.5) * 0.05;
            }
        }
        out
    }

    fn entry_indices(enc: &EncodedUpdate) -> Vec<u32> {
        match enc {
            EncodedUpdate::TopKDelta { entries } | EncodedUpdate::TopKPacked { entries } => {
                entries.iter().map(|&(i, _)| i).collect()
            }
            other => panic!("expected a sparse update, got {other:?}"),
        }
    }

    #[test]
    fn down_codec_names_roundtrip() {
        for codec in [
            DownCodec::Dense,
            DownCodec::QuantI8,
            DownCodec::QuantI8Group { block: 32 },
            DownCodec::QuantI4Group { block: 16 },
            DownCodec::TopK { frac: 0.1 },
            DownCodec::TopKPacked { frac: 0.25 },
        ] {
            assert_eq!(DownCodec::parse(&codec.name(), 0.9).unwrap(), codec);
        }
        assert_eq!(DownCodec::parse("quant", 0.1).unwrap(), DownCodec::QuantI8);
        assert!(DownCodec::parse("topk", 0.0).is_err());
        assert!(DownCodec::parse("gzip", 0.1).is_err());
        assert!(DownCodec::TopK { frac: 0.1 }.is_delta());
        assert!(!DownCodec::QuantI8Group { block: 64 }.is_delta());
        // q4g is a full-state broadcast, not a delta codec.
        assert!(!DownCodec::QuantI4Group { block: 64 }.is_delta());
        assert_eq!(
            DownCodec::QuantI4Group { block: 64 }.wire_spec(),
            CodecSpec::QuantI4Group { block: 64 }
        );
    }

    #[test]
    fn stateless_uplink_matches_free_function() {
        let (global, local) = random_pair(1);
        for spec in [
            CodecSpec::Dense,
            CodecSpec::QuantI8,
            CodecSpec::QuantI8Group { block: 16 },
            CodecSpec::QuantI4Group { block: 16 },
            CodecSpec::TopK { frac: 0.2 },
            CodecSpec::TopKPacked { frac: 0.2 },
        ] {
            let up = StatelessUplink::new(spec);
            assert!(!up.stateful());
            let a = up.compress(0, 0, &global, &local).unwrap();
            let b = up.compress(3, 1, &global, &local).unwrap();
            let free = encode_update(spec, &global, &local).unwrap();
            assert_eq!(a, free, "stateless must equal the free function");
            assert_eq!(b, free, "…for every (client, sub-model) key");
        }
    }

    #[test]
    fn feedback_dense_is_a_no_op() {
        let (global, local) = random_pair(2);
        let up = FeedbackUplink::new(CodecSpec::Dense, 2, 1);
        let enc = up.compress(1, 0, &global, &local).unwrap();
        assert_eq!(enc, encode_update(CodecSpec::Dense, &global, &local).unwrap());
        assert!(up.residual(1, 0).is_empty(), "dense must never store residual");
    }

    #[test]
    fn feedback_topk_resurfaces_dropped_coordinates() {
        let (global, local) = random_pair(3);
        let spec = CodecSpec::TopK { frac: 0.1 };
        let up = FeedbackUplink::new(spec, 1, 1);

        // Round 1: no residual yet — identical to the stateless encode.
        let r1 = up.compress(0, 0, &global, &local).unwrap();
        assert_eq!(r1, encode_update(spec, &global, &local).unwrap());
        let kept1 = entry_indices(&r1);
        // Residual is exactly the un-shipped delta.
        let res = up.residual(0, 0);
        assert_eq!(res.len(), global.num_params());
        let (gf, lf) = (global.flat_values(), local.flat_values());
        for (i, r) in res.iter().enumerate() {
            if kept1.contains(&(i as u32)) {
                assert_eq!(*r, 0.0, "shipped coordinate {i} keeps no residual");
            } else {
                assert_eq!(*r, lf[i] - gf[i], "dropped coordinate {i}");
            }
        }

        // Round 2 with the *same* local: dropped coordinates now carry a
        // doubled accumulated delta, so the selection must move off the
        // round-1 set — feedback re-surfaces what was dropped.
        let r2 = up.compress(0, 0, &global, &local).unwrap();
        let kept2 = entry_indices(&r2);
        assert_ne!(kept1, kept2, "feedback must change the top-k selection");
        let fresh: usize = kept2.iter().filter(|&i| !kept1.contains(i)).count();
        assert!(fresh > 0, "round 2 must ship previously dropped coordinates");

        // A stateless uplink keeps shipping the identical set forever.
        let stateless = StatelessUplink::new(spec);
        assert_eq!(
            stateless.compress(0, 0, &global, &local).unwrap(),
            stateless.compress(0, 0, &global, &local).unwrap()
        );
    }

    #[test]
    fn feedback_q8_residual_is_quantization_bounded() {
        let (global, local) = random_pair(4);
        let up = FeedbackUplink::new(CodecSpec::QuantI8, 1, 1);
        up.compress(0, 0, &global, &local).unwrap();
        let res = up.residual(0, 0);
        assert_eq!(res.len(), local.num_params());
        // Per-tensor bound: |residual| ≤ scale/2 (+ float slack).
        let mut off = 0usize;
        for t in local.tensors.iter() {
            let max_abs = t.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = max_abs / 127.0;
            for &r in &res[off..off + t.len()] {
                assert!(r.abs() <= scale * 0.5 + 1e-6, "residual {r} vs scale {scale}");
            }
            off += t.len();
        }
    }

    #[test]
    fn feedback_slots_are_independent() {
        let (global, la) = random_pair(5);
        let (_, lb) = random_pair(6);
        let spec = CodecSpec::TopK { frac: 0.1 };
        let up = FeedbackUplink::new(spec, 2, 2);
        up.compress(0, 0, &global, &la).unwrap();
        // A different slot has no residual yet: its first compress is
        // exactly the stateless encode, regardless of slot (0,0) state.
        let other = up.compress(1, 1, &global, &lb).unwrap();
        assert_eq!(other, encode_update(spec, &global, &lb).unwrap());
        assert!(up.residual(0, 1).is_empty());
    }

    #[test]
    fn feedback_rejects_out_of_range_slot() {
        let (global, local) = random_pair(7);
        let up = FeedbackUplink::new(CodecSpec::QuantI8, 2, 2);
        assert!(up.compress(2, 0, &global, &local).is_err());
    }

    #[test]
    fn uplink_state_snapshots_bitwise() {
        let (global, la) = random_pair(31);
        let (_, lb) = random_pair(32);
        let spec = CodecSpec::TopK { frac: 0.1 };
        let up = FeedbackUplink::new(spec, 3, 2);
        up.compress(0, 0, &global, &la).unwrap();
        up.compress(2, 1, &global, &lb).unwrap();
        let state = up.snapshot_state();

        // Restore into a fresh uplink: the next compress of each slot
        // must be bitwise identical to continuing the original.
        let restored = FeedbackUplink::new(spec, 3, 2);
        restored.restore_state(&state).unwrap();
        assert_eq!(restored.residual(0, 0), up.residual(0, 0));
        assert_eq!(restored.residual(2, 1), up.residual(2, 1));
        assert_eq!(
            restored.compress(0, 0, &global, &la).unwrap(),
            up.compress(0, 0, &global, &la).unwrap()
        );
        // Snapshot bytes are canonical (key-sorted), so re-snapshotting
        // an untouched restore reproduces them exactly.
        let again = FeedbackUplink::new(spec, 3, 2);
        again.restore_state(&state).unwrap();
        assert_eq!(again.snapshot_state(), state);

        // Corrupt state is rejected, out-of-bounds slots are rejected.
        assert!(restored.restore_state(&state[..state.len() - 1]).is_err());
        let narrow = FeedbackUplink::new(spec, 1, 1);
        assert!(narrow.restore_state(&state).is_err());
        // Stateless uplinks refuse non-empty blobs.
        assert!(StatelessUplink::new(spec).restore_state(&state).is_err());
        assert!(StatelessUplink::new(spec).restore_state(&[]).is_ok());
    }

    #[test]
    fn downlink_state_snapshots_bitwise() {
        let (g0, _) = random_pair(33);
        let globals = vec![g0.clone()];

        // Folding downlink: residuals round-trip and the next broadcast
        // continues bitwise.
        let mut folding = FoldingDownlink::new(DownCodec::QuantI8, 1);
        folding.broadcast(0, &[0], &globals).unwrap();
        let state = folding.snapshot_state();
        let mut restored = FoldingDownlink::new(DownCodec::QuantI8, 1);
        restored.restore_state(&state).unwrap();
        let a = folding.broadcast(1, &[0], &globals).unwrap();
        let b = restored.broadcast(1, &[0], &globals).unwrap();
        assert_eq!(a.global(0, 0), b.global(0, 0));
        let mut wrong = FoldingDownlink::new(DownCodec::QuantI8, 2);
        assert!(wrong.restore_state(&state).is_err(), "slot count mismatch");

        // Delta downlink: replicas (model + version) round-trip, so a
        // restored server ships the same delta the original would.
        let mut delta = DeltaDownlink::new(DownCodec::TopK { frac: 0.2 }, 4, 1, 10).unwrap();
        delta.broadcast(0, &[1, 3], &globals).unwrap();
        let state = delta.snapshot_state();
        let mut restored = DeltaDownlink::new(DownCodec::TopK { frac: 0.2 }, 4, 1, 10).unwrap();
        restored.restore_state(&state).unwrap();
        assert_eq!(restored.base_version(1, 0), delta.base_version(1, 0));
        assert_eq!(restored.replica(3, 0), delta.replica(3, 0));
        let (g1, _) = random_pair(34);
        let next = vec![g1];
        let a = delta.broadcast(1, &[3], &next).unwrap();
        let b = restored.broadcast(1, &[3], &next).unwrap();
        assert_eq!(a.global(0, 0), b.global(0, 0));
        assert_eq!(a.payload(0, 0).to_bytes(), b.payload(0, 0).to_bytes());
        assert_eq!(
            restored.snapshot_state(),
            delta.snapshot_state(),
            "post-broadcast states stay in lockstep"
        );
    }

    #[test]
    fn dense_downlink_is_bitwise_lossless() {
        let (global, _) = random_pair(8);
        let globals = vec![global.clone()];
        for stateful in [false, true] {
            let bcast = if stateful {
                FoldingDownlink::new(DownCodec::Dense, 1)
                    .broadcast(0, &[0, 1], &globals)
                    .unwrap()
            } else {
                StatelessDownlink::new(DownCodec::Dense)
                    .broadcast(0, &[0, 1], &globals)
                    .unwrap()
            };
            assert!(!bcast.is_per_client(), "dense broadcast is shared");
            for slot in 0..2 {
                assert_eq!(bcast.global(slot, 0), &global, "dense broadcast must be exact");
                assert_eq!(bcast.payload(slot, 0).byte_len(), global.byte_size());
                assert_eq!(bcast.payload(slot, 0).codec(), DownCodec::Dense);
                assert!(bcast.payload(slot, 0).is_full());
            }
        }
    }

    #[test]
    fn q8_downlink_folding_cancels_quantization_bias() {
        let (global, _) = random_pair(9);
        let gf = global.flat_values();
        let globals = vec![global.clone()];
        let mut folding = FoldingDownlink::new(DownCodec::QuantI8, 1);

        let first = folding.broadcast(0, &[0], &globals).unwrap();
        let first_err: f64 = first
            .global(0, 0)
            .flat_values()
            .iter()
            .zip(gf.iter())
            .map(|(a, b)| (*a as f64 - *b as f64).abs())
            .sum();
        assert!(first_err > 0.0, "q8 of a random model must be lossy");

        // Re-broadcasting the same global T times: the mean of the
        // decoded broadcasts converges to the true global (the folded
        // residual is bounded, so bias ~ residual/T), while the
        // stateless downlink repeats the same biased decode forever.
        let t = 8usize;
        let mut mean = vec![0.0f64; gf.len()];
        let mut folding = FoldingDownlink::new(DownCodec::QuantI8, 1);
        for round in 0..t {
            let bcast = folding.broadcast(round, &[0], &globals).unwrap();
            for (m, v) in mean.iter_mut().zip(bcast.global(0, 0).flat_values()) {
                *m += v as f64 / t as f64;
            }
        }
        let mean_err: f64 = mean
            .iter()
            .zip(gf.iter())
            .map(|(a, b)| (a - *b as f64).abs())
            .sum();
        assert!(
            mean_err < first_err * 0.5,
            "folding must shrink the broadcast bias: mean {mean_err} vs single {first_err}"
        );
    }

    #[test]
    fn q8g_downlink_broadcasts_within_block_bounds() {
        let (global, _) = random_pair(10);
        let bcast = StatelessDownlink::new(DownCodec::QuantI8Group { block: 8 })
            .broadcast(0, &[0], &[global.clone()])
            .unwrap();
        let decoded = bcast.global(0, 0);
        for (t_g, t_d) in global.tensors.iter().zip(decoded.tensors.iter()) {
            for (chunk_g, chunk_d) in t_g.data().chunks(8).zip(t_d.data().chunks(8)) {
                let scale = chunk_g.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 127.0;
                for (&a, &b) in chunk_g.iter().zip(chunk_d.iter()) {
                    assert!((a - b).abs() <= 0.5 * scale + 1e-7);
                }
            }
        }
        // Smaller than dense, larger than plain q8 (extra scales).
        assert!(bcast.payload(0, 0).byte_len() < global.byte_size());
    }

    #[test]
    fn q4g_downlink_broadcasts_within_block_bounds() {
        let (global, _) = random_pair(20);
        let bcast = StatelessDownlink::new(DownCodec::QuantI4Group { block: 8 })
            .broadcast(0, &[0], &[global.clone()])
            .unwrap();
        let decoded = bcast.global(0, 0);
        for (t_g, t_d) in global.tensors.iter().zip(decoded.tensors.iter()) {
            for (chunk_g, chunk_d) in t_g.data().chunks(8).zip(t_d.data().chunks(8)) {
                let scale = chunk_g.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 7.0;
                for (&a, &b) in chunk_g.iter().zip(chunk_d.iter()) {
                    assert!((a - b).abs() <= 0.5 * scale + 1e-7);
                }
            }
        }
        // Sub-byte: strictly smaller than the q8g broadcast of the
        // same model at the same block.
        let q8g = StatelessDownlink::new(DownCodec::QuantI8Group { block: 8 })
            .broadcast(0, &[0], &[global.clone()])
            .unwrap();
        assert!(bcast.payload(0, 0).byte_len() < q8g.payload(0, 0).byte_len());
    }

    #[test]
    fn stateless_downlink_rejects_delta_codecs() {
        let (global, _) = random_pair(11);
        let globals = vec![global];
        let err = StatelessDownlink::new(DownCodec::TopK { frac: 0.1 })
            .broadcast(0, &[0], &globals)
            .unwrap_err();
        assert!(err.to_string().contains("DeltaDownlink"), "{err}");
        assert!(FoldingDownlink::new(DownCodec::TopK { frac: 0.1 }, 1)
            .broadcast(0, &[0], &globals)
            .is_err());
        assert!(DeltaDownlink::new(DownCodec::Dense, 1, 1, 4).is_err());
    }

    #[test]
    fn delta_downlink_first_contact_is_a_full_dense_resync() {
        let (global, _) = random_pair(12);
        let mut down = DeltaDownlink::new(DownCodec::TopK { frac: 0.1 }, 3, 1, 8).unwrap();
        let bcast = down.broadcast(0, &[0, 2], &[global.clone()]).unwrap();
        assert!(bcast.is_per_client());
        for slot in 0..2 {
            let p = bcast.payload(slot, 0);
            assert!(p.is_full(), "first contact must be a full resync");
            assert_eq!(p.version(), 1);
            // Bitwise: the client lands exactly on the broadcast base.
            assert_eq!(bcast.global(slot, 0), &global);
            // Full resync is dense + the 9-byte versioned header.
            assert_eq!(p.byte_len(), global.byte_size() + 9);
        }
        assert_eq!(down.base_version(0, 0), 1);
        assert_eq!(down.base_version(2, 0), 1);
        assert_eq!(down.base_version(1, 0), 0, "unselected client stays unsynced");
    }

    #[test]
    fn delta_downlink_ships_versioned_deltas_against_the_replica() {
        let (g0, _) = random_pair(13);
        let g1 = drift(&g0, 100);
        let mut down = DeltaDownlink::new(DownCodec::TopKPacked { frac: 0.2 }, 2, 1, 8).unwrap();
        down.broadcast(0, &[0], &[g0.clone()]).unwrap();
        let bcast = down.broadcast(1, &[0], &[g1.clone()]).unwrap();
        let p = bcast.payload(0, 0);
        assert_eq!(p.kind(), PayloadKind::Delta { base_version: 1 });
        assert_eq!(p.version(), 2);
        // The decoded state is the delta applied onto the old base (g0),
        // and the server's replica tracks it exactly.
        assert_eq!(down.replica(0, 0).unwrap(), bcast.global(0, 0));
        // Top-k is lossy, so the client is near — not at — the global;
        // the pending difference stays in the replica for next round.
        assert_ne!(bcast.global(0, 0), &g1);
        // A delta is much smaller than the full model.
        assert!(p.byte_len() < g1.byte_size() / 2, "{} bytes", p.byte_len());
    }

    #[test]
    fn delta_downlink_resyncs_past_the_staleness_cap() {
        let (mut global, _) = random_pair(14);
        let mut down = DeltaDownlink::new(DownCodec::TopK { frac: 0.2 }, 2, 1, 2).unwrap();
        // Round 0: both clients sync. Client 1 then sits out rounds 1–3.
        down.broadcast(0, &[0, 1], &[global.clone()]).unwrap();
        for round in 1..4 {
            global = drift(&global, 200 + round as u64);
            let bcast = down.broadcast(round, &[0], &[global.clone()]).unwrap();
            assert!(
                !bcast.payload(0, 0).is_full(),
                "round {round}: fresh client keeps getting deltas"
            );
        }
        // Round 4: client 1's base is 4 versions old (> resync_every 2):
        // it must get a full dense resync that lands it bitwise on the
        // current broadcast base, while client 0 still gets a delta.
        global = drift(&global, 300);
        let bcast = down.broadcast(4, &[0, 1], &[global.clone()]).unwrap();
        assert!(!bcast.payload(0, 0).is_full());
        let p1 = bcast.payload(1, 0);
        assert!(p1.is_full(), "stale client must be resynced");
        assert_eq!(p1.version(), 5);
        assert_eq!(bcast.global(1, 0), &global, "resync is bitwise");
        assert_eq!(down.replica(1, 0).unwrap(), &global);
    }

    #[test]
    fn delta_downlink_within_window_applies_onto_the_stale_base() {
        let (g0, _) = random_pair(15);
        let mut down = DeltaDownlink::new(DownCodec::TopK { frac: 0.3 }, 2, 1, 4).unwrap();
        down.broadcast(0, &[0, 1], &[g0.clone()]).unwrap();
        let stale_base = down.replica(1, 0).unwrap().clone();
        // Client 1 sits out rounds 1–2 (staleness 3 ≤ cap 4 at round 3).
        let mut global = g0.clone();
        for round in 1..3 {
            global = drift(&global, 400 + round as u64);
            down.broadcast(round, &[0], &[global.clone()]).unwrap();
        }
        global = drift(&global, 500);
        let bcast = down.broadcast(3, &[0, 1], &[global.clone()]).unwrap();
        let p1 = bcast.payload(1, 0);
        assert_eq!(p1.kind(), PayloadKind::Delta { base_version: 1 });
        // The decoded state is exactly the payload applied to the base
        // the client has held since round 0.
        assert_eq!(bcast.global(1, 0), &p1.apply(&stale_base).unwrap());
    }

    #[test]
    fn downlink_payload_bytes_roundtrip() {
        let (global, _) = random_pair(16);
        let n_tensors = global.tensors.len();
        let n = global.num_params();
        // Shared (non-delta) payloads: headerless, PR 3 layout.
        for codec in [
            DownCodec::Dense,
            DownCodec::QuantI8,
            DownCodec::QuantI8Group { block: 16 },
            DownCodec::QuantI4Group { block: 16 },
        ] {
            let bcast = StatelessDownlink::new(codec)
                .broadcast(0, &[0], &[global.clone()])
                .unwrap();
            let payload = bcast.payload(0, 0);
            let bytes = payload.to_bytes();
            assert_eq!(bytes.len(), payload.byte_len(), "{}", codec.name());
            let back = DownlinkPayload::from_bytes(codec, n_tensors, n, &bytes).unwrap();
            assert_eq!(&back, payload);
            assert_eq!(
                back.decode_full(&global).unwrap(),
                payload.decode_full(&global).unwrap()
            );
        }
        // Delta payloads: versioned header + body, both kinds.
        let codec = DownCodec::TopK { frac: 0.2 };
        let mut down = DeltaDownlink::new(codec, 1, 1, 8).unwrap();
        let full = down.broadcast(0, &[0], &[global.clone()]).unwrap();
        let g1 = drift(&global, 600);
        let delta = down.broadcast(1, &[0], &[g1.clone()]).unwrap();
        for (bcast, tag) in [(&full, "full"), (&delta, "delta")] {
            let payload = bcast.payload(0, 0);
            let bytes = payload.to_bytes();
            assert_eq!(bytes.len(), payload.byte_len(), "{tag}");
            let back = DownlinkPayload::from_bytes(codec, n_tensors, n, &bytes).unwrap();
            assert_eq!(&back, payload, "{tag}");
        }
        // A delta payload refuses to decode without a base.
        assert!(delta.payload(0, 0).decode_full(&global).is_err());
        // Truncated and corrupt-kind payloads are rejected.
        let bytes = delta.payload(0, 0).to_bytes();
        assert!(DownlinkPayload::from_bytes(codec, n_tensors, n, &bytes[..8]).is_err());
        let mut bad = bytes.clone();
        bad[0] = 7;
        assert!(DownlinkPayload::from_bytes(codec, n_tensors, n, &bad).is_err());
    }

    #[test]
    fn facade_selects_impls_from_config() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.codec = CodecSpec::TopK { frac: 0.1 };
        let t = Transport::new(&cfg, 2).unwrap();
        assert!(!t.stateful(), "feedback off → stateless pipeline");
        cfg.error_feedback = true;
        let t = Transport::new(&cfg, 2).unwrap();
        assert!(t.stateful());
        assert_eq!(t.uplink().spec(), CodecSpec::TopK { frac: 0.1 });
        // A sparse down codec selects the delta downlink even with
        // feedback off — it is stateful by construction.
        cfg.error_feedback = false;
        cfg.down_codec = DownCodec::TopK { frac: 0.1 };
        let t = Transport::new(&cfg, 2).unwrap();
        assert!(t.stateful());
    }

    #[test]
    fn facade_broadcast_and_decode_close_the_loop() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.codec = CodecSpec::QuantI8;
        cfg.down_codec = DownCodec::QuantI8;
        cfg.error_feedback = true;
        let (global, local) = random_pair(17);
        let globals = vec![global.clone()];
        let mut transport = Transport::new(&cfg, 1).unwrap();
        let bcast = transport.broadcast(0, &[0], &globals).unwrap();
        assert_eq!(bcast.n_models(), 1);
        // q8 broadcast is smaller than dense and decodes near the global.
        assert!(bcast.payload(0, 0).byte_len() < global.byte_size());
        // Close the loop: client encodes against the *decoded* broadcast,
        // server decodes against the same reference.
        let enc = transport
            .uplink()
            .compress(0, 0, bcast.global(0, 0), &local)
            .unwrap();
        let back = transport.decode(bcast.global(0, 0), &enc).unwrap();
        assert_eq!(back.num_params(), local.num_params());
    }
}
