//! Crash-resume snapshots (`--snapshot-every N --resume <dir>`).
//!
//! The synchronous round loop is a pure function of `(config, round)`
//! plus a small amount of cross-round state: the global sub-models, the
//! transport's residuals/replica bases, the history, the comm meters,
//! and the early stopper. Everything else — client sampling, data
//! shards, fault fates — is re-derived from the seed on demand, so
//! persisting exactly that state lets a killed run continue *bitwise
//! identically* to an uninterrupted one.
//!
//! The on-disk `state.fmls` format follows the serve checkpoints'
//! discipline: little-endian fields behind a magic + version header,
//! every variable-length region length-prefixed and bounds-checked
//! before allocation (a corrupt length can't OOM the loader), and a
//! trailing FNV-1a checksum over the whole body. A config fingerprint
//! (everything that shapes the trajectory *except* `--rounds`, so a
//! snapshot taken at round 3 of 10 can also seed a `--rounds 20` run)
//! refuses resumes under a different experiment. Writes go to a temp
//! file in the same directory and are renamed into place, so a crash
//! *during* snapshotting leaves the previous snapshot intact.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::comm::CommMeter;
use super::history::{History, RoundRecord, RoundTiming};
use super::wire::fnv1a64;
use crate::config::ExperimentConfig;
use crate::eval::metrics::AccuracyReport;
use crate::model::params::ModelParams;

/// Snapshot file name inside the `--resume` directory.
pub const SNAPSHOT_FILE: &str = "state.fmls";

const MAGIC: [u8; 4] = *b"FMLS";
const VERSION: u32 = 1;

// ---------------------------------------------------- byte cursors

/// Little-endian byte sink for snapshot serialization; also used by the
/// transport compressors to serialize their private cross-round state.
#[derive(Default)]
pub(crate) struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Raw f32 bit patterns, no length prefix (callers record counts).
    pub fn f32s(&mut self, vals: &[f32]) {
        self.buf.reserve(vals.len() * 4);
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Length-prefixed byte blob.
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked little-endian cursor: every read answers `Err` past
/// the end, and counted reads are validated against the bytes actually
/// remaining *before* any allocation.
pub(crate) struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "truncated: wanted {n} bytes at offset {}, only {} remain",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// `count` as a usize, validated so that `count * unit_bytes` more
    /// bytes actually remain — the guard that keeps a corrupt declared
    /// length from turning into an OOM-sized allocation.
    pub fn counted(&mut self, unit_bytes: usize) -> Result<usize> {
        let n = self.u64()?;
        let need = (n as usize)
            .checked_mul(unit_bytes)
            .filter(|&need| need <= self.remaining());
        match need {
            Some(_) => Ok(n as usize),
            None => bail!(
                "declared {n} × {unit_bytes}-byte entries at offset {} but only {} bytes remain",
                self.pos,
                self.remaining()
            ),
        }
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Length-prefixed byte blob (inverse of [`ByteWriter::bytes`]).
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.counted(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after the last field", self.remaining());
        }
        Ok(())
    }
}

// ------------------------------------------------------- fingerprint

/// Hash of everything that shapes the training trajectory — resuming
/// under a different value of any of these would silently splice two
/// unrelated runs. `--rounds` is deliberately excluded (extending a run
/// is the legitimate use of resume), and so are observational knobs
/// (`--workers`, `--trace-out`, output paths).
pub fn config_fingerprint(cfg: &ExperimentConfig) -> u64 {
    let canon = format!(
        "preset={};clients={};cpr={};epochs={};patience={};lr={:08x};seed={};eval={};r={};b={};\
         codec={};down={};resync={};ef={};inject={};robust={}",
        cfg.preset.name,
        cfg.clients,
        cfg.clients_per_round,
        cfg.local_epochs,
        cfg.patience,
        cfg.lr.to_bits(),
        cfg.seed,
        cfg.eval_every,
        cfg.override_r,
        cfg.override_b,
        cfg.codec.name(),
        cfg.down_codec.name(),
        cfg.resync_every,
        cfg.error_feedback,
        cfg.inject,
        cfg.robust.name(),
    );
    fnv1a64(canon.as_bytes())
}

// ---------------------------------------------------------- snapshot

/// Everything the synchronous round loop needs to continue a run
/// bitwise from `next_round`.
pub struct RunSnapshot {
    /// The round the resumed loop starts at (the snapshot was taken
    /// after round `next_round - 1` completed).
    pub next_round: usize,
    pub globals: Vec<ModelParams>,
    pub history: History,
    pub comm: CommMeter,
    /// Early-stopper state: `(best, best_round, since_best, observed)`.
    pub stopper: (f64, usize, usize, usize),
    /// Opaque uplink compressor state
    /// ([`super::transport::UplinkCompressor::snapshot_state`]).
    pub uplink_state: Vec<u8>,
    /// Opaque downlink compressor state
    /// ([`super::transport::DownlinkCompressor::snapshot_state`]).
    pub downlink_state: Vec<u8>,
}

impl RunSnapshot {
    fn to_bytes(&self, fingerprint: u64) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.buf.extend_from_slice(&MAGIC);
        w.u32(VERSION);
        w.u64(fingerprint);
        w.u64(self.next_round as u64);
        w.u32(self.globals.len() as u32);
        for g in &self.globals {
            w.u32(g.d as u32);
            w.u32(g.hidden as u32);
            w.u32(g.out as u32);
            w.u64(g.num_params() as u64);
            w.f32s(&g.flat_values());
        }
        w.u64(self.history.records.len() as u64);
        for r in &self.history.records {
            w.u64(r.round as u64);
            let a = &r.accuracy;
            for v in [
                a.top1, a.top3, a.top5, a.freq1, a.freq3, a.freq5, a.infreq1, a.infreq3, a.infreq5,
            ] {
                w.f64(v);
            }
            w.u64(a.samples as u64);
            w.u64(r.comm_bytes);
            w.u64(r.down_bytes);
            w.u64(r.up_bytes);
            w.f64(r.round_seconds);
            w.f64(r.mean_loss);
            w.f64(r.timing.train_seconds);
            w.f64(r.timing.encode_seconds);
            w.f64(r.timing.aggregate_seconds);
            w.f64(r.sim_seconds);
        }
        let (down, up, dense_up, dense_down, per_round) = self.comm.snapshot_parts();
        w.u64(down);
        w.u64(up);
        w.u64(dense_up);
        w.u64(dense_down);
        w.u64(per_round.len() as u64);
        for &t in per_round {
            w.u64(t);
        }
        let (best, best_round, since_best, observed) = self.stopper;
        w.f64(best);
        w.u64(best_round as u64);
        w.u64(since_best as u64);
        w.u64(observed as u64);
        w.bytes(&self.uplink_state);
        w.bytes(&self.downlink_state);
        let mut bytes = w.into_bytes();
        let digest = fnv1a64(&bytes);
        bytes.extend_from_slice(&digest.to_le_bytes());
        bytes
    }

    fn from_bytes(bytes: &[u8], expected_fingerprint: u64) -> Result<RunSnapshot> {
        if bytes.len() < MAGIC.len() + 4 + 8 + 8 {
            bail!("{} bytes is too short to be a snapshot", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let declared = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv1a64(body);
        if declared != actual {
            bail!("checksum mismatch: file says {declared:#018x}, body hashes to {actual:#018x}");
        }
        let mut r = ByteReader::new(body);
        if r.take(4)? != MAGIC {
            bail!("bad magic (not a FMLS snapshot)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("snapshot format v{version}, this build reads v{VERSION}");
        }
        let fingerprint = r.u64()?;
        if fingerprint != expected_fingerprint {
            bail!(
                "snapshot was taken under a different experiment config \
                 (fingerprint {fingerprint:#018x}, current {expected_fingerprint:#018x}) — \
                 refusing to resume; point --resume at a fresh directory"
            );
        }
        let next_round = r.u64()? as usize;
        let n_models = r.u32()? as usize;
        let mut globals = Vec::with_capacity(n_models.min(1024));
        for _ in 0..n_models {
            globals.push(read_params(&mut r)?);
        }
        let n_records = r.counted(20 * 8)?;
        let mut history = History::new();
        for _ in 0..n_records {
            let round = r.u64()? as usize;
            let mut acc = [0.0f64; 9];
            for v in acc.iter_mut() {
                *v = r.f64()?;
            }
            let samples = r.u64()? as usize;
            let (comm_bytes, down_bytes, up_bytes) = (r.u64()?, r.u64()?, r.u64()?);
            let (round_seconds, mean_loss) = (r.f64()?, r.f64()?);
            let (train, enc, agg) = (r.f64()?, r.f64()?, r.f64()?);
            let sim_seconds = r.f64()?;
            history.push(RoundRecord {
                round,
                accuracy: AccuracyReport {
                    top1: acc[0],
                    top3: acc[1],
                    top5: acc[2],
                    freq1: acc[3],
                    freq3: acc[4],
                    freq5: acc[5],
                    infreq1: acc[6],
                    infreq3: acc[7],
                    infreq5: acc[8],
                    samples,
                },
                comm_bytes,
                down_bytes,
                up_bytes,
                round_seconds,
                mean_loss,
                timing: RoundTiming {
                    train_seconds: train,
                    encode_seconds: enc,
                    aggregate_seconds: agg,
                },
                sim_seconds,
            });
        }
        let (down, up, dense_up, dense_down) = (r.u64()?, r.u64()?, r.u64()?, r.u64()?);
        let n_totals = r.counted(8)?;
        let mut per_round = Vec::with_capacity(n_totals);
        for _ in 0..n_totals {
            per_round.push(r.u64()?);
        }
        let comm = CommMeter::from_parts(down, up, dense_up, dense_down, per_round);
        let stopper = (
            r.f64()?,
            r.u64()? as usize,
            r.u64()? as usize,
            r.u64()? as usize,
        );
        let uplink_state = r.bytes()?;
        let downlink_state = r.bytes()?;
        r.finish()?;
        Ok(RunSnapshot {
            next_round,
            globals,
            history,
            comm,
            stopper,
            uplink_state,
            downlink_state,
        })
    }

    /// Atomically write the snapshot into `dir` (created if absent):
    /// serialize to `state.fmls.tmp`, then rename over [`SNAPSHOT_FILE`].
    pub fn save(&self, dir: &Path, fingerprint: u64) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating snapshot directory {}", dir.display()))?;
        let path = dir.join(SNAPSHOT_FILE);
        let tmp = dir.join(format!("{SNAPSHOT_FILE}.tmp"));
        std::fs::write(&tmp, self.to_bytes(fingerprint))
            .with_context(|| format!("writing snapshot {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing snapshot {}", path.display()))?;
        Ok(path)
    }

    /// Load the snapshot in `dir` if one exists. `Ok(None)` when the
    /// directory holds no snapshot yet (a fresh run); `Err` when one
    /// exists but is corrupt or was taken under a different config.
    pub fn load(dir: &Path, expected_fingerprint: u64) -> Result<Option<RunSnapshot>> {
        let path = dir.join(SNAPSHOT_FILE);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("reading snapshot {}", path.display()))
            }
        };
        Self::from_bytes(&bytes, expected_fingerprint)
            .with_context(|| format!("loading snapshot {}", path.display()))
            .map(Some)
    }
}

fn read_params(r: &mut ByteReader<'_>) -> Result<ModelParams> {
    let d = r.u32()? as usize;
    let hidden = r.u32()? as usize;
    let out = r.u32()? as usize;
    let n = r.counted(4)?;
    let mut p = ModelParams::zeros(d, hidden, out);
    if n != p.num_params() {
        bail!(
            "sub-model ({d},{hidden},{out}) declares {n} values, shape needs {}",
            p.num_params()
        );
    }
    p.set_from_flat(&r.f32s(n)?)?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, InjectConfig};
    use crate::federated::early_stop::EarlyStopper;

    fn tiny_config() -> ExperimentConfig {
        ExperimentConfig::new(presets::by_name("tiny").expect("tiny preset"))
    }

    fn sample_snapshot() -> RunSnapshot {
        let mut comm = CommMeter::new();
        comm.download_encoded(30, 120);
        comm.upload_encoded(10, 120);
        comm.end_round();
        let mut stopper = EarlyStopper::new(5);
        stopper.observe(0, 0.25);
        let mut history = History::new();
        history.push(RoundRecord {
            round: 0,
            accuracy: AccuracyReport {
                top1: 0.25,
                top3: 0.35,
                top5: 0.45,
                samples: 64,
                ..Default::default()
            },
            comm_bytes: 40,
            down_bytes: 30,
            up_bytes: 10,
            round_seconds: 1.25,
            mean_loss: 0.9,
            timing: RoundTiming {
                train_seconds: 0.7,
                encode_seconds: 0.2,
                aggregate_seconds: 0.35,
            },
            sim_seconds: 0.0,
        });
        RunSnapshot {
            next_round: 1,
            globals: vec![ModelParams::init(6, 4, 9, 3), ModelParams::init(6, 4, 9, 4)],
            history,
            comm,
            stopper: stopper.snapshot_parts(),
            uplink_state: vec![1, 2, 3, 4],
            downlink_state: Vec::new(),
        }
    }

    #[test]
    fn snapshot_roundtrips_bitwise() {
        let snap = sample_snapshot();
        let bytes = snap.to_bytes(0xabcd);
        let back = RunSnapshot::from_bytes(&bytes, 0xabcd).unwrap();
        assert_eq!(back.next_round, 1);
        assert_eq!(back.globals, snap.globals);
        assert_eq!(back.history, snap.history);
        assert_eq!(back.comm, snap.comm);
        assert_eq!(back.stopper, snap.stopper);
        assert_eq!(back.uplink_state, snap.uplink_state);
        assert_eq!(back.downlink_state, snap.downlink_state);
        // Re-serializing the loaded snapshot is byte-identical — the
        // property the kill-and-resume CI step leans on.
        assert_eq!(back.to_bytes(0xabcd), bytes);
    }

    #[test]
    fn rejects_corruption_truncation_and_wrong_fingerprint() {
        let snap = sample_snapshot();
        let bytes = snap.to_bytes(7);
        // Any single-byte flip fails the trailing checksum (or, in the
        // last 8 bytes, the declared digest itself).
        for i in [0, 4, 12, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            assert!(RunSnapshot::from_bytes(&bad, 7).is_err(), "flip at {i}");
        }
        for cut in [0, 3, 16, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                RunSnapshot::from_bytes(&bytes[..cut], 7).is_err(),
                "truncation at {cut}"
            );
        }
        let err = RunSnapshot::from_bytes(&bytes, 8).unwrap_err().to_string();
        assert!(err.contains("different experiment config"), "{err}");
    }

    #[test]
    fn save_load_names_the_file_and_is_atomic() {
        let dir = std::env::temp_dir().join(format!("fedmlh-snap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(RunSnapshot::load(&dir, 1).unwrap().is_none(), "no file yet");
        let snap = sample_snapshot();
        let path = snap.save(&dir, 1).unwrap();
        assert!(path.ends_with(SNAPSHOT_FILE));
        assert!(!dir.join(format!("{SNAPSHOT_FILE}.tmp")).exists());
        let back = RunSnapshot::load(&dir, 1).unwrap().expect("snapshot");
        assert_eq!(back.globals, snap.globals);
        // A corrupt file's error names the offending path.
        let mut raw = std::fs::read(&path).unwrap();
        raw[10] ^= 0xff;
        std::fs::write(&path, &raw).unwrap();
        let err = RunSnapshot::load(&dir, 1).unwrap_err().to_string();
        assert!(err.contains(SNAPSHOT_FILE), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_tracks_trajectory_knobs_only() {
        let a = tiny_config();
        let mut b = a.clone();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        // Trajectory-shaping knobs move the fingerprint…
        b.seed += 1;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        b = a.clone();
        b.inject = InjectConfig::parse("corrupt:0.05").unwrap();
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        // …while --rounds and --workers deliberately don't.
        b = a.clone();
        b.rounds += 10;
        b.workers = 8;
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
    }
}
