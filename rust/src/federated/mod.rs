//! The federated coordinator — the paper's Algorithm 2 as a system.
//!
//! [`server`] owns the synchronization-round loop; communication flows
//! through the stateful transport pipeline ([`transport`]), which
//! drives the stateless wire codecs ([`wire`]) as pluggable backends:
//!
//! ```text
//!   globals ──▶ DownlinkCompressor ──payload(s)──▶ clients decode
//!      ▲        (dense/q8/q8g shared     │         and locally train
//!      │         broadcast + residual    ▼         (engine fan-out)
//!      │         folding, or per-client
//!      │         versioned topk deltas)
//!   aggregate ◀──decode◀──payload◀──── UplinkCompressor
//!                                      (dense/q8/q8g/topk/topkv +
//!                                       per-(client, sub-model) error-
//!                                       feedback accumulators)
//! ```
//!
//! Per round: sample S of K clients ([`sampler`]), compress the globals
//! down ([`transport::Transport::broadcast`]) — one shared payload per
//! sub-model for the full-state codecs, or one payload per `(client,
//! sub-model)` under the delta downlink
//! ([`transport::DeltaDownlink`]: a versioned top-k delta against the
//! replica that client last decoded, with a full dense resync once the
//! base is stale past `--resync-every`) — fan local training out
//! through the [`engine`] worker pool (through a [`backend`] that is
//! either the PJRT runtime executing AOT artifacts or the pure-rust
//! reference trainer), encode each update through the shared
//! [`transport::UplinkCompressor`], decode each update against the base
//! its client trained from and aggregate per sub-model
//! ([`aggregate`]), charge both links' *encoded* bytes per client to
//! the [`comm::CommMeter`] (dense-equivalent tracked alongside),
//! evaluate, early-stop. With `dense` on both links and
//! `--error-feedback off` this is bit-identical to the historical
//! stateless pipeline; FedAvg is the degenerate case with one sub-model
//! trained on raw class labels.
//!
//! Compression *state* — the error-feedback residuals on the client
//! side, the broadcast quantization residual and the per-client base
//! replicas on the server side — lives across rounds inside the
//! [`transport::Transport`] owned by one run, which is what lets
//! aggressive `topk`/`q8` settings keep the signal they would otherwise
//! discard every round, and what lets the downlink ship deltas at all.

pub mod aggregate;
pub mod backend;
pub mod batcher;
pub mod comm;
pub mod early_stop;
pub mod engine;
pub mod history;
pub mod sampler;
pub mod server;
pub mod sim;
pub mod transport;
pub mod wire;

pub use backend::{RustBackend, TrainBackend};
pub use engine::RoundEngine;
pub use server::{run, RunOutput};
pub use sim::{run_async, ClientRegistry, Dist, SimStats};
pub use transport::{
    DeltaDownlink, DownCodec, DownlinkCompressor, DownlinkPayload, FeedbackUplink,
    FoldingDownlink, PayloadKind, RoundBroadcast, StatelessDownlink, StatelessUplink, Transport,
    UplinkCompressor,
};
pub use wire::{CodecSpec, EncodedUpdate};
