//! The federated coordinator — the paper's Algorithm 2 as a system.
//!
//! [`server`] owns the synchronization-round loop: sample S of K
//! clients, fan their local training out through the [`engine`] worker
//! pool (through a [`backend`] that is either the PJRT runtime
//! executing AOT artifacts or the pure-rust reference trainer), decode
//! the [`wire`]-encoded updates, aggregate per sub-model, account
//! communication bytes, evaluate, early-stop. FedAvg is the degenerate
//! case with one sub-model trained on raw class labels.

pub mod aggregate;
pub mod backend;
pub mod batcher;
pub mod comm;
pub mod early_stop;
pub mod engine;
pub mod history;
pub mod sampler;
pub mod server;
pub mod wire;

pub use backend::{RustBackend, TrainBackend};
pub use engine::RoundEngine;
pub use server::{run, RunOutput};
pub use wire::{CodecSpec, EncodedUpdate};
