//! The federated coordinator — the paper's Algorithm 2 as a system.
//!
//! [`server`] owns the synchronization-round loop; communication flows
//! through the stateful transport pipeline ([`transport`]), which
//! drives the stateless wire codecs ([`wire`]) as pluggable backends:
//!
//! ```text
//!   globals ──▶ DownlinkCompressor ──payload──▶ clients decode
//!      ▲        (dense/q8 + server     │        and locally train
//!      │         residual folding)     ▼        (engine fan-out)
//!   aggregate ◀──decode◀──payload◀── UplinkCompressor
//!                                    (dense/q8/topk/topkv + per-
//!                                     (client, sub-model) error-
//!                                     feedback accumulators)
//! ```
//!
//! Per round: sample S of K clients ([`sampler`]), compress and
//! broadcast each global sub-model down ([`transport::Transport::broadcast`]),
//! fan local training out through the [`engine`] worker pool (through a
//! [`backend`] that is either the PJRT runtime executing AOT artifacts
//! or the pure-rust reference trainer), encode each update through the
//! shared [`transport::UplinkCompressor`], decode and aggregate per
//! sub-model ([`aggregate`]), charge both links' *encoded* bytes to the
//! [`comm::CommMeter`] (dense-equivalent tracked alongside), evaluate,
//! early-stop. With `dense` on both links and `--error-feedback off`
//! this is bit-identical to the historical stateless pipeline; FedAvg
//! is the degenerate case with one sub-model trained on raw class
//! labels.
//!
//! Compression *state* — the error-feedback residuals on the client
//! side, the broadcast quantization residual on the server side — lives
//! across rounds inside the [`transport::Transport`] owned by one run,
//! which is what lets aggressive `topk`/`q8` settings keep the signal
//! they would otherwise discard every round.

pub mod aggregate;
pub mod backend;
pub mod batcher;
pub mod comm;
pub mod early_stop;
pub mod engine;
pub mod history;
pub mod sampler;
pub mod server;
pub mod transport;
pub mod wire;

pub use backend::{RustBackend, TrainBackend};
pub use engine::RoundEngine;
pub use server::{run, RunOutput};
pub use transport::{
    BroadcastPayload, DownCodec, DownlinkCompressor, FeedbackUplink, FoldingDownlink,
    StatelessDownlink, StatelessUplink, Transport, UplinkCompressor,
};
pub use wire::{CodecSpec, EncodedUpdate};
