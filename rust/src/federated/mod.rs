//! The federated coordinator — the paper's Algorithm 2 as a system.
//!
//! [`server`] owns the synchronization-round loop; communication flows
//! through the stateful transport pipeline ([`transport`]), which
//! drives the stateless wire codecs ([`wire`]) as pluggable backends:
//!
//! ```text
//!   globals ──▶ DownlinkCompressor ──payload(s)──▶ clients decode
//!      ▲        (dense/q8/q8g shared     │         and locally train
//!      │         broadcast + residual    ▼         (engine fan-out)
//!      │         folding, or per-client
//!      │         versioned topk deltas)
//!   aggregate ◀──decode◀──payload◀──── UplinkCompressor
//!                                      (dense/q8/q8g/topk/topkv +
//!                                       per-(client, sub-model) error-
//!                                       feedback accumulators)
//! ```
//!
//! Per round: sample S of K clients ([`sampler`]), compress the globals
//! down ([`transport::Transport::broadcast`]) — one shared payload per
//! sub-model for the full-state codecs, or one payload per `(client,
//! sub-model)` under the delta downlink
//! ([`transport::DeltaDownlink`]: a versioned top-k delta against the
//! replica that client last decoded, with a full dense resync once the
//! base is stale past `--resync-every`) — fan local training out
//! through the [`engine`] worker pool (through a [`backend`] that is
//! either the PJRT runtime executing AOT artifacts or the pure-rust
//! reference trainer), encode each update through the shared
//! [`transport::UplinkCompressor`], decode each update against the base
//! its client trained from and aggregate per sub-model
//! ([`aggregate`]), charge both links' *encoded* bytes per client to
//! the [`comm::CommMeter`] (dense-equivalent tracked alongside),
//! evaluate, early-stop. With `dense` on both links and
//! `--error-feedback off` this is bit-identical to the historical
//! stateless pipeline; FedAvg is the degenerate case with one sub-model
//! trained on raw class labels.
//!
//! Compression *state* — the error-feedback residuals on the client
//! side, the broadcast quantization residual and the per-client base
//! replicas on the server side — lives across rounds inside the
//! [`transport::Transport`] owned by one run, which is what lets
//! aggressive `topk`/`q8` settings keep the signal they would otherwise
//! discard every round, and what lets the downlink ship deltas at all.
//!
//! # Fault tolerance
//!
//! Real fleets corrupt payloads, ship divergent updates, drop uplinks,
//! and outlive any single server process; the round loop is built to
//! survive all four without perturbing a clean run:
//!
//! - **Hardened decode** ([`wire::EncodedUpdate::from_framed_bytes`]):
//!   uplink payloads can travel in a checksummed frame (magic + codec
//!   tag + declared length + FNV-1a digest). Truncated, oversized, or
//!   bit-flipped frames answer `Err` — never a panic — and the server
//!   discards the update while still charging its bytes to
//!   [`comm::CommMeter`] and counting it in `fedmlh_faults_total{kind}`.
//! - **Defensive aggregation** ([`aggregate::aggregate_robust`],
//!   `--robust-agg norm-clip:<c>|trimmed:<frac>|none`): NaN/Inf
//!   sub-model updates are screened out, and surviving updates are
//!   norm-clipped or coordinate-wise trimmed before averaging, so one
//!   poisoned client cannot take the global model non-finite. `none`
//!   is bit-identical to the historical plain average.
//! - **Deterministic fault injection** ([`fault`],
//!   `--inject corrupt:<p>,truncate:<p>,nan:<p>,fail:<p>`): per-`(round,
//!   client, sub-model)` fates drawn from tagged seed streams — the
//!   same derive-seed discipline as [`sim`]'s dropout — in both sync
//!   and async runs; the async sim retries transient `fail` fates with
//!   exponential backoff charged on the simulated clock. Injection off
//!   ⇒ zero RNG draws ⇒ clean runs stay bitwise identical.
//! - **Crash-resume** ([`snapshot`], `--snapshot-every N --resume
//!   <dir>`): the sync loop atomically persists globals, transport
//!   residuals/replica bases, history, comm meters, and the early
//!   stopper, and a resumed run continues *bitwise identically* to an
//!   uninterrupted one (everything else is derived from `(seed, round)`
//!   and needs no cursor).

pub mod aggregate;
pub mod backend;
pub mod batcher;
pub mod comm;
pub mod early_stop;
pub mod engine;
pub mod fault;
pub mod history;
pub mod sampler;
pub mod server;
pub mod sim;
pub mod snapshot;
pub mod transport;
pub mod wire;

pub use backend::{RustBackend, TrainBackend};
pub use engine::RoundEngine;
pub use server::{run, RunOutput};
pub use sim::{run_async, ClientRegistry, Dist, SimStats};
pub use transport::{
    DeltaDownlink, DownCodec, DownlinkCompressor, DownlinkPayload, FeedbackUplink,
    FoldingDownlink, PayloadKind, RoundBroadcast, StatelessDownlink, StatelessUplink, Transport,
    UplinkCompressor,
};
pub use wire::{CodecSpec, EncodedUpdate};
