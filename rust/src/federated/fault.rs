//! Deterministic fault injection — the adversarial half of the
//! fault-tolerance layer.
//!
//! Real federated fleets lose, truncate, and bit-flip payloads, and
//! occasionally ship garbage updates. This module injects exactly those
//! failures from the run's *seeded* RNG, so a faulty run is as bitwise
//! reproducible as a clean one: every fate is a pure function of
//! `(seed, round, client, sub-model)`, drawn with the same
//! [`derive_seed`] discipline as the async simulator's dropout stream
//! (tagged streams, one fate per item, no draws when injection is off).
//!
//! Two fate streams exist per run:
//!
//! - **Payload fates** ([`payload_fate`], tag [`PAYLOAD_TAG`]): per
//!   `(round, client, sub-model)` — one uniform draw chooses corrupt /
//!   truncate / NaN-poison / clean from cumulative probability
//!   intervals. Corrupt and truncate mutate the *framed* wire bytes
//!   (see `wire::EncodedUpdate::to_framed_bytes`), so the server's
//!   checksummed decode rejects them and discards the update; NaN
//!   poisons the decoded sub-model, which `--robust-agg` screens.
//! - **Transient-failure fates** ([`fail_fate`], tag [`FAIL_TAG`]):
//!   per `(round, client)` (per dispatch in the async sim) — the client
//!   trained but its upload never completes. The synchronous loop drops
//!   the contribution; the async simulator retries with exponential
//!   backoff on the simulated clock ([`retry_plan`]) before giving up.
//!
//! Every observed fault increments `fedmlh_faults_total{kind}` in the
//! process-global metrics registry via [`record`].

use crate::config::InjectConfig;
use crate::model::params::ModelParams;
use crate::util::rng::{derive_seed, Rng};

/// Seed-stream tag for per-(round, client) transient-failure fates.
pub const FAIL_TAG: u64 = 0xfa11_0000_0000_0000;
/// Seed-stream tag for per-(round, client, sub-model) payload fates.
pub const PAYLOAD_TAG: u64 = 0xfa17_0000_0000_0000;

/// Upload attempts the async simulator makes per dispatch (1 initial +
/// `MAX_RETRIES` retries) before declaring the update lost.
pub const MAX_RETRIES: u32 = 3;

/// What went wrong with one client contribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// A bit flipped in the payload (caught by the frame checksum).
    Corrupt,
    /// The payload arrived cut short.
    Truncate,
    /// The update's values are NaN-poisoned.
    Nan,
    /// The upload never completed (transient client failure).
    Fail,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Corrupt => "corrupt",
            FaultKind::Truncate => "truncate",
            FaultKind::Nan => "nan",
            FaultKind::Fail => "fail",
        }
    }
}

/// Count one observed fault in `fedmlh_faults_total{kind}`.
pub fn record(kind: FaultKind) {
    record_kind(kind.name());
}

/// Count a fault by raw kind label — for faults the injector did not
/// cause (a genuinely undecodable payload in production is
/// `kind="decode"`).
pub fn record_kind(kind: &'static str) {
    crate::obs::metrics::global()
        .counter_with(
            "fedmlh_faults_total",
            "Faulty client contributions observed, by kind.",
            &[("kind", kind)],
        )
        .inc();
}

/// The per-item RNG stream id the payload fate is drawn from — the
/// round engine's `(round, client, sub-model)` stream arithmetic, kept
/// in one place so sync and async runs inject identically-shaped
/// streams.
pub fn item_stream(round: u64, population: u64, client: u64, n_models: u64, j: u64) -> u64 {
    round
        .wrapping_mul(population)
        .wrapping_add(client)
        .wrapping_mul(n_models)
        .wrapping_add(j)
}

/// Draw the payload fate for one `(round, client, sub-model)` item.
/// Returns the fate plus the RNG cursor positioned to draw that fault's
/// details (corruption site, truncation point) — using the same stream
/// keeps the whole fault a function of the item key.
///
/// One uniform sample chooses among cumulative `[corrupt, truncate,
/// nan]` intervals in that fixed order; [`InjectConfig::validate`]
/// guarantees they fit in `[0, 1]` together.
pub fn payload_fate(inject: &InjectConfig, seed: u64, stream: u64) -> (Option<FaultKind>, Rng) {
    let mut rng = Rng::new(derive_seed(seed, PAYLOAD_TAG ^ stream));
    if inject.corrupt <= 0.0 && inject.truncate <= 0.0 && inject.nan <= 0.0 {
        return (None, rng);
    }
    let u = rng.next_f64();
    let kind = if u < inject.corrupt {
        Some(FaultKind::Corrupt)
    } else if u < inject.corrupt + inject.truncate {
        Some(FaultKind::Truncate)
    } else if u < inject.corrupt + inject.truncate + inject.nan {
        Some(FaultKind::Nan)
    } else {
        None
    };
    (kind, rng)
}

/// Draw the transient-failure fate for one `(round, client)` pair
/// (sync) or dispatch (async). `true` = the first upload attempt fails.
pub fn fail_fate(inject: &InjectConfig, seed: u64, stream: u64) -> bool {
    if inject.fail <= 0.0 {
        return false;
    }
    let mut rng = Rng::new(derive_seed(seed, FAIL_TAG ^ stream));
    rng.bernoulli(inject.fail)
}

/// The async simulator's bounded retry-with-backoff plan for a
/// dispatch whose first upload attempt failed ([`fail_fate`] fired).
/// Returns `(extra_attempts, lost)`: how many *additional* upload
/// attempts were made (each costing `t_up` plus exponential backoff on
/// the simulated clock — see [`backoff_seconds`]) and whether the
/// update was ultimately lost after [`MAX_RETRIES`] retries.
///
/// Retry fates continue the same tagged stream the first-attempt fate
/// came from, so the whole plan is a function of `(seed, stream)`.
pub fn retry_plan(inject: &InjectConfig, seed: u64, stream: u64) -> (u32, bool) {
    let mut rng = Rng::new(derive_seed(seed, FAIL_TAG ^ stream));
    if !rng.bernoulli(inject.fail) {
        return (0, false);
    }
    for attempt in 1..=MAX_RETRIES {
        if !rng.bernoulli(inject.fail) {
            return (attempt, false);
        }
    }
    (MAX_RETRIES, true)
}

/// Simulated-clock seconds a client waits before retry `attempt`
/// (1-based): 1s, 2s, 4s, … doubling per attempt.
pub fn backoff_seconds(attempt: u32) -> f64 {
    f64::from(1u32 << (attempt - 1).min(16))
}

/// Flip one random bit of the payload in place. FNV-1a's per-byte step
/// is bijective, so any single-bit change is guaranteed to fail the
/// frame checksum.
pub fn corrupt_bytes(bytes: &mut [u8], rng: &mut Rng) {
    if bytes.is_empty() {
        return;
    }
    let pos = rng.below(bytes.len());
    let bit = rng.below(8) as u8;
    bytes[pos] ^= 1 << bit;
}

/// Cut the payload short at a random point strictly before its end.
pub fn truncate_bytes(bytes: &mut Vec<u8>, rng: &mut Rng) {
    if bytes.is_empty() {
        return;
    }
    let keep = rng.below(bytes.len());
    bytes.truncate(keep);
}

/// Overwrite every value of a decoded update with NaN — the worst-case
/// divergent client, exactly what `--robust-agg` must screen.
pub fn poison_nan(params: &mut ModelParams) {
    for t in params.tensors.iter_mut() {
        for v in t.data_mut() {
            *v = f32::NAN;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inject(corrupt: f64, truncate: f64, nan: f64, fail: f64) -> InjectConfig {
        InjectConfig {
            corrupt,
            truncate,
            nan,
            fail,
        }
    }

    #[test]
    fn fates_are_deterministic_per_item() {
        let cfg = inject(0.2, 0.2, 0.2, 0.3);
        for stream in 0..50u64 {
            let (a, _) = payload_fate(&cfg, 42, stream);
            let (b, _) = payload_fate(&cfg, 42, stream);
            assert_eq!(a, b, "stream {stream}");
            assert_eq!(fail_fate(&cfg, 42, stream), fail_fate(&cfg, 42, stream));
            assert_eq!(retry_plan(&cfg, 42, stream), retry_plan(&cfg, 42, stream));
        }
    }

    #[test]
    fn zero_rates_never_fault() {
        let cfg = InjectConfig::default();
        for stream in 0..100u64 {
            assert_eq!(payload_fate(&cfg, 7, stream).0, None);
            assert!(!fail_fate(&cfg, 7, stream));
            assert_eq!(retry_plan(&cfg, 7, stream), (0, false));
        }
    }

    #[test]
    fn fate_frequencies_track_rates() {
        let cfg = inject(0.1, 0.05, 0.05, 0.0);
        let n = 20_000u64;
        let mut counts = [0usize; 4];
        for stream in 0..n {
            match payload_fate(&cfg, 99, stream).0 {
                Some(FaultKind::Corrupt) => counts[0] += 1,
                Some(FaultKind::Truncate) => counts[1] += 1,
                Some(FaultKind::Nan) => counts[2] += 1,
                Some(FaultKind::Fail) => unreachable!("payload fates never yield Fail"),
                None => counts[3] += 1,
            }
        }
        let frac = |c: usize| c as f64 / n as f64;
        assert!((frac(counts[0]) - 0.1).abs() < 0.02, "corrupt {}", frac(counts[0]));
        assert!((frac(counts[1]) - 0.05).abs() < 0.02, "truncate {}", frac(counts[1]));
        assert!((frac(counts[2]) - 0.05).abs() < 0.02, "nan {}", frac(counts[2]));
        assert!((frac(counts[3]) - 0.8).abs() < 0.03, "clean {}", frac(counts[3]));
    }

    #[test]
    fn fail_and_payload_streams_are_independent() {
        // The same stream id under the two tags must not be correlated:
        // a client can fail its upload whether or not its payload would
        // have been corrupted.
        let cfg = inject(0.5, 0.0, 0.0, 0.5);
        let mut agree = 0usize;
        let n = 2_000u64;
        for stream in 0..n {
            let faulted = payload_fate(&cfg, 5, stream).0.is_some();
            if faulted == fail_fate(&cfg, 5, stream) {
                agree += 1;
            }
        }
        let rate = agree as f64 / n as f64;
        assert!((rate - 0.5).abs() < 0.05, "correlated streams: {rate}");
    }

    #[test]
    fn retry_plan_bounds_attempts() {
        let cfg = inject(0.0, 0.0, 0.0, 0.95);
        let mut lost_any = false;
        let mut recovered_any = false;
        for stream in 0..500u64 {
            let (extra, lost) = retry_plan(&cfg, 3, stream);
            assert!(extra <= MAX_RETRIES);
            if lost {
                assert_eq!(extra, MAX_RETRIES, "a lost update used every retry");
                lost_any = true;
            } else {
                recovered_any = true;
            }
        }
        assert!(lost_any, "95% fail rate must lose some updates");
        assert!(recovered_any, "…but not all of them");
        assert_eq!(backoff_seconds(1), 1.0);
        assert_eq!(backoff_seconds(2), 2.0);
        assert_eq!(backoff_seconds(3), 4.0);
    }

    #[test]
    fn corruption_helpers_mutate_deterministically() {
        let mut rng = Rng::new(11);
        let orig: Vec<u8> = (0..64u8).collect();
        let mut a = orig.clone();
        corrupt_bytes(&mut a, &mut rng);
        assert_eq!(a.len(), orig.len());
        let flipped: Vec<usize> = (0..orig.len()).filter(|&i| a[i] != orig[i]).collect();
        assert_eq!(flipped.len(), 1, "exactly one byte changes");
        assert_eq!(
            (a[flipped[0]] ^ orig[flipped[0]]).count_ones(),
            1,
            "exactly one bit flips"
        );
        let mut b = orig.clone();
        truncate_bytes(&mut b, &mut Rng::new(12));
        assert!(b.len() < orig.len());
        assert_eq!(&orig[..b.len()], &b[..], "truncation keeps a prefix");
        // Same seed → same mutation.
        let mut a2 = orig.clone();
        corrupt_bytes(&mut a2, &mut Rng::new(11));
        let mut a3 = orig.clone();
        corrupt_bytes(&mut a3, &mut Rng::new(11));
        assert_eq!(a2, a3);
    }

    #[test]
    fn poison_nan_poisons_every_value() {
        let mut p = ModelParams::zeros(3, 2, 4);
        poison_nan(&mut p);
        for t in &p.tensors {
            assert!(t.data().iter().all(|v| v.is_nan()));
        }
    }

    #[test]
    fn item_stream_matches_engine_arithmetic() {
        // Pin the stream id layout the engine seeds batches with — the
        // fault streams tag the same ids, so a layout change here is a
        // determinism break.
        assert_eq!(item_stream(0, 10, 3, 2, 1), 7);
        assert_eq!(item_stream(2, 10, 3, 2, 0), 46);
    }
}
